"""Layer-1 Pallas tiled matmul kernel — the paper's OpenCL kernel, rethought for TPU.

Paper (§4.3) optimizations and their TPU/Pallas analogues:

* TILED multiplication with 16 KB local memory (tiles 4x4 .. 16x16)
    -> ``BlockSpec`` tiling: operand blocks ``(bm, bk)`` and ``(bk, bn)`` are
       DMA'd HBM->VMEM per grid step; VMEM is the software-managed scratchpad.
* Work-group shaping (32x32 work items, ROW/4 x COL/4 global)
    -> the 3-D Pallas ``grid`` ``(n/bm, n/bn, n/bk)``; each grid step plays
       the role of one work-group invocation over a tile.
* Coalesced global reads/writes (row-major)
    -> row-major index maps ``(i, k)`` / ``(k, j)`` keep every HBM->VMEM DMA
       a contiguous row-major slab.
* float4 vector registers / SIMD
    -> whole-block ``jnp.dot`` feeds the MXU systolic array (the TPU
       equivalent of getting off scalar FMAs); elementwise tails use the
       8x128 VPU lanes automatically.
* Loop unrolling x4/x8/x16
    -> the reduction dimension advances ``bk`` elements per grid step; the
       compiler unrolls inside the block. ``bk`` is the unroll factor.
* Barriers within a work-group
    -> grid-step semantics: the ``@pl.when`` guarded zero-init plus ``+=``
       accumulation into the output block is the Pallas idiom replacing the
       explicit ``barrier(CLK_LOCAL_MEM_FENCE)`` pairs of the OpenCL kernel.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO that any backend runs.
Real-TPU efficiency is estimated from the VMEM footprint (see
``vmem_footprint_bytes``) and recorded in EXPERIMENTS.md, not measured here.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM per TPU core (v4/v5 ballpark) used for footprint sanity checks.
VMEM_BYTES = 16 * 1024 * 1024

# Tile catalogue mirroring the paper's §4.3.7 sweep (4x4 .. 16x16), scaled to
# TPU-reasonable block edges. Keys are the ablation names used by aot.py.
TILE_CATALOGUE: dict[str, Tuple[int, int, int]] = {
    "t16": (16, 16, 16),
    "t32": (32, 32, 32),
    "t64": (64, 64, 64),
    "t128": (128, 128, 128),
    # rectangular tiles, analogous to the paper's 4x8 / 8x16 / 16x8 variants
    "t64x128": (64, 128, 64),
    "t128x64": (128, 64, 128),
}


def default_blocks(n: int) -> Tuple[int, int, int]:
    """Pick the default (bm, bn, bk) for an ``n x n`` problem.

    Mirrors the paper's finding that the largest tile fitting local memory
    (16x16 on the C2050) wins: we take the largest square block edge that
    divides ``n``, capped at 128 (one MXU-friendly slab), floor 8.
    """
    for edge in (128, 64, 32, 16, 8):
        if n % edge == 0:
            return (edge, edge, edge)
    if n < 8:
        return (n, n, n)
    raise ValueError(f"matrix size {n} not divisible by any supported block edge")


def vmem_footprint_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Working-set bytes per grid step: one x-block, one y-block, one o-block.

    The double-buffered DMA pipeline needs ~2x this to overlap; both numbers
    are reported by the A1 ablation and must stay under ``VMEM_BYTES``.
    """
    return (bm * bk + bk * bn + bm * bn) * itemsize


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of each 128x128x128 MXU pass doing useful work.

    The MXU is a 128x128 systolic array; blocks smaller than 128 on any edge
    leave lanes idle in that dimension. This is the structural estimate used
    for the §Perf roofline discussion (interpret-mode wall-clock is not a
    TPU proxy).
    """
    return min(bm, 128) / 128.0 * min(bn, 128) / 128.0 * min(bk, 128) / 128.0


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One grid step: accumulate x_block @ y_block into the output block."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.named_call, name="pallas_tiled_matmul")
def _named_identity(x):  # pragma: no cover - trivial
    return x


def tiled_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    blocks: Tuple[int, int, int] | None = None,
) -> jax.Array:
    """``x @ y`` via the tiled Pallas kernel.

    Args:
      x, y: square ``(n, n)`` operands of the same dtype.
      blocks: ``(bm, bn, bk)`` block shape; defaults to :func:`default_blocks`.
    """
    n, n2 = x.shape
    if x.shape != y.shape or n != n2:
        raise ValueError(f"tiled_matmul needs equal square operands, got {x.shape} @ {y.shape}")
    bm, bn, bk = blocks or default_blocks(n)
    for name, b in (("bm", bm), ("bn", bn), ("bk", bk)):
        if n % b != 0:
            raise ValueError(f"{name}={b} does not divide n={n}")
    itemsize = jnp.dtype(x.dtype).itemsize
    if vmem_footprint_bytes(bm, bn, bk, itemsize) > VMEM_BYTES:
        raise ValueError(f"blocks ({bm},{bn},{bk}) overflow VMEM")

    grid = (n // bm, n // bn, n // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        interpret=True,
    )(x, y)


def tiled_square(x: jax.Array, *, blocks: Tuple[int, int, int] | None = None) -> jax.Array:
    """``x @ x`` through the same kernel (one squaring step of the plan)."""
    return tiled_matmul(x, x, blocks=blocks)
