"""Pure-jnp correctness oracles for the Pallas kernel and the L2 graphs.

These are the build-time analogue of the paper's §6 precision methodology:
"All the results are strictly compared with the sequential code results for
any precision problems."  Every artifact we ship is pytest-checked against
these references before the rust side ever sees it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain dense matmul — the oracle for the tiled kernel."""
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def expm_naive_ref(x: jax.Array, power: int) -> jax.Array:
    """A^power by ``power - 1`` successive multiplies (paper SS4.1/SS4.2).

    This is the semantics both baselines implement: the naive CPU loop and
    the naive GPU method that launches the kernel ``power`` times.
    """
    if power < 1:
        raise ValueError("power must be >= 1")
    acc = x
    for _ in range(power - 1):
        acc = matmul_ref(acc, x)
    return acc


def expm_binary_ref(x: jax.Array, power: int) -> jax.Array:
    """A^power by square-and-multiply (paper SS4.3, 'Our Approach')."""
    if power < 1:
        raise ValueError("power must be >= 1")
    acc = None
    base = x
    p = power
    while p > 0:
        if p & 1:
            acc = base if acc is None else matmul_ref(acc, base)
        p >>= 1
        if p > 0:
            base = matmul_ref(base, base)
    return acc


def expm_numpy_f64(x: np.ndarray, power: int) -> np.ndarray:
    """float64 numpy exponentiation — the high-precision yardstick (A4)."""
    return np.linalg.matrix_power(x.astype(np.float64), power)


def spectral_scale(x: np.ndarray, target: float = 1.0) -> np.ndarray:
    """Rescale so the spectral radius is ``target``.

    Raising a random matrix to power 512 overflows f32 unless the spectrum
    is tamed; the paper is silent on this, so all experiment workloads use
    spectrally-normalized inputs (documented in DESIGN.md SS8).
    """
    eigs = np.linalg.eigvals(x.astype(np.float64))
    radius = float(np.max(np.abs(eigs)))
    if radius == 0.0:
        return x
    return (x * (target / radius)).astype(x.dtype)
