"""AOT pipeline: lower every Layer-2 graph to HLO text + write manifest.json.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); the rust binary is then fully
self-contained. Usage:

    cd python && python -m compile.aot --out ../artifacts [--only PREFIX]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time
from dataclasses import dataclass, field, asdict
from pathlib import Path
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import matmul as kmm

MANIFEST_VERSION = 2

DTYPES = {"f32": jnp.float32, "f64": jnp.float64}

#: Matrix sizes shipped by default. 4..32 exist so rust unit/integration
#: tests stay fast; 64..512 are the paper's evaluation sizes (Tables 2-5).
CORE_SIZES = [4, 8, 16, 32, 64, 128, 256, 512]

#: Core ops per size (both variants, f32). The step_*/pack2/unpack0 ops
#: implement the device-resident packed-state binary exponentiation loop.
CORE_OPS = [
    "matmul", "square", "sqmul", "square2", "square4",
    "pack2", "step_mul", "step_sq", "unpack0",
]

#: (size, [powers]) combos of Tables 2-5 — fused whole-exponentiation
#: executables (ablation A3 limiting case; xla variant only to keep the
#: artifact set lean).
EXPM_TABLE = [
    (64, [64, 128, 256, 512, 1024]),
    (128, [64, 128, 256, 512]),
    (256, [64, 128, 256, 512]),
    (512, [64, 128, 256]),
]

#: Tile-sweep artifacts for ablation A1 (paper §4.3.7).
TILE_SIZES = [128, 256, 512]


@dataclass
class Entry:
    name: str
    op: str
    n: int
    dtype: str
    variant: str
    num_inputs: int
    num_outputs: int
    file: str
    blocks: Optional[List[int]] = None
    tile: Optional[str] = None
    vmem_bytes: Optional[int] = None
    mxu_utilization: Optional[float] = None
    sha256: str = ""
    hlo_chars: int = 0


def to_hlo_text(lowered) -> str:
    # return_tuple=False: single-output computations keep a bare array root,
    # so PJRT hands back an array buffer that feeds straight into the next
    # execute_b call (device-resident chaining). Multi-output ops (sqmul)
    # still get a tuple root — PJRT returns ONE tuple buffer for those,
    # which is exactly why the packed-state step_* ops exist (see model.py).
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def catalogue() -> List[dict]:
    """The full artifact build list as kwargs dicts."""
    jobs: List[dict] = []
    for n in CORE_SIZES:
        for op in CORE_OPS:
            for variant in ("xla", "pallas"):
                jobs.append(dict(op=op, n=n, dtype="f32", variant=variant))
    # f64 precision artifacts (A4)
    for n in (4, 64):
        for op in ("matmul", "square"):
            jobs.append(dict(op=op, n=n, dtype="f64", variant="xla"))
    # fused whole-exponentiation graphs
    for n, powers in EXPM_TABLE:
        for p in powers:
            jobs.append(dict(op=f"expm{p}", n=n, dtype="f32", variant="xla"))
    # tile-sweep (A1)
    for n in TILE_SIZES:
        for tile, blocks in kmm.TILE_CATALOGUE.items():
            bm, bn, bk = blocks
            if n % bm or n % bn or n % bk:
                continue
            jobs.append(
                dict(op="matmul", n=n, dtype="f32", variant="pallas",
                     blocks=list(blocks), tile=tile)
            )
    return jobs


def entry_name(op: str, n: int, dtype: str, variant: str, tile: Optional[str] = None) -> str:
    base = f"{op}_n{n}_{dtype}_{variant}"
    return f"{base}_{tile}" if tile else base


def lower_one(job: dict, out_dir: Path) -> Entry:
    op, n, dtype, variant = job["op"], job["n"], job["dtype"], job["variant"]
    blocks = tuple(job["blocks"]) if job.get("blocks") else None
    tile = job.get("tile")
    fn, specs = model.build_op(op, n, DTYPES[dtype], variant, blocks)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    name = entry_name(op, n, dtype, variant, tile)
    fname = f"{name}.hlo.txt"
    (out_dir / fname).write_text(text)
    n_out = 2 if op == "sqmul" else 1
    eff_blocks = blocks or (kmm.default_blocks(n) if variant == "pallas" else None)
    itemsize = jnp.dtype(DTYPES[dtype]).itemsize
    return Entry(
        name=name, op=op, n=n, dtype=dtype, variant=variant,
        num_inputs=len(specs), num_outputs=n_out, file=fname,
        blocks=list(eff_blocks) if eff_blocks else None, tile=tile,
        vmem_bytes=kmm.vmem_footprint_bytes(*eff_blocks, itemsize) if eff_blocks else None,
        mxu_utilization=round(kmm.mxu_utilization_estimate(*eff_blocks), 4) if eff_blocks else None,
        sha256=hashlib.sha256(text.encode()).hexdigest()[:16],
        hlo_chars=len(text),
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="only build entries whose name starts with PREFIX")
    ap.add_argument("--list", action="store_true", help="print the catalogue and exit")
    args = ap.parse_args(argv)

    jobs = catalogue()
    if args.only:
        jobs = [j for j in jobs
                if entry_name(j["op"], j["n"], j["dtype"], j["variant"], j.get("tile"))
                .startswith(args.only)]
    if args.list:
        for j in jobs:
            print(entry_name(j["op"], j["n"], j["dtype"], j["variant"], j.get("tile")))
        return 0

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    entries: List[Entry] = []
    t_start = time.time()
    for i, job in enumerate(jobs):
        t0 = time.time()
        entry = lower_one(job, out_dir)
        entries.append(entry)
        print(f"[{i + 1:3d}/{len(jobs)}] {entry.name:40s} "
              f"{entry.hlo_chars:8d} chars  {time.time() - t0:5.2f}s", flush=True)

    manifest = {
        "version": MANIFEST_VERSION,
        "generated_by": "compile.aot",
        "jax_version": jax.__version__,
        "entries": [asdict(e) for e in entries],
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(entries)} artifacts + manifest.json in {time.time() - t_start:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
