"""Layer-2 JAX compute graphs, lowered once by aot.py and run from rust.

Each builder returns ``(fn, input_specs)`` where ``fn`` maps its inputs to a
*tuple* of outputs (the rust loader unwraps the tuple — see
/opt/xla-example/load_hlo). Two kernel variants exist for every op:

* ``pallas`` — calls the Layer-1 tiled kernel (kernels/matmul.py), i.e. the
  paper's optimized OpenCL kernel. interpret=True, so it lowers to plain HLO.
* ``xla``    — plain ``jnp.dot``; the fast path on this CPU testbed and the
  oracle the pallas variant must match bit-for-bit in pytest.

Ops (shapes all ``(n, n)``, one dtype per artifact):

* ``matmul``  (x, y) -> (x @ y,)                 — one kernel launch
* ``square``  (x,)   -> (x @ x,)                 — one squaring step
* ``sqmul``   (a, b) -> (a @ b, b @ b)           — fused square-and-multiply
                                                   step as a 2-tuple output;
                                                   PJRT returns ONE tuple
                                                   buffer, forcing a host
                                                   round-trip to split — kept
                                                   as the ablation-A2 "bad"
                                                   arm
* ``pack2``   (x,)   -> ([x, x],)                — packed state init:
                                                   acc = base = x, shape (2,n,n)
* ``step_mul`` (s,)  -> ([acc@b2, b2],)          — fused set-bit step over
                                                   packed state, b2 = base@base:
                                                   the base advances to the next
                                                   bit weight, then folds into
                                                   acc. ONE single-output
                                                   launch, so the whole chain
                                                   stays device-resident
* ``step_sq``  (s,)  -> ([acc, base@base],)      — fused clear-bit step
* ``unpack0`` (s,)   -> (acc,)                   — extract the result
* ``square2`` (x,)   -> (x^4,)                   — 2 squarings fused
* ``square4`` (x,)   -> (x^16,)                  — 4 squarings fused
* ``expm<N>`` (x,)   -> (x^N,)                   — whole exponentiation in a
                                                   single graph (paper §4.3.8
                                                   taken to its limit: ONE
                                                   offload per request)
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import matmul as kmm
from compile.kernels import ref as kref

Variant = str  # "pallas" | "xla"


def _mm(variant: Variant, blocks: Tuple[int, int, int] | None = None):
    """Pick the multiply primitive for a variant."""
    if variant == "pallas":
        return lambda x, y: kmm.tiled_matmul(x, y, blocks=blocks)
    if variant == "xla":
        return kref.matmul_ref
    raise ValueError(f"unknown variant {variant!r}")


def spec(n: int, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((n, n), dtype)


def build_matmul(n, dtype, variant, blocks=None):
    mm = _mm(variant, blocks)

    def fn(x, y):
        return (mm(x, y),)

    return fn, [spec(n, dtype), spec(n, dtype)]


def build_square(n, dtype, variant, blocks=None):
    mm = _mm(variant, blocks)

    def fn(x):
        return (mm(x, x),)

    return fn, [spec(n, dtype)]


def build_sqmul(n, dtype, variant, blocks=None):
    """Fused binary-exponentiation step: (acc, base) -> (acc*base, base^2)."""
    mm = _mm(variant, blocks)

    def fn(acc, base):
        return (mm(acc, base), mm(base, base))

    return fn, [spec(n, dtype), spec(n, dtype)]


def build_square_chain(n, dtype, variant, chain_len, blocks=None):
    """``chain_len`` squarings fused into one executable: x -> x^(2^chain_len)."""
    mm = _mm(variant, blocks)

    def fn(x):
        for _ in range(chain_len):
            x = mm(x, x)
        return (x,)

    return fn, [spec(n, dtype)]


def build_expm_fixed(n, dtype, variant, power, blocks=None):
    """Whole A^power as one graph via an unrolled square-and-multiply chain.

    The launch schedule is identical to what the rust planner emits for
    ``power``; here it is baked into a single HLO so the host offloads the
    matrix exactly once (the limiting case of the paper's §4.3.8).
    """
    if power < 1:
        raise ValueError("power must be >= 1")
    mm = _mm(variant, blocks)

    def fn(x):
        acc = None
        base = x
        p = power
        while p > 0:
            if p & 1:
                acc = base if acc is None else mm(acc, base)
            p >>= 1
            if p > 0:
                base = mm(base, base)
        return (acc,)

    return fn, [spec(n, dtype)]


def build_pack2(n, dtype, variant, blocks=None):
    """Packed-state init: x -> stack([x, x]) (acc = base = x)."""

    def fn(x):
        return (jnp.stack([x, x]),)

    return fn, [spec(n, dtype)]


def build_step_mul(n, dtype, variant, blocks=None):
    """Set-bit step: base advances one weight, then folds into acc.

    LSB-first square-and-multiply consumes one exponent bit per step; both
    multiplies happen in one launch and the state never leaves the device.
    """
    mm = _mm(variant, blocks)

    def fn(s):
        acc, base = s[0], s[1]
        new_base = mm(base, base)
        new_acc = mm(acc, new_base)
        return (jnp.stack([new_acc, new_base]),)

    return fn, [jax.ShapeDtypeStruct((2, n, n), dtype)]


def build_step_sq(n, dtype, variant, blocks=None):
    """Clear-bit step: only the base advances."""
    mm = _mm(variant, blocks)

    def fn(s):
        acc, base = s[0], s[1]
        return (jnp.stack([acc, mm(base, base)]),)

    return fn, [jax.ShapeDtypeStruct((2, n, n), dtype)]


def build_unpack0(n, dtype, variant, blocks=None):
    """Extract the accumulator from packed state."""

    def fn(s):
        return (s[0],)

    return fn, [jax.ShapeDtypeStruct((2, n, n), dtype)]


#: op-name -> builder
OP_BUILDERS: dict[str, Callable] = {
    "matmul": build_matmul,
    "square": build_square,
    "sqmul": build_sqmul,
    "pack2": build_pack2,
    "step_mul": build_step_mul,
    "step_sq": build_step_sq,
    "unpack0": build_unpack0,
}


def build_op(op: str, n: int, dtype, variant: Variant, blocks=None):
    """Dispatch by op name, including parametric ``square{k}`` / ``expm{N}``."""
    if op in OP_BUILDERS:
        return OP_BUILDERS[op](n, dtype, variant, blocks)
    if op.startswith("square") and op[6:].isdigit():
        return build_square_chain(n, dtype, variant, int(op[6:]), blocks)
    if op.startswith("expm") and op[4:].isdigit():
        return build_expm_fixed(n, dtype, variant, int(op[4:]), blocks)
    raise ValueError(f"unknown op {op!r}")
