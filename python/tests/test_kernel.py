"""L1 correctness: the Pallas tiled matmul kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path — it is what makes
the paper's "strictly compared with the sequential code results" claim hold
for every artifact we ship.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as kmm
from compile.kernels import ref as kref


def rand(n, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (n, n), dtype)


TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64, 128])
def test_matmul_matches_ref_default_blocks(n):
    x, y = rand(n, seed=1), rand(n, seed=2)
    got = kmm.tiled_matmul(x, y)
    want = kref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("blocks", [(16, 16, 16), (32, 32, 32), (64, 64, 64),
                                    (32, 64, 32), (64, 32, 64), (16, 64, 32)])
def test_matmul_matches_ref_block_sweep(blocks):
    n = 64
    x, y = rand(n, seed=3), rand(n, seed=4)
    got = kmm.tiled_matmul(x, y, blocks=blocks)
    np.testing.assert_allclose(got, kref.matmul_ref(x, y), **TOL)


@pytest.mark.parametrize("tile,blocks", sorted(kmm.TILE_CATALOGUE.items()))
def test_tile_catalogue_all_correct_on_256(tile, blocks):
    n = 256
    if any(n % b for b in blocks):
        pytest.skip("tile does not divide 256")
    x, y = rand(n, seed=5), rand(n, seed=6)
    got = kmm.tiled_matmul(x, y, blocks=blocks)
    # smaller bk => more accumulation rounds in a different order than the
    # single-pass oracle; 1e-4 abs is the f32 reassociation noise floor here.
    np.testing.assert_allclose(got, kref.matmul_ref(x, y), rtol=1e-3, atol=1e-4)


def test_square_is_matmul_with_itself():
    x = rand(32, seed=7)
    np.testing.assert_allclose(kmm.tiled_square(x), kref.matmul_ref(x, x), **TOL)


def test_f64_kernel():
    n = 64
    x = rand(n, jnp.float64, seed=8)
    y = rand(n, jnp.float64, seed=9)
    got = kmm.tiled_matmul(x, y)
    np.testing.assert_allclose(got, kref.matmul_ref(x, y), rtol=1e-12, atol=1e-12)


def test_identity_and_zero():
    n = 32
    eye = jnp.eye(n, dtype=jnp.float32)
    x = rand(n, seed=10)
    np.testing.assert_allclose(kmm.tiled_matmul(x, eye), x, **TOL)
    np.testing.assert_allclose(kmm.tiled_matmul(eye, x), x, **TOL)
    zero = jnp.zeros((n, n), jnp.float32)
    np.testing.assert_allclose(kmm.tiled_matmul(x, zero), zero, **TOL)


def test_rejects_non_square():
    x = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(ValueError):
        kmm.tiled_matmul(x, x)


def test_rejects_mismatched_shapes():
    with pytest.raises(ValueError):
        kmm.tiled_matmul(jnp.zeros((4, 4)), jnp.zeros((8, 8)))


def test_rejects_non_dividing_blocks():
    x = rand(64)
    with pytest.raises(ValueError):
        kmm.tiled_matmul(x, x, blocks=(48, 16, 16))


def test_rejects_vmem_overflow():
    # 4096-edge blocks: 3 * 4096^2 * 4B = 192 MiB >> 16 MiB VMEM
    with pytest.raises(ValueError):
        kmm.tiled_matmul(jnp.zeros((4096, 4096)), jnp.zeros((4096, 4096)),
                         blocks=(4096, 4096, 4096))


def test_default_blocks_divide():
    for n in [4, 8, 16, 24, 32, 40, 64, 96, 128, 256, 512]:
        bm, bn, bk = kmm.default_blocks(n)
        assert n % bm == 0 and n % bn == 0 and n % bk == 0


def test_default_blocks_prefer_large():
    assert kmm.default_blocks(512) == (128, 128, 128)
    assert kmm.default_blocks(64) == (64, 64, 64)
    assert kmm.default_blocks(4) == (4, 4, 4)


def test_vmem_footprint_formula():
    assert kmm.vmem_footprint_bytes(16, 16, 16) == 3 * 16 * 16 * 4
    assert kmm.vmem_footprint_bytes(64, 128, 32, itemsize=8) == (64 * 32 + 32 * 128 + 64 * 128) * 8


def test_mxu_utilization_monotone():
    u = [kmm.mxu_utilization_estimate(b, b, b) for b in (16, 32, 64, 128, 256)]
    assert all(a <= b for a, b in zip(u, u[1:]))
    assert kmm.mxu_utilization_estimate(128, 128, 128) == 1.0


@settings(max_examples=20, deadline=None)
@given(
    n_pow=st.integers(min_value=2, max_value=6),       # n in {4..64}
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dtype=st.sampled_from(["float32", "float64"]),
)
def test_hypothesis_shape_dtype_sweep(n_pow, seed, dtype):
    """Hypothesis sweep of the kernel's (shape, dtype) space vs ref."""
    n = 2 ** n_pow
    dt = jnp.dtype(dtype)
    x = rand(n, dt, seed=seed)
    y = rand(n, dt, seed=seed + 1)
    got = kmm.tiled_matmul(x, y)
    tol = 1e-4 if dtype == "float32" else 1e-10
    np.testing.assert_allclose(got, kref.matmul_ref(x, y), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_hypothesis_block_sweep(bm, bn, bk, seed):
    """Any (bm, bn, bk) dividing n must give identical numerics."""
    n = 32
    x, y = rand(n, seed=seed), rand(n, seed=seed + 7)
    got = kmm.tiled_matmul(x, y, blocks=(bm, bn, bk))
    np.testing.assert_allclose(got, kref.matmul_ref(x, y), **TOL)
