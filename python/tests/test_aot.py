"""AOT pipeline tests: catalogue, lowering, manifest integrity."""

import json
from pathlib import Path

import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import matmul as kmm


def test_catalogue_covers_paper_tables():
    jobs = aot.catalogue()
    names = {aot.entry_name(j["op"], j["n"], j["dtype"], j["variant"], j.get("tile"))
             for j in jobs}
    # every table size needs matmul/square/sqmul in both variants
    for n in (64, 128, 256, 512):
        for op in ("matmul", "square", "sqmul"):
            assert f"{op}_n{n}_f32_xla" in names
            assert f"{op}_n{n}_f32_pallas" in names
    # fused expm graphs for the exact table powers
    for n, powers in aot.EXPM_TABLE:
        for p in powers:
            assert f"expm{p}_n{n}_f32_xla" in names


def test_catalogue_no_duplicate_names():
    jobs = aot.catalogue()
    names = [aot.entry_name(j["op"], j["n"], j["dtype"], j["variant"], j.get("tile"))
             for j in jobs]
    assert len(names) == len(set(names))


def test_tile_jobs_divide():
    for j in aot.catalogue():
        if j.get("blocks"):
            assert all(j["n"] % b == 0 for b in j["blocks"])


def test_lower_one_writes_valid_entry(tmp_path):
    entry = aot.lower_one(dict(op="matmul", n=8, dtype="f32", variant="xla"), tmp_path)
    assert entry.num_inputs == 2 and entry.num_outputs == 1
    text = (tmp_path / entry.file).read_text()
    assert "HloModule" in text
    assert entry.hlo_chars == len(text)


def test_lower_sqmul_has_two_outputs(tmp_path):
    entry = aot.lower_one(dict(op="sqmul", n=8, dtype="f32", variant="xla"), tmp_path)
    assert entry.num_outputs == 2
    text = (tmp_path / entry.file).read_text()
    assert "HloModule" in text


def test_lower_pallas_records_blocks(tmp_path):
    entry = aot.lower_one(dict(op="matmul", n=64, dtype="f32", variant="pallas"), tmp_path)
    assert entry.blocks == [64, 64, 64]
    assert entry.vmem_bytes == kmm.vmem_footprint_bytes(64, 64, 64)
    assert entry.mxu_utilization == pytest.approx(0.125, abs=1e-4)


def test_entry_name_format():
    assert aot.entry_name("matmul", 64, "f32", "xla") == "matmul_n64_f32_xla"
    assert aot.entry_name("matmul", 64, "f32", "pallas", "t16") == "matmul_n64_f32_pallas_t16"


def test_shipped_manifest_is_consistent():
    """If `make artifacts` has run, validate the shipped manifest."""
    mpath = Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not mpath.exists():
        pytest.skip("artifacts not built")
    manifest = json.loads(mpath.read_text())
    assert manifest["version"] == aot.MANIFEST_VERSION
    entries = manifest["entries"]
    assert len(entries) == len(aot.catalogue())
    for e in entries:
        f = mpath.parent / e["file"]
        assert f.exists(), e["name"]
        assert e["num_inputs"] in (1, 2)
        assert e["num_outputs"] in (1, 2)


def test_hlo_text_has_no_serialized_proto_markers(tmp_path):
    """Interchange must be text (xla_extension 0.5.1 rejects 64-bit-id protos)."""
    entry = aot.lower_one(dict(op="square", n=8, dtype="f32", variant="xla"), tmp_path)
    text = (tmp_path / entry.file).read_text()
    assert text.lstrip().startswith("HloModule")
