"""L2 correctness: every compute graph in model.py vs the oracles in ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref as kref


def rand(n, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, n), dtype)


TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("variant", ["xla", "pallas"])
def test_matmul_graph(variant):
    fn, specs = model.build_matmul(16, jnp.float32, variant)
    assert [s.shape for s in specs] == [(16, 16), (16, 16)]
    x, y = rand(16, seed=1), rand(16, seed=2)
    (out,) = jax.jit(fn)(x, y)
    np.testing.assert_allclose(out, kref.matmul_ref(x, y), **TOL)


@pytest.mark.parametrize("variant", ["xla", "pallas"])
def test_square_graph(variant):
    fn, _ = model.build_square(16, jnp.float32, variant)
    x = rand(16, seed=3)
    (out,) = jax.jit(fn)(x)
    np.testing.assert_allclose(out, kref.matmul_ref(x, x), **TOL)


@pytest.mark.parametrize("variant", ["xla", "pallas"])
def test_sqmul_graph_two_outputs(variant):
    fn, specs = model.build_sqmul(16, jnp.float32, variant)
    assert len(specs) == 2
    acc, base = rand(16, seed=4), rand(16, seed=5)
    out_acc, out_base = jax.jit(fn)(acc, base)
    np.testing.assert_allclose(out_acc, kref.matmul_ref(acc, base), **TOL)
    np.testing.assert_allclose(out_base, kref.matmul_ref(base, base), **TOL)


@pytest.mark.parametrize("chain_len,power", [(1, 2), (2, 4), (3, 8), (4, 16)])
def test_square_chain(chain_len, power):
    fn, _ = model.build_square_chain(8, jnp.float32, "xla", chain_len)
    x = kref.spectral_scale(np.asarray(rand(8, seed=6)))
    (out,) = jax.jit(fn)(jnp.asarray(x))
    np.testing.assert_allclose(out, kref.expm_binary_ref(jnp.asarray(x), power),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("power", [1, 2, 3, 5, 7, 8, 13, 16, 64, 100])
def test_expm_fixed_matches_naive(power):
    fn, _ = model.build_expm_fixed(8, jnp.float32, "xla", power)
    x = jnp.asarray(kref.spectral_scale(np.asarray(rand(8, seed=7))))
    (out,) = jax.jit(fn)(x)
    want = kref.expm_naive_ref(x, power)
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-3)


def test_expm_power_one_is_identity_map():
    fn, _ = model.build_expm_fixed(8, jnp.float32, "xla", 1)
    x = rand(8, seed=8)
    (out,) = jax.jit(fn)(x)
    np.testing.assert_allclose(out, x)


def test_expm_rejects_power_zero():
    with pytest.raises(ValueError):
        model.build_expm_fixed(8, jnp.float32, "xla", 0)


def test_build_op_dispatch():
    for op, n_in in [("matmul", 2), ("square", 1), ("sqmul", 2),
                     ("square2", 1), ("square4", 1), ("expm64", 1)]:
        fn, specs = model.build_op(op, 8, jnp.float32, "xla")
        assert len(specs) == n_in, op


def test_build_op_unknown():
    with pytest.raises(ValueError):
        model.build_op("cholesky", 8, jnp.float32, "xla")
    with pytest.raises(ValueError):
        model.build_op("matmul", 8, jnp.float32, "cuda")


def test_binary_ref_equals_naive_ref():
    x = jnp.asarray(kref.spectral_scale(np.asarray(rand(6, seed=9))))
    for p in [1, 2, 3, 4, 5, 9, 16, 31, 33]:
        np.testing.assert_allclose(
            kref.expm_binary_ref(x, p), kref.expm_naive_ref(x, p),
            rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(power=st.integers(min_value=1, max_value=200),
       seed=st.integers(min_value=0, max_value=10_000))
def test_hypothesis_binary_vs_f64(power, seed):
    """Binary square-and-multiply matches float64 matrix_power."""
    x = kref.spectral_scale(np.asarray(rand(5, seed=seed)), target=0.9)
    got = kref.expm_binary_ref(jnp.asarray(x), power)
    want = kref.expm_numpy_f64(x, power)
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-3)


def test_pallas_variant_bitwise_matches_xla_variant_small():
    """Same graph, two variants: numerics must agree tightly (A4)."""
    for n in (8, 16, 32):
        fx, _ = model.build_matmul(n, jnp.float32, "xla")
        fp, _ = model.build_matmul(n, jnp.float32, "pallas")
        x, y = rand(n, seed=11), rand(n, seed=12)
        (a,) = jax.jit(fx)(x, y)
        (b,) = jax.jit(fp)(x, y)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
