"""A4 — precision experiment (paper §6: 'tested ... for the precision problem').

The binary method performs ~log2(N) multiplies instead of N, so rounding
error accumulates *less*; these tests document that our approach is at
least as precise as the naive chain it replaces.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref as kref


def stochastic(n, seed):
    """Row-stochastic matrix: powers stay bounded (Markov-chain workload)."""
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)).astype(np.float32)
    return m / m.sum(axis=1, keepdims=True)


@pytest.mark.parametrize("power", [64, 128, 256, 512, 1024])
def test_binary_f32_close_to_f64_truth(power):
    x = stochastic(16, seed=power)
    truth = kref.expm_numpy_f64(x, power)
    got = np.asarray(kref.expm_binary_ref(jnp.asarray(x), power))
    np.testing.assert_allclose(got, truth, rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("power", [16, 64, 256])
def test_binary_no_less_precise_than_naive(power):
    x = stochastic(12, seed=power + 1)
    truth = kref.expm_numpy_f64(x, power)
    err_binary = np.abs(np.asarray(kref.expm_binary_ref(jnp.asarray(x), power)) - truth).max()
    err_naive = np.abs(np.asarray(kref.expm_naive_ref(jnp.asarray(x), power)) - truth).max()
    # binary accumulates over ~log2 N rounds vs N rounds; allow 4x slack for
    # the lucky cases where naive cancels.
    assert err_binary <= max(err_naive * 4.0, 1e-6), (err_binary, err_naive)


def test_spectral_scale_controls_radius():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((24, 24)).astype(np.float32)
    y = kref.spectral_scale(x, target=1.0)
    radius = np.max(np.abs(np.linalg.eigvals(y.astype(np.float64))))
    assert radius == pytest.approx(1.0, rel=1e-3)


def test_powers_of_scaled_matrix_bounded():
    rng = np.random.default_rng(1)
    x = kref.spectral_scale(rng.standard_normal((16, 16)).astype(np.float32), 0.99)
    out = np.asarray(kref.expm_binary_ref(jnp.asarray(x), 1024))
    assert np.isfinite(out).all()
