//! Integration: the TCP front-end — wire protocol over a real socket,
//! concurrent clients, malformed input, metrics endpoint. Runs
//! unconditionally on the default (pure-Rust CPU) backend.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::server::client::MatexpClient;
use matexp::server::server::{serve_background, Server};
use matexp::util::json::Json;

/// The returned [`Server`] must be held for the test's lifetime: dropping
/// it shuts the listener down (that IS the shutdown satellite — tests no
/// longer leak accept threads and sockets when they finish).
fn start_server() -> (Arc<matexp::coordinator::service::ServiceHandle>, Server, String) {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 2;
    cfg.batcher.max_wait_ms = 1;
    let service = Arc::new(Service::start(cfg).expect("service starts"));
    let server = serve_background(Arc::clone(&service), "127.0.0.1:0", 8).expect("binds");
    let addr = server.local_addr().to_string();
    (service, server, addr)
}

#[test]
fn expm_roundtrip_over_tcp() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    client.ping().expect("ping");
    let a = Matrix::random_spectral(16, 0.95, 77);
    let want = linalg::expm::expm(&a, 100, CpuAlgo::Ikj).unwrap();
    let (got, stats) = client.expm(&a, 100, Method::Ours).expect("expm");
    assert!(
        got.approx_eq(&want, 1e-3, 1e-3),
        "diff {}",
        got.max_abs_diff(&want)
    );
    assert!(stats.launches > 0 && stats.launches <= 12, "{stats:?}");
    assert_eq!(stats.multiplies, 8); // 100 = 0b1100100: 6 squarings + 2 mults
}

#[test]
fn concurrent_tcp_clients() {
    let (_service, _server, addr) = start_server();
    std::thread::scope(|scope| {
        for c in 0..4u64 {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut client = MatexpClient::connect(&addr).expect("connect");
                let a = Matrix::random_spectral(16, 0.9, c);
                for power in [8u64, 64, 200] {
                    let want = linalg::expm::expm(&a, power, CpuAlgo::Ikj).unwrap();
                    let (got, _) = client.expm(&a, power, Method::Ours).expect("expm");
                    assert!(got.approx_eq(&want, 1e-3, 1e-3), "client {c} N={power}");
                }
            });
        }
    });
}

#[test]
fn metrics_endpoint_reports_counts() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let a = Matrix::random_spectral(16, 0.9, 5);
    client.expm(&a, 16, Method::Ours).unwrap();
    client.expm(&a, 16, Method::NaiveGpu).unwrap();
    let m = client.metrics().expect("metrics");
    assert_eq!(m.get("responses_total").and_then(Json::as_u64), Some(2));
    // naive N=16 = 15 launches; ours N=16 under the default chained
    // planner = ONE square4-chain launch (2^4)
    assert!(m.get("launches_total").and_then(Json::as_u64).unwrap() >= 15 + 1);
    assert!(m.get("latency_p50_us").is_some());
    // the residency counters are live on the wire: ours copies its two
    // host edges, naive-gpu round-trips 15 × 3 edges — 47 edges total
    let bytes = m.get("bytes_copied_total").and_then(Json::as_u64).unwrap();
    assert_eq!(bytes, 47 * 16 * 16 * 4, "{m}");
    assert!(m.get("buffers_recycled_total").and_then(Json::as_u64).is_some());
}

#[test]
fn expm_response_carries_residency_stats() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let a = Matrix::random_spectral(16, 0.9, 9);
    let (_, stats) = client.expm(&a, 1024, Method::OursPacked).expect("expm");
    // device-resident discipline: exactly the two host-edge transfers
    assert_eq!(stats.bytes_copied, 2 * 16 * 16 * 4, "{stats:?}");
    assert!(stats.buffers_recycled > 0, "{stats:?}");
    assert!(stats.peak_resident_bytes > 0, "{stats:?}");
}

#[test]
fn malformed_lines_get_error_responses_and_connection_survives() {
    let (_service, _server, addr) = start_server();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut send_recv = |line: &str| -> String {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        buf
    };
    for bad in ["not json", r#"{"op":"nope"}"#, r#"{"op":"expm","n":4,"power":2,"method":"ours","matrix":[1,2]}"#] {
        let resp = send_recv(bad);
        assert!(resp.contains("\"status\":\"error\""), "{bad} -> {resp}");
    }
    // connection still usable after errors
    let resp = send_recv(r#"{"op":"ping"}"#);
    assert!(resp.contains("\"status\":\"ok\""), "{resp}");
}

#[test]
fn listener_survives_bad_connections() {
    // regression: the accept loop used to exit on the first connection
    // error, silently killing the server. Slam it with connections that
    // die mid-handshake/mid-line and verify later clients still get
    // served.
    let (_service, _server, addr) = start_server();
    for i in 0..8 {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        if i % 2 == 0 {
            // half-written garbage, never terminated by a newline
            let _ = w.write_all(b"{\"op\":\"expm\",\"n\":4,");
        }
        let _ = stream.shutdown(std::net::Shutdown::Both);
        drop(w);
        drop(stream); // slam the connection shut
    }
    // the listener must still accept and serve
    let mut client = MatexpClient::connect(&addr).expect("listener still alive");
    client.ping().expect("server still serves after bad connections");
    let a = Matrix::random_spectral(8, 0.9, 3);
    let want = linalg::expm::expm(&a, 8, CpuAlgo::Ikj).unwrap();
    let (got, _) = client.expm(&a, 8, Method::Ours).expect("expm after bad connections");
    assert!(got.approx_eq(&want, 1e-3, 1e-3));
}

/// Satellite acceptance: ≥8 pipelined in-flight requests on ONE
/// connection, resolved out of submission order with correct
/// id↔result pairing.
#[test]
fn pipelined_requests_on_one_connection_pair_ids_to_results() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    // distinct (matrix, power) per request so a mispaired reply is
    // guaranteed to fail its oracle check
    let inputs: Vec<(Matrix, u64)> = (0..10u64)
        .map(|i| (Matrix::random_spectral(8 + (i as usize % 3) * 4, 0.9, 100 + i), 3 + i))
        .collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|(a, p)| client.submit(a, *p, Method::Ours).expect("submit"))
        .collect();
    assert_eq!(tickets.len(), 10, "all 10 in flight before any wait");
    // resolve in REVERSE submission order: the client must pair by id,
    // buffering whatever other replies land first
    for (ticket, (a, p)) in tickets.iter().zip(&inputs).rev() {
        let want = linalg::expm::expm(a, *p, CpuAlgo::Ikj).unwrap();
        let (got, stats) = client.wait(ticket).expect("pipelined wait");
        assert!(
            got.approx_eq(&want, 1e-4, 1e-4),
            "ticket {} (N={p}): diff {}",
            ticket.id(),
            got.max_abs_diff(&want)
        );
        assert!(stats.multiplies > 0);
    }
    // a ticket resolves exactly once: a second wait errors (typed)
    // instead of blocking forever on a reply that will never come again
    let err = client.wait(&tickets[0]).unwrap_err().to_string();
    assert!(err.contains("already resolved"), "{err}");
}

/// Replies genuinely arrive out of submission order: a slow job
/// submitted FIRST resolves after a fast one submitted second, on the
/// same connection (two workers serve the two batches concurrently).
#[test]
fn slow_first_fast_second_completes_out_of_order() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let slow_a = Matrix::random_spectral(32, 0.9, 1);
    let fast_a = Matrix::random_spectral(16, 0.9, 2);
    // cpu-seq power 300 = 299 full multiplies; the fast job is 3 launches
    let slow = client.submit(&slow_a, 300, Method::CpuSeq).expect("submit slow");
    let fast = client.submit(&fast_a, 8, Method::Ours).expect("submit fast");
    // wait the SLOW one first: the fast reply arrives meanwhile and must
    // be buffered under its id, not misdelivered
    let (got_slow, _) = client.wait(&slow).expect("slow");
    let (got_fast, _) = client.wait(&fast).expect("fast");
    assert!(got_slow
        .approx_eq(&linalg::expm::expm(&slow_a, 300, CpuAlgo::Ikj).unwrap(), 1e-3, 1e-3));
    assert!(got_fast
        .approx_eq(&linalg::expm::expm(&fast_a, 8, CpuAlgo::Ikj).unwrap(), 1e-4, 1e-4));
}

/// Legacy one-shot requests (no id on the wire) and pipelined requests
/// coexist on one connection: the un-id'd reply is answered in order,
/// id-tagged replies are paired by id around it.
#[test]
fn legacy_one_shot_and_pipelined_coexist_on_one_connection() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let a = Matrix::random_spectral(12, 0.9, 21);
    let b = Matrix::random_spectral(12, 0.9, 22);
    let t1 = client.submit(&a, 100, Method::Ours).expect("pipelined submit");
    // legacy blocking call with pipelined work still in flight
    let want_b = linalg::expm::expm(&b, 16, CpuAlgo::Ikj).unwrap();
    let (got_b, _) = client.expm(&b, 16, Method::Ours).expect("legacy expm");
    assert!(got_b.approx_eq(&want_b, 1e-4, 1e-4));
    // the pipelined ticket still resolves correctly afterwards
    let want_a = linalg::expm::expm(&a, 100, CpuAlgo::Ikj).unwrap();
    let (got_a, _) = client.wait(&t1).expect("pipelined wait");
    assert!(got_a.approx_eq(&want_a, 1e-4, 1e-4));
}

/// Admission failures on pipelined requests come back as id-tagged
/// error lines, so the ticket resolves to the typed error.
#[test]
fn pipelined_admission_error_is_id_tagged() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let bad = client.submit(&Matrix::identity(8), 1 << 40, Method::Ours).expect("submit");
    let good = client.submit(&Matrix::identity(8), 4, Method::Ours).expect("submit");
    let err = client.wait(&bad).unwrap_err().to_string();
    assert!(err.contains("MAX_POWER"), "{err}");
    let (got, _) = client.wait(&good).expect("good request unaffected");
    assert!(got.approx_eq(&Matrix::identity(8), 1e-5, 1e-5));
}

#[test]
fn server_rejects_oversized_power_via_admission() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let a = Matrix::identity(16);
    let err = client.expm(&a, 1 << 40, Method::Ours).unwrap_err().to_string();
    assert!(err.contains("MAX_POWER"), "{err}");
}
