//! Integration: the TCP front-end — wire protocol over a real socket,
//! concurrent clients, malformed input, metrics endpoint. Runs
//! unconditionally on the default (pure-Rust CPU) backend.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::server::client::MatexpClient;
use matexp::server::server::serve_background;
use matexp::util::json::Json;

fn start_server() -> (Arc<matexp::coordinator::service::ServiceHandle>, String) {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 2;
    cfg.batcher.max_wait_ms = 1;
    let service = Arc::new(Service::start(cfg).expect("service starts"));
    let server = serve_background(Arc::clone(&service), "127.0.0.1:0", 8).expect("binds");
    (service, server.local_addr().to_string())
}

#[test]
fn expm_roundtrip_over_tcp() {
    let (_service, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    client.ping().expect("ping");
    let a = Matrix::random_spectral(16, 0.95, 77);
    let want = linalg::expm::expm(&a, 100, CpuAlgo::Ikj).unwrap();
    let (got, stats) = client.expm(&a, 100, Method::Ours).expect("expm");
    assert!(
        got.approx_eq(&want, 1e-3, 1e-3),
        "diff {}",
        got.max_abs_diff(&want)
    );
    assert!(stats.launches > 0 && stats.launches <= 12, "{stats:?}");
    assert_eq!(stats.multiplies, 8); // 100 = 0b1100100: 6 squarings + 2 mults
}

#[test]
fn concurrent_tcp_clients() {
    let (_service, addr) = start_server();
    std::thread::scope(|scope| {
        for c in 0..4u64 {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut client = MatexpClient::connect(&addr).expect("connect");
                let a = Matrix::random_spectral(16, 0.9, c);
                for power in [8u64, 64, 200] {
                    let want = linalg::expm::expm(&a, power, CpuAlgo::Ikj).unwrap();
                    let (got, _) = client.expm(&a, power, Method::Ours).expect("expm");
                    assert!(got.approx_eq(&want, 1e-3, 1e-3), "client {c} N={power}");
                }
            });
        }
    });
}

#[test]
fn metrics_endpoint_reports_counts() {
    let (_service, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let a = Matrix::random_spectral(16, 0.9, 5);
    client.expm(&a, 16, Method::Ours).unwrap();
    client.expm(&a, 16, Method::NaiveGpu).unwrap();
    let m = client.metrics().expect("metrics");
    assert_eq!(m.get("responses_total").and_then(Json::as_u64), Some(2));
    // naive N=16 = 15 launches; ours N=16 under the default chained
    // planner = ONE square4-chain launch (2^4)
    assert!(m.get("launches_total").and_then(Json::as_u64).unwrap() >= 15 + 1);
    assert!(m.get("latency_p50_us").is_some());
    // the residency counters are live on the wire: ours copies its two
    // host edges, naive-gpu round-trips 15 × 3 edges — 47 edges total
    let bytes = m.get("bytes_copied_total").and_then(Json::as_u64).unwrap();
    assert_eq!(bytes, 47 * 16 * 16 * 4, "{m}");
    assert!(m.get("buffers_recycled_total").and_then(Json::as_u64).is_some());
}

#[test]
fn expm_response_carries_residency_stats() {
    let (_service, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let a = Matrix::random_spectral(16, 0.9, 9);
    let (_, stats) = client.expm(&a, 1024, Method::OursPacked).expect("expm");
    // device-resident discipline: exactly the two host-edge transfers
    assert_eq!(stats.bytes_copied, 2 * 16 * 16 * 4, "{stats:?}");
    assert!(stats.buffers_recycled > 0, "{stats:?}");
    assert!(stats.peak_resident_bytes > 0, "{stats:?}");
}

#[test]
fn malformed_lines_get_error_responses_and_connection_survives() {
    let (_service, addr) = start_server();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut send_recv = |line: &str| -> String {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut buf = String::new();
        reader.read_line(&mut buf).unwrap();
        buf
    };
    for bad in ["not json", r#"{"op":"nope"}"#, r#"{"op":"expm","n":4,"power":2,"method":"ours","matrix":[1,2]}"#] {
        let resp = send_recv(bad);
        assert!(resp.contains("\"status\":\"error\""), "{bad} -> {resp}");
    }
    // connection still usable after errors
    let resp = send_recv(r#"{"op":"ping"}"#);
    assert!(resp.contains("\"status\":\"ok\""), "{resp}");
}

#[test]
fn listener_survives_bad_connections() {
    // regression: the accept loop used to exit on the first connection
    // error, silently killing the server. Slam it with connections that
    // die mid-handshake/mid-line and verify later clients still get
    // served.
    let (_service, addr) = start_server();
    for i in 0..8 {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        if i % 2 == 0 {
            // half-written garbage, never terminated by a newline
            let _ = w.write_all(b"{\"op\":\"expm\",\"n\":4,");
        }
        let _ = stream.shutdown(std::net::Shutdown::Both);
        drop(w);
        drop(stream); // slam the connection shut
    }
    // the listener must still accept and serve
    let mut client = MatexpClient::connect(&addr).expect("listener still alive");
    client.ping().expect("server still serves after bad connections");
    let a = Matrix::random_spectral(8, 0.9, 3);
    let want = linalg::expm::expm(&a, 8, CpuAlgo::Ikj).unwrap();
    let (got, _) = client.expm(&a, 8, Method::Ours).expect("expm after bad connections");
    assert!(got.approx_eq(&want, 1e-3, 1e-3));
}

#[test]
fn server_rejects_oversized_power_via_admission() {
    let (_service, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    let a = Matrix::identity(16);
    let err = client.expm(&a, 1 << 40, Method::Ours).unwrap_err().to_string();
    assert!(err.contains("MAX_POWER"), "{err}");
}
