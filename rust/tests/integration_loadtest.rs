//! Integration: the `matexp loadtest` driver end-to-end against a real
//! server — every wire mode completes its full request count, the binary
//! codec is measurably leaner on the wire than the JSON line codec, the
//! open-loop pacer works, and the emitted snapshot validates against the
//! schema the CI gate enforces.

use std::sync::Arc;

use matexp::bench::loadtest::{self, LoadtestConfig, WireMode};
use matexp::config::MatexpConfig;
use matexp::coordinator::service::Service;
use matexp::server::server::{serve_background, Server};

fn start_server() -> (Server, String) {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 2;
    cfg.batcher.max_wait_ms = 1;
    let service = Arc::new(Service::start(cfg).expect("service starts"));
    let server = serve_background(service, "127.0.0.1:0", 16).expect("binds");
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn small() -> LoadtestConfig {
    LoadtestConfig { clients: 2, requests: 4, warmup: 1, n: 16, power: 32, ..Default::default() }
}

#[test]
fn every_wire_mode_completes_and_binary_is_leaner() {
    let (_server, addr) = start_server();
    let cfg = small();
    let reports: Vec<_> = WireMode::all()
        .iter()
        .map(|&mode| loadtest::run_mode(&addr, mode, &cfg).expect("mode run"))
        .collect();
    for r in &reports {
        assert_eq!(r.requests, cfg.clients * cfg.requests, "{:?}", r.mode);
        for (name, v) in [
            ("p50", r.p50_s),
            ("p99", r.p99_s),
            ("mean", r.mean_s),
            ("throughput", r.throughput_rps),
            ("wall", r.wall_s),
        ] {
            assert!(v.is_finite() && v > 0.0, "{:?} {name} = {v}", r.mode);
        }
        assert!(r.p50_s <= r.p99_s, "{:?}: p50 {} > p99 {}", r.mode, r.p50_s, r.p99_s);
        assert!(r.min_s <= r.p50_s && r.max_s >= r.p99_s, "{:?}", r.mode);
    }
    let by_mode = |m: WireMode| reports.iter().find(|r| r.mode == m).unwrap();
    let (json, binary) = (by_mode(WireMode::Json), by_mode(WireMode::Binary));
    // a 16x16 f32 matrix is 1KiB raw; its JSON text is several KiB. The
    // measured-phase byte counters must show the gap in both directions.
    assert!(
        binary.wire_bytes_out < json.wire_bytes_out,
        "binary out {} !< json out {}",
        binary.wire_bytes_out,
        json.wire_bytes_out
    );
    assert!(
        binary.wire_bytes_in < json.wire_bytes_in,
        "binary in {} !< json in {}",
        binary.wire_bytes_in,
        json.wire_bytes_in
    );
}

#[test]
fn open_loop_pacer_completes_and_measures_from_scheduled_start() {
    let (_server, addr) = start_server();
    // 2 clients x 3 requests at a rate the tiny workload easily sustains
    let cfg = LoadtestConfig { requests: 3, rate: Some(200.0), ..small() };
    let r = loadtest::run_mode(&addr, WireMode::Binary, &cfg).expect("open-loop run");
    assert_eq!(r.requests, 6);
    assert!(r.p50_s > 0.0 && r.p50_s.is_finite());
    // the run is paced: wall clock covers at least the scheduled span of
    // the last request (requests-1)/rate, minus scheduling slop
    assert!(r.wall_s >= (cfg.requests - 1) as f64 / 200.0 * 0.5, "wall {}", r.wall_s);
}

#[test]
fn snapshot_from_real_reports_validates() {
    let (_server, addr) = start_server();
    let cfg = small();
    let reports: Vec<_> = WireMode::all()
        .iter()
        .map(|&mode| loadtest::run_mode(&addr, mode, &cfg).expect("mode run"))
        .collect();
    let codec = loadtest::codec_roundtrip(64, 2);
    // a plain server exposes no members block — fetch finds none, and the
    // empty spread is still a valid v3 snapshot
    let members = loadtest::fetch_members(&addr);
    assert!(members.is_empty(), "single server must expose no member spread");
    let snap = loadtest::snapshot(6, &cfg, &reports, &codec, &members);
    loadtest::validate_snapshot(&snap).expect("real snapshot validates");
    // the gate really gates: a snapshot claiming a foreign schema fails
    let damaged = snap.to_string().replace(loadtest::SNAPSHOT_SCHEMA, "someone-else/9");
    let damaged = matexp::util::json::Json::parse(&damaged).unwrap();
    assert!(loadtest::validate_snapshot(&damaged).is_err(), "foreign schema must be rejected");
}
