//! Integration: the runtime end-to-end against the CPU oracle — every
//! execution discipline, on the pure-Rust backends, across sizes and
//! powers. Runs unconditionally (no artifacts needed); the PJRT variants
//! live at the bottom behind `--features xla` and stay artifact-gated.

// These tests deliberately keep exercising the deprecated one-release
// shims (expm_* / blocking submit) — they ARE the shim regression
// coverage. New code routes through exec::Executor::submit.
#![allow(deprecated)]
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::plan::Plan;
use matexp::runtime::{Engine, FUSED_EXPM_POWERS};

fn cpu_oracle(a: &Matrix, power: u64) -> Matrix {
    linalg::expm::expm(a, power, CpuAlgo::Ikj).expect("cpu oracle")
}

#[test]
fn device_resident_binary_matches_cpu_across_sizes() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    for n in [4usize, 16, 64] {
        let a = Matrix::random_spectral(n, 0.95, n as u64);
        for power in [1u64, 2, 3, 13, 64, 100] {
            let want = cpu_oracle(&a, power);
            let (got, stats) = engine.expm(&a, &Plan::binary(power, false)).unwrap();
            assert!(
                got.approx_eq(&want, 1e-3, 1e-3),
                "n={n} N={power}: max diff {}",
                got.max_abs_diff(&want)
            );
            assert_eq!(stats.h2d_transfers, 1, "device-resident uploads once");
            assert_eq!(stats.d2h_transfers, 1);
        }
    }
}

#[test]
fn all_disciplines_agree_on_one_workload() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let n = 32;
    let power = 100;
    let a = Matrix::random_spectral(n, 0.97, 5);
    let want = cpu_oracle(&a, power);
    let check = |name: &str, got: &Matrix| {
        assert!(
            got.approx_eq(&want, 1e-3, 1e-3),
            "{name}: max diff {}",
            got.max_abs_diff(&want)
        );
    };
    check("binary", &engine.expm(&a, &Plan::binary(power, false)).unwrap().0);
    check("fused", &engine.expm(&a, &Plan::binary(power, true)).unwrap().0);
    check("chained", &engine.expm(&a, &Plan::chained(power, &[4, 2])).unwrap().0);
    check("addition-chain", &engine.expm(&a, &Plan::addition_chain(power)).unwrap().0);
    check("packed", &engine.expm_packed(&a, power).unwrap().0);
    check("naive-roundtrip", &engine.expm_naive_roundtrip(&a, power).unwrap().0);
    check(
        "plan-roundtrip",
        &engine.expm_plan_roundtrip(&a, &Plan::binary(power, false)).unwrap().0,
    );
}

#[test]
fn every_cpu_algo_backend_agrees() {
    let n = 24;
    let power = 50;
    let a = Matrix::random_spectral(n, 0.95, 11);
    let want = cpu_oracle(&a, power);
    for algo in CpuAlgo::all() {
        let mut engine = Engine::cpu(algo);
        let (got, _) = engine.expm(&a, &Plan::binary(power, false)).unwrap();
        assert!(
            got.approx_eq(&want, 1e-3, 1e-3),
            "algo {}: max diff {}",
            algo.name(),
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn fused_expm_ops_match_plans() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let n = 16;
    let a = Matrix::random_spectral(n, 0.98, 21);
    for power in FUSED_EXPM_POWERS {
        let (fused, stats) = engine.expm_fused_artifact(&a, power).unwrap();
        assert_eq!(stats.launches, 1, "fused = single launch");
        let (planned, _) = engine.expm(&a, &Plan::binary(power, false)).unwrap();
        assert!(
            fused.approx_eq(&planned, 1e-2, 1e-2),
            "N={power}: max diff {}",
            fused.max_abs_diff(&planned)
        );
    }
    // non-shipped power errors like a missing artifact would
    assert!(engine.expm_fused_artifact(&a, 65).is_err());
}

#[test]
fn naive_roundtrip_transfer_accounting() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let a = Matrix::random_spectral(16, 0.9, 31);
    let (_, stats) = engine.expm_naive_roundtrip(&a, 64).unwrap();
    assert_eq!(stats.launches, 63);
    assert_eq!(stats.multiplies, 63);
    assert_eq!(stats.h2d_transfers, 2 * 63, "both operands re-uploaded per launch");
    assert_eq!(stats.d2h_transfers, 63, "result downloaded per launch");
}

#[test]
fn launch_counts_match_plan_costs() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let a = Matrix::random_spectral(16, 0.9, 41);
    for power in [64u64, 100, 511, 1024] {
        let plan = Plan::binary(power, false);
        let (_, stats) = engine.expm(&a, &plan).unwrap();
        assert_eq!(stats.launches, plan.launches(), "N={power}");
        assert_eq!(stats.multiplies, plan.multiplies(), "N={power}");
    }
}

#[test]
fn identity_and_stochastic_invariants_hold_through_engine() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    // identity stays identity at any power
    let e = Matrix::identity(32);
    let (p, _) = engine.expm(&e, &Plan::binary(1024, false)).unwrap();
    assert!(p.approx_eq(&e, 1e-5, 0.0));
    // stochastic rows keep summing to 1
    let s = Matrix::random_stochastic(32, 9);
    let (p, _) = engine.expm_packed(&s, 512).unwrap();
    for i in 0..32 {
        let sum: f32 = p.row(i).iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "row {i}: {sum}");
    }
}

#[test]
fn power_zero_rejected_everywhere() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let a = Matrix::identity(8);
    assert!(engine.expm_naive_roundtrip(&a, 0).is_err());
    assert!(engine.expm_packed(&a, 0).is_err());
}

#[test]
fn sim_backend_numerics_match_cpu_and_times_follow_model() {
    let mut sim = Engine::sim();
    let a = Matrix::random_spectral(64, 0.95, 13);
    let power = 256;
    let want = cpu_oracle(&a, power);
    let (ours, ours_stats) = sim.expm(&a, &Plan::binary(power, false)).unwrap();
    assert!(ours.approx_eq(&want, 1e-3, 1e-3), "sim numerics diverge");
    let (_, naive_stats) = sim.expm_naive_roundtrip(&a, power).unwrap();
    // wall_s is SIMULATED 2012-testbed time: the paper's core claim must
    // hold by construction — device residency beats per-launch round-trips
    assert!(ours_stats.wall_s > 0.0);
    assert!(
        naive_stats.wall_s > ours_stats.wall_s * 5.0,
        "simulated naive {} must be far slower than ours {}",
        naive_stats.wall_s,
        ours_stats.wall_s
    );
    // and the simulated clock tracks launch counts: 255 launches vs 8
    assert_eq!(naive_stats.launches, 255);
    assert_eq!(ours_stats.launches, 8);
}

#[test]
fn cpu_and_sim_backends_agree_numerically() {
    let mut cpu = Engine::cpu(CpuAlgo::Blocked);
    let mut sim = Engine::sim();
    let a = Matrix::random_stochastic(24, 17);
    for power in [13u64, 100] {
        let (c, _) = cpu.expm(&a, &Plan::chained(power, &[4, 2])).unwrap();
        let (s, _) = sim.expm(&a, &Plan::chained(power, &[4, 2])).unwrap();
        assert!(c.approx_eq(&s, 1e-4, 1e-4), "N={power}: {}", c.max_abs_diff(&s));
    }
}

// ---------------------------------------------------------------------------
// PJRT variants: need `--features xla`, a real xla-rs link AND built
// artifacts; they skip (pass trivially) when `make artifacts` hasn't run.
// ---------------------------------------------------------------------------
#[cfg(feature = "xla")]
mod pjrt {
    use super::*;
    use matexp::config::default_artifacts_dir;
    use matexp::runtime::artifacts::ArtifactRegistry;
    use matexp::runtime::Variant;

    fn registry() -> Option<ArtifactRegistry> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return None;
        }
        Some(ArtifactRegistry::discover(&dir).expect("manifest parses"))
    }

    #[test]
    fn pjrt_binary_matches_cpu_across_sizes() {
        let Some(reg) = registry() else { return };
        let mut engine = Engine::pjrt(&reg, Variant::Xla).unwrap();
        for n in [4usize, 16, 64] {
            let a = Matrix::random_spectral(n, 0.95, n as u64);
            for power in [1u64, 2, 13, 100] {
                let want = cpu_oracle(&a, power);
                let (got, _) = engine.expm(&a, &Plan::binary(power, false)).unwrap();
                assert!(got.approx_eq(&want, 1e-3, 1e-3), "n={n} N={power}");
            }
        }
    }

    #[test]
    fn pallas_variant_matches_xla_variant() {
        let Some(reg) = registry() else { return };
        let mut xla_e = Engine::pjrt(&reg, Variant::Xla).unwrap();
        let mut pal_e = Engine::pjrt(&reg, Variant::Pallas).unwrap();
        let n = 64;
        let a = Matrix::random_spectral(n, 0.95, 11);
        let b = Matrix::random_spectral(n, 0.95, 12);
        let (mx, _) = xla_e.matmul(&a, &b).unwrap();
        let (mp, _) = pal_e.matmul(&a, &b).unwrap();
        assert!(mx.approx_eq(&mp, 1e-4, 1e-4), "variants diverge: {}", mx.max_abs_diff(&mp));
    }

    #[test]
    fn pjrt_sqmul_split_costs_the_tuple_roundtrip() {
        let Some(reg) = registry() else { return };
        let mut engine = Engine::pjrt(&reg, Variant::Xla).unwrap();
        let a = Matrix::random_spectral(16, 0.9, 3);
        // 11 = 0b1011 → fused binary plan contains SqMul steps
        let (_, stats) = engine.expm(&a, &Plan::binary(11, true)).unwrap();
        assert!(stats.h2d_transfers > 1, "PJRT pays for tuple splits: {stats:?}");
    }
}
