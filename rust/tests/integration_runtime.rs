//! Integration: the runtime end-to-end against the CPU oracle — every
//! execution discipline, on the pure-Rust backends, across sizes and
//! powers. Runs unconditionally (no artifacts needed); the PJRT variants
//! live at the bottom behind `--features xla` and stay artifact-gated.
//!
//! Every discipline is exercised through the one execution surface
//! (`exec::Executor` submissions) — the deprecated `expm_*` shims were
//! removed in 0.4.0.

use matexp::coordinator::request::{ExpmResponse, Method};
use matexp::exec::{Executor, Submission};
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::plan::Plan;
use matexp::runtime::{Backend, Engine, FUSED_EXPM_POWERS};

fn cpu_oracle(a: &Matrix, power: u64) -> Matrix {
    linalg::expm::expm(a, power, CpuAlgo::Ikj).expect("cpu oracle")
}

/// Replay an explicit plan through the surface.
fn replay<B: Backend>(engine: &mut Engine<B>, a: &Matrix, plan: Plan) -> ExpmResponse {
    let power = plan.power;
    engine.run(Submission::expm(a.clone(), power).plan(plan)).expect("replay")
}

/// Run one method through the surface.
fn run_method<B: Backend>(
    engine: &mut Engine<B>,
    a: &Matrix,
    power: u64,
    method: Method,
) -> ExpmResponse {
    engine.run(Submission::expm(a.clone(), power).method(method)).expect("run")
}

#[test]
fn device_resident_binary_matches_cpu_across_sizes() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    for n in [4usize, 16, 64] {
        let a = Matrix::random_spectral(n, 0.95, n as u64);
        for power in [1u64, 2, 3, 13, 64, 100] {
            let want = cpu_oracle(&a, power);
            let resp = replay(&mut engine, &a, Plan::binary(power, false));
            assert!(
                resp.result.approx_eq(&want, 1e-3, 1e-3),
                "n={n} N={power}: max diff {}",
                resp.result.max_abs_diff(&want)
            );
            assert_eq!(resp.stats.h2d_transfers, 1, "device-resident uploads once");
            assert_eq!(resp.stats.d2h_transfers, 1);
        }
    }
}

#[test]
fn all_disciplines_agree_on_one_workload() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let n = 32;
    let power = 100;
    let a = Matrix::random_spectral(n, 0.97, 5);
    let want = cpu_oracle(&a, power);
    let check = |name: &str, got: &Matrix| {
        assert!(
            got.approx_eq(&want, 1e-3, 1e-3),
            "{name}: max diff {}",
            got.max_abs_diff(&want)
        );
    };
    check("binary", &replay(&mut engine, &a, Plan::binary(power, false)).result);
    check("fused", &replay(&mut engine, &a, Plan::binary(power, true)).result);
    check("chained", &replay(&mut engine, &a, Plan::chained(power, &[4, 2])).result);
    check("addition-chain", &replay(&mut engine, &a, Plan::addition_chain(power)).result);
    check("packed", &run_method(&mut engine, &a, power, Method::OursPacked).result);
    check("naive-roundtrip", &run_method(&mut engine, &a, power, Method::NaiveGpu).result);
    check(
        "plan-roundtrip",
        &engine
            .run(
                Submission::expm(a.clone(), power)
                    .method(Method::PlanRoundtrip)
                    .plan(Plan::binary(power, false)),
            )
            .expect("plan-roundtrip")
            .result,
    );
}

#[test]
fn every_cpu_algo_backend_agrees() {
    let n = 24;
    let power = 50;
    let a = Matrix::random_spectral(n, 0.95, 11);
    let want = cpu_oracle(&a, power);
    for algo in CpuAlgo::all() {
        let mut engine = Engine::cpu(algo);
        let resp = replay(&mut engine, &a, Plan::binary(power, false));
        assert!(
            resp.result.approx_eq(&want, 1e-3, 1e-3),
            "algo {}: max diff {}",
            algo.name(),
            resp.result.max_abs_diff(&want)
        );
    }
}

#[test]
fn fused_expm_ops_match_plans() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let n = 16;
    let a = Matrix::random_spectral(n, 0.98, 21);
    for power in FUSED_EXPM_POWERS {
        let fused = run_method(&mut engine, &a, power, Method::FusedArtifact);
        assert_eq!(fused.stats.launches, 1, "fused = single launch");
        let planned = replay(&mut engine, &a, Plan::binary(power, false));
        assert!(
            fused.result.approx_eq(&planned.result, 1e-2, 1e-2),
            "N={power}: max diff {}",
            fused.result.max_abs_diff(&planned.result)
        );
    }
    // non-shipped power errors like a missing artifact would
    assert!(engine
        .run(Submission::expm(a.clone(), 65).method(Method::FusedArtifact))
        .is_err());
}

#[test]
fn naive_roundtrip_transfer_accounting() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let a = Matrix::random_spectral(16, 0.9, 31);
    let resp = run_method(&mut engine, &a, 64, Method::NaiveGpu);
    assert_eq!(resp.stats.launches, 63);
    assert_eq!(resp.stats.multiplies, 63);
    assert_eq!(resp.stats.h2d_transfers, 2 * 63, "both operands re-uploaded per launch");
    assert_eq!(resp.stats.d2h_transfers, 63, "result downloaded per launch");
}

#[test]
fn launch_counts_match_plan_costs() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let a = Matrix::random_spectral(16, 0.9, 41);
    for power in [64u64, 100, 511, 1024] {
        let plan = Plan::binary(power, false);
        let (launches, multiplies) = (plan.launches(), plan.multiplies());
        let resp = replay(&mut engine, &a, plan);
        assert_eq!(resp.stats.launches, launches, "N={power}");
        assert_eq!(resp.stats.multiplies, multiplies, "N={power}");
    }
}

#[test]
fn identity_and_stochastic_invariants_hold_through_engine() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    // identity stays identity at any power
    let e = Matrix::identity(32);
    let p = replay(&mut engine, &e, Plan::binary(1024, false)).result;
    assert!(p.approx_eq(&e, 1e-5, 0.0));
    // stochastic rows keep summing to 1
    let s = Matrix::random_stochastic(32, 9);
    let p = run_method(&mut engine, &s, 512, Method::OursPacked).result;
    for i in 0..32 {
        let sum: f32 = p.row(i).iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "row {i}: {sum}");
    }
}

#[test]
fn power_zero_rejected_everywhere() {
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let a = Matrix::identity(8);
    assert!(engine.run(Submission::expm(a.clone(), 0).method(Method::NaiveGpu)).is_err());
    assert!(engine.run(Submission::expm(a, 0).method(Method::OursPacked)).is_err());
}

#[test]
fn sim_backend_numerics_match_cpu_and_times_follow_model() {
    let mut sim = Engine::sim();
    let a = Matrix::random_spectral(64, 0.95, 13);
    let power = 256;
    let want = cpu_oracle(&a, power);
    let ours = replay(&mut sim, &a, Plan::binary(power, false));
    assert!(ours.result.approx_eq(&want, 1e-3, 1e-3), "sim numerics diverge");
    let naive = run_method(&mut sim, &a, power, Method::NaiveGpu);
    // wall_s is SIMULATED 2012-testbed time: the paper's core claim must
    // hold by construction — device residency beats per-launch round-trips
    assert!(ours.stats.wall_s > 0.0);
    assert!(
        naive.stats.wall_s > ours.stats.wall_s * 5.0,
        "simulated naive {} must be far slower than ours {}",
        naive.stats.wall_s,
        ours.stats.wall_s
    );
    // and the simulated clock tracks launch counts: 255 launches vs 8
    assert_eq!(naive.stats.launches, 255);
    assert_eq!(ours.stats.launches, 8);
}

#[test]
fn cpu_and_sim_backends_agree_numerically() {
    let mut cpu = Engine::cpu(CpuAlgo::Blocked);
    let mut sim = Engine::sim();
    let a = Matrix::random_stochastic(24, 17);
    for power in [13u64, 100] {
        let c = replay(&mut cpu, &a, Plan::chained(power, &[4, 2])).result;
        let s = replay(&mut sim, &a, Plan::chained(power, &[4, 2])).result;
        assert!(c.approx_eq(&s, 1e-4, 1e-4), "N={power}: {}", c.max_abs_diff(&s));
    }
}

// ---------------------------------------------------------------------------
// PJRT variants: need `--features xla`, a real xla-rs link AND built
// artifacts; they skip (pass trivially) when `make artifacts` hasn't run.
// ---------------------------------------------------------------------------
#[cfg(feature = "xla")]
mod pjrt {
    use super::*;
    use matexp::config::default_artifacts_dir;
    use matexp::runtime::artifacts::ArtifactRegistry;
    use matexp::runtime::Variant;

    fn registry() -> Option<ArtifactRegistry> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return None;
        }
        Some(ArtifactRegistry::discover(&dir).expect("manifest parses"))
    }

    #[test]
    fn pjrt_binary_matches_cpu_across_sizes() {
        let Some(reg) = registry() else { return };
        let mut engine = Engine::pjrt(&reg, Variant::Xla).unwrap();
        for n in [4usize, 16, 64] {
            let a = Matrix::random_spectral(n, 0.95, n as u64);
            for power in [1u64, 2, 13, 100] {
                let want = cpu_oracle(&a, power);
                let resp = replay(&mut engine, &a, Plan::binary(power, false));
                assert!(resp.result.approx_eq(&want, 1e-3, 1e-3), "n={n} N={power}");
            }
        }
    }

    #[test]
    fn pallas_variant_matches_xla_variant() {
        let Some(reg) = registry() else { return };
        let mut xla_e = Engine::pjrt(&reg, Variant::Xla).unwrap();
        let mut pal_e = Engine::pjrt(&reg, Variant::Pallas).unwrap();
        let n = 64;
        let a = Matrix::random_spectral(n, 0.95, 11);
        let b = Matrix::random_spectral(n, 0.95, 12);
        let (mx, _) = xla_e.matmul(&a, &b).unwrap();
        let (mp, _) = pal_e.matmul(&a, &b).unwrap();
        assert!(mx.approx_eq(&mp, 1e-4, 1e-4), "variants diverge: {}", mx.max_abs_diff(&mp));
    }

    #[test]
    fn pjrt_sqmul_split_costs_the_tuple_roundtrip() {
        let Some(reg) = registry() else { return };
        let mut engine = Engine::pjrt(&reg, Variant::Xla).unwrap();
        let a = Matrix::random_spectral(16, 0.9, 3);
        // 11 = 0b1011 → fused binary plan contains SqMul steps
        let resp = replay(&mut engine, &a, Plan::binary(11, true));
        assert!(resp.stats.h2d_transfers > 1, "PJRT pays for tuple splits: {:?}", resp.stats);
    }
}
