//! Regression: [`MatexpClient`] auto-reconnect against a scripted fake
//! server — kill the connection and the client redials and carries on,
//! tickets from before the break fail with the typed "lost to a
//! reconnect" error instead of blocking forever, and when the listener
//! itself is gone the backoff schedule exhausts into a typed error.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::thread;

use matexp::coordinator::request::Method;
use matexp::error::MatexpError;
use matexp::linalg::matrix::Matrix;
use matexp::server::proto::{WireRequest, WireResponse};
use matexp::server::{MatexpClient, ReconnectPolicy};

/// Millisecond-scale backoff so the failure paths stay fast under test.
fn fast_policy() -> ReconnectPolicy {
    ReconnectPolicy { max_attempts: 4, base_ms: 1, max_ms: 4 }
}

/// Answer `count` JSON lines on `conn` (pong for pings, a typed error
/// for anything else), then hang up by returning.
fn serve_lines(conn: TcpStream, count: usize) {
    conn.set_nodelay(true).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    for _ in 0..count {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return, // client went away first
            Ok(_) => {}
        }
        let reply = match WireRequest::decode(line.trim_end()) {
            Ok(WireRequest::Ping) => WireResponse::pong(),
            _ => WireResponse::from_error(&MatexpError::Service(
                "fake server only answers pings".into(),
            )),
        };
        let encoded = reply.encode().unwrap();
        if writer.write_all(encoded.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            return;
        }
    }
}

#[test]
fn client_redials_after_the_server_hangs_up() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let (hung_up_tx, hung_up) = mpsc::channel();
    let server = thread::spawn(move || {
        // first connection: answer exactly one ping, then hang up
        let (conn, _) = listener.accept().unwrap();
        serve_lines(conn, 1);
        hung_up_tx.send(()).unwrap();
        // second connection: the redial — keep serving
        let (conn, _) = listener.accept().unwrap();
        serve_lines(conn, usize::MAX);
    });

    let mut client = MatexpClient::connect(&addr).unwrap().with_reconnect(fast_policy());
    client.ping().expect("first connection serves");
    hung_up.recv().unwrap();

    // the call that DISCOVERS the dead socket fails typed (the reply it
    // was owed died with the connection) ...
    match client.ping() {
        Err(MatexpError::Disconnected(_)) => {}
        other => panic!("expected Disconnected on the broken socket, got {other:?}"),
    }
    // ... and the next send redials transparently
    client.ping().expect("redial carries on");
    assert_eq!(client.reconnects(), 1, "exactly one reconnect");
    client.ping().expect("the redialed connection is stable");
    assert_eq!(client.reconnects(), 1, "no spurious redials once healthy");

    drop(client);
    server.join().unwrap();
}

#[test]
fn tickets_from_before_the_break_fail_typed_after_reconnect() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let (hung_up_tx, hung_up) = mpsc::channel();
    let server = thread::spawn(move || {
        // first connection: swallow the pipelined submit unanswered, then
        // hang up — the reply this ticket is owed will never exist
        let (conn, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(conn);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        drop(reader);
        hung_up_tx.send(()).unwrap();
        let (conn, _) = listener.accept().unwrap();
        serve_lines(conn, usize::MAX);
    });

    let mut client = MatexpClient::connect(&addr).unwrap().with_reconnect(fast_policy());
    let ticket = client.submit(&Matrix::identity(4), 8, Method::Ours).unwrap();
    hung_up.recv().unwrap();

    // drive the client over the break: one call discovers the dead
    // socket, the next one reconnects
    assert!(client.ping().is_err(), "the broken socket must surface");
    client.ping().expect("redial carries on");
    assert_eq!(client.reconnects(), 1);

    // the pre-break ticket is typed-lost, not silently re-paired with
    // replies from the new connection
    match client.wait(&ticket) {
        Err(MatexpError::Disconnected(msg)) => {
            assert!(msg.contains("lost to a reconnect"), "unexpected loss message: {msg}")
        }
        other => panic!("pre-break ticket must fail typed, got {other:?}"),
    }

    drop(client);
    server.join().unwrap();
}

#[test]
fn backoff_exhausts_into_a_typed_error_when_the_listener_is_gone() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = thread::spawn(move || {
        let (conn, _) = listener.accept().unwrap();
        serve_lines(conn, 1);
        // listener drops here: the port stops answering entirely
    });

    let mut client = MatexpClient::connect(&addr).unwrap().with_reconnect(fast_policy());
    client.ping().expect("first connection serves");
    server.join().unwrap();

    assert!(client.ping().is_err(), "the closed connection must surface");
    // every redial is refused; after max_attempts the client reports the
    // exhaustion as a typed error instead of retrying forever
    match client.ping() {
        Err(MatexpError::Disconnected(msg)) => {
            assert!(msg.contains("exhausted after 4 attempts"), "unexpected message: {msg}")
        }
        other => panic!("expected typed exhaustion, got {other:?}"),
    }
    assert_eq!(client.reconnects(), 0, "no dial ever succeeded");
}
