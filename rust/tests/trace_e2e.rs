//! Integration: the trace subsystem end to end — a TCP request leaves a
//! span trail in the flight recorder that covers the request's life
//! (wire decode, queue, planning, execution, wire encode), exports as a
//! valid Chrome trace-event document over the `trace` wire op, and the
//! per-request stage breakdown on the stats block stays inside the
//! client-observed end-to-end latency. A second test bounds the
//! recorder's overhead.
//!
//! The recorder is process-global (one ring, one enable flag), so every
//! test here serializes on [`common::test_guard`] — the overhead test flips the global
//! enable flag and would otherwise race the span-collection test.

use std::time::Instant;

use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::exec::{Executor, Submission};
use matexp::linalg::matrix::Matrix;
use matexp::server::client::MatexpClient;
use matexp::util::json::Json;

mod common;
use common::{start_server, test_guard};

/// Acceptance: one TCP request produces spans covering at least five
/// distinct stages, the `trace` wire op exports them as a valid Chrome
/// trace document, and the stats stage breakdown sums to no more than
/// the end-to-end latency the client actually observed.
#[test]
fn tcp_request_leaves_a_multi_stage_trace() {
    let _guard = test_guard();
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");

    // n=20 is unique to this test, so the request's events are
    // recognizable in the shared ring without access to its trace id
    let a = Matrix::random_spectral(20, 0.9, 41);
    let t0 = Instant::now();
    let (_result, stats) = client.expm(&a, 100, Method::Ours).expect("expm");
    let elapsed_us = t0.elapsed().as_micros() as u64;

    // stage breakdown: every stage fits inside the observed latency,
    // and so does their sum (stages are disjoint slices of the request)
    let stage_sum =
        stats.queue_us + stats.plan_us + stats.prepare_us + stats.launch_us + stats.wire_us;
    assert!(
        stage_sum <= elapsed_us,
        "stage sum {stage_sum}us exceeds end-to-end latency {elapsed_us}us: {stats:?}"
    );
    assert!(stage_sum > 0, "no stage measured a nonzero duration: {stats:?}");

    // pull the flight recorder over the wire and validate the document
    let doc = client.trace_dump().expect("trace op");
    let events = matexp::trace::chrome::validate(&doc).expect("valid Chrome trace");
    assert!(events > 0, "empty trace document");

    // find our request's root span by its unique n, then collect every
    // event that shares its tid (the trace id)
    let arr = doc.as_arr().expect("trace doc is an event array");
    let our_n = |e: &Json| e.get("args").and_then(|a| a.get("n")).and_then(Json::as_u64);
    let root = arr
        .iter()
        .find(|e| {
            e.get("name").and_then(Json::as_str) == Some("execute") && our_n(e) == Some(20)
        })
        .expect("execute root span for the n=20 request");
    let tid = root.get("tid").and_then(Json::as_u64).expect("root tid");
    assert_ne!(tid, 0, "request ran untraced");

    let mut stages: Vec<&str> = arr
        .iter()
        .filter(|e| e.get("tid").and_then(Json::as_u64) == Some(tid))
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    stages.sort_unstable();
    stages.dedup();
    assert!(
        stages.len() >= 5,
        "expected >=5 distinct stages for trace {tid}, got {stages:?}"
    );
    // the trail must reach both edges of the stack: the wire codec layer
    // and the executor
    assert!(stages.contains(&"wire_decode_json"), "{stages:?}");
    assert!(stages.contains(&"wire_encode_json"), "{stages:?}");
    assert!(stages.contains(&"queue"), "{stages:?}");
    assert!(stages.contains(&"execute"), "{stages:?}");
}

/// The recorder stays cheap enough to leave on: p50 latency with
/// tracing enabled is within a few percent of tracing disabled (plus an
/// absolute floor — at sub-millisecond p50 a few percent is below
/// scheduler noise). Debug builds get a relaxed bound; the release gate
/// is the one CI's release-test job enforces.
#[test]
fn tracing_overhead_is_bounded() {
    let _guard = test_guard();

    fn p50_us(cfg: MatexpConfig, seed_base: u64) -> f64 {
        let mut service = Service::start(cfg).expect("service starts");
        // distinct matrices per iteration so runs exercise the full
        // traced path instead of collapsing into result-cache hits
        let inputs: Vec<Matrix> =
            (0..50).map(|i| Matrix::random_spectral(32, 0.9, seed_base + i)).collect();
        for a in &inputs[..10] {
            service.run(Submission::expm(a.clone(), 64).method(Method::Ours)).expect("warmup");
        }
        let mut lat: Vec<f64> = inputs[10..]
            .iter()
            .map(|a| {
                let t0 = Instant::now();
                service.run(Submission::expm(a.clone(), 64).method(Method::Ours)).expect("run");
                t0.elapsed().as_secs_f64() * 1e6
            })
            .collect();
        lat.sort_by(|x, y| x.total_cmp(y));
        lat[lat.len() / 2]
    }

    let mut cfg_on = MatexpConfig::default();
    cfg_on.workers = 2;
    cfg_on.batcher.max_wait_ms = 1;
    let mut cfg_off = cfg_on.clone();
    cfg_off.trace.enabled = false;

    // Service::start configures the global recorder from cfg.trace, so
    // the two runs must be sequential: traced first, untraced second
    let on = p50_us(cfg_on, 1_000);
    let off = p50_us(cfg_off, 2_000);

    // leave the recorder on for whichever test runs next
    matexp::trace::set_enabled(true);

    let (factor, slack_us) = if cfg!(debug_assertions) { (1.5, 1_000.0) } else { (1.05, 200.0) };
    assert!(
        on <= off * factor + slack_us,
        "tracing overhead too high: p50 on={on:.1}us off={off:.1}us \
         (bound {factor}x + {slack_us}us)"
    );
}
