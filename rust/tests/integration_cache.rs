//! Acceptance tests for the multi-tier caching subsystem (ISSUE 5):
//!
//! 1. **A6 speedups, asserted** — plan-warm setup ≥ 1.2× faster than
//!    cold at n=1024 (measured, execution elided), and result-warm
//!    serving ≥ 10× faster than cold (measured end-to-end on a real
//!    engine; plus the modeled-cold comparison at n=1024 with a
//!    debug-profile-relaxed floor).
//! 2. **Correctness** — warm-path results are BIT-identical to cold-path
//!    results across all three config-driven executors; the result cache
//!    never serves across differing tolerance buckets; bypass/refresh do
//!    what they say.
//! 3. **Eviction** — the byte budget holds under proptest-random
//!    insert/get sequences, checked against an exact LRU model.
//! 4. **Observability** — hit/miss/eviction counters ride the service
//!    metrics (and thus the wire's `metrics` JSON).

use matexp::cache::{CacheControl, ResultCache, ResultKey};
use matexp::config::MatexpConfig;
use matexp::coordinator::request::{ExpmResponse, Method};
use matexp::coordinator::service::Service;
use matexp::coordinator::worker;
use matexp::error::Result;
use matexp::exec::{Executor, Submission};
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::pool::{PoolDeviceKind, PoolEngine};
use matexp::runtime::BackendKind;
use matexp::util::prop::property;
use matexp::experiments::ablations;

/// A config with result caching enabled (the default budget, so parallel
/// tests never evict each other's distinctly-keyed entries).
fn caching_cfg() -> MatexpConfig {
    let mut cfg = MatexpConfig::default();
    cfg.cache.results = true;
    cfg.cpu_algo = CpuAlgo::Ikj;
    cfg.batcher.max_wait_ms = 1;
    cfg
}

// ---------------------------------------------------------------------------
// A6 acceptance: the speedup floors
// ---------------------------------------------------------------------------

/// Plan-warm ≥ 1.2× faster than cold at n=1024 — measured on the setup
/// path (planner + prepare, execution elided; the execution itself is
/// identical in both arms by construction).
#[test]
fn a6_plan_warm_setup_beats_cold_at_n1024() {
    let arms = ablations::cache_setup_arms(1024, 1024, 3000);
    let (cold, warm) = (&arms[0], &arms[1]);
    let speedup = cold.wall_s / warm.wall_s.max(f64::MIN_POSITIVE);
    assert!(
        speedup >= 1.2,
        "plan-warm setup must be >= 1.2x faster than cold at n=1024: {speedup:.2}x \
         (cold {:.6}s vs warm {:.6}s over 3000 requests)",
        cold.wall_s,
        warm.wall_s
    );
}

/// Result-warm ≥ 10× faster than cold, measured end-to-end on a real
/// engine (cold = fresh engine + CacheControl::Bypass; warm = second
/// identical request served from the cache). n=96/power=512 keeps the
/// cold run debug-feasible; the ratio only grows with n (O(n³·log N)
/// execution avoided vs O(n²) digest + copy paid).
#[test]
fn a6_result_warm_serves_10x_faster_measured() {
    let cfg = caching_cfg();
    let arms = ablations::cache_engine_arms(&cfg, 96, 512).unwrap();
    let get = |name: &str| arms.iter().find(|a| a.name == name).unwrap();
    let (cold, warm) = (get("cold"), get("result-warm"));
    assert_eq!(warm.launches, 0, "warm serve must not touch a device");
    let speedup = cold.wall_s / warm.wall_s.max(f64::MIN_POSITIVE);
    assert!(
        speedup >= 10.0,
        "result-warm must be >= 10x faster than cold: {speedup:.1}x \
         (cold {:.6}s vs warm {:.6}s)",
        cold.wall_s,
        warm.wall_s
    );
}

/// The n=1024 result-tier arms: measured warm serve vs the modeled
/// calibrated-C2050 cold execution (the repro's yardstick for 2012
/// device time). Release builds assert the full 10× criterion; debug
/// builds relax the floor (the 4 MiB content digest is ~10× slower
/// unoptimized while the modeled cold side is constant) — the release
/// tier-1 CI job enforces the real floor.
#[test]
fn a6_result_tier_modeled_cold_vs_measured_warm_at_n1024() {
    let arms = ablations::cache_result_arms(1024, 1024, 42);
    let (cold, warm) = (&arms[0], &arms[1]);
    let speedup = cold.wall_s / warm.wall_s.max(f64::MIN_POSITIVE);
    let floor = if cfg!(debug_assertions) { 2.0 } else { 10.0 };
    assert!(
        speedup >= floor,
        "result-warm serving must be >= {floor}x faster than the modeled cold \
         execution at n=1024: {speedup:.1}x (cold {:.6}s vs warm {:.6}s)",
        cold.wall_s,
        warm.wall_s
    );
}

// ---------------------------------------------------------------------------
// Correctness: bit-identical warm paths, tolerance-bucket isolation
// ---------------------------------------------------------------------------

/// The same submission served twice through every config-driven executor:
/// the second (warm) response is BIT-identical to the first (cold) one
/// and performed zero launches.
#[test]
fn warm_results_bit_identical_across_all_three_executors() {
    let cfg = caching_cfg();

    let mut pool_cfg = caching_cfg();
    pool_cfg.backend = BackendKind::Pool;
    pool_cfg.pool.devices = vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu];

    let mut service_cfg = caching_cfg();
    service_cfg.workers = 2;

    // distinct seeds per executor: each runs its own cold pass even
    // though the three share the process-wide cache
    let run_twice =
        |executor: &mut dyn Executor, seed: u64| -> (ExpmResponse, ExpmResponse, Matrix) {
            let a = Matrix::random_spectral(24, 0.95, seed);
            let want = linalg::expm::expm(&a, 100, CpuAlgo::Ikj).expect("oracle");
            let cold = executor.run(Submission::expm(a.clone(), 100)).expect("cold run");
            let warm = executor.run(Submission::expm(a, 100)).expect("warm run");
            (cold, warm, want)
        };

    let mut engine = worker::build_worker_engine(&cfg, None).expect("engine");
    let mut pool = PoolEngine::from_config(&pool_cfg).expect("pool");
    let mut service = Service::start(service_cfg).expect("service");
    let executors: [(&str, &mut dyn Executor, u64); 3] = [
        ("engine", &mut engine, 1001),
        ("pool", &mut pool, 1002),
        ("service", &mut service, 1003),
    ];
    for (name, executor, seed) in executors {
        let (cold, warm, want) = run_twice(executor, seed);
        assert!(cold.stats.launches > 0, "{name}: cold run must execute");
        assert_eq!(warm.stats.launches, 0, "{name}: warm run must be served from cache");
        assert_eq!(warm.stats.multiplies, 0, "{name}");
        assert_eq!(
            warm.result, cold.result,
            "{name}: warm result must be bit-identical to the cold one"
        );
        assert_eq!(warm.plan_kind, cold.plan_kind, "{name}: plan_kind echoed");
        // and the cached answer is right, not just self-consistent
        assert!(
            cold.result.approx_eq(&want, 1e-3, 1e-3),
            "{name}: cold result diverges from the oracle by {}",
            cold.result.max_abs_diff(&want)
        );
    }
}

/// The result cache never serves across differing tolerance buckets: a
/// request with a different order-of-magnitude tolerance re-executes.
#[test]
fn result_cache_never_serves_across_tolerance_buckets() {
    let cfg = caching_cfg();
    let mut engine = worker::build_worker_engine(&cfg, None).expect("engine");
    let a = Matrix::random_spectral(16, 0.9, 2001);
    let run = |engine: &mut worker::WorkerEngine, tol: Option<f32>| -> Result<ExpmResponse> {
        let mut sub = Submission::expm(a.clone(), 64);
        if let Some(t) = tol {
            sub = sub.tolerance(t);
        }
        engine.run(sub)
    };
    // cold at tolerance 1e-3, warm at the same bucket (2e-3 is the same
    // decade)
    assert!(run(&mut engine, Some(1e-3)).unwrap().stats.launches > 0);
    assert_eq!(run(&mut engine, Some(2e-3)).unwrap().stats.launches, 0, "same bucket serves");
    // a different decade is a different bucket: must re-execute
    assert!(
        run(&mut engine, Some(1e-5)).unwrap().stats.launches > 0,
        "tighter tolerance bucket must not be served from the looser one"
    );
    // and no-tolerance is its own bucket
    assert!(run(&mut engine, None).unwrap().stats.launches > 0);
    assert_eq!(run(&mut engine, None).unwrap().stats.launches, 0);
}

/// Bypass never reads or writes; Refresh re-executes and overwrites.
#[test]
fn bypass_and_refresh_semantics_through_the_surface() {
    let cfg = caching_cfg();
    let mut engine = worker::build_worker_engine(&cfg, None).expect("engine");
    let a = Matrix::random_spectral(16, 0.9, 3001);
    let sub = |ctl: CacheControl| Submission::expm(a.clone(), 64).cache(ctl);

    // two bypass runs: both execute, nothing stored
    assert!(engine.run(sub(CacheControl::Bypass)).unwrap().stats.launches > 0);
    assert!(engine.run(sub(CacheControl::Bypass)).unwrap().stats.launches > 0);
    // Use after bypass-only traffic: still cold (bypass stored nothing)
    assert!(engine.run(sub(CacheControl::Use)).unwrap().stats.launches > 0);
    assert_eq!(engine.run(sub(CacheControl::Use)).unwrap().stats.launches, 0);
    // Refresh re-executes even though a warm entry exists…
    assert!(engine.run(sub(CacheControl::Refresh)).unwrap().stats.launches > 0);
    // …and leaves a servable (overwritten) entry behind
    assert_eq!(engine.run(sub(CacheControl::Use)).unwrap().stats.launches, 0);
}

/// An explicit plan override opts out of the result tier entirely: the
/// pinned replay always executes, and is never served to others.
#[test]
fn plan_overrides_never_touch_the_result_cache() {
    use matexp::plan::Plan;
    let cfg = caching_cfg();
    let mut engine = worker::build_worker_engine(&cfg, None).expect("engine");
    let a = Matrix::random_spectral(16, 0.9, 4001);
    for _ in 0..2 {
        let resp = engine
            .run(Submission::expm(a.clone(), 64).plan(Plan::binary(64, false)))
            .unwrap();
        assert!(resp.stats.launches > 0, "pinned-plan runs always execute");
    }
}

// ---------------------------------------------------------------------------
// Eviction: byte budget under random traffic, vs an exact LRU model
// ---------------------------------------------------------------------------

/// Byte-budget eviction under proptest-random insert/get sequences: the
/// cache's live set and byte total match an exact LRU model at every
/// step, and the budget is never exceeded.
#[test]
fn eviction_respects_byte_budget_under_random_traffic() {
    property("result cache == LRU model", 60, |g| {
        // tiny matrices so entry bytes (n²·4) vary: n in 2..6 → 16..100 B
        let budget = g.u64(32, 512);
        let cache = ResultCache::new(budget);
        // model: (key-seed, bytes, last-used tick), most fields mirrored
        let mut model: Vec<(u64, u64, u64)> = Vec::new();
        let mut tick = 0u64;
        let keyed = |seed: u64| {
            let n = 2 + (seed % 5) as usize; // deterministic size per seed
            let m = Matrix::random(n, seed);
            (ResultKey::for_parts(&m, 8, Method::Ours, None), m)
        };
        for _ in 0..g.usize(1, 60) {
            let seed = g.u64(1, 12);
            let (key, m) = keyed(seed);
            let bytes = (m.n() * m.n() * 4) as u64;
            tick += 1;
            if g.bool() {
                // insert
                cache.insert(key, &m, Method::Ours, None);
                if bytes <= budget {
                    model.retain(|&(s, _, _)| s != seed);
                    model.push((seed, bytes, tick));
                    // evict LRU until the budget holds
                    while model.iter().map(|&(_, b, _)| b).sum::<u64>() > budget {
                        let oldest = model
                            .iter()
                            .enumerate()
                            .min_by_key(|(_, &(_, _, t))| t)
                            .map(|(i, _)| i)
                            .unwrap();
                        model.remove(oldest);
                    }
                }
            } else {
                // get refreshes recency on a hit
                let hit = cache.get(&key);
                let modeled = model.iter_mut().find(|e| e.0 == seed);
                match (&hit, &modeled) {
                    (Some(_), Some(_)) | (None, None) => {}
                    other => panic!("cache/model diverge for seed {seed}: {other:?}"),
                }
                if let Some(entry) = modeled {
                    entry.2 = tick;
                }
                if let Some(h) = hit {
                    assert_eq!(h.result, m, "served payload is bit-identical");
                }
            }
            // invariants after every operation
            let model_bytes: u64 = model.iter().map(|&(_, b, _)| b).sum();
            assert_eq!(cache.bytes(), model_bytes, "byte accounting mirrors the model");
            assert_eq!(cache.len(), model.len(), "entry count mirrors the model");
            assert!(cache.bytes() <= budget, "budget never exceeded");
        }
    });
}

// ---------------------------------------------------------------------------
// Observability: counters on the service metrics path
// ---------------------------------------------------------------------------

/// Cache counters are visible in the service metrics snapshot and its
/// JSON (the same object the TCP `metrics` endpoint ships).
#[test]
fn cache_counters_visible_in_service_metrics() {
    let mut cfg = caching_cfg();
    cfg.workers = 1;
    let service = Service::start(cfg).expect("service");
    let a = Matrix::random_spectral(16, 0.9, 5001);
    let before = service.metrics().cache.clone();
    for _ in 0..2 {
        service
            .submit_job(Submission::expm(a.clone(), 32))
            .expect("submit")
            .wait()
            .expect("served");
    }
    let after = service.metrics().cache.clone();
    assert!(
        after.result_hits > before.result_hits,
        "the second identical request must count a result hit: {before:?} -> {after:?}"
    );
    assert!(after.result_inserts > before.result_inserts);
    assert!(after.plan_hits + after.plan_misses > 0);
    let j = service.metrics().to_json().to_string();
    for field in ["result_hits", "result_misses", "result_evictions", "plan_hits", "prepared_hits"]
    {
        assert!(j.contains(field), "{field} missing from metrics json: {j}");
    }
}
