//! Integration: the heterogeneous device pool end-to-end — coordinator
//! service on `--backend pool`, admission control, TCP metrics with
//! per-device utilization, and the pool scaling experiment's acceptance
//! criteria. Runs unconditionally (cpu + sim devices need no hardware).

use std::sync::Arc;

use matexp::exec::Submission;

use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::error::MatexpError;
use matexp::experiments::scaling::{self, run_pool_scaling};
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::pool::{PoolDeviceKind, PoolEngine};
use matexp::runtime::BackendKind;
use matexp::server::client::MatexpClient;
use matexp::server::server::serve_background;
use matexp::util::json::Json;

fn pool_cfg(devices: Vec<PoolDeviceKind>) -> MatexpConfig {
    let mut cfg = MatexpConfig::default();
    cfg.backend = BackendKind::Pool;
    cfg.pool.devices = devices;
    cfg.workers = 2;
    cfg.batcher.max_wait_ms = 1;
    cfg
}

#[test]
fn pool_service_serves_correct_results_with_device_breakdowns() {
    let service =
        Service::start(pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu])).unwrap();
    for seed in 1..=6u64 {
        let a = Matrix::random_spectral(16, 0.9, seed);
        let want = linalg::expm::expm(&a, 50, CpuAlgo::Ikj).unwrap();
        let resp = service
            .submit_job(Submission::expm(a, 50).method(Method::Ours))
            .unwrap()
            .wait()
            .unwrap();
        assert!(
            resp.result.approx_eq(&want, 1e-3, 1e-3),
            "seed {seed}: diff {}",
            resp.result.max_abs_diff(&want)
        );
        assert_eq!(resp.stats.per_device.len(), 1, "{:?}", resp.stats.per_device);
        assert_eq!(resp.stats.per_device[0].launches, resp.stats.launches);
    }
    let m = service.metrics();
    assert_eq!(m.responses_total, 6);
    assert_eq!(m.devices.len(), 2, "{:?}", m.devices);
    let jobs: u64 = m.devices.iter().map(|d| d.jobs).sum();
    assert!(jobs >= 6, "{:?}", m.devices);
    service.shutdown();
}

#[test]
fn admission_enforces_max_n_with_typed_error() {
    let mut cfg = pool_cfg(vec![PoolDeviceKind::Cpu]);
    cfg.max_n = 32;
    let service = Service::start(cfg).unwrap();
    // at the limit: fine
    service
        .submit_job(Submission::expm(Matrix::identity(32), 2))
        .unwrap()
        .wait()
        .unwrap();
    // over it: the typed admission rejection (surfaces at submit),
    // counted in metrics
    let err = service.submit_job(Submission::expm(Matrix::identity(33), 2)).unwrap_err();
    assert!(matches!(err, MatexpError::Admission(_)), "{err:?}");
    assert!(err.to_string().contains("max_n"), "{err}");
    assert_eq!(service.metrics().rejected_total, 1);
    service.shutdown();
}

#[test]
fn tcp_metrics_report_pool_observability() {
    let service = Arc::new(
        Service::start(pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu])).unwrap(),
    );
    let server = serve_background(Arc::clone(&service), "127.0.0.1:0", 4).unwrap();
    let mut client = MatexpClient::connect(&server.local_addr().to_string()).unwrap();
    let a = Matrix::random_spectral(12, 0.9, 3);
    let want = linalg::expm::expm(&a, 64, CpuAlgo::Ikj).unwrap();
    let (got, _) = client.expm(&a, 64, Method::Ours).unwrap();
    assert!(got.approx_eq(&want, 1e-3, 1e-3));
    let m = client.metrics().unwrap();
    let devices = m.get("devices").and_then(Json::as_arr).expect("devices array");
    assert_eq!(devices.len(), 2, "{m}");
    for d in devices {
        assert!(d.get("name").and_then(Json::as_str).is_some(), "{d}");
        assert!(d.get("queue_depth").is_some(), "{d}");
        assert!(d.get("steals").is_some(), "{d}");
    }
    assert!(m.get("steals_total").is_some(), "{m}");
    assert!(m.get("queue_depth").is_some(), "{m}");
}

#[test]
fn tcp_admission_errors_are_typed() {
    let mut cfg = pool_cfg(vec![PoolDeviceKind::Cpu]);
    cfg.max_n = 16;
    let service = Arc::new(Service::start(cfg).unwrap());
    let server = serve_background(Arc::clone(&service), "127.0.0.1:0", 2).unwrap();
    let mut client = MatexpClient::connect(&server.local_addr().to_string()).unwrap();
    // the typed admission rejection survives the wire roundtrip
    let err = client.expm(&Matrix::identity(17), 2, Method::Ours).unwrap_err();
    assert!(matches!(err, MatexpError::Admission(_)), "{err:?}");
    // an in-limit request still works on the same connection
    let (got, _) = client.expm(&Matrix::identity(16), 2, Method::Ours).unwrap();
    assert!(got.approx_eq(&Matrix::identity(16), 1e-5, 1e-5));
}

#[test]
fn hetero_cpu_sim_pool_agrees_with_both_members() {
    // cpu + sim devices in ONE pool: results must agree with the
    // single-device oracle no matter which member serves which request
    let cfg = pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Sim]);
    let engine = PoolEngine::from_config(&cfg).unwrap();
    let reqs: Vec<matexp::coordinator::request::ExpmRequest> = (0..8)
        .map(|i| {
            matexp::coordinator::request::ExpmRequest::new(
                i + 1,
                Matrix::random_spectral(24, 0.9, i + 10),
                100,
                Method::Ours,
            )
        })
        .collect();
    let oracles: Vec<Matrix> = reqs
        .iter()
        .map(|r| linalg::expm::expm(&r.matrix, 100, CpuAlgo::Ikj).unwrap())
        .collect();
    let mut replies = engine.execute_batch(reqs);
    replies.sort_by_key(|(id, _)| *id);
    for (i, (_, outcome)) in replies.into_iter().enumerate() {
        let resp = outcome.unwrap();
        assert!(
            resp.result.approx_eq(&oracles[i], 1e-3, 1e-3),
            "request {i} diverged by {}",
            resp.result.max_abs_diff(&oracles[i])
        );
    }
}

#[test]
fn scaling_experiment_acceptance_criteria() {
    let cfg = MatexpConfig::default();
    // 4-sim pool >= 1.7x over a single SimBackend on the Table-4 workload
    // at 1024x1024 (predicted on the exact models the sim clock runs on)
    let arms = vec![vec![PoolDeviceKind::Sim; 4]];
    let t = run_pool_scaling(&cfg, 1024, &arms, false).unwrap();
    assert!(t.speedup_pred(0) >= 1.7, "only {:.2}x", t.speedup_pred(0));

    // heterogeneous cpu+sim split never underperforms the faster member
    // by more than 10% — measured, at a debug-friendly size
    let arms = vec![vec![PoolDeviceKind::Cpu, PoolDeviceKind::Sim]];
    let t = run_pool_scaling(&cfg, 128, &arms, true).unwrap();
    let pool_wall = t.arms[0].measured_s.unwrap();
    let sim_alone = t.baseline_measured_s.unwrap();
    assert!(
        pool_wall <= sim_alone * 1.10,
        "hetero pool {pool_wall} vs sim alone {sim_alone}"
    );
}

#[test]
fn scaling_table_renders_all_arms() {
    let cfg = MatexpConfig::default();
    let arms: Vec<Vec<PoolDeviceKind>> = scaling::default_scaling_arms()
        .into_iter()
        .filter(|a| a.iter().all(|d| *d == PoolDeviceKind::Sim))
        .collect();
    let t = run_pool_scaling(&cfg, 1024, &arms, false).unwrap();
    let rendered = scaling::render_scaling(&t);
    assert!(rendered.contains("single sim (baseline)"), "{rendered}");
    assert!(rendered.contains("pool 4x sim"), "{rendered}");
    assert!(rendered.contains("pool 8x sim"), "{rendered}");
}
