//! Shared integration-test harness, included by the suites as
//! `mod common;`.
//!
//! Three things the suites used to each duplicate live here once:
//!
//! * [`test_guard`] — a process-global lock. The crate holds global
//!   state (the flight recorder's ring and enable flag, the cache
//!   tiers, the artifact store slot and its counters), so any test that
//!   reconfigures or asserts on that state must serialize against every
//!   other such test **across suites is impossible** (separate test
//!   binaries are separate processes) but within a suite this guard is
//!   the one lock to take. Poisoning is forgiven: an earlier panicked
//!   test must not cascade.
//! * [`start_server`] — the standard two-worker service + TCP server
//!   on an OS-assigned port, returning the handle, the server (shut
//!   down on drop by the caller holding it) and its address.
//! * [`scratch_dir`] / [`free_port`] — a tempdir guard with scoped
//!   cleanup (the directory is removed when the guard drops, even on
//!   panic) and a port allocator for tests that need an address before
//!   anything is listening on it.

#![allow(dead_code)] // each suite uses the subset it needs

use std::net::TcpListener;
use std::sync::{Arc, Mutex, MutexGuard};

use matexp::config::MatexpConfig;
use matexp::coordinator::service::{Service, ServiceHandle};
use matexp::server::server::{serve_background, Server};
use matexp::util::tempdir::TempDir;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize this test against every other guard-holding test in the
/// same binary (shared process-global state: recorder, caches, store).
pub fn test_guard() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// A scoped scratch directory: unique, empty, and deleted (recursively)
/// when the returned guard goes out of scope — panicking tests included,
/// since cleanup rides `Drop`.
pub fn scratch_dir() -> TempDir {
    TempDir::new().expect("create scratch dir")
}

/// An OS-assigned free TCP port on localhost. The probe listener is
/// closed before returning, so the port is free at the moment of return
/// (a later bind can still race other processes — tests that can should
/// prefer binding to port 0 directly).
pub fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .expect("probe bind")
        .local_addr()
        .expect("probe addr")
        .port()
}

/// The standard integration fixture: a two-worker service with a fast
/// batcher behind a TCP server on an OS-assigned port. Drop the returned
/// [`Server`] to shut down.
pub fn start_server() -> (Arc<ServiceHandle>, Server, String) {
    start_server_with(MatexpConfig::default())
}

/// [`start_server`] with a caller-shaped config (workers and batcher
/// wait are still pinned to the fast-test values unless the caller set
/// them away from the defaults).
pub fn start_server_with(mut cfg: MatexpConfig) -> (Arc<ServiceHandle>, Server, String) {
    let defaults = MatexpConfig::default();
    if cfg.workers == defaults.workers {
        cfg.workers = 2;
    }
    if cfg.batcher.max_wait_ms == defaults.batcher.max_wait_ms {
        cfg.batcher.max_wait_ms = 1;
    }
    let service = Arc::new(Service::start(cfg).expect("service starts"));
    let server = serve_background(Arc::clone(&service), "127.0.0.1:0", 8).expect("binds");
    let addr = server.local_addr().to_string();
    (service, server, addr)
}
