//! Property-based parity for the raw-speed CPU kernel tier: the packed /
//! simd microkernels and the Strassen recursion against the naive oracle
//! at awkward sizes (odd n, non-multiples of the pack widths), the
//! Strassen *plan* against the binary plan through a real engine, and
//! determinism of the autotuner's selection logic.
//!
//! This runs as its own test binary, so the process-global autotuner
//! table it touches is isolated from the library's unit tests.

use matexp::exec::{Executor, Submission};
use matexp::linalg::matrix::Matrix;
use matexp::linalg::{autotune, naive, packed, strassen, CpuAlgo};
use matexp::plan::Plan;
use matexp::runtime::Engine;
use matexp::util::prop::property;

#[test]
fn packed_kernels_match_naive_at_awkward_sizes() {
    property("packed/simd parity vs naive", 48, |g| {
        // deliberately hits 1, odd sizes, and non-multiples of MR/NR
        let n = g.usize(1, 40);
        let seed = g.u64(0, u64::MAX / 2);
        let a = Matrix::random(n, seed);
        let b = Matrix::random(n, seed ^ 0xABCD);
        let want = naive::matmul_naive(&a, &b);
        let packed = packed::matmul_packed(&a, &b);
        assert!(
            packed.approx_eq(&want, 1e-4, 1e-4),
            "packed diverged at n={n}: {}",
            packed.max_abs_diff(&want)
        );
        let simd = packed::matmul_simd(&a, &b);
        assert!(
            simd.approx_eq(&want, 1e-4, 1e-4),
            "simd diverged at n={n}: {}",
            simd.max_abs_diff(&want)
        );
    });
}

#[test]
fn strassen_matches_naive_below_and_above_the_crossover() {
    property("strassen parity vs naive", 32, |g| {
        let n = g.usize(1, 32);
        let crossover = *g.choose(&[4usize, 8, 16]);
        let seed = g.u64(0, u64::MAX / 2);
        let a = Matrix::random(n, seed);
        let b = Matrix::random(n, seed ^ 0x5151);
        let want = naive::matmul_naive(&a, &b);
        let got = strassen::matmul_strassen_with(&a, &b, crossover);
        assert!(
            got.approx_eq(&want, 1e-4, 1e-4),
            "strassen diverged at n={n} crossover={crossover}: {}",
            got.max_abs_diff(&want)
        );
    });
}

#[test]
fn strassen_plan_matches_the_binary_plan_end_to_end() {
    property("strassen plan parity", 12, |g| {
        let mut engine = Engine::cpu(CpuAlgo::Blocked);
        let n = g.usize(3, 12);
        let power = g.u64(1, 24);
        let a = Matrix::random_spectral(n, 0.9, g.u64(0, u64::MAX / 2));
        let binary = engine
            .run(Submission::expm(a.clone(), power).plan(Plan::binary(power, false)))
            .expect("binary plan executes");
        let strassen_kind = engine
            .run(Submission::expm(a, power).plan(Plan::strassen(power)))
            .expect("strassen plan executes");
        assert!(
            strassen_kind.result.approx_eq(&binary.result, 1e-4, 1e-4),
            "plans diverged at n={n} N={power}: {}",
            strassen_kind.result.max_abs_diff(&binary.result)
        );
        assert_eq!(
            strassen_kind.stats.multiplies, binary.stats.multiplies,
            "the strassen plan keeps the binary schedule"
        );
    });
}

#[test]
fn autotuner_selection_is_deterministic() {
    property("select_winner determinism", 96, |g| {
        let algos = [
            CpuAlgo::Blocked,
            CpuAlgo::Ikj,
            CpuAlgo::Threaded,
            CpuAlgo::Packed,
            CpuAlgo::Simd,
            CpuAlgo::Strassen,
        ];
        let count = g.usize(0, algos.len() - 1);
        let measured: Vec<(CpuAlgo, f64)> = (0..=count)
            .map(|i| {
                // mix usable timings with unusable ones (zero / negative /
                // non-finite) the selector must skip
                let secs = match g.usize(0, 4) {
                    0 => f64::NAN,
                    1 => -1.0,
                    2 => 0.0,
                    _ => g.u64(1, 1_000_000) as f64 * 1e-9,
                };
                (algos[i], secs)
            })
            .collect();
        let first = autotune::select_winner(&measured);
        assert_eq!(first, autotune::select_winner(&measured), "same input, same winner");
        if let Some((_, secs)) = first {
            let best_usable = measured
                .iter()
                .map(|&(_, s)| s)
                .filter(|s| s.is_finite() && *s > 0.0)
                .fold(f64::INFINITY, f64::min);
            assert_eq!(secs, best_usable, "winner carries the fastest usable timing");
        } else {
            assert!(
                measured.iter().all(|&(_, s)| !s.is_finite() || s <= 0.0),
                "no winner only when nothing was usable: {measured:?}"
            );
        }
    });
}

#[test]
fn autotuner_table_is_deterministic_over_fixed_probe_data() {
    property("record determinism", 24, |g| {
        // unique-per-case odd sizes well away from any real probe sweep
        let n = 50_001 + 2 * g.usize(0, 499);
        let secs = g.u64(1, 1_000_000) as f64 * 1e-9;
        let measured = [
            (CpuAlgo::Blocked, secs * 3.0),
            (CpuAlgo::Packed, secs),
            (CpuAlgo::Strassen, secs * 2.0),
        ];
        let first = autotune::record(n, &measured).expect("usable timings yield a row");
        let second = autotune::record(n, &measured).expect("usable timings yield a row");
        assert_eq!(first, second, "same probe data, same table row");
        assert_eq!(first.winner, CpuAlgo::Packed);
        assert_eq!(autotune::best_for(n), CpuAlgo::Packed);
    });
}
