//! Property battery for the persistence tier's durability contract:
//!
//! * put/get roundtrips are **bit-exact** for arbitrary payloads, and
//!   for result artifacts holding every f32 bit pattern — NaNs, ±Inf,
//!   subnormals, -0.0 — through the result codec and both sinks;
//! * a committed entry truncated at **every** byte boundary is answered
//!   with the typed [`MatexpError::Store`] error, never wrong bits;
//! * a random bit flip anywhere in a committed entry file is likewise a
//!   typed store error, and the damage is isolated — the store keeps
//!   serving its other entries bit-identically.

use matexp::cache::ResultKey;
use matexp::coordinator::request::Method;
use matexp::error::MatexpError;
use matexp::linalg::matrix::Matrix;
use matexp::plan::PlanKind;
use matexp::store::codec::{decode_result, encode_result, result_store_key};
use matexp::store::{ArtifactKind, FsSink, MemorySink, Sink, StoreKey};
use matexp::util::prop::property;

mod common;
use common::scratch_dir;

/// The f32 bit patterns a textual codec would mangle; every matrix in
/// this suite gets a few of them on top of random bits.
const ADVERSARIAL_BITS: [u32; 7] = [
    0x7FC0_0001,        // quiet NaN with payload
    0xFFC0_0000,        // negative NaN
    0x7F80_0000,        // +Inf
    0xFF80_0000,        // -Inf
    0x0000_0001,        // smallest positive subnormal
    0x8000_0000,        // -0.0
    0x0070_0000,        // larger subnormal
];

fn key(lo: u64) -> StoreKey {
    StoreKey { kind: ArtifactKind::Result, hi: 0xA5A5, lo }
}

fn assert_bits_eq(a: &Matrix, b: &Matrix) {
    assert_eq!(a.n(), b.n());
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "element {i}: {x} vs {y}");
    }
}

/// Arbitrary byte payloads roundtrip bit-for-bit through both sinks,
/// survive an FsSink reopen, and replacement takes the last write.
#[test]
fn prop_raw_payloads_roundtrip_through_both_sinks() {
    let dir = scratch_dir();
    let fs = FsSink::open(dir.path()).expect("open");
    let mem = MemorySink::new();
    property("raw payload roundtrip", 64, |g| {
        let len = g.usize(0, 512);
        let payload: Vec<u8> = (0..len).map(|_| g.u64(0, 255) as u8).collect();
        let k = key(g.u64(0, u64::MAX));
        for sink in [&fs as &dyn Sink, &mem as &dyn Sink] {
            sink.put(k, &payload).expect("put");
            assert_eq!(sink.get(&k).expect("get").as_deref(), Some(&payload[..]));
        }
    });
    // everything the property committed is still there after a reopen
    let reopened = FsSink::open(dir.path()).expect("reopen");
    assert_eq!(reopened.len(), fs.len());
    for k in fs.keys() {
        assert_eq!(reopened.get(&k).expect("get"), fs.get(&k).expect("get"));
    }
}

/// Result artifacts carrying every hostile f32 bit pattern roundtrip
/// bit-exactly through the codec and the on-disk sink.
#[test]
fn prop_result_artifacts_are_bit_exact_for_all_f32_patterns() {
    let dir = scratch_dir();
    let fs = FsSink::open(dir.path()).expect("open");
    property("result artifact roundtrip", 48, |g| {
        let n = g.usize(1, 8);
        let mut data: Vec<f32> =
            (0..n * n).map(|_| f32::from_bits(g.u64(0, u32::MAX as u64) as u32)).collect();
        // plant adversarial patterns at random positions
        for &bits in &ADVERSARIAL_BITS {
            let at = g.usize(0, n * n - 1);
            data[at] = f32::from_bits(bits);
        }
        let matrix = Matrix::from_vec(n, data).expect("square");
        let rkey = ResultKey::for_parts(&matrix, g.u64(1, 1 << 40), Method::Ours, None);
        const KINDS: [PlanKind; 6] = [
            PlanKind::Naive,
            PlanKind::Binary,
            PlanKind::BinaryFused,
            PlanKind::Chained,
            PlanKind::AdditionChain,
            PlanKind::Strassen,
        ];
        let plan_kind = if g.bool() { Some(*g.choose(&KINDS)) } else { None };
        let payload = encode_result(&rkey, &matrix, Method::Ours, plan_kind);

        let skey = result_store_key(&rkey);
        fs.put(skey, &payload).expect("put");
        let back = fs.get(&skey).expect("get").expect("present");
        assert_eq!(back, payload, "sink must return the committed bytes");

        let (dkey, cached) = decode_result(&back).expect("decode");
        assert_eq!(dkey, rkey, "embedded key survives");
        assert_eq!(cached.plan_kind, plan_kind);
        assert_bits_eq(&cached.result, &matrix);
    });
}

/// A committed entry truncated at EVERY byte boundary — mid-magic,
/// mid-header, mid-payload, one byte short — answers the typed store
/// error, and the undamaged sibling entry keeps serving bit-exactly.
#[test]
fn every_truncation_boundary_is_a_typed_store_miss() {
    let dir = scratch_dir();
    let fs = FsSink::open(dir.path()).expect("open");
    let victim = key(1);
    let sibling = key(2);
    let sibling_payload = b"the sibling entry must keep serving".to_vec();
    fs.put(victim, b"victim payload: 0123456789abcdef").expect("put victim");
    fs.put(sibling, &sibling_payload).expect("put sibling");

    let path = fs.entry_path(&victim);
    let full = std::fs::read(&path).expect("read entry file");
    for cut in 0..full.len() {
        std::fs::write(&path, &full[..cut]).expect("truncate");
        match fs.get(&victim) {
            Err(MatexpError::Store(_)) => {}
            other => panic!("truncation at byte {cut}/{} must be a typed store error, got {other:?}", full.len()),
        }
        // damage is isolated: the sibling still serves its exact bytes
        assert_eq!(
            fs.get(&sibling).expect("sibling get").as_deref(),
            Some(&sibling_payload[..]),
            "sibling lost after truncating victim at byte {cut}"
        );
        // restore for the next boundary
        std::fs::write(&path, &full).expect("restore");
    }
    // fully restored, the victim serves again — corruption was in the
    // file, not in any state the sink accumulated
    assert_eq!(fs.get(&victim).expect("restored get").as_deref(), Some(&full[40..]));
}

/// Any single bit flip anywhere in a committed entry file (magic,
/// header fields, checksum, payload) is detected and answered as the
/// typed store error — never as wrong bits — while other entries keep
/// serving. A reopen of the damaged directory then quarantines the torn
/// entry and keeps the healthy ones.
#[test]
fn prop_random_bit_flips_are_detected_never_served() {
    let dir = scratch_dir();
    let fs = FsSink::open(dir.path()).expect("open");
    let healthy = key(7777);
    let healthy_payload = b"healthy entry".to_vec();
    fs.put(healthy, &healthy_payload).expect("put healthy");

    property("bit flips detected", 64, |g| {
        let victim = key(g.u64(0, u64::MAX - 1));
        if victim == healthy {
            return;
        }
        let len = g.usize(1, 256);
        let payload: Vec<u8> = (0..len).map(|_| g.u64(0, 255) as u8).collect();
        fs.put(victim, &payload).expect("put");

        let path = fs.entry_path(&victim);
        let mut file = std::fs::read(&path).expect("read");
        let byte = g.usize(0, file.len() - 1);
        let bit = g.usize(0, 7);
        file[byte] ^= 1 << bit;
        std::fs::write(&path, &file).expect("flip");

        match fs.get(&victim) {
            Err(MatexpError::Store(_)) => {}
            Ok(Some(served)) => panic!(
                "flip of bit {bit} in byte {byte} was served: {} bytes back",
                served.len()
            ),
            other => panic!("expected typed store error, got {other:?}"),
        }
        assert_eq!(
            fs.get(&healthy).expect("healthy get").as_deref(),
            Some(&healthy_payload[..]),
            "healthy entry lost after flipping bit {bit} of byte {byte}"
        );
        fs.delete(&victim).expect("delete victim");
    });

    // the survivor outlives a reopen of the (previously damaged) dir
    let reopened = FsSink::open(dir.path()).expect("reopen");
    assert_eq!(reopened.get(&healthy).expect("get").as_deref(), Some(&healthy_payload[..]));
}
