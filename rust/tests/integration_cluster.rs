//! Integration: the cluster tier end-to-end on real sockets — a
//! [`matexp::cluster::Cluster`] of three member servers behind the
//! content-affinity router. Covers the acceptance bar for the tier:
//! repeated digests concentrate on their rendezvous owners (≥90%
//! affinity), routed results are bit-identical to a single server's,
//! killing a member loses no subsequent requests, saturation sheds with
//! the typed `Admission` error, and drain + runtime join/leave work over
//! the `cluster` wire op.

use std::sync::{Arc, Barrier};
use std::thread;

use matexp::cache::CacheControl;
use matexp::cluster::Cluster;
use matexp::config::{ClusterSettings, MatexpConfig};
use matexp::coordinator::request::Method;
use matexp::error::MatexpError;
use matexp::linalg::matrix::Matrix;
use matexp::server::{ClusterAction, MatexpClient};
use matexp::util::json::Json;

mod common;
use common::{start_server, start_server_with};

/// A deterministic, numerically tame workload matrix (spectral radius
/// well under 1, so high powers stay finite).
fn hot_matrix(n: usize, seed: u64) -> Matrix {
    Matrix::random_spectral(n, 0.6, seed)
}

/// Sum of a status row counter across every member in a router status
/// document.
fn sum_member_counter(status: &Json, field: &str) -> u64 {
    status
        .get("members")
        .and_then(Json::as_arr)
        .map(|rows| rows.iter().filter_map(|r| r.get(field).and_then(Json::as_u64)).sum())
        .unwrap_or(0)
}

#[test]
fn repeated_digests_concentrate_with_affinity_and_match_single_server() {
    let cluster = Cluster::spawn_local(3).expect("cluster spawns");
    let mut client = MatexpClient::connect(&cluster.router_addr()).expect("connect router");
    assert!(client.negotiate_binary().expect("hello roundtrips"), "router must ack frames");

    // two hot matrices, each repeated many times through the router
    let hot = [hot_matrix(32, 11), hot_matrix(32, 22)];
    let mut routed: Vec<Matrix> = Vec::new();
    for round in 0..15 {
        for m in &hot {
            let (result, _) = client.expm(m, 64, Method::Ours).expect("routed expm");
            if round == 0 {
                routed.push(result);
            }
        }
    }

    // every request was cache-eligible and nothing was saturated, so the
    // router must have placed ALL of them by affinity (≥90% is the
    // acceptance floor; the deterministic path gives 100%)
    let status = client.cluster(ClusterAction::Status, None).expect("status");
    let affinity = sum_member_counter(&status, "routed_affinity");
    let total = sum_member_counter(&status, "routed");
    assert_eq!(total, 30, "all requests accounted for: {status}");
    assert!(
        affinity as f64 >= 0.9 * total as f64,
        "affinity {affinity}/{total} below 90%: {status}"
    );

    // concentration: two distinct digests can warm at most two members —
    // the third must have seen nothing
    let rows = status.get("members").and_then(Json::as_arr).expect("members block");
    assert_eq!(rows.len(), 3);
    let busy = rows
        .iter()
        .filter(|r| r.get("routed").and_then(Json::as_u64).unwrap_or(0) > 0)
        .count();
    assert!(busy <= hot.len(), "2 hot digests spread over {busy} members: {status}");

    // bit-identical to a single server computing the same submissions
    let (_service, _single, direct_addr) = start_server();
    let mut direct = MatexpClient::connect(&direct_addr).expect("connect");
    for (m, via_router) in hot.iter().zip(&routed) {
        let (expect, _) = direct.expm(m, 64, Method::Ours).expect("direct expm");
        let same = expect
            .data()
            .iter()
            .zip(via_router.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "routed result differs bitwise from single-server result");
    }

    cluster.shutdown();
}

#[test]
fn killing_a_member_loses_no_subsequent_requests() {
    let mut cluster = Cluster::spawn_local(3).expect("cluster spawns");
    let addr = cluster.router_addr();
    let mut client = MatexpClient::connect(&addr).expect("connect router");

    // warm every member's egress path with a spread of digests
    for seed in 0..6 {
        let m = hot_matrix(24, 100 + seed);
        client.expm(&m, 32, Method::Ours).expect("warmup expm");
    }

    cluster.kill_member(0);

    // same connection: the egress socket to the dead member is already
    // open, so the first request aimed at it may fail with the typed
    // in-flight error — but only typed errors, and only briefly
    let mut typed_errors = 0;
    let mut tail_ok = 0;
    for seed in 0..20 {
        let m = hot_matrix(24, 200 + seed);
        match client.expm(&m, 32, Method::Ours) {
            Ok((result, _)) => {
                assert_eq!(result.n(), 24);
                tail_ok += 1;
            }
            Err(MatexpError::Disconnected(_) | MatexpError::Service(_)) => {
                typed_errors += 1;
                tail_ok = 0;
            }
            Err(e) => panic!("untyped failure after member kill: {e:?}"),
        }
    }
    assert!(typed_errors <= 3, "{typed_errors} typed errors after kill — reroute not sticking");
    assert!(tail_ok >= 10, "requests kept failing after the router saw the dead member");

    // a fresh connection has a fresh egress pool: the dead member fails
    // at connect time, which reroutes transparently — zero errors
    let mut fresh = MatexpClient::connect(&addr).expect("reconnect router");
    for seed in 0..10 {
        let m = hot_matrix(24, 300 + seed);
        let (result, _) = fresh.expm(&m, 32, Method::Ours).expect("post-kill expm");
        assert_eq!(result.n(), 24);
    }

    cluster.shutdown();
}

#[test]
fn saturated_cluster_sheds_with_typed_admission() {
    // one member, shed-at 1: while any request is in flight, every other
    // pick must shed — the concurrent barrage below makes overlap certain
    let settings = ClusterSettings { shed_at: 1, ..ClusterSettings::default() };
    let cluster = Cluster::spawn_local_with(1, settings).expect("cluster spawns");
    let addr = cluster.router_addr();

    let threads = 4;
    let per_thread = 6;
    let barrier = Arc::new(Barrier::new(threads));
    let mut handles = Vec::new();
    for t in 0..threads {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        handles.push(thread::spawn(move || {
            let mut client = MatexpClient::connect(&addr).expect("connect router");
            barrier.wait();
            let (mut ok, mut shed) = (0u32, 0u32);
            for i in 0..per_thread {
                // distinct matrices + bypass: no result-cache shortcut,
                // so every request holds the member for real work
                let m = hot_matrix(48, 1_000 + (t * per_thread + i) as u64);
                match client.expm_cached(&m, 512, Method::Ours, CacheControl::Bypass) {
                    Ok(_) => ok += 1,
                    Err(MatexpError::Admission(msg)) => {
                        assert!(msg.contains("saturated"), "unexpected admission text: {msg}");
                        shed += 1;
                    }
                    Err(e) => panic!("expected ok or Admission, got {e:?}"),
                }
            }
            (ok, shed)
        }));
    }
    let mut total_ok = 0;
    let mut total_shed = 0;
    for h in handles {
        let (ok, shed) = h.join().expect("client thread");
        total_ok += ok;
        total_shed += shed;
    }
    assert!(total_ok > 0, "nothing succeeded — the cluster is broken, not shedding");
    assert!(total_shed > 0, "4 concurrent clients at shed-at=1 never overlapped");

    // the router counted every shed it issued
    let mut control = MatexpClient::connect(&addr).expect("connect router");
    let status = control.cluster(ClusterAction::Status, None).expect("status");
    let counted = status.get("shed_total").and_then(Json::as_u64).unwrap_or(0);
    assert!(counted >= u64::from(total_shed), "shed_total {counted} < observed {total_shed}");

    cluster.shutdown();
}

#[test]
fn drain_detaches_the_member_and_it_refuses_direct_work() {
    let cluster = Cluster::spawn_local(3).expect("cluster spawns");
    let victim = cluster.member_addr(0).to_string();
    let mut control = MatexpClient::connect(&cluster.router_addr()).expect("connect router");

    let doc = control.cluster(ClusterAction::Drain, Some(victim.as_str())).expect("drain");
    assert_eq!(doc.get("drained").and_then(Json::as_bool), Some(true), "{doc}");
    assert_eq!(doc.get("detached").and_then(Json::as_bool), Some(true), "{doc}");
    let rows = doc.get("members").and_then(Json::as_arr).expect("members block");
    assert_eq!(rows.len(), 2, "drained member must leave the set: {doc}");
    assert!(rows.iter().all(|r| r.get("member").and_then(Json::as_str) != Some(victim.as_str())));

    // the member itself now refuses new direct work with the same typed
    // admission error the single-server drain gate uses
    let mut direct = MatexpClient::connect(&victim).expect("member still listens");
    let status = direct.cluster(ClusterAction::Status, None).expect("member status");
    assert_eq!(status.get("role").and_then(Json::as_str), Some("member"), "{status}");
    assert_eq!(status.get("draining").and_then(Json::as_bool), Some(true), "{status}");
    let m = hot_matrix(16, 7);
    match direct.expm(&m, 16, Method::Ours) {
        Err(MatexpError::Admission(msg)) => {
            assert!(msg.contains("draining"), "unexpected admission text: {msg}")
        }
        other => panic!("draining member accepted work: {other:?}"),
    }

    // the remaining members absorb the drained member's digest range
    for seed in 0..8 {
        let m = hot_matrix(24, 400 + seed);
        let (result, _) = control.expm(&m, 32, Method::Ours).expect("post-drain expm");
        assert_eq!(result.n(), 24);
    }

    cluster.shutdown();
}

#[test]
fn runtime_join_and_leave_reshape_the_member_set() {
    let cluster = Cluster::spawn_local(2).expect("cluster spawns");
    let mut control = MatexpClient::connect(&cluster.router_addr()).expect("connect router");

    // a third, standalone member started outside the sim harness
    let mut cfg = MatexpConfig::default();
    cfg.cache.results = true;
    let (_extra_service, _extra, extra_addr) = start_server_with(cfg);

    let doc = control.cluster(ClusterAction::Join, Some(extra_addr.as_str())).expect("join");
    let rows = doc.get("members").and_then(Json::as_arr).expect("members block");
    assert_eq!(rows.len(), 3, "join must grow the set: {doc}");

    // traffic still flows over the reshaped set
    for seed in 0..6 {
        let m = hot_matrix(24, 500 + seed);
        let (result, _) = control.expm(&m, 32, Method::Ours).expect("post-join expm");
        assert_eq!(result.n(), 24);
    }

    let doc = control.cluster(ClusterAction::Leave, Some(extra_addr.as_str())).expect("leave");
    let rows = doc.get("members").and_then(Json::as_arr).expect("members block");
    assert_eq!(rows.len(), 2, "leave must shrink the set: {doc}");

    // bad membership ops answer typed config errors, not protocol breaks
    match control.cluster(ClusterAction::Join, Some("noport")) {
        Err(MatexpError::Config(_)) => {}
        other => panic!("join of a portless address must be a config error: {other:?}"),
    }
    match control.cluster(ClusterAction::Leave, Some("ghost:1")) {
        Err(MatexpError::Config(_)) => {}
        other => panic!("leave of an unknown member must be a config error: {other:?}"),
    }

    cluster.shutdown();
}
