//! Acceptance tests for the one execution surface:
//!
//! 1. **Parity** — the SAME `Submission` served by `Engine<B>`,
//!    `PoolEngine` and `ServiceHandle` (all as `dyn Executor`) matches
//!    the `linalg::expm` oracle at 1e-5.
//! 2. **No stragglers** — a source grep over `src/` asserting the 0.3.x
//!    entry points removed in 0.4.0 (`expm_*`, blocking `submit`) are
//!    neither called nor redeclared: everything routes through the
//!    surface.
//! 3. **Capabilities** — each executor truthfully reports what it is.

use std::path::{Path, PathBuf};

use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::exec::{Executor, Submission};
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::pool::{PoolDeviceKind, PoolEngine};
use matexp::runtime::{BackendKind, Engine};

fn executors() -> Vec<(&'static str, Box<dyn Executor>)> {
    // Ikj everywhere so every arm shares the oracle's multiply kernel —
    // the 1e-5 bound then measures the execution surface, not kernel
    // reassociation differences
    let mut service_cfg = MatexpConfig::default();
    service_cfg.cpu_algo = CpuAlgo::Ikj;
    service_cfg.workers = 2;
    service_cfg.batcher.max_wait_ms = 1;

    let mut pool_cfg = MatexpConfig::default();
    pool_cfg.cpu_algo = CpuAlgo::Ikj;
    pool_cfg.backend = BackendKind::Pool;
    pool_cfg.pool.devices = vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu];

    vec![
        ("engine", Box::new(Engine::cpu(CpuAlgo::Ikj))),
        ("pool", Box::new(PoolEngine::from_config(&pool_cfg).expect("pool starts"))),
        ("service", Box::new(Service::start(service_cfg).expect("service starts"))),
    ]
}

/// Acceptance: one submission, three executors, one oracle, 1e-5.
#[test]
fn same_submission_matches_oracle_on_every_executor() {
    let a = Matrix::random_stochastic(16, 5);
    let power = 29;
    let want = linalg::expm::expm(&a, power, CpuAlgo::Ikj).expect("oracle");
    for (name, mut executor) in executors() {
        // square-and-multiply disciplines share the oracle's multiply
        // ordering: 1e-5 holds exactly as specified
        for method in [Method::Ours, Method::OursPacked] {
            let resp = executor
                .run(Submission::expm(a.clone(), power).method(method))
                .unwrap_or_else(|e| panic!("{name}/{method}: {e}"));
            assert!(
                resp.result.approx_eq(&want, 1e-5, 1e-5),
                "{name}/{method}: diff {}",
                resp.result.max_abs_diff(&want)
            );
            assert_eq!(resp.method, method, "{name}");
        }
        // the naive baseline multiplies in a different order (28
        // sequential products), so it gets the usual cross-ordering bound
        let resp = executor
            .run(Submission::expm(a.clone(), power).method(Method::NaiveGpu))
            .unwrap_or_else(|e| panic!("{name}/naive-gpu: {e}"));
        assert!(
            resp.result.approx_eq(&want, 1e-4, 1e-4),
            "{name}/naive-gpu: diff {}",
            resp.result.max_abs_diff(&want)
        );
    }
}

#[test]
fn capabilities_are_truthful() {
    for (name, executor) in executors() {
        let caps = executor.capabilities();
        assert!(!caps.platform.is_empty(), "{name}");
        assert!(caps.sizes.is_empty(), "{name}: cpu executors are size-unrestricted");
        assert!(caps.max_power >= 1 << 20, "{name}");
        for m in Method::all() {
            assert!(caps.methods.contains(&m), "{name} missing {m}");
        }
        assert_eq!(caps.async_submit, name == "service", "{name}");
    }
}

/// The handle contract end-to-end on the asynchronous executor:
/// try_result polls, wait resolves, cancel withdraws.
#[test]
fn service_handles_wait_poll_and_cancel() {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 1;
    cfg.batcher.max_wait_ms = 1;
    let service = Service::start(cfg).expect("service starts");
    let a = Matrix::random_spectral(12, 0.9, 3);
    let want = linalg::expm::expm(&a, 40, CpuAlgo::Ikj).unwrap();

    let mut job = service.submit_job(Submission::expm(a.clone(), 40)).expect("submit");
    // poll until done (async submission: the result arrives on its own)
    let resp = loop {
        if let Some(outcome) = job.try_result() {
            break outcome.expect("job succeeds");
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    };
    assert!(resp.result.approx_eq(&want, 1e-3, 1e-3));

    // a cancelled job never delivers, and the service stays healthy
    let mut doomed = service.submit_job(Submission::expm(a.clone(), 40)).expect("submit");
    doomed.cancel();
    assert!(doomed.wait().is_err());
    let mut after = service.submit_job(Submission::expm(a, 40)).expect("submit");
    assert!(after.wait().expect("service healthy after cancel").result.is_finite());
}

fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("read src dir") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rust_sources(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// The deprecation window CLOSED in 0.4.0: the `expm_*` shims and the
/// blocking `ServiceHandle::submit` are gone, nothing in `src/` calls
/// (or redeclares) them, and no `#[deprecated]` item lingers — every
/// caller routes through `exec::Executor::submit` / the crate-internal
/// strategy dispatch.
#[test]
fn removed_entry_points_stay_removed() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    rust_sources(&root, &mut files);
    assert!(files.len() > 40, "source walker looks broken: {} files", files.len());
    // call sites AND declarations of the removed entry points
    const FORBIDDEN: [&str; 10] = [
        ".expm(",
        ".expm_packed(",
        ".expm_naive_roundtrip(",
        ".expm_plan_roundtrip(",
        ".expm_fused_artifact(",
        "fn expm_packed(",
        "fn expm_naive_roundtrip(",
        "fn expm_plan_roundtrip(",
        "fn expm_fused_artifact(",
        "#[deprecated",
    ];
    for file in files {
        let rel = file
            .strip_prefix(&root)
            .expect("under src/")
            .to_string_lossy()
            .replace('\\', "/");
        if rel == "lib.rs" {
            continue; // the crate docs carry the old→new migration table
        }
        let src = std::fs::read_to_string(&file).expect("read source");
        for needle in FORBIDDEN {
            assert!(
                !src.contains(needle),
                "{rel} reintroduces a removed 0.3.x entry point ({needle:?}) — \
                 route through exec::Executor::submit / Submission"
            );
        }
    }
}
