//! Cross-backend parity: engine output on [`CpuBackend`] matches the
//! direct `linalg::expm` oracle to 1e-5 for every plan kind across the
//! size/power grid the issue pins down — sizes {4, 16, 64} and powers
//! {1, 2, 13, 100, 1024}.
//!
//! Row-stochastic inputs keep every power well-conditioned (spectral
//! radius exactly 1), so the comparison is meaningful even at N=1024
//! where a contractive matrix would collapse to zero.
//!
//! Everything routes through the one execution surface
//! (`exec::Executor::submit` with explicit plan overrides) — the
//! deprecated `expm_*` shims were removed in 0.4.0.

use matexp::exec::{Executor, Submission};
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::plan::Plan;
use matexp::runtime::{CpuEngine, Engine};

const SIZES: [usize; 3] = [4, 16, 64];
const POWERS: [u64; 5] = [1, 2, 13, 100, 1024];
const TOL: f32 = 1e-5;

fn input(n: usize) -> Matrix {
    Matrix::random_stochastic(n, n as u64 + 1)
}

/// The oracle the issue names: `linalg::expm` (binary square-and-multiply
/// on the CPU substrate), same matmul variant as the engine under test.
fn oracle(a: &Matrix, power: u64) -> Matrix {
    linalg::expm::expm(a, power, CpuAlgo::Ikj).expect("oracle")
}

fn check(name: &str, n: usize, power: u64, got: &Matrix, want: &Matrix) {
    assert!(
        got.approx_eq(want, TOL, TOL),
        "{name} n={n} N={power}: max diff {}",
        got.max_abs_diff(want)
    );
}

fn engine() -> CpuEngine {
    Engine::cpu(CpuAlgo::Ikj)
}

/// Replay an explicit plan through the execution surface.
fn replay(e: &mut CpuEngine, a: &Matrix, power: u64, plan: Plan) -> Matrix {
    e.run(Submission::expm(a.clone(), power).plan(plan)).expect("replay").result
}

#[test]
fn binary_plan_parity() {
    let mut e = engine();
    for n in SIZES {
        let a = input(n);
        for power in POWERS {
            let want = oracle(&a, power);
            let got = replay(&mut e, &a, power, Plan::binary(power, false));
            check("binary", n, power, &got, &want);
        }
    }
}

#[test]
fn fused_binary_plan_parity() {
    let mut e = engine();
    for n in SIZES {
        let a = input(n);
        for power in POWERS {
            let want = oracle(&a, power);
            let got = replay(&mut e, &a, power, Plan::binary(power, true));
            check("binary-fused", n, power, &got, &want);
        }
    }
}

#[test]
fn chained_plan_parity() {
    let mut e = engine();
    for n in SIZES {
        let a = input(n);
        for power in POWERS {
            let want = oracle(&a, power);
            let got = replay(&mut e, &a, power, Plan::chained(power, &[4, 2]));
            check("chained", n, power, &got, &want);
        }
    }
}

#[test]
fn addition_chain_plan_parity() {
    let mut e = engine();
    for n in SIZES {
        let a = input(n);
        for power in POWERS {
            let want = oracle(&a, power);
            let got = replay(&mut e, &a, power, Plan::addition_chain(power));
            check("addition-chain", n, power, &got, &want);
        }
    }
}

#[test]
fn naive_plan_parity() {
    let mut e = engine();
    for n in SIZES {
        let a = input(n);
        for power in POWERS {
            // the naive plan replays the oracle's own multiply chain
            // (`expm_naive`), so compare against that form directly
            let want = linalg::expm::expm_naive(&a, power, CpuAlgo::Ikj).unwrap();
            let got = replay(&mut e, &a, power, Plan::naive(power));
            check("naive", n, power, &got, &want);
            // and the binary oracle agrees too (different association
            // order, so only to tolerance)
            check("naive-vs-binary-oracle", n, power, &got, &oracle(&a, power));
        }
    }
}

#[test]
fn packed_discipline_parity() {
    use matexp::coordinator::request::Method;
    let mut e = engine();
    for n in SIZES {
        let a = input(n);
        for power in POWERS {
            let want = oracle(&a, power);
            let got = e
                .run(Submission::expm(a.clone(), power).method(Method::OursPacked))
                .expect("packed")
                .result;
            check("packed", n, power, &got, &want);
        }
    }
}

#[test]
fn parity_holds_across_matmul_variants() {
    // the backend's selectable MatmulFn changes summation order, not
    // results: every variant stays within tolerance of the Ikj oracle
    for algo in CpuAlgo::all() {
        let mut e = Engine::cpu(algo);
        for n in SIZES {
            let a = input(n);
            for power in [13u64, 100] {
                let want = oracle(&a, power);
                let got = replay(&mut e, &a, power, Plan::binary(power, false));
                assert!(
                    got.approx_eq(&want, 1e-4, 1e-4),
                    "algo {} n={n} N={power}: max diff {}",
                    algo.name(),
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}
