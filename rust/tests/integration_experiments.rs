//! Integration: the experiment harness — measured tables reproduce the
//! paper's claim structure on this testbed; ablations run end-to-end.

use matexp::config::MatexpConfig;
use matexp::experiments::{ablations, report, run_table};
use matexp::runtime::artifacts::ArtifactRegistry;
use matexp::runtime::engine::Engine;
use matexp::runtime::Variant;

fn cfg() -> MatexpConfig {
    let mut c = MatexpConfig::default();
    c.cpu_measure_cap = 2; // keep the CPU arm fast in CI
    c
}

fn registry(cfg: &MatexpConfig) -> Option<ArtifactRegistry> {
    if !cfg.artifacts_dir.join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping");
        return None;
    }
    Some(ArtifactRegistry::discover(&cfg.artifacts_dir).unwrap())
}

#[test]
fn all_four_tables_simulate_with_paper_columns() {
    let cfg = cfg();
    for id in 2..=5u8 {
        let t = run_table(id, &cfg, None).unwrap();
        assert!(!t.cells.is_empty());
        assert!(t.cells.iter().all(|c| c.paper.is_some()));
        let rendered = report::render_table(&t);
        assert!(rendered.contains(&format!("Table {id}")));
        let figs = report::render_figures(&t);
        assert!(figs.contains("Figure"));
    }
}

#[test]
fn measured_table2_preserves_the_claim_structure() {
    let cfg = cfg();
    let Some(reg) = registry(&cfg) else { return };
    let t = run_table(2, &cfg, Some(&reg)).unwrap();
    for c in &t.cells {
        let m = c.measured.expect("measured column present");
        // the paper's two core claims, on OUR testbed:
        // 1. ours beats the naive GPU discipline
        assert!(
            m.ours_s < m.naive_gpu_s,
            "N={}: ours {} vs naive {}",
            c.power,
            m.ours_s,
            m.naive_gpu_s
        );
        // 2. the gap grows with the exponent (launch counts: N-1 vs ~log N)
    }
    let first = t.cells.first().unwrap().measured.unwrap();
    let last = t.cells.last().unwrap().measured.unwrap();
    assert!(
        last.ours_vs_naive() > first.ours_vs_naive(),
        "speedup must grow with N: {} -> {}",
        first.ours_vs_naive(),
        last.ours_vs_naive()
    );
}

#[test]
fn measured_naive_gpu_beats_measured_seq_cpu_at_large_n() {
    // the paper's other claim — GPU beats CPU — needs a big enough matrix
    // on this CPU-PJRT testbed (XLA's matmul is multithreaded+vectorized,
    // the baseline is a scalar triple loop)
    let cfg = cfg();
    let Some(reg) = registry(&cfg) else { return };
    let mut engine = Engine::new(&reg, Variant::Xla).unwrap();
    let a = matexp::linalg::matrix::Matrix::random_spectral(256, 0.99, 1);
    let m = matexp::experiments::tables::measure_cell(&mut engine, &cfg, &a, 64).unwrap();
    assert!(
        m.naive_gpu_s < m.seq_cpu_s,
        "XLA-backed naive GPU arm {} should beat the scalar CPU loop {}",
        m.naive_gpu_s,
        m.seq_cpu_s
    );
}

#[test]
fn ablation_suite_runs() {
    let cfg = cfg();
    let Some(reg) = registry(&cfg) else { return };
    let mut engine = Engine::new(&reg, Variant::Xla).unwrap();

    let arms = ablations::transfer_ablation(&mut engine, 32, 64, cfg.seed).unwrap();
    assert_eq!(arms.len(), 2);
    assert!(arms[0].transfers < arms[1].transfers);

    let arms = ablations::fusion_ablation(&mut engine, 32, 64, cfg.seed).unwrap();
    assert!(arms.len() >= 5);
    // all fusion arms do the same O(log N) work modulo fusion bookkeeping
    for a in &arms {
        assert!(a.multiplies <= 12, "{}: {}", a.name, a.multiplies);
    }

    let arms = ablations::cpu_variants(64, cfg.seed);
    assert_eq!(arms.len(), 5);
    let naive = arms.iter().find(|a| a.name == "naive").unwrap();
    let best = arms
        .iter()
        .map(|a| a.wall_s)
        .fold(f64::INFINITY, f64::min);
    assert!(best <= naive.wall_s, "some variant at least ties naive");
}

#[test]
fn tile_sweep_covers_manifest_tiles() {
    let cfg = cfg();
    let Some(reg) = registry(&cfg) else { return };
    let mut engine = Engine::new(&reg, Variant::Xla).unwrap();
    let tiles = reg.tiles("matmul", 128);
    if tiles.is_empty() {
        return;
    }
    let arms = ablations::tile_sweep(&mut engine, &reg, 128, cfg.seed).unwrap();
    assert_eq!(arms.len(), tiles.len());
    print!("{}", report::render_ablation("tiles n=128", &arms));
}
