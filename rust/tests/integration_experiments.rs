//! Integration: the experiment harness — measured tables reproduce the
//! paper's claim structure on this testbed; ablations run end-to-end.
//! Runs unconditionally on the pure-Rust backends (no artifacts).

use matexp::config::MatexpConfig;
use matexp::experiments::{ablations, report, run_table, run_table_sim};
use matexp::linalg::CpuAlgo;
use matexp::runtime::Engine;

fn cfg() -> MatexpConfig {
    let mut c = MatexpConfig::default();
    c.cpu_measure_cap = 2; // keep the CPU arm fast in CI
    c
}

#[test]
fn all_four_tables_simulate_with_paper_columns() {
    let cfg = cfg();
    for id in 2..=5u8 {
        let t = run_table_sim(id, &cfg).unwrap();
        assert!(!t.cells.is_empty());
        assert!(t.cells.iter().all(|c| c.paper.is_some()));
        let rendered = report::render_table(&t);
        assert!(rendered.contains(&format!("Table {id}")));
        let figs = report::render_figures(&t);
        assert!(figs.contains("Figure"));
    }
}

#[test]
fn measured_table2_preserves_the_claim_structure() {
    let cfg = cfg();
    let mut engine = Engine::cpu(CpuAlgo::Blocked);
    let t = run_table(2, &cfg, Some(&mut engine)).unwrap();
    for c in &t.cells {
        let m = c.measured.expect("measured column present");
        // the paper's core claim, on OUR testbed: ours (log N launches,
        // two host crossings) beats the naive per-launch discipline
        assert!(
            m.ours_s < m.naive_gpu_s,
            "N={}: ours {} vs naive {}",
            c.power,
            m.ours_s,
            m.naive_gpu_s
        );
    }
    // and the gap grows with the exponent (launch counts: N-1 vs ~log N)
    let first = t.cells.first().unwrap().measured.unwrap();
    let last = t.cells.last().unwrap().measured.unwrap();
    assert!(
        last.ours_vs_naive() > first.ours_vs_naive(),
        "speedup must grow with N: {} -> {}",
        first.ours_vs_naive(),
        last.ours_vs_naive()
    );
}

#[test]
fn measured_cell_on_sim_backend_reproduces_paper_ordering() {
    let cfg = cfg();
    let mut engine = Engine::sim();
    let a = matexp::linalg::matrix::Matrix::random_spectral(64, 0.99, 1);
    let m = matexp::experiments::tables::measure_cell(&mut engine, &cfg, &a, 256).unwrap();
    // simulated 2012 testbed: the full paper ordering — ours beats naive
    // GPU beats sequential CPU — and the CPU arm is MODELED (same
    // calibration), never this host's wall-clock
    assert!(
        m.ours_s < m.naive_gpu_s,
        "sim ours {} should beat sim naive {}",
        m.ours_s,
        m.naive_gpu_s
    );
    assert!(
        m.naive_gpu_s < m.seq_cpu_s,
        "sim naive GPU {} should beat modeled seq CPU {}",
        m.naive_gpu_s,
        m.seq_cpu_s
    );
}

#[test]
fn measured_threaded_backend_beats_measured_seq_cpu() {
    // the paper's other claim — the parallel device beats the sequential
    // CPU — holds on this testbed once the backend actually uses the
    // cores: the threaded-matmul backend vs the scalar i-j-k loop
    let cfg = cfg();
    let mut engine = Engine::cpu(CpuAlgo::Threaded);
    let a = matexp::linalg::matrix::Matrix::random_spectral(256, 0.99, 1);
    engine.warmup_exec(256).unwrap(); // measure_cell expects a warm engine
    let m = matexp::experiments::tables::measure_cell(&mut engine, &cfg, &a, 64).unwrap();
    assert!(
        m.naive_gpu_s < m.seq_cpu_s,
        "threaded-backend naive arm {} should beat the scalar CPU loop {}",
        m.naive_gpu_s,
        m.seq_cpu_s
    );
}

#[test]
fn ablation_suite_runs() {
    let cfg = cfg();
    let mut engine = Engine::cpu(CpuAlgo::Blocked);

    let arms = ablations::transfer_ablation(&mut engine, 32, 64, cfg.seed).unwrap();
    assert_eq!(arms.len(), 2);
    assert!(arms[0].transfers < arms[1].transfers);

    let arms = ablations::fusion_ablation(&mut engine, 32, 64, cfg.seed).unwrap();
    assert!(arms.len() >= 5);
    // all fusion arms do the same O(log N) work modulo fusion bookkeeping
    for a in &arms {
        assert!(a.multiplies <= 12, "{}: {}", a.name, a.multiplies);
    }

    let arms = ablations::cpu_variants(64, cfg.seed);
    assert_eq!(arms.len(), 5);
    let naive = arms.iter().find(|a| a.name == "naive").unwrap();
    let best = arms
        .iter()
        .map(|a| a.wall_s)
        .fold(f64::INFINITY, f64::min);
    assert!(best <= naive.wall_s, "some variant at least ties naive");
}

#[cfg(feature = "xla")]
mod pjrt {
    use super::*;
    use matexp::runtime::artifacts::ArtifactRegistry;
    use matexp::runtime::Variant;

    #[test]
    fn tile_sweep_covers_manifest_tiles() {
        let cfg = cfg();
        if !cfg.artifacts_dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let reg = ArtifactRegistry::discover(&cfg.artifacts_dir).unwrap();
        let mut engine = Engine::pjrt(&reg, Variant::Xla).unwrap();
        let tiles = reg.tiles("matmul", 128);
        if tiles.is_empty() {
            return;
        }
        let arms = ablations::tile_sweep(&mut engine, &reg, 128, cfg.seed).unwrap();
        assert_eq!(arms.len(), tiles.len());
        print!("{}", report::render_ablation("tiles n=128", &arms));
    }
}
