//! Property tests for the device pool: the tile partitioner against the
//! single-device `linalg` oracle (random sizes, device counts 1..4,
//! uneven heterogeneous splits) and the per-device `ExecStats`
//! invariants.

use matexp::config::MatexpConfig;
use matexp::exec::{Executor, Submission};
use matexp::linalg::matrix::Matrix;
use matexp::linalg::naive::matmul_naive;
use matexp::plan::Plan;
use matexp::pool::{DevicePool, PoolDeviceKind, PoolEngine, ShardPlan, TileGrid};
use matexp::runtime::BackendKind;
use matexp::util::prop::property;

fn pool_cfg(devices: Vec<PoolDeviceKind>) -> MatexpConfig {
    let mut cfg = MatexpConfig::default();
    cfg.backend = BackendKind::Pool;
    cfg.pool.devices = devices;
    cfg
}

#[test]
fn sharded_product_matches_single_device_oracle() {
    // the satellite property: reassembled sharded products == the
    // single-device linalg oracle at 1e-5, across random sizes, device
    // counts {1,2,3,4}, and arbitrary (typically uneven) tile->device
    // assignments
    property("sharded matmul == linalg oracle", 30, |g| {
        let devices = g.usize(1, 4);
        let pool = DevicePool::new(&pool_cfg(vec![PoolDeviceKind::Cpu; devices])).unwrap();
        let n = g.usize(2, 40);
        let grid = TileGrid::new(n, g.usize(1, 4)).unwrap();
        let assignment: Vec<usize> =
            (0..grid.tiles()).map(|_| g.usize(0, devices - 1)).collect();
        let plan = ShardPlan {
            grid: grid.g(),
            assignment: assignment.clone(),
            predicted_step_s: 0.0,
        };
        let a = Matrix::random(n, g.u64(1, 1 << 20));
        let b = Matrix::random(n, g.u64(1, 1 << 20));
        let (got, stats) = pool
            .sharded_matmul(&a, &b, 1, 2, 3, &plan)
            .expect("sharded multiply runs");
        let want = matmul_naive(&a, &b);
        assert!(
            got.approx_eq(&want, 1e-5, 1e-5),
            "n={n} g={} devices={devices}: diff {}",
            grid.g(),
            got.max_abs_diff(&want)
        );
        // one fused launch per tile, and the per-device breakdown is
        // conserved against the totals
        assert_eq!(stats.launches, grid.tiles());
        assert_eq!(stats.multiplies, grid.tiles() * grid.g());
        let launches: usize = stats.per_device.iter().map(|d| d.launches).sum();
        assert_eq!(launches, stats.launches);
        let h2d: usize = stats.per_device.iter().map(|d| d.h2d_transfers).sum();
        assert_eq!(h2d, stats.h2d_transfers);
    });
}

#[test]
fn per_device_launches_sum_to_plan_launches() {
    // whole-request dispatch: the response's per-device launches must sum
    // to exactly the plan's launch count
    property("pool per-device launches == plan launches", 20, |g| {
        let devices = g.usize(1, 3);
        let mut engine =
            PoolEngine::from_config(&pool_cfg(vec![PoolDeviceKind::Cpu; devices])).unwrap();
        let power = g.u64(1, 512);
        let plan = match g.usize(0, 2) {
            0 => Plan::binary(power, false),
            1 => Plan::binary(power, true),
            _ => Plan::chained(power, &[4, 2]),
        };
        let (kind, launches) = (plan.kind, plan.launches());
        let a = Matrix::random_spectral(g.usize(4, 16), 0.9, g.u64(1, 1 << 20));
        let resp = engine.run(Submission::expm(a, power).plan(plan)).unwrap();
        assert!(resp.result.is_finite());
        assert_eq!(resp.stats.launches, launches, "{kind:?}");
        let sum: usize = resp.stats.per_device.iter().map(|d| d.launches).sum();
        assert_eq!(sum, launches, "{kind:?}");
    });
}

#[test]
fn sharded_replay_breakdown_is_conserved() {
    // forced-grid sharded replay: per-device launch/transfer sums equal
    // the totals, and launches = tiles x logical multiplies
    property("sharded replay stats conserved", 12, |g| {
        let devices = g.usize(1, 3);
        let mut cfg = pool_cfg(vec![PoolDeviceKind::Cpu; devices]);
        let grid_dim = g.usize(1, 3);
        cfg.pool.grid = Some(grid_dim);
        let mut engine = PoolEngine::from_config(&cfg).unwrap();
        let n = g.usize(6, 24);
        let power = g.u64(1, 64);
        let plan = Plan::binary(power, false);
        let multiplies = plan.multiplies();
        let a = Matrix::random_spectral(n, 0.9, g.u64(1, 1 << 20));
        let resp = engine.run(Submission::expm(a.clone(), power).plan(plan)).unwrap();
        let (got, stats) = (resp.result, resp.stats);
        let want = matexp::linalg::expm::expm(&a, power, matexp::linalg::CpuAlgo::Naive)
            .unwrap();
        assert!(
            got.approx_eq(&want, 1e-4, 1e-4),
            "n={n} N={power}: diff {}",
            got.max_abs_diff(&want)
        );
        let tiles = TileGrid::new(n, grid_dim).unwrap().tiles();
        assert_eq!(stats.launches, tiles * multiplies);
        let launches: usize = stats.per_device.iter().map(|d| d.launches).sum();
        assert_eq!(launches, stats.launches);
        let d2h: usize = stats.per_device.iter().map(|d| d.d2h_transfers).sum();
        assert_eq!(d2h, stats.d2h_transfers);
    });
}
