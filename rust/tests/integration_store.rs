//! Integration: the persistence tier's crash-recovery contract.
//!
//! * A write killed mid-flight (an injectable [`Sink`] wrapper that
//!   commits only part of the entry file — the moral equivalent of the
//!   process dying mid-flush) leaves a torn entry that the
//!   rebuild-on-open index **skips and quarantines**, while every
//!   fully-committed entry survives the reopen bit-exactly.
//! * The warm-restart acceptance bar: a service restarted on the same
//!   `--store-dir` serves a repeated request **with zero kernel
//!   launches** and a result **bit-identical** to the pre-restart cold
//!   run — after every in-memory tier was wiped.
//!
//! The store slot, result cache and counters are process-global, so the
//! restart tests serialize on [`common::test_guard`].

use std::sync::atomic::{AtomicBool, Ordering};

use matexp::cache::ResultCache;
use matexp::config::MatexpConfig;
use matexp::coordinator::request::Method;
use matexp::coordinator::service::Service;
use matexp::error::Result;
use matexp::exec::{Executor, Submission};
use matexp::linalg::matrix::Matrix;
use matexp::store::{ArtifactKind, FsSink, Sink, StoreKey};

mod common;
use common::{scratch_dir, test_guard};

fn key(lo: u64) -> StoreKey {
    StoreKey { kind: ArtifactKind::Result, hi: 3, lo }
}

fn assert_bits_eq(a: &Matrix, b: &Matrix) {
    assert_eq!(a.n(), b.n());
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "element {i}: {x} vs {y}");
    }
}

/// Fault-injecting [`Sink`]: delegates to a real [`FsSink`], but when
/// armed it commits only the first half of the entry file — simulating
/// a crash mid-write on a filesystem that reordered the flush past the
/// rename.
struct TornSink {
    inner: FsSink,
    tear_next: AtomicBool,
}

impl TornSink {
    fn new(inner: FsSink) -> TornSink {
        TornSink { inner, tear_next: AtomicBool::new(false) }
    }

    /// Arm the wrapper: the NEXT put commits only half its bytes.
    fn tear_next_write(&self) {
        self.tear_next.store(true, Ordering::SeqCst);
    }
}

impl Sink for TornSink {
    fn put(&self, key: StoreKey, payload: &[u8]) -> Result<()> {
        self.inner.put(key, payload)?;
        if self.tear_next.swap(false, Ordering::SeqCst) {
            let path = self.inner.entry_path(&key);
            let bytes = std::fs::read(&path).expect("read committed entry");
            std::fs::write(&path, &bytes[..bytes.len() / 2]).expect("tear entry");
        }
        Ok(())
    }

    fn get(&self, key: &StoreKey) -> Result<Option<Vec<u8>>> {
        self.inner.get(key)
    }

    fn delete(&self, key: &StoreKey) -> Result<bool> {
        self.inner.delete(key)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn keys(&self) -> Vec<StoreKey> {
        self.inner.keys()
    }

    fn bytes(&self) -> u64 {
        self.inner.bytes()
    }

    fn contains(&self, key: &StoreKey) -> bool {
        self.inner.contains(key)
    }
}

/// Kill a write mid-flight, reopen the directory: the index rebuild
/// skips (and quarantines) the torn entry, every committed entry
/// survives bit-exactly, and stray temp files from interrupted atomic
/// writes are swept.
#[test]
fn reopen_after_torn_write_keeps_committed_entries_and_skips_the_torn_one() {
    let dir = scratch_dir();
    let sink = TornSink::new(FsSink::open(dir.path()).expect("open"));

    let warm_a = b"committed before the crash".to_vec();
    let warm_b: Vec<u8> = (0..=255u8).collect();
    sink.put(key(1), &warm_a).expect("put a");
    sink.put(key(2), &warm_b).expect("put b");

    // the mid-flight kill: entry 3's write commits only half its bytes
    sink.tear_next_write();
    sink.put(key(3), b"this write dies halfway through the flush").expect("torn put");

    // a stray temp from an interrupted atomic write, pre-rename
    std::fs::write(dir.path().join("deadbeef-0.tmp"), b"half a header").expect("stray tmp");

    drop(sink); // "process exit"

    let reopened = FsSink::open(dir.path()).expect("reopen after crash");
    assert_eq!(reopened.len(), 2, "index rebuild must skip the torn entry");
    assert_eq!(reopened.get(&key(1)).expect("get a").as_deref(), Some(&warm_a[..]));
    assert_eq!(reopened.get(&key(2)).expect("get b").as_deref(), Some(&warm_b[..]));
    assert_eq!(reopened.get(&key(3)).expect("torn get"), None, "torn entry must read as absent");
    assert!(
        !reopened.entry_path(&key(3)).exists(),
        "torn entry file must be quarantined at open"
    );
    assert!(!dir.path().join("deadbeef-0.tmp").exists(), "temp files must be swept at open");

    // the slot is reusable: a fresh committed write under the torn key
    let fresh = b"rewritten after recovery".to_vec();
    reopened.put(key(3), &fresh).expect("rewrite");
    assert_eq!(reopened.get(&key(3)).expect("get").as_deref(), Some(&fresh[..]));
}

/// The warm-restart acceptance bar, in-process: cold run against a
/// store-backed service, wipe every in-memory tier (the "restart"),
/// start a new service on the same directory — the repeated request is
/// served with ZERO kernel launches and bit-identical result.
#[test]
fn restarted_service_serves_warm_hit_with_zero_launches_bit_identical() {
    let _guard = test_guard();
    let dir = scratch_dir();
    let mut cfg = MatexpConfig::default();
    cfg.workers = 2;
    cfg.batcher.max_wait_ms = 1;
    cfg.cache.results = true;
    cfg.store.dir = Some(dir.path().to_path_buf());

    // pristine tiers: nothing from other tests leaks into this contract
    ResultCache::global().clear();
    matexp::store::deactivate();

    let a = Matrix::random_spectral(40, 0.8, 99);
    let cold = {
        let mut service = Service::start(cfg.clone()).expect("first service");
        let resp =
            service.run(Submission::expm(a.clone(), 128).method(Method::Ours)).expect("cold run");
        assert!(resp.stats.launches > 0, "cold run must execute");
        resp
    };

    // "restart": the first service is gone, every in-memory tier wiped —
    // only the directory remains
    ResultCache::global().clear();
    matexp::store::deactivate();

    let mut service = Service::start(cfg).expect("restarted service");
    let warm =
        service.run(Submission::expm(a.clone(), 128).method(Method::Ours)).expect("warm run");
    assert_eq!(
        warm.stats.launches, 0,
        "a restart on the same --store-dir must serve the repeat from the store"
    );
    assert_bits_eq(&cold.result, &warm.result);
    assert_eq!(warm.method, cold.method);

    // the promotion was counted: at least one store load happened
    assert!(matexp::store::counters().loads >= 1, "{:?}", matexp::store::counters());

    matexp::store::deactivate();
}

/// Corrupting the persisted result on disk between restarts downgrades
/// the repeat to a (correct) cold re-run — the checksum rejects the
/// entry, the service never serves damaged bits.
#[test]
fn corrupted_store_entry_is_recomputed_not_served() {
    let _guard = test_guard();
    let dir = scratch_dir();
    let mut cfg = MatexpConfig::default();
    cfg.workers = 2;
    cfg.batcher.max_wait_ms = 1;
    cfg.cache.results = true;
    cfg.store.dir = Some(dir.path().to_path_buf());

    ResultCache::global().clear();
    matexp::store::deactivate();

    let a = Matrix::random_spectral(32, 0.8, 123);
    let cold = {
        let mut service = Service::start(cfg.clone()).expect("first service");
        service.run(Submission::expm(a.clone(), 64).method(Method::Ours)).expect("cold run")
    };
    assert!(cold.stats.launches > 0);

    // flip one payload bit in every persisted result entry
    let mut flipped = 0;
    for entry in std::fs::read_dir(dir.path()).expect("read dir") {
        let path = entry.expect("entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("mxst") {
            continue;
        }
        let mut bytes = std::fs::read(&path).expect("read");
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).expect("corrupt");
        flipped += 1;
    }
    assert!(flipped > 0, "cold run must have persisted at least one artifact");

    ResultCache::global().clear();
    matexp::store::deactivate();

    let mut service = Service::start(cfg).expect("restarted service");
    let rerun =
        service.run(Submission::expm(a.clone(), 64).method(Method::Ours)).expect("re-run");
    assert!(
        rerun.stats.launches > 0,
        "corrupt entries must force a re-execution, not a warm serve"
    );
    assert_bits_eq(&cold.result, &rerun.result);

    matexp::store::deactivate();
}
