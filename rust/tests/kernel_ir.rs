//! Acceptance tests for the typed kernel IR + buffer-residency rebase:
//!
//! 1. **Zero stringly-typed op names in the launch path** — a source grep
//!    over every file between the planner and the substrates: op names may
//!    be rendered/parsed ONLY in `runtime/op.rs` (and at the artifact/wire
//!    edge, which these files are not).
//! 2. **The paper's residency claim as an invariant** — a packed n=1024
//!    power-1024 run copies exactly the two host-edge transfers the §4.3.8
//!    model predicts (the compute-light i-k-j kernel keeps the debug-mode
//!    run fast without weakening the data-path accounting).
//! 3. **Resident beats clone-per-launch at n=1024** — the
//!    `--ablate-residency` comparison, asserted with a generous 1.2×
//!    floor (the structural gap is ~10×: 2 copies vs 2-per-step).

use matexp::coordinator::request::Method;
use matexp::exec::{Executor, Submission};
use matexp::experiments::ablations;
use matexp::linalg::{CpuAlgo, Matrix};
use matexp::plan::Plan;
use matexp::runtime::{Engine, KernelOp};

/// Launch-path sources: everything that dispatches, executes or schedules
/// kernels. None of these may contain a quoted op name or an op-name
/// string builder — `KernelOp` is the only vocabulary.
const LAUNCH_PATH: [&str; 10] = [
    "src/plan/step.rs",
    "src/runtime/backend.rs",
    "src/runtime/engine.rs",
    "src/runtime/cpu.rs",
    "src/runtime/sim.rs",
    "src/runtime/any.rs",
    "src/runtime/arena.rs",
    "src/pool/device.rs",
    "src/pool/pool.rs",
    "src/pool/engine.rs",
];

/// Forbidden tokens: every quoted vocabulary name, the prefix-parsing
/// idiom, and the format-string builders the string protocol used.
const FORBIDDEN: [&str; 16] = [
    "\"matmul\"",
    "\"square\"",
    "\"square2\"",
    "\"square4\"",
    "\"sqmul\"",
    "\"pack2\"",
    "\"step_sq\"",
    "\"step_mul\"",
    "\"unpack0\"",
    "\"mma1\"",
    "\"mma2\"",
    "\"expm64\"",
    "strip_prefix(\"mma\")",
    "strip_prefix(\"square\")",
    "strip_prefix(\"expm\")",
    "format!(\"mma{",
];

#[test]
fn launch_path_has_zero_stringly_typed_ops() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    for file in LAUNCH_PATH {
        let path = root.join(file);
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        for needle in FORBIDDEN {
            assert!(
                !src.contains(needle),
                "{file} contains {needle:?} — op names may only appear in \
                 KernelOp::name/parse (runtime/op.rs) and at the artifact/wire edge"
            );
        }
        // the format!-builders for square{k}/expm{N} names
        for builder in ["format!(\"square", "format!(\"expm"] {
            assert!(
                !src.contains(builder),
                "{file} builds an op name with {builder:?}…) — use KernelOp"
            );
        }
    }
}

#[test]
fn kernel_op_is_the_only_name_authority() {
    // the canonical names still exist — at the edge, via KernelOp
    for (op, name) in [
        (KernelOp::Matmul, "matmul"),
        (KernelOp::SquareChain(4), "square4"),
        (KernelOp::Mma(3), "mma3"),
        (KernelOp::Expm(512), "expm512"),
    ] {
        assert_eq!(op.name(), name);
        assert_eq!(KernelOp::parse(name).unwrap(), op);
    }
}

/// Acceptance: a packed n=1024 power-1024 run's `bytes_copied` drops to
/// the TWO host-edge transfers the paper's model predicts — 8 MiB in, and
/// that's it, regardless of the 12 launches in between.
///
/// The i-k-j kernel skips zero rows, so the all-zeros input keeps each of
/// the 12 launches O(n²) — the test runs in seconds even in debug mode
/// while exercising the full real data path (upload, 10 squarings, pack,
/// unpack, download) at the full 1024×1024 buffer size.
#[test]
fn packed_n1024_power1024_copies_exactly_two_host_edges() {
    const N: usize = 1024;
    let mut engine = Engine::cpu(CpuAlgo::Ikj);
    let a = Matrix::zeros(N);
    let resp = engine
        .run(Submission::expm(a, 1024).method(Method::OursPacked))
        .unwrap();
    let (result, stats) = (resp.result, resp.stats);
    assert_eq!(result, Matrix::zeros(N));
    assert_eq!(stats.h2d_transfers, 1);
    assert_eq!(stats.d2h_transfers, 1);
    assert_eq!(stats.multiplies, 10); // 1024 = 2^10
    // THE criterion: two host-edge transfers' worth of bytes, nothing more
    assert_eq!(stats.bytes_copied, 2 * (N * N * 4) as u64, "{stats:?}");
    // and the launches ping-ponged recycled buffers instead of allocating
    assert!(stats.buffers_recycled >= 8, "{stats:?}");
    // peak residency stays a handful of n×n buffers, not O(launches)
    assert!(
        stats.peak_resident_bytes <= 4 * (N * N * 4) as u64,
        "{stats:?}"
    );
}

/// Acceptance: the residency ablation shows resident execution beating
/// clone-per-launch on the CPU backend at n=1024. The structural gap is
/// 2 host-edge copies vs 2-copies-per-step, so the measured data-path
/// speedup is ~10×; 1.2× is the generous floor that keeps the assertion
/// robust on noisy CI machines.
#[test]
fn residency_ablation_resident_beats_clone_per_launch_at_n1024() {
    let [clone_arm, resident] = ablations::residency_data_path(1024, 10, 42);
    // bytes: 2 per step vs 2 total
    assert_eq!(clone_arm.bytes_copied, 20 * 1024 * 1024 * 4);
    assert_eq!(resident.bytes_copied, 2 * 1024 * 1024 * 4);
    assert!(resident.buffers_recycled >= 9, "{resident:?}");
    let speedup = clone_arm.data_path_s / resident.data_path_s.max(f64::MIN_POSITIVE);
    assert!(
        speedup >= 1.2,
        "resident data path must beat clone-per-launch: {speedup:.2}x \
         (clone {:.6}s vs resident {:.6}s)",
        clone_arm.data_path_s,
        resident.data_path_s
    );
}

/// The full-engine arms at n=1024 (compute-light zeros workload): the
/// clone-per-launch counterfactual copies an order of magnitude more
/// bytes than the resident discipline for the identical plan.
#[test]
fn engine_resident_vs_roundtrip_bytes_at_n1024() {
    const N: usize = 1024;
    let mut engine = Engine::cpu(CpuAlgo::Ikj);
    let a = Matrix::zeros(N);
    let plan = Plan::binary(1024, false); // 10 squarings
    let resident = engine
        .run(Submission::expm(a.clone(), 1024).plan(plan.clone()))
        .unwrap()
        .stats;
    let roundtrip = engine
        .run(Submission::expm(a, 1024).method(Method::PlanRoundtrip).plan(plan))
        .unwrap()
        .stats;
    assert_eq!(resident.bytes_copied, 2 * (N * N * 4) as u64);
    assert_eq!(roundtrip.bytes_copied, 20 * (N * N * 4) as u64);
    assert!(
        roundtrip.bytes_copied >= 10 * resident.bytes_copied,
        "resident {resident:?} vs roundtrip {roundtrip:?}"
    );
}
