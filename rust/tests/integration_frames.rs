//! Integration: the binary frame codec and the wire-layer correctness
//! fixes — bit-exact roundtrips under the property harness, per-connection
//! negotiation, both codecs interleaved on one socket, best-effort id
//! salvage on corrupt lines, client poisoning on connection death, and
//! `Server::shutdown`. Runs unconditionally on the pure-Rust CPU backend.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};

use matexp::bench::loadtest;
use matexp::cache::CacheControl;
use matexp::coordinator::request::Method;
use matexp::error::MatexpError;
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};
use matexp::server::client::MatexpClient;
use matexp::server::frame::{self, Frame};
use matexp::server::proto::{Payload, WireRequest, WireResponse};
use matexp::util::json::Json;
use matexp::util::prop::property;

mod common;
use common::start_server;

/// Bit-exact f32 slice comparison (NaN-tolerant, unlike `==`).
fn assert_bits_eq(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "element {i}: {x} vs {y}");
    }
}

// ---------------------------------------------------------------- proptest

/// Any f32 bit pattern — NaNs, ±Inf, subnormals, -0.0 — survives a frame
/// roundtrip unchanged, at any edge size down to n=1.
#[test]
fn prop_expm_frames_roundtrip_bit_exact() {
    property("expm frame roundtrip", 128, |g| {
        let n = g.usize(1, 6);
        let matrix: Vec<f32> =
            (0..n * n).map(|_| f32::from_bits(g.u64(0, u32::MAX as u64) as u32)).collect();
        let f = Frame::Expm {
            id: g.u64(0, u64::MAX),
            n,
            power: g.u64(0, u64::MAX),
            method: *g.choose(&Method::all()),
            matrix: matrix.clone(),
        };
        let bytes = f.encode();
        let (got, wire) = Frame::read_from(&mut &bytes[..], frame::MAX_PAYLOAD).unwrap();
        assert_eq!(wire, bytes.len());
        match got {
            Frame::Expm { id, n: gn, power, method, matrix: gm } => {
                let Frame::Expm { id: wid, n: wn, power: wp, method: wm, .. } = &f else {
                    unreachable!()
                };
                assert_eq!((id, gn, power, method), (*wid, *wn, *wp, *wm));
                assert_bits_eq(&matrix, &gm);
            }
            other => panic!("decoded wrong variant: {other:?}"),
        }
    });
}

/// Reply frames roundtrip too, stats object included.
#[test]
fn prop_expm_ok_frames_roundtrip_bit_exact() {
    property("expm-ok frame roundtrip", 96, |g| {
        let n = g.usize(1, 5);
        let result: Vec<f32> =
            (0..n * n).map(|_| f32::from_bits(g.u64(0, u32::MAX as u64) as u32)).collect();
        let stats = matexp::server::proto::WireStats {
            launches: g.usize(0, 1000),
            multiplies: g.usize(0, 1000),
            h2d_transfers: g.usize(0, 50),
            d2h_transfers: g.usize(0, 50),
            bytes_copied: g.u64(0, 1 << 40),
            buffers_recycled: g.u64(0, 1 << 20),
            peak_resident_bytes: g.u64(0, 1 << 40),
            wall_s: g.u64(0, 1_000_000) as f64 / 1e6,
            per_device: Vec::new(),
        };
        let f = Frame::ExpmOk { id: g.u64(0, u64::MAX), n, stats: stats.clone(), result: result.clone() };
        let bytes = f.encode();
        let (got, _) = Frame::read_from(&mut &bytes[..], frame::MAX_PAYLOAD).unwrap();
        match got {
            Frame::ExpmOk { stats: gs, result: gr, .. } => {
                assert_eq!(gs, stats);
                assert_bits_eq(&result, &gr);
            }
            other => panic!("decoded wrong variant: {other:?}"),
        }
    });
}

/// Truncating an encoded frame at ANY byte boundary yields a typed
/// error, never a panic, a hang, or a bogus decode.
#[test]
fn prop_truncated_frames_rejected_with_typed_errors() {
    property("truncated frame rejected", 96, |g| {
        let n = g.usize(1, 4);
        let f = Frame::Expm {
            id: g.u64(0, u64::MAX),
            n,
            power: g.u64(1, 1 << 20),
            method: *g.choose(&Method::all()),
            matrix: (0..n * n).map(|_| g.f32(2.0)).collect(),
        };
        let bytes = f.encode();
        let cut = g.usize(0, bytes.len() - 1);
        let err = Frame::read_from(&mut &bytes[..cut], frame::MAX_PAYLOAD).unwrap_err();
        assert!(matches!(err, MatexpError::Service(_)), "cut {cut}: {err}");
    });
}

/// An adversarial declared length is rejected up front by the payload
/// cap — no multi-gigabyte allocation ever happens.
#[test]
fn prop_oversized_lengths_rejected() {
    property("oversized frame rejected", 64, |g| {
        let mut bytes =
            Frame::Error { id: None, kind: "service".into(), message: "x".into() }.encode();
        let huge = g.u64(u64::from(frame::MAX_PAYLOAD) + 1, u32::MAX as u64) as u32;
        bytes[8..12].copy_from_slice(&huge.to_le_bytes());
        let err = Frame::read_from(&mut &bytes[..], frame::MAX_PAYLOAD).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
    });
}

// ------------------------------------------------------- negotiation + e2e

#[test]
fn negotiated_binary_client_computes_correctly() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    assert!(!client.is_binary());
    assert!(client.negotiate_binary().expect("hello roundtrip"), "server speaks frames");
    assert!(client.is_binary());
    let a = Matrix::random_spectral(16, 0.95, 123);
    let want = linalg::expm::expm(&a, 100, CpuAlgo::Ikj).unwrap();
    let (got, stats) = client.expm(&a, 100, Method::Ours).expect("binary expm");
    assert!(got.approx_eq(&want, 1e-3, 1e-3), "diff {}", got.max_abs_diff(&want));
    assert!(stats.multiplies > 0);
    // the server really did speak frames, and the binary payload is
    // leaner on the wire than any JSON encoding of a 16x16 matrix
    let m = client.metrics().expect("metrics");
    assert!(m.get("frames_total").and_then(Json::as_u64).unwrap() >= 2, "{m}");
    let (out_bytes, in_bytes) = client.wire_bytes();
    assert!(out_bytes > 0 && in_bytes > 0);
}

#[test]
fn binary_pipelining_resolves_out_of_order() {
    let (_service, _server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).expect("connect");
    assert!(client.negotiate_binary().unwrap());
    let inputs: Vec<(Matrix, u64)> =
        (0..8u64).map(|i| (Matrix::random_spectral(8, 0.9, 500 + i), 3 + i)).collect();
    let tickets: Vec<_> =
        inputs.iter().map(|(a, p)| client.submit(a, *p, Method::Ours).expect("submit")).collect();
    for (ticket, (a, p)) in tickets.iter().zip(&inputs).rev() {
        let want = linalg::expm::expm(a, *p, CpuAlgo::Ikj).unwrap();
        let (got, _) = client.wait(ticket).expect("binary wait");
        assert!(got.approx_eq(&want, 1e-4, 1e-4), "ticket {}", ticket.id());
    }
}

/// All three request shapes interleave on ONE socket: a binary frame, a
/// pipelined JSON line, and a legacy id-less JSON line — each answered in
/// the codec it arrived in.
#[test]
fn binary_json_and_legacy_interleave_on_one_connection() {
    let (_service, _server, addr) = start_server();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let a = Matrix::random_spectral(8, 0.9, 31);
    let b = Matrix::random_spectral(8, 0.9, 32);
    let c = Matrix::random_spectral(8, 0.9, 33);

    // 1: binary frame, id 1
    let req = Frame::Expm { id: 1, n: 8, power: 5, method: Method::Ours, matrix: a.data().to_vec() };
    writer.write_all(&req.encode()).unwrap();
    // 2: pipelined JSON line, id 2
    let req = WireRequest::Expm {
        n: 8,
        power: 6,
        method: Method::Ours,
        matrix: b.data().to_vec(),
        payload: Payload::Json,
        id: Some(2),
        cache: CacheControl::Use,
    };
    writer.write_all((req.encode().unwrap() + "\n").as_bytes()).unwrap();
    // 3: legacy id-less JSON line (ordered one-shot contract)
    let req = WireRequest::Expm {
        n: 8,
        power: 7,
        method: Method::Ours,
        matrix: c.data().to_vec(),
        payload: Payload::Json,
        id: None,
        cache: CacheControl::Use,
    };
    writer.write_all((req.encode().unwrap() + "\n").as_bytes()).unwrap();

    let (mut got_frame, mut got_json, mut got_legacy) = (None, None, None);
    for _ in 0..3 {
        let first = reader.fill_buf().unwrap()[0];
        if first == frame::MAGIC[0] {
            let (f, _) = Frame::read_from(&mut reader, frame::MAX_PAYLOAD).unwrap();
            match f {
                Frame::ExpmOk { id: 1, n: 8, result, .. } => {
                    got_frame = Some(Matrix::from_vec(8, result).unwrap());
                }
                other => panic!("unexpected frame reply: {other:?}"),
            }
        } else {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            match WireResponse::decode(line.trim_end()).unwrap() {
                WireResponse::Ok { result: Some(data), id, .. } => {
                    let m = Matrix::from_vec(8, data).unwrap();
                    match id {
                        Some(2) => got_json = Some(m),
                        None => got_legacy = Some(m),
                        other => panic!("unexpected reply id {other:?}"),
                    }
                }
                other => panic!("unexpected line reply: {other:?}"),
            }
        }
    }
    let oracle = |m: &Matrix, p: u64| linalg::expm::expm(m, p, CpuAlgo::Ikj).unwrap();
    assert!(got_frame.unwrap().approx_eq(&oracle(&a, 5), 1e-4, 1e-4), "frame reply");
    assert!(got_json.unwrap().approx_eq(&oracle(&b, 6), 1e-4, 1e-4), "json reply");
    assert!(got_legacy.unwrap().approx_eq(&oracle(&c, 7), 1e-4, 1e-4), "legacy reply");
}

/// Content damage inside one well-delimited frame answers an error frame
/// (id salvaged from the intact prefix) and the connection keeps serving.
#[test]
fn damaged_frame_payload_answers_error_and_connection_survives() {
    let (_service, _server, addr) = start_server();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // declared n=3 but a 2x2 matrix present: a content error, id intact
    let a = Matrix::identity(2);
    let good =
        Frame::Expm { id: 77, n: 2, power: 2, method: Method::Ours, matrix: a.data().to_vec() };
    let mut bytes = good.encode();
    bytes[frame::HEADER_LEN + 16..frame::HEADER_LEN + 20].copy_from_slice(&3u32.to_le_bytes());
    writer.write_all(&bytes).unwrap();

    let (f, _) = Frame::read_from(&mut reader, frame::MAX_PAYLOAD).unwrap();
    match f {
        Frame::Error { id, kind, message } => {
            assert_eq!(id, Some(77), "salvaged id routes the error to the ticket");
            assert_eq!(kind, "service");
            assert!(message.contains("truncated") || message.contains("frame"), "{message}");
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    // the stream framing was intact, so the connection still serves
    writer.write_all(&good.encode()).unwrap();
    let (f, _) = Frame::read_from(&mut reader, frame::MAX_PAYLOAD).unwrap();
    assert!(matches!(f, Frame::ExpmOk { id: 77, .. }), "connection survived: {f:?}");
}

// --------------------------------------------------- id salvage (satellite)

/// A corrupt (undecodable) id-tagged line among healthy pipelined ones
/// gets an id-tagged error reply, so its ticket resolves instead of
/// hanging — and the healthy requests are untouched.
#[test]
fn corrupt_line_with_salvageable_id_resolves_its_ticket() {
    let (_service, _server, addr) = start_server();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let a = Matrix::identity(4);
    let healthy = |id: u64, power: u64| WireRequest::Expm {
        n: 4,
        power,
        method: Method::Ours,
        matrix: a.data().to_vec(),
        payload: Payload::Json,
        id: Some(id),
        cache: CacheControl::Use,
    };
    writer.write_all((healthy(10, 2).encode().unwrap() + "\n").as_bytes()).unwrap();
    // truncated JSON — unparseable, but the id fragment is intact
    writer
        .write_all(b"{\"op\":\"expm\",\"id\":11,\"n\":4,\"power\":2,\"matrix\":[1,2\n")
        .unwrap();
    writer.write_all((healthy(12, 3).encode().unwrap() + "\n").as_bytes()).unwrap();

    let mut by_id = std::collections::HashMap::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = WireResponse::decode(line.trim_end()).unwrap();
        by_id.insert(resp.id().expect("every reply carries its request id"), resp);
    }
    match &by_id[&11] {
        WireResponse::Error { message, .. } => {
            assert!(message.contains("bad request"), "{message}");
        }
        other => panic!("corrupt line should error: {other:?}"),
    }
    for id in [10u64, 12] {
        assert!(
            matches!(&by_id[&id], WireResponse::Ok { result: Some(_), .. }),
            "healthy request {id} unaffected: {:?}",
            by_id[&id]
        );
    }
}

// ------------------------------------------------ poisoning (satellite)

/// The server dies mid-pipeline: every outstanding ticket resolves to the
/// typed disconnect error — nothing blocks forever — and so does every
/// later call on the same client.
#[test]
fn client_poisons_when_server_dies_mid_pipeline() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake_server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // swallow exactly the two request lines, then die without a reply
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let _ = stream.shutdown(Shutdown::Both);
    });

    let mut client = MatexpClient::connect(&addr).unwrap();
    let a = Matrix::identity(4);
    let t1 = client.submit(&a, 2, Method::Ours).unwrap();
    let t2 = client.submit(&a, 3, Method::Ours).unwrap();
    let e1 = client.wait(&t1).unwrap_err();
    assert!(matches!(e1, MatexpError::Disconnected(_)), "first ticket: {e1}");
    let e2 = client.wait(&t2).unwrap_err();
    assert!(matches!(e2, MatexpError::Disconnected(_)), "second ticket: {e2}");
    let e3 = client.ping().unwrap_err();
    assert!(matches!(e3, MatexpError::Disconnected(_)), "later calls too: {e3}");
    fake_server.join().unwrap();
}

/// An id-less reply while pipelined tickets are in flight breaks the
/// stream's reply pairing: the client poisons the whole connection
/// instead of mispairing or hanging (the old behavior silently dropped
/// the reply and waited forever).
#[test]
fn client_poisons_on_unidentified_reply_mid_pipeline() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake_server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let mut w = stream.try_clone().unwrap();
        // a reply with no id, while an id-tagged request is outstanding
        w.write_all(b"{\"status\":\"ok\"}\n").unwrap();
        // keep the socket open so the only failure mode is the protocol one
        let mut park = String::new();
        let _ = reader.read_line(&mut park);
    });

    let mut client = MatexpClient::connect(&addr).unwrap();
    let t = client.submit(&Matrix::identity(4), 2, Method::Ours).unwrap();
    let err = client.wait(&t).unwrap_err();
    match &err {
        MatexpError::Disconnected(why) => {
            assert!(why.contains("un-identified"), "{why}");
        }
        other => panic!("expected Disconnected, got {other}"),
    }
    drop(client); // closes the socket; the fake server's park read returns
    fake_server.join().unwrap();
}

// ------------------------------------------------- shutdown (satellite)

/// `Server::shutdown` unblocks the accept loop, cuts live connections,
/// and joins every server thread — while the coordinator service keeps
/// working underneath.
#[test]
fn server_shutdown_cuts_connections_and_stops_listening() {
    let (service, server, addr) = start_server();
    let mut client = MatexpClient::connect(&addr).unwrap();
    client.ping().unwrap();
    let a = Matrix::random_spectral(16, 0.9, 7);
    let in_flight = client.submit(&a, 300, Method::CpuSeq).unwrap();

    server.shutdown(); // returns only after accept + handlers have joined

    // the outstanding ticket resolves (typed disconnect, or the reply won
    // the race against the socket cut) — it must not hang
    match client.wait(&in_flight) {
        Err(MatexpError::Disconnected(_)) | Ok(_) => {}
        Err(e) => panic!("unexpected wait error after shutdown: {e}"),
    }
    // no new connections are served
    let still_up = MatexpClient::connect(&addr).and_then(|mut c| c.ping());
    assert!(still_up.is_err(), "server still serving after shutdown");
    // the service outlives its TCP front-end: direct submission works
    use matexp::exec::Submission;
    let resp = service
        .submit_job(Submission::expm(Matrix::identity(8), 4).method(Method::Ours))
        .and_then(|mut h| h.wait())
        .expect("service usable after server shutdown");
    assert!(resp.result.approx_eq(&Matrix::identity(8), 1e-5, 1e-5));
}

// --------------------------------------------------- codec performance

/// Tentpole acceptance: one encode+decode round trip of an n=1024 expm
/// reply must be ≥5x faster as a binary frame than as the (faster,
/// base64) JSON line encoding. Debug builds assert a relaxed floor — the
/// optimizer gap between the two paths is a release property.
#[test]
fn binary_frames_beat_the_line_codec_at_n1024() {
    let c = loadtest::codec_roundtrip(1024, 3);
    let floor = if cfg!(debug_assertions) { 1.0 } else { 5.0 };
    assert!(
        c.speedup >= floor,
        "frame codec only {:.2}x faster than json+base64 at n=1024 \
         (json_b64 {:.4}s vs frame {:.4}s, floor {floor}x)",
        c.speedup,
        c.json_b64_s,
        c.frame_s,
    );
}
