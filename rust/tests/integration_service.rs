//! Integration: the serving coordinator — concurrent submission, batching
//! behaviour, admission control, metrics, graceful shutdown. Runs
//! unconditionally on the default (pure-Rust CPU) backend.
//!
//! Everything submits through the asynchronous `exec::Executor` surface
//! (`submit_job` + `JobHandle`) — the blocking `submit` shim was removed
//! in 0.4.0.

use std::sync::Arc;
use std::time::Duration;

use matexp::config::MatexpConfig;
use matexp::coordinator::request::{ExpmResponse, Method};
use matexp::coordinator::service::{Service, ServiceHandle};
use matexp::error::{MatexpError, Result};
use matexp::exec::{Priority, Submission};
use matexp::linalg::{self, matrix::Matrix, CpuAlgo};

fn start(workers: usize) -> Arc<ServiceHandle> {
    let mut cfg = MatexpConfig::default();
    cfg.workers = workers;
    cfg.batcher.max_wait_ms = 1;
    Arc::new(Service::start(cfg).expect("service starts"))
}

/// Submit through the surface and wait — the old blocking shim, spelled
/// out (admission errors surface at submit, execution errors at wait).
fn submit_wait(
    service: &ServiceHandle,
    matrix: Matrix,
    power: u64,
    method: Method,
) -> Result<ExpmResponse> {
    service.submit_job(Submission::expm(matrix, power).method(method))?.wait()
}

#[test]
fn serves_correct_results_concurrently() {
    let service = start(2);
    let n = 16;
    std::thread::scope(|scope| {
        for c in 0..6u64 {
            let service = Arc::clone(&service);
            scope.spawn(move || {
                let a = Matrix::random_spectral(n, 0.95, c);
                let power = 32 + c;
                let want = linalg::expm::expm(&a, power, CpuAlgo::Ikj).unwrap();
                let resp = submit_wait(&service, a, power, Method::Ours).expect("served");
                assert!(
                    resp.result.approx_eq(&want, 1e-3, 1e-3),
                    "client {c}: diff {}",
                    resp.result.max_abs_diff(&want)
                );
            });
        }
    });
    let m = service.metrics();
    assert_eq!(m.requests_total, 6);
    assert_eq!(m.responses_total, 6);
    assert_eq!(m.errors_total, 0);
}

#[test]
fn all_methods_servable() {
    let service = start(1);
    let a = Matrix::random_spectral(64, 0.95, 3);
    let want = linalg::expm::expm(&a, 64, CpuAlgo::Ikj).unwrap();
    for method in [
        Method::Ours,
        Method::OursPacked,
        Method::OursChained,
        Method::AdditionChain,
        Method::FusedArtifact, // 64 is a shipped fused power
        Method::NaiveGpu,
        Method::CpuSeq,
    ] {
        let resp = submit_wait(&service, a.clone(), 64, method).expect("served");
        assert!(
            resp.result.approx_eq(&want, 1e-2, 1e-2),
            "{method}: diff {}",
            resp.result.max_abs_diff(&want)
        );
        assert_eq!(resp.method, method);
    }
}

#[test]
fn admission_rejects_bad_requests() {
    let service = start(1);
    // power 0
    assert!(submit_wait(&service, Matrix::identity(16), 0, Method::Ours).is_err());
    // absurd power
    assert!(submit_wait(&service, Matrix::identity(16), 1 << 40, Method::Ours).is_err());
    // non-finite input
    let mut bad = Matrix::identity(16);
    bad.set(0, 0, f32::INFINITY);
    assert!(submit_wait(&service, bad, 8, Method::Ours).is_err());
    let m = service.metrics();
    assert_eq!(m.rejected_total, 3);
    // the cpu backend is size-unrestricted: odd sizes are served, not
    // rejected (PJRT admission rejects sizes outside the artifact set)
    submit_wait(&service, Matrix::identity(10), 8, Method::Ours).unwrap();
    submit_wait(&service, Matrix::identity(100), 8, Method::CpuSeq).unwrap();
    assert_eq!(service.metrics().rejected_total, 3);
}

#[test]
fn missing_fused_power_is_clean_error_not_crash() {
    let service = start(1);
    // power 65 is not a shipped fused power
    let err = submit_wait(&service, Matrix::identity(64), 65, Method::FusedArtifact)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no artifact") || err.contains("no fused"), "{err}");
    // service still healthy afterwards
    submit_wait(&service, Matrix::identity(64), 64, Method::Ours).unwrap();
}

#[test]
fn batching_coalesces_same_size_requests() {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 1;
    cfg.batcher.max_batch = 4;
    cfg.batcher.max_wait_ms = 200; // long deadline: size triggers shipping
    let service = Arc::new(Service::start(cfg).expect("service starts"));
    std::thread::scope(|scope| {
        for c in 0..8u64 {
            let service = Arc::clone(&service);
            scope.spawn(move || {
                let a = Matrix::random_spectral(16, 0.9, c);
                submit_wait(&service, a, 16, Method::Ours).expect("served");
            });
        }
    });
    let m = service.metrics();
    assert_eq!(m.batched_requests_total, 8);
    assert!(
        m.batches_total < 8,
        "some coalescing expected: {} batches for 8 requests",
        m.batches_total
    );
}

#[test]
fn sim_backend_serves_with_simulated_wall_clock() {
    let mut cfg = MatexpConfig::default();
    cfg.backend = matexp::runtime::BackendKind::Sim;
    cfg.workers = 1;
    cfg.batcher.max_wait_ms = 1;
    let service = Service::start(cfg).expect("sim service starts");
    let a = Matrix::random_spectral(64, 0.95, 4);
    let naive = submit_wait(&service, a.clone(), 128, Method::NaiveGpu).unwrap();
    let ours = submit_wait(&service, a, 128, Method::Ours).unwrap();
    // simulated 2012 wall-clock: the paper's headline ordering holds
    assert!(
        naive.stats.wall_s > ours.stats.wall_s,
        "sim naive {} <= sim ours {}",
        naive.stats.wall_s,
        ours.stats.wall_s
    );
}

/// Satellite acceptance: deadline expiry and cancellation against a LIVE
/// ServiceHandle — a queued job behind a slow one misses a tight
/// deadline (typed error), a cancelled job never delivers, and the
/// service serves normally afterwards.
#[test]
fn live_deadline_and_cancel_behind_a_slow_job() {
    let mut cfg = MatexpConfig::default();
    cfg.workers = 1; // one worker: the slow job serializes everything behind it
    cfg.batcher.max_wait_ms = 1;
    let service = Service::start(cfg).expect("service starts");

    // occupy the worker: 199 sequential full multiplies at n=48
    let slow_sub =
        Submission::expm(Matrix::random_spectral(48, 0.9, 1), 200).method(Method::CpuSeq);
    let slow = service.submit_job(slow_sub).expect("slow submit");

    // a queued job with a deadline far shorter than the slow job's run
    let mut doomed = service
        .submit_job(
            Submission::expm(Matrix::random_spectral(16, 0.9, 2), 8)
                .deadline(Duration::from_millis(2)),
        )
        .expect("doomed submit");
    match doomed.wait() {
        Err(MatexpError::Deadline(_)) => {}
        other => panic!("want typed deadline error, got {other:?}"),
    }

    // a cancelled queued job never delivers
    let mut cancelled = service
        .submit_job(Submission::expm(Matrix::random_spectral(16, 0.9, 3), 8))
        .expect("submit");
    cancelled.cancel();
    assert!(cancelled.wait().is_err());

    // drain the slow job, then verify the service is healthy
    let mut slow = slow;
    assert!(slow.wait().expect("slow job completes").result.is_finite());
    let a = Matrix::random_spectral(16, 0.9, 4);
    let want = linalg::expm::expm(&a, 32, CpuAlgo::Ikj).unwrap();
    let resp = service
        .submit_job(Submission::expm(a, 32).priority(Priority::High))
        .expect("submit")
        .wait()
        .expect("healthy after deadline + cancel");
    assert!(resp.result.approx_eq(&want, 1e-3, 1e-3));
}

#[test]
fn shutdown_then_submit_fails_cleanly() {
    let service = start(1);
    let service = Arc::try_unwrap(service).ok().expect("sole owner");
    submit_wait(&service, Matrix::identity(16), 4, Method::Ours).unwrap();
    service.shutdown();
}
