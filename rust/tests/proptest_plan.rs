//! Property-based tests over the planner, engine-replay and coordinator
//! invariants (in-tree `util::prop` harness; see DESIGN.md §8).

use std::time::Instant;

use matexp::config::BatcherConfig;
use matexp::coordinator::batcher::Batcher;
use matexp::coordinator::request::{ExpmRequest, Method};
use matexp::exec::{Executor, Submission};
use matexp::linalg::{matrix::Matrix, CpuAlgo};
use matexp::plan::{mod_pow, Plan, PlanKind, Step};
use matexp::runtime::Engine;
use matexp::util::json::Json;
use matexp::util::prop::property;

const M: u64 = 1_000_003;

#[test]
fn every_planner_evaluates_to_pow_mod() {
    property("planners == mod_pow", 300, |g| {
        let power = g.u64(1, 1 << 14);
        let base = g.u64(2, 1000);
        let want = mod_pow(base, power, M);
        for plan in [
            Plan::naive(power.min(2048)), // naive plans are O(N); bound them
            Plan::binary(power, false),
            Plan::binary(power, true),
            Plan::chained(power, &[4, 2]),
            Plan::addition_chain(power),
        ] {
            plan.validate().expect("plan validates");
            if plan.power == power {
                assert_eq!(plan.eval_mod(base, M).unwrap(), want, "{:?}", plan.kind);
            }
        }
    });
}

#[test]
fn binary_multiply_count_formula() {
    property("binary multiplies = floor(log2)+popcount-1", 500, |g| {
        let power = g.u64(1, 1 << 30);
        let plan = Plan::binary(power, false);
        let expected = (63 - power.leading_zeros()) as usize + power.count_ones() as usize - 1;
        assert_eq!(plan.multiplies(), expected);
        // fusion never changes multiplies, never increases launches
        let fused = Plan::binary(power, true);
        assert_eq!(fused.multiplies(), expected);
        assert!(fused.launches() <= plan.launches());
    });
}

#[test]
fn addition_chain_never_worse_than_binary() {
    property("chain <= binary multiplies", 150, |g| {
        let power = g.u64(1, 4096);
        let chain = Plan::addition_chain(power);
        let binary = Plan::binary(power, false);
        chain.validate().unwrap();
        assert!(
            chain.multiplies() <= binary.multiplies(),
            "N={power}: chain {} > binary {}",
            chain.multiplies(),
            binary.multiplies()
        );
    });
}

#[test]
fn chained_plan_multiplies_invariant_under_chain_set() {
    property("chained multiplies == binary multiplies", 200, |g| {
        let power = g.u64(1, 1 << 20);
        let with = Plan::chained(power, &[4, 2]);
        let without = Plan::binary(power, false);
        assert_eq!(with.multiplies(), without.multiplies());
        assert!(with.launches() <= without.launches());
    });
}

#[test]
fn plan_eval_matches_matrix_exponentiation_small() {
    property("plan eval on 2x2 matrices", 60, |g| {
        let power = g.u64(1, 64);
        // a 2x2 contraction keeps f32 powers finite
        let a = Matrix::from_vec(
            2,
            vec![g.f32(0.7), g.f32(0.7), g.f32(0.7), g.f32(0.7)],
        )
        .unwrap();
        let naive = matexp::linalg::expm::expm_naive(&a, power, matexp::linalg::CpuAlgo::Naive)
            .unwrap();
        for plan in [Plan::binary(power, true), Plan::addition_chain(power)] {
            let got =
                matexp::linalg::expm::expm_plan(&a, &plan, matexp::linalg::CpuAlgo::Naive)
                    .unwrap();
            assert!(
                got.approx_eq(&naive, 1e-3, 1e-3),
                "{:?} N={power}: diff {}",
                plan.kind,
                got.max_abs_diff(&naive)
            );
        }
    });
}

#[test]
fn sqmul_register_aliasing_squares() {
    // `SqMul { acc, base }` with acc == base: eval computes
    // new_acc = acc·base = b², then new_base = b², and both writes land on
    // the same register — the aliased step degenerates to one squaring.
    let plan = Plan {
        power: 2,
        kind: PlanKind::Binary,
        steps: vec![Step::SqMul { acc: 0, base: 0 }],
        n_regs: 1,
        result: 0,
    };
    plan.validate().unwrap();
    for base in [2u64, 3, 97] {
        assert_eq!(plan.eval_mod(base, M).unwrap(), base * base % M);
    }
    // two aliased steps: ((b²)²)² is NOT what you get — each SqMul squares
    // once under aliasing, so two steps give b⁴
    let plan2 = Plan {
        power: 4,
        kind: PlanKind::Binary,
        steps: vec![Step::SqMul { acc: 0, base: 0 }, Step::SqMul { acc: 0, base: 0 }],
        n_regs: 1,
        result: 0,
    };
    assert_eq!(plan2.eval_mod(3, M).unwrap(), mod_pow(3, 4, M));
}

#[test]
fn random_plans_with_aliasing_track_exponent_model() {
    // Build random (valid-by-construction) plans over 3 registers,
    // including aliased SqMul steps, while tracking the exponent each
    // register holds; eval_mod must agree with mod_pow of the model.
    property("random plans == exponent model", 200, |g| {
        let n_regs = 3usize;
        let mut exp: Vec<Option<u64>> = vec![None; n_regs];
        exp[0] = Some(1);
        let mut steps = Vec::new();
        let limit: u64 = 1 << 40;
        for _ in 0..g.usize(1, 14) {
            let written: Vec<usize> =
                (0..n_regs).filter(|&r| exp[r].is_some()).collect();
            match g.usize(0, 3) {
                0 => {
                    let src = *g.choose(&written);
                    let dst = g.usize(0, n_regs - 1);
                    steps.push(Step::Copy { dst, src });
                    exp[dst] = exp[src];
                }
                1 => {
                    let lhs = *g.choose(&written);
                    let rhs = *g.choose(&written);
                    let dst = g.usize(0, n_regs - 1);
                    let e = exp[lhs].unwrap() + exp[rhs].unwrap();
                    if e > limit {
                        continue;
                    }
                    steps.push(Step::Mul { dst, lhs, rhs });
                    exp[dst] = Some(e);
                }
                2 => {
                    let acc = *g.choose(&written);
                    let base = *g.choose(&written); // may alias acc
                    let (ea, eb) = (exp[acc].unwrap(), exp[base].unwrap());
                    if ea + eb > limit || eb * 2 > limit {
                        continue;
                    }
                    steps.push(Step::SqMul { acc, base });
                    // eval order: acc = old_acc + old_base, then
                    // base = 2·old_base; an aliased pair ends at 2·old_base
                    exp[acc] = Some(ea + eb);
                    exp[base] = Some(eb * 2);
                }
                _ => {
                    let reg = *g.choose(&written);
                    let k = g.usize(1, 4) as u32;
                    let e = exp[reg].unwrap();
                    if e << k > limit {
                        continue;
                    }
                    steps.push(Step::SquareChain { reg, k });
                    exp[reg] = Some(e << k);
                }
            }
        }
        let result = *g.choose(
            &(0..n_regs).filter(|&r| exp[r].is_some()).collect::<Vec<_>>(),
        );
        let power = exp[result].unwrap();
        let plan = Plan { power, kind: PlanKind::Binary, steps, n_regs, result };
        plan.validate().expect("constructed valid");
        let base = g.u64(2, 1000);
        assert_eq!(
            plan.eval_mod(base, M).unwrap(),
            mod_pow(base, power, M),
            "plan {plan:?}"
        );
    });
}

#[test]
fn cpu_engine_replay_matches_plan_cost_model() {
    // ExecStats invariants on CpuBackend: replaying ANY valid plan yields
    // launches == plan.launches(), multiplies == plan.multiplies(), and
    // exactly one host crossing each way (the cpu pair-split is free, so
    // this holds for fused/SqMul plans too).
    property("engine replay == plan cost model", 120, |g| {
        let mut engine = Engine::cpu(CpuAlgo::Naive); // construction is free
        let power = g.u64(1, 1 << 12);
        // the naive planner is O(N), so its arm bounds the power — the
        // submission's power must match the plan's for admission
        let plan = match g.usize(0, 4) {
            0 => Plan::naive(power.min(64)),
            1 => Plan::binary(power, false),
            2 => Plan::binary(power, true),
            3 => Plan::chained(power, &[4, 2]),
            _ => Plan::addition_chain(power),
        };
        let power = plan.power;
        let (kind, launches, multiplies) = (plan.kind, plan.launches(), plan.multiplies());
        let a = Matrix::identity(4);
        let resp = engine
            .run(Submission::expm(a.clone(), power).plan(plan))
            .expect("replay through the execution surface");
        assert!(resp.result.approx_eq(&a, 1e-6, 0.0), "identity stays identity");
        assert_eq!(resp.stats.launches, launches, "{kind:?}");
        assert_eq!(resp.stats.multiplies, multiplies, "{kind:?}");
        assert_eq!(resp.stats.h2d_transfers, 1, "{kind:?}");
        assert_eq!(resp.stats.d2h_transfers, 1, "{kind:?}");
    });
}

#[test]
fn batcher_conserves_and_orders_requests() {
    property("batcher conservation", 120, |g| {
        let max_batch = g.usize(1, 8);
        let cfg = BatcherConfig { max_batch, max_wait_ms: 1000, max_queue: usize::MAX };
        let mut b = Batcher::new(cfg);
        let now = Instant::now();
        let n_reqs = g.usize(0, 40);
        let mut shipped = Vec::new();
        for id in 0..n_reqs as u64 {
            let n = 8usize << g.usize(0, 2); // sizes 8/16/32
            let req = ExpmRequest::new(id, Matrix::zeros(n), 4, Method::Ours);
            if let Some(batch) = b.push(req, now) {
                assert_eq!(batch.requests.len(), max_batch, "ships exactly at max_batch");
                assert!(batch.requests.iter().all(|r| r.n() == batch.n));
                shipped.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        for batch in b.flush_all() {
            assert!(batch.requests.len() <= max_batch);
            shipped.extend(batch.requests.iter().map(|r| r.id));
        }
        // conservation: every id exactly once
        shipped.sort_unstable();
        let want: Vec<u64> = (0..n_reqs as u64).collect();
        assert_eq!(shipped, want);
        assert!(b.is_empty());
    });
}

#[test]
fn json_roundtrip_of_random_values() {
    property("json value roundtrip", 200, |g| {
        // build a random JSON tree from the draws
        fn build(g: &mut matexp::util::prop::Gen, depth: usize) -> Json {
            match if depth >= 3 { g.usize(0, 3) } else { g.usize(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.u64(0, 1 << 50) as f64) / 8.0),
                3 => Json::Str(format!("s{}\n\"{}\"", g.u64(0, 999), g.u64(0, 9))),
                4 => Json::Arr((0..g.usize(0, 4)).map(|_| build(g, depth + 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..g.usize(0, 4) {
                        m.insert(format!("k{i}"), build(g, depth + 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = build(g, 0);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    });
}

#[test]
fn matrix_algebra_properties() {
    property("matrix algebra", 80, |g| {
        let n = g.usize(1, 12);
        let seed = g.u64(0, 1 << 32);
        let a = Matrix::random(n, seed.max(1));
        let b = Matrix::random(n, seed.wrapping_add(1).max(1));
        let e = Matrix::identity(n);
        let mm = matexp::linalg::naive::matmul_naive;
        // identity
        assert_eq!(mm(&a, &e), a);
        // transpose anti-homomorphism: (ab)^T == b^T a^T
        let ab_t = mm(&a, &b).transpose();
        let bt_at = mm(&b.transpose(), &a.transpose());
        assert!(ab_t.approx_eq(&bt_at, 1e-3, 1e-3));
        // associativity (within f32 tolerance)
        let c = Matrix::random(n, seed.wrapping_add(2).max(1));
        let left = mm(&mm(&a, &b), &c);
        let right = mm(&a, &mm(&b, &c));
        assert!(left.approx_eq(&right, 1e-2, 1e-2));
    });
}
