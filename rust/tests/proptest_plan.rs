//! Property-based tests over the planner and coordinator invariants
//! (in-tree `util::prop` harness; see DESIGN.md §8).

use std::time::Instant;

use matexp::config::BatcherConfig;
use matexp::coordinator::batcher::Batcher;
use matexp::coordinator::request::{ExpmRequest, Method};
use matexp::linalg::matrix::Matrix;
use matexp::plan::{mod_pow, Plan};
use matexp::util::json::Json;
use matexp::util::prop::property;

const M: u64 = 1_000_003;

#[test]
fn every_planner_evaluates_to_pow_mod() {
    property("planners == mod_pow", 300, |g| {
        let power = g.u64(1, 1 << 14);
        let base = g.u64(2, 1000);
        let want = mod_pow(base, power, M);
        for plan in [
            Plan::naive(power.min(2048)), // naive plans are O(N); bound them
            Plan::binary(power, false),
            Plan::binary(power, true),
            Plan::chained(power, &[4, 2]),
            Plan::addition_chain(power),
        ] {
            plan.validate().expect("plan validates");
            if plan.power == power {
                assert_eq!(plan.eval_mod(base, M).unwrap(), want, "{:?}", plan.kind);
            }
        }
    });
}

#[test]
fn binary_multiply_count_formula() {
    property("binary multiplies = floor(log2)+popcount-1", 500, |g| {
        let power = g.u64(1, 1 << 30);
        let plan = Plan::binary(power, false);
        let expected = (63 - power.leading_zeros()) as usize + power.count_ones() as usize - 1;
        assert_eq!(plan.multiplies(), expected);
        // fusion never changes multiplies, never increases launches
        let fused = Plan::binary(power, true);
        assert_eq!(fused.multiplies(), expected);
        assert!(fused.launches() <= plan.launches());
    });
}

#[test]
fn addition_chain_never_worse_than_binary() {
    property("chain <= binary multiplies", 150, |g| {
        let power = g.u64(1, 4096);
        let chain = Plan::addition_chain(power);
        let binary = Plan::binary(power, false);
        chain.validate().unwrap();
        assert!(
            chain.multiplies() <= binary.multiplies(),
            "N={power}: chain {} > binary {}",
            chain.multiplies(),
            binary.multiplies()
        );
    });
}

#[test]
fn chained_plan_multiplies_invariant_under_chain_set() {
    property("chained multiplies == binary multiplies", 200, |g| {
        let power = g.u64(1, 1 << 20);
        let with = Plan::chained(power, &[4, 2]);
        let without = Plan::binary(power, false);
        assert_eq!(with.multiplies(), without.multiplies());
        assert!(with.launches() <= without.launches());
    });
}

#[test]
fn plan_eval_matches_matrix_exponentiation_small() {
    property("plan eval on 2x2 matrices", 60, |g| {
        let power = g.u64(1, 64);
        // a 2x2 contraction keeps f32 powers finite
        let a = Matrix::from_vec(
            2,
            vec![g.f32(0.7), g.f32(0.7), g.f32(0.7), g.f32(0.7)],
        )
        .unwrap();
        let naive = matexp::linalg::expm::expm_naive(&a, power, matexp::linalg::CpuAlgo::Naive)
            .unwrap();
        for plan in [Plan::binary(power, true), Plan::addition_chain(power)] {
            let got =
                matexp::linalg::expm::expm_plan(&a, &plan, matexp::linalg::CpuAlgo::Naive)
                    .unwrap();
            assert!(
                got.approx_eq(&naive, 1e-3, 1e-3),
                "{:?} N={power}: diff {}",
                plan.kind,
                got.max_abs_diff(&naive)
            );
        }
    });
}

#[test]
fn batcher_conserves_and_orders_requests() {
    property("batcher conservation", 120, |g| {
        let max_batch = g.usize(1, 8);
        let cfg = BatcherConfig { max_batch, max_wait_ms: 1000, max_queue: usize::MAX };
        let mut b = Batcher::new(cfg);
        let now = Instant::now();
        let n_reqs = g.usize(0, 40);
        let mut shipped = Vec::new();
        for id in 0..n_reqs as u64 {
            let n = 8usize << g.usize(0, 2); // sizes 8/16/32
            let req = ExpmRequest { id, matrix: Matrix::zeros(n), power: 4, method: Method::Ours };
            if let Some(batch) = b.push(req, now) {
                assert_eq!(batch.requests.len(), max_batch, "ships exactly at max_batch");
                assert!(batch.requests.iter().all(|r| r.n() == batch.n));
                shipped.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        for batch in b.flush_all() {
            assert!(batch.requests.len() <= max_batch);
            shipped.extend(batch.requests.iter().map(|r| r.id));
        }
        // conservation: every id exactly once
        shipped.sort_unstable();
        let want: Vec<u64> = (0..n_reqs as u64).collect();
        assert_eq!(shipped, want);
        assert!(b.is_empty());
    });
}

#[test]
fn json_roundtrip_of_random_values() {
    property("json value roundtrip", 200, |g| {
        // build a random JSON tree from the draws
        fn build(g: &mut matexp::util::prop::Gen, depth: usize) -> Json {
            match if depth >= 3 { g.usize(0, 3) } else { g.usize(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.u64(0, 1 << 50) as f64) / 8.0),
                3 => Json::Str(format!("s{}\n\"{}\"", g.u64(0, 999), g.u64(0, 9))),
                4 => Json::Arr((0..g.usize(0, 4)).map(|_| build(g, depth + 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..g.usize(0, 4) {
                        m.insert(format!("k{i}"), build(g, depth + 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = build(g, 0);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    });
}

#[test]
fn matrix_algebra_properties() {
    property("matrix algebra", 80, |g| {
        let n = g.usize(1, 12);
        let seed = g.u64(0, 1 << 32);
        let a = Matrix::random(n, seed.max(1));
        let b = Matrix::random(n, seed.wrapping_add(1).max(1));
        let e = Matrix::identity(n);
        let mm = matexp::linalg::naive::matmul_naive;
        // identity
        assert_eq!(mm(&a, &e), a);
        // transpose anti-homomorphism: (ab)^T == b^T a^T
        let ab_t = mm(&a, &b).transpose();
        let bt_at = mm(&b.transpose(), &a.transpose());
        assert!(ab_t.approx_eq(&bt_at, 1e-3, 1e-3));
        // associativity (within f32 tolerance)
        let c = Matrix::random(n, seed.wrapping_add(2).max(1));
        let left = mm(&mm(&a, &b), &c);
        let right = mm(&a, &mm(&b, &c));
        assert!(left.approx_eq(&right, 1e-2, 1e-2));
    });
}
