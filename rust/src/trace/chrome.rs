//! Chrome trace-event export: flight-recorder spans → a JSON array
//! loadable in Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`.
//!
//! Every span becomes one **complete event** (`"ph":"X"`) with
//! microsecond `ts`/`dur` on the shared trace clock; the request's
//! [`super::TraceId`] is used as the `tid`, so each request renders as
//! its own timeline row and the per-stage spans (wire decode → queue →
//! execute → launches → wire encode) line up visually.
//!
//! The encoding is **bit-stable**: events are sorted by `(ts, seq, name)`
//! and the JSON object keys are emitted in sorted order
//! ([`crate::util::json::Json::Obj`] is a `BTreeMap`), so the same span
//! set always serializes to byte-identical output — asserted by a test,
//! and what makes `matexp trace` dumps diffable across runs.

use crate::error::{MatexpError, Result};
use crate::json_obj;
use crate::util::json::Json;

use super::Span;

/// Render spans as a Chrome trace-event JSON array (complete events,
/// deterministically ordered).
pub fn export(spans: &[Span]) -> Json {
    let mut sorted: Vec<&Span> = spans.iter().collect();
    sorted.sort_by(|a, b| {
        (a.start_us, a.seq, a.name()).cmp(&(b.start_us, b.seq, b.name()))
    });
    let events: Vec<Json> = sorted
        .into_iter()
        .map(|s| {
            let mut args = json_obj![("n", s.n), ("seq", s.seq), ("trace_id", s.trace_id)];
            if let (Json::Obj(map), Some(op)) = (&mut args, s.op) {
                map.insert("op".to_string(), Json::Str(op.name()));
            }
            json_obj![
                ("name", s.name()),
                ("cat", s.kind.category()),
                ("ph", "X"),
                ("ts", s.start_us),
                ("dur", s.dur_us),
                ("pid", 1u64),
                ("tid", s.trace_id),
                ("args", args),
            ]
        })
        .collect();
    Json::Arr(events)
}

/// Render spans straight to the serialized Chrome trace string.
pub fn export_string(spans: &[Span]) -> String {
    export(spans).to_string()
}

fn want_u64(event: &Json, field: &str, idx: usize) -> Result<u64> {
    event
        .get(field)
        .and_then(Json::as_u64)
        .ok_or_else(|| bad(idx, &format!("missing or non-integer {field:?}")))
}

fn bad(idx: usize, msg: &str) -> MatexpError {
    MatexpError::Service(format!("chrome trace event {idx}: {msg}"))
}

/// Validate a parsed document against the Chrome trace-event shape this
/// module emits (what `matexp trace --check` and the CI smoke job run).
/// Returns the number of events.
pub fn validate(doc: &Json) -> Result<usize> {
    let events = doc
        .as_arr()
        .ok_or_else(|| MatexpError::Service("chrome trace must be a JSON array".into()))?;
    for (idx, event) in events.iter().enumerate() {
        if event.as_obj().is_none() {
            return Err(bad(idx, "not an object"));
        }
        match event.get("name").and_then(Json::as_str) {
            Some(name) if !name.is_empty() => {}
            _ => return Err(bad(idx, "missing or empty \"name\"")),
        }
        if event.get("ph").and_then(Json::as_str) != Some("X") {
            return Err(bad(idx, "\"ph\" must be \"X\" (complete event)"));
        }
        let ts = want_u64(event, "ts", idx)?;
        let dur = want_u64(event, "dur", idx)?;
        if ts.checked_add(dur).is_none() {
            return Err(bad(idx, "ts + dur overflows"));
        }
        want_u64(event, "pid", idx)?;
        want_u64(event, "tid", idx)?;
        if let Some(args) = event.get("args") {
            if args.as_obj().is_none() {
                return Err(bad(idx, "\"args\" must be an object"));
            }
        }
    }
    Ok(events.len())
}

/// Parse and validate a serialized trace dump. Returns the event count.
pub fn validate_str(text: &str) -> Result<usize> {
    let doc = Json::parse(text).map_err(MatexpError::Json)?;
    validate(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::KernelOp;
    use crate::trace::{Codec, SpanKind, Tier};

    fn sample_spans() -> Vec<Span> {
        vec![
            Span {
                seq: 3,
                trace_id: 7,
                kind: SpanKind::Execute,
                start_us: 15,
                dur_us: 100,
                op: None,
                n: 64,
            },
            Span {
                seq: 1,
                trace_id: 7,
                kind: SpanKind::WireDecode(Codec::Frame),
                start_us: 0,
                dur_us: 5,
                op: None,
                n: 64,
            },
            Span {
                seq: 4,
                trace_id: 7,
                kind: SpanKind::Launch,
                start_us: 20,
                dur_us: 50,
                op: Some(KernelOp::SquareChain(4)),
                n: 64,
            },
            Span {
                seq: 5,
                trace_id: 7,
                kind: SpanKind::CacheMiss(Tier::Result),
                start_us: 16,
                dur_us: 0,
                op: None,
                n: 64,
            },
        ]
    }

    #[test]
    fn export_is_bit_stable_and_sorted() {
        let spans = sample_spans();
        let a = export_string(&spans);
        let mut reversed = spans.clone();
        reversed.reverse();
        let b = export_string(&reversed);
        assert_eq!(a, b, "same span set must serialize byte-identically");
        // sorted by ts: decode (0) first, execute (15) before launch (20)
        let first_decode = a.find("wire_decode_frame").unwrap();
        let exec = a.find("\"execute\"").unwrap();
        let launch = a.find("launch:square4").unwrap();
        assert!(first_decode < exec && exec < launch, "{a}");
    }

    #[test]
    fn export_validates_and_counts() {
        let spans = sample_spans();
        let text = export_string(&spans);
        assert_eq!(validate_str(&text).unwrap(), spans.len());
    }

    #[test]
    fn launch_events_carry_op_and_n() {
        let text = export_string(&sample_spans());
        let doc = Json::parse(&text).unwrap();
        let launch = doc
            .as_arr()
            .unwrap()
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("launch:square4"))
            .unwrap();
        let args = launch.get("args").unwrap();
        assert_eq!(args.get("op").and_then(Json::as_str), Some("square4"));
        assert_eq!(args.get("n").and_then(Json::as_u64), Some(64));
        assert_eq!(launch.get("cat").and_then(Json::as_str), Some("exec"));
        assert_eq!(launch.get("tid").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        assert!(validate_str("{}").is_err(), "object, not array");
        assert!(validate_str("[{}]").is_err(), "event without name");
        assert!(validate_str("[{\"name\":\"x\",\"ph\":\"B\",\"ts\":0,\"dur\":0,\"pid\":1,\"tid\":1}]").is_err(), "wrong phase");
        assert!(validate_str("[{\"name\":\"x\",\"ph\":\"X\",\"ts\":-4,\"dur\":0,\"pid\":1,\"tid\":1}]").is_err(), "negative ts");
        assert!(validate_str("not json").is_err());
        assert_eq!(validate_str("[]").unwrap(), 0, "empty trace is valid");
        assert_eq!(
            validate_str("[{\"name\":\"x\",\"ph\":\"X\",\"ts\":1,\"dur\":2,\"pid\":1,\"tid\":9}]")
                .unwrap(),
            1
        );
    }
}
