//! Prometheus text-exposition rendering of the service
//! [`MetricsSnapshot`] — the third trace egress path (`metrics --format
//! prometheus` on the wire and CLI), scrape-ready for a stock Prometheus
//! server with zero dependencies.
//!
//! Every counter keeps the `_total` suffix, the batcher queue depth and
//! cache occupancy are gauges, per-device pool utilization becomes
//! labeled series (`matexp_device_jobs{device="sim#0"}`), and the
//! latency histogram is rendered as a proper cumulative
//! `_bucket`/`_sum`/`_count` family with `le="+Inf"` — not the raw
//! per-bucket counts the JSON endpoint reports. [`lint`] enforces the
//! naming rules (unique series, `_total` on counters, declared types)
//! and runs in this module's tests so a renderer change cannot silently
//! ship malformed exposition.

use std::collections::HashSet;
use std::fmt::Write as _;

use crate::coordinator::metrics::MetricsSnapshot;

/// Metric name prefix for everything this module emits.
pub const PREFIX: &str = "matexp_";

fn counter(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {PREFIX}{name} {help}");
    let _ = writeln!(out, "# TYPE {PREFIX}{name} counter");
    let _ = writeln!(out, "{PREFIX}{name} {value}");
}

fn gauge(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {PREFIX}{name} {help}");
    let _ = writeln!(out, "# TYPE {PREFIX}{name} gauge");
    let _ = writeln!(out, "{PREFIX}{name} {value}");
}

/// Render a metrics snapshot in Prometheus text exposition format
/// (version 0.0.4 — what `/metrics` scrape endpoints serve).
pub fn render(snap: &MetricsSnapshot) -> String {
    let mut out = String::with_capacity(4096);
    counter(&mut out, "requests_total", "Requests submitted (accepted or not).", snap.requests_total);
    counter(&mut out, "responses_total", "Requests answered successfully.", snap.responses_total);
    counter(&mut out, "rejected_total", "Requests rejected by admission control.", snap.rejected_total);
    counter(&mut out, "errors_total", "Requests that failed in execution.", snap.errors_total);
    counter(&mut out, "batches_total", "Batches shipped to workers.", snap.batches_total);
    counter(
        &mut out,
        "batched_requests_total",
        "Requests across all shipped batches.",
        snap.batched_requests_total,
    );
    counter(&mut out, "launches_total", "Kernel launches across all served responses.", snap.launches_total);
    counter(&mut out, "multiplies_total", "Matrix multiplies across all served responses.", snap.multiplies_total);
    counter(&mut out, "bytes_copied_total", "Host-edge bytes copied across all served responses.", snap.bytes_copied_total);
    counter(
        &mut out,
        "buffers_recycled_total",
        "Launch outputs served from recycled arena buffers.",
        snap.buffers_recycled_total,
    );
    counter(&mut out, "wire_bytes_in_total", "Wire bytes read off client connections.", snap.wire_bytes_in_total);
    counter(&mut out, "wire_bytes_out_total", "Wire bytes written to client connections.", snap.wire_bytes_out_total);
    counter(&mut out, "frames_total", "Binary frames handled by the TCP front-end.", snap.frames_total);
    counter(
        &mut out,
        "wire_bytes_recycled_total",
        "Request payload bytes decoded into recycled wire-arena buffers.",
        snap.wire_bytes_recycled_total,
    );
    counter(&mut out, "steals_total", "Cross-queue steals in the device pool.", snap.steals_total);

    counter(&mut out, "cache_plan_hits_total", "Plan-cache hits.", snap.cache.plan_hits);
    counter(&mut out, "cache_plan_misses_total", "Plan-cache misses.", snap.cache.plan_misses);
    counter(&mut out, "cache_prepared_hits_total", "Prepared-executable cache hits.", snap.cache.prepared_hits);
    counter(&mut out, "cache_prepared_misses_total", "Prepared-executable cache misses.", snap.cache.prepared_misses);
    counter(&mut out, "cache_result_hits_total", "Result-cache hits.", snap.cache.result_hits);
    counter(&mut out, "cache_result_misses_total", "Result-cache misses.", snap.cache.result_misses);
    counter(&mut out, "cache_result_inserts_total", "Result-cache inserts.", snap.cache.result_inserts);
    counter(&mut out, "cache_result_evictions_total", "Result-cache LRU evictions.", snap.cache.result_evictions);

    counter(&mut out, "store_hits_total", "Artifact-store lookups that found a verified entry.", snap.store.hits);
    counter(
        &mut out,
        "store_misses_total",
        "Artifact-store lookups that found nothing or a corrupt entry.",
        snap.store.misses,
    );
    counter(
        &mut out,
        "store_spills_total",
        "Result entries demoted to disk by the in-memory byte budget.",
        snap.store.spills,
    );
    counter(
        &mut out,
        "store_loads_total",
        "Entries loaded from the artifact store back into a warm tier.",
        snap.store.loads,
    );

    gauge(&mut out, "queue_depth", "Requests waiting in the batcher right now.", snap.queue_depth);
    gauge(&mut out, "cache_result_entries", "Entries resident in the result cache.", snap.cache.result_entries);
    gauge(&mut out, "cache_result_bytes", "Bytes resident in the result cache.", snap.cache.result_bytes);
    gauge(&mut out, "store_entries", "Entries held by the artifact store.", snap.store.entries);
    gauge(&mut out, "store_bytes", "Payload bytes held by the artifact store.", snap.store.bytes);

    if !snap.devices.is_empty() {
        let _ = writeln!(out, "# HELP {PREFIX}device_jobs Requests executed per pool device.");
        let _ = writeln!(out, "# TYPE {PREFIX}device_jobs gauge");
        for d in &snap.devices {
            let _ = writeln!(out, "{PREFIX}device_jobs{{device=\"{}\"}} {}", d.name, d.jobs);
        }
        let _ = writeln!(out, "# HELP {PREFIX}device_busy_seconds Busy time per pool device.");
        let _ = writeln!(out, "# TYPE {PREFIX}device_busy_seconds gauge");
        for d in &snap.devices {
            let _ = writeln!(out, "{PREFIX}device_busy_seconds{{device=\"{}\"}} {}", d.name, d.busy_s);
        }
        let _ = writeln!(out, "# HELP {PREFIX}device_queue_depth Queued requests per pool device.");
        let _ = writeln!(out, "# TYPE {PREFIX}device_queue_depth gauge");
        for d in &snap.devices {
            let _ =
                writeln!(out, "{PREFIX}device_queue_depth{{device=\"{}\"}} {}", d.name, d.queue_depth);
        }
    }

    // latency histogram: snapshot buckets are per-bucket counts with
    // upper bounds; Prometheus wants cumulative counts and le="+Inf"
    let _ = writeln!(out, "# HELP {PREFIX}request_latency_us Served request latency, microseconds.");
    let _ = writeln!(out, "# TYPE {PREFIX}request_latency_us histogram");
    let mut cumulative = 0u64;
    for &(bound, count) in &snap.latency_buckets {
        cumulative += count;
        if bound == u64::MAX {
            let _ = writeln!(out, "{PREFIX}request_latency_us_bucket{{le=\"+Inf\"}} {cumulative}");
        } else {
            let _ =
                writeln!(out, "{PREFIX}request_latency_us_bucket{{le=\"{bound}\"}} {cumulative}");
        }
    }
    // the sum is reconstructed from the tracked mean (exact: the service
    // maintains sum and count; mean = sum/count)
    let sum = snap.latency_mean_us * cumulative as f64;
    let _ = writeln!(out, "{PREFIX}request_latency_us_sum {sum}");
    let _ = writeln!(out, "{PREFIX}request_latency_us_count {cumulative}");
    out
}

fn base_name(series: &str) -> &str {
    series.split('{').next().unwrap_or(series)
}

fn histogram_base(name: &str) -> Option<&str> {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            return Some(base);
        }
    }
    None
}

/// Lint text exposition output: every series name is well-formed and
/// declared with a `# TYPE`, counters carry the `_total` suffix, no
/// series (name + labels) appears twice, and every histogram family has
/// `_bucket` with `le="+Inf"`, `_sum` and `_count`.
pub fn lint(text: &str) -> Result<(), String> {
    let mut types: std::collections::HashMap<String, String> = std::collections::HashMap::new();
    let mut seen: HashSet<String> = HashSet::new();
    let mut histogram_parts: std::collections::HashMap<String, HashSet<&'static str>> =
        std::collections::HashMap::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let (name, kind) = match (parts.next(), parts.next()) {
                (Some(n), Some(k)) => (n, k),
                _ => return Err(format!("malformed TYPE line: {line:?}")),
            };
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("unknown metric type {kind:?} for {name}"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return Err(format!("duplicate TYPE declaration for {name}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let series = match line.split_whitespace().next() {
            Some(s) => s,
            None => continue,
        };
        let name = base_name(series);
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || name.chars().next().is_some_and(|c| c.is_ascii_digit())
        {
            return Err(format!("invalid metric name {name:?}"));
        }
        if !seen.insert(series.to_string()) {
            return Err(format!("duplicate series {series:?}"));
        }
        let declared = match histogram_base(name) {
            Some(base) if types.get(base).map(String::as_str) == Some("histogram") => {
                let parts = histogram_parts.entry(base.to_string()).or_default();
                if name.ends_with("_sum") {
                    parts.insert("sum");
                } else if name.ends_with("_count") {
                    parts.insert("count");
                } else if series.contains("le=\"+Inf\"") {
                    parts.insert("inf");
                }
                continue;
            }
            _ => types.get(name),
        };
        match declared.map(String::as_str) {
            None => return Err(format!("series {name} has no TYPE declaration")),
            Some("counter") if !name.ends_with("_total") => {
                return Err(format!("counter {name} must end with _total"));
            }
            _ => {}
        }
    }
    for (base, parts) in &histogram_parts {
        for (part, label) in
            [("inf", "a le=\"+Inf\" bucket"), ("sum", "a _sum series"), ("count", "a _count series")]
        {
            if !parts.contains(part) {
                return Err(format!("histogram {base} is missing {label}"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use std::sync::atomic::Ordering;

    fn sample_snapshot() -> MetricsSnapshot {
        let m = Metrics::new();
        m.requests_total.fetch_add(12, Ordering::Relaxed);
        m.responses_total.fetch_add(10, Ordering::Relaxed);
        m.queue_depth.store(3, Ordering::Relaxed);
        for us in [90, 90, 2_000, 40_000] {
            m.observe_latency_us(us);
        }
        let mut s = m.snapshot();
        s.steals_total = 4;
        s.devices.push(crate::pool::DeviceUtil {
            name: "sim#0".into(),
            kind: crate::pool::PoolDeviceKind::Sim,
            jobs: 5,
            steals: 2,
            launches: 9,
            busy_s: 0.5,
            bytes_copied: 4096,
            buffers_recycled: 3,
            queue_depth: 1,
        });
        s
    }

    #[test]
    fn render_passes_the_lint() {
        lint(&render(&sample_snapshot())).unwrap();
        lint(&render(&Metrics::new().snapshot())).unwrap();
    }

    #[test]
    fn histogram_is_cumulative_with_inf() {
        let text = render(&sample_snapshot());
        assert!(text.contains("matexp_request_latency_us_bucket{le=\"100\"} 2"), "{text}");
        assert!(text.contains("matexp_request_latency_us_bucket{le=\"2500\"} 3"), "{text}");
        assert!(text.contains("matexp_request_latency_us_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("matexp_request_latency_us_count 4"), "{text}");
        // sum = 90+90+2000+40000
        assert!(text.contains("matexp_request_latency_us_sum 42180"), "{text}");
    }

    #[test]
    fn counters_and_gauges_render() {
        let text = render(&sample_snapshot());
        assert!(text.contains("# TYPE matexp_requests_total counter"), "{text}");
        assert!(text.contains("matexp_requests_total 12"), "{text}");
        assert!(text.contains("# TYPE matexp_queue_depth gauge"), "{text}");
        assert!(text.contains("matexp_queue_depth 3"), "{text}");
        assert!(text.contains("matexp_device_jobs{device=\"sim#0\"} 5"), "{text}");
        assert!(text.contains("matexp_cache_plan_hits_total"), "{text}");
    }

    #[test]
    fn store_series_render_and_pass_the_lint() {
        let mut s = sample_snapshot();
        s.store = crate::store::StoreCounters {
            hits: 7,
            misses: 2,
            spills: 5,
            loads: 3,
            entries: 4,
            bytes: 4096,
        };
        let text = render(&s);
        lint(&text).unwrap();
        assert!(text.contains("# TYPE matexp_store_loads_total counter"), "{text}");
        assert!(text.contains("matexp_store_hits_total 7"), "{text}");
        assert!(text.contains("matexp_store_misses_total 2"), "{text}");
        assert!(text.contains("matexp_store_spills_total 5"), "{text}");
        assert!(text.contains("matexp_store_loads_total 3"), "{text}");
        assert!(text.contains("matexp_store_entries 4"), "{text}");
        assert!(text.contains("matexp_store_bytes 4096"), "{text}");
    }

    #[test]
    fn lint_catches_naming_violations() {
        let dup = "# TYPE m_x_total counter\nm_x_total 1\nm_x_total 2\n";
        assert!(lint(dup).unwrap_err().contains("duplicate series"));
        let unsuffixed = "# TYPE m_req counter\nm_req 1\n";
        assert!(lint(unsuffixed).unwrap_err().contains("_total"));
        let undeclared = "m_mystery 1\n";
        assert!(lint(undeclared).unwrap_err().contains("no TYPE"));
        let bad_name = "# TYPE 9lives counter\n9lives 1\n";
        assert!(lint(bad_name).is_err());
        let incomplete = "# TYPE m_h histogram\nm_h_bucket{le=\"1\"} 1\nm_h_sum 1\nm_h_count 1\n";
        assert!(lint(incomplete).unwrap_err().contains("+Inf"));
        let labeled_ok = "# TYPE m_g gauge\nm_g{a=\"1\"} 1\nm_g{a=\"2\"} 2\n";
        lint(labeled_ok).unwrap();
    }
}
