//! The flight-recorder ring: a lock-free, fixed-capacity, overwrite-oldest
//! span store.
//!
//! Writers claim a monotonically increasing **ticket** with one
//! `fetch_add` and write into slot `ticket % capacity`; the newest
//! `capacity` spans are always retained and older ones are silently
//! overwritten, so memory stays bounded no matter how long the process
//! serves. Each slot is a seqlock: a sequence word derived from the
//! ticket (odd while a write is in flight, even when committed) brackets
//! the payload words, so readers detect and skip torn slots instead of
//! blocking writers. Payload words are relaxed atomics — a reader can
//! never observe a half-written *word*, and a half-written *slot* fails
//! sequence validation.
//!
//! The one race this design accepts: if a writer stalls mid-write for
//! long enough that the ring wraps fully and a later writer finishes the
//! same slot, a reader may decode a span mixing words from both writes.
//! [`super::Span::decode`] bounds-checks every field, so the worst case
//! is one garbled-but-well-formed span in a dump — an acceptable trade
//! for a recorder that never takes a lock on the serving path.

use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::Span;

/// Atomic words per slot: sequence, trace id, start, duration, meta, n.
pub(crate) const SLOT_WORDS: usize = 6;

const SEQ: usize = 0;
const TRACE: usize = 1;
const START: usize = 2;
const DUR: usize = 3;
const META: usize = 4;
const DIM: usize = 5;

/// Fixed-capacity lock-free span ring (see module docs).
pub struct Ring {
    slots: Box<[AtomicU64]>,
    capacity: usize,
    /// Next ticket to claim. Tickets are global: `head / capacity` is the
    /// wrap count, `head % capacity` the slot.
    head: AtomicU64,
}

impl Ring {
    /// A ring retaining the newest `capacity` spans (rounded up to a
    /// power of two, minimum 16).
    pub fn new(capacity: usize) -> Ring {
        let capacity = capacity.max(16).next_power_of_two();
        let slots = (0..capacity * SLOT_WORDS).map(|_| AtomicU64::new(0)).collect();
        Ring { slots, capacity, head: AtomicU64::new(0) }
    }

    /// How many spans this ring retains.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total spans ever recorded (monotone; exceeds `capacity` once the
    /// ring has wrapped).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    fn word(&self, slot: usize, field: usize) -> &AtomicU64 {
        &self.slots[slot * SLOT_WORDS + field]
    }

    /// Record one span. Lock-free: one `fetch_add` plus five relaxed
    /// stores bracketed by the slot's sequence word.
    pub fn push(&self, span: &Span) {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = (ticket % self.capacity as u64) as usize;
        // odd = write in flight
        self.word(slot, SEQ).store(2 * ticket + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        let (meta, n) = span.encode_meta();
        self.word(slot, TRACE).store(span.trace_id, Ordering::Relaxed);
        self.word(slot, START).store(span.start_us, Ordering::Relaxed);
        self.word(slot, DUR).store(span.dur_us, Ordering::Relaxed);
        self.word(slot, META).store(meta, Ordering::Relaxed);
        self.word(slot, DIM).store(n, Ordering::Relaxed);
        // commit: even, and only if no later writer claimed the slot while
        // we were writing (a full wrap mid-write) — losing the race means
        // our span is already overwritten, so dropping the commit is right
        let _ = self.word(slot, SEQ).compare_exchange(
            2 * ticket + 1,
            2 * ticket + 2,
            Ordering::Release,
            Ordering::Relaxed,
        );
    }

    /// Snapshot the newest committed spans, oldest first. Torn or
    /// in-flight slots are skipped, so the result may hold fewer than
    /// `capacity` entries even on a wrapped ring.
    pub fn recent(&self) -> Vec<Span> {
        let head = self.head.load(Ordering::Acquire);
        let first = head.saturating_sub(self.capacity as u64);
        let mut out = Vec::with_capacity((head - first) as usize);
        for ticket in first..head {
            let slot = (ticket % self.capacity as u64) as usize;
            let seq1 = self.word(slot, SEQ).load(Ordering::Acquire);
            if seq1 != 2 * ticket + 2 {
                continue; // in flight, torn, or already overwritten
            }
            let trace_id = self.word(slot, TRACE).load(Ordering::Relaxed);
            let start_us = self.word(slot, START).load(Ordering::Relaxed);
            let dur_us = self.word(slot, DUR).load(Ordering::Relaxed);
            let meta = self.word(slot, META).load(Ordering::Relaxed);
            let n = self.word(slot, DIM).load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            let seq2 = self.word(slot, SEQ).load(Ordering::Relaxed);
            if seq1 != seq2 {
                continue; // overwritten while reading
            }
            if let Some(span) = Span::decode(ticket, trace_id, start_us, dur_us, meta, n) {
                out.push(span);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::SpanKind;

    fn span(trace_id: u64, start: u64) -> Span {
        Span {
            seq: 0,
            trace_id,
            kind: SpanKind::Launch,
            start_us: start,
            dur_us: 3,
            op: Some(crate::runtime::KernelOp::Matmul),
            n: 64,
        }
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(Ring::new(0).capacity(), 16);
        assert_eq!(Ring::new(100).capacity(), 128);
        assert_eq!(Ring::new(4096).capacity(), 4096);
    }

    #[test]
    fn push_then_recent_roundtrips() {
        let ring = Ring::new(16);
        for i in 0..5 {
            ring.push(&span(i, 10 * i));
        }
        let got = ring.recent();
        assert_eq!(got.len(), 5);
        for (i, s) in got.iter().enumerate() {
            assert_eq!(s.trace_id, i as u64);
            assert_eq!(s.start_us, 10 * i as u64);
            assert_eq!(s.op, Some(crate::runtime::KernelOp::Matmul));
            assert_eq!(s.n, 64);
        }
    }

    #[test]
    fn overwrites_oldest_when_full() {
        let ring = Ring::new(16); // rounds to 16
        for i in 0..40u64 {
            ring.push(&span(i, i));
        }
        let got = ring.recent();
        assert_eq!(got.len(), 16, "exactly the newest capacity spans survive");
        assert_eq!(got.first().unwrap().trace_id, 24);
        assert_eq!(got.last().unwrap().trace_id, 39);
        assert_eq!(ring.recorded(), 40);
    }

    #[test]
    fn concurrent_writers_never_corrupt_the_ring() {
        use std::sync::Arc;
        let ring = Arc::new(Ring::new(64));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let ring = Arc::clone(&ring);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        ring.push(&span(w * 1000 + i, i));
                        if i % 7 == 0 {
                            // readers race the writers; they must never
                            // panic or return undecodable spans
                            let _ = ring.recent();
                        }
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        assert_eq!(ring.recorded(), 2000);
        // quiescent read: every slot is committed and decodable
        let got = ring.recent();
        assert_eq!(got.len(), 64);
        for s in &got {
            assert!(s.trace_id % 1000 < 500, "garbled span {s:?}");
            assert_eq!(s.n, 64);
        }
    }
}
