//! # End-to-end request tracing: the observability substrate
//!
//! The serving path — wire decode → admission → batcher queue → plan /
//! cache consult → prepare → launch chain → wire encode — used to report
//! only end-to-end latency and global counters, so a perf PR could not
//! prove *which* stage it moved. This module is the measurement layer the
//! paper's per-phase host/transfer/kernel breakdown implies:
//!
//! * **[`TraceId`]** — minted when a [`crate::exec::Submission`] is built
//!   and threaded through the request, the coordinator, the engines and
//!   the wire edge, so every span of one request correlates.
//! * **Spans in a flight recorder** — every instrumented region records a
//!   [`Span`] into a process-global, lock-free, fixed-capacity
//!   [`ring::Ring`] (always on, overwrite-oldest, bounded memory). Three
//!   egress paths: the `trace` wire op / `matexp trace` CLI dump them as
//!   Chrome trace-event JSON ([`chrome`]), the per-request stage
//!   breakdown rides [`crate::runtime::ExecStats`], and
//!   [`prometheus`] renders the metrics snapshot in text exposition
//!   format.
//! * **Stage accumulators** — thread-local counters ([`enter`] /
//!   [`take_stages`]) let deep layers (engine prepare/launch) bill their
//!   time to the request without threading a context through every
//!   signature.
//! * **Slow-request log** — requests slower than the configured
//!   threshold ([`crate::config::TraceSettings::slow_ms`],
//!   `--trace-slow-ms`) are emitted to stderr as single-line JSON by the
//!   serving coordinator.
//!
//! The recorder is configured once at startup ([`configure`]) from
//! [`crate::config::TraceSettings`]; recording one span is a
//! `fetch_add` plus five relaxed stores, cheap enough to leave on in
//! production (a loadtest asserts the overhead bound).

pub mod chrome;
pub mod prometheus;
pub mod ring;

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::runtime::op::KernelOp;

// ---------------------------------------------------------------- trace id

/// Correlates every span of one request. Minted at
/// [`crate::exec::Submission`] construction; `NONE` (id 0) marks
/// activity outside any traced request (warmup, benches).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct TraceId(u64);

impl TraceId {
    /// The "no trace" id (0) — spans recorded outside a request.
    pub const NONE: TraceId = TraceId(0);

    /// Mint a fresh, process-unique trace id.
    pub fn mint() -> TraceId {
        static NEXT: AtomicU64 = AtomicU64::new(1);
        TraceId(NEXT.fetch_add(1, Ordering::Relaxed))
    }

    /// The raw id.
    pub fn get(self) -> u64 {
        self.0
    }

    /// A trace id from a raw value (wire / tests).
    pub fn from_raw(id: u64) -> TraceId {
        TraceId(id)
    }
}

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

// ---------------------------------------------------------------- span model

/// Which cache tier a cache event belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Tier 1: the plan cache.
    Plan,
    /// Tier 2: the per-engine prepared set.
    Prepared,
    /// Tier 3: the content-addressed result cache.
    Result,
}

impl Tier {
    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            Tier::Plan => "plan",
            Tier::Prepared => "prepared",
            Tier::Result => "result",
        }
    }
}

/// Which wire codec a decode/encode span used.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// JSON line codec.
    Json,
    /// Length-prefixed binary frame codec.
    Frame,
}

impl Codec {
    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            Codec::Json => "json",
            Codec::Frame => "frame",
        }
    }
}

/// The span taxonomy — every instrumented region/event on the serving
/// path. `Execute` is the per-request **root**: plan/prepare/launch spans
/// and cache events nest inside it; wire and queue spans are its
/// siblings on the request timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Wire request decode (server edge), tagged with the codec.
    WireDecode(Codec),
    /// Wire response encode + write (server edge), tagged with the codec.
    WireEncode(Codec),
    /// Time spent queued in the batcher (enqueue → worker dequeue).
    Queue,
    /// Strategy/plan selection (scheduler dispatch, plan-cache consult).
    Plan,
    /// `Backend::prepare` work (compile/validate), cold entries only.
    Prepare,
    /// One kernel launch (carries the [`KernelOp`] and matrix size).
    Launch,
    /// Whole request execution on a worker engine (the root span).
    Execute,
    /// A cache tier served a warm entry.
    CacheHit(Tier),
    /// A cache tier had no entry.
    CacheMiss(Tier),
    /// A cache tier stored a fresh entry.
    CacheStore(Tier),
    /// Cluster router: one routing decision (digest → member pick,
    /// including reroutes around down members).
    Route,
    /// Cluster router: one egress round-trip to a chosen member.
    MemberSend,
}

impl SpanKind {
    /// Canonical span name (Chrome trace `name`, slow-log keys).
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::WireDecode(Codec::Json) => "wire_decode_json",
            SpanKind::WireDecode(Codec::Frame) => "wire_decode_frame",
            SpanKind::WireEncode(Codec::Json) => "wire_encode_json",
            SpanKind::WireEncode(Codec::Frame) => "wire_encode_frame",
            SpanKind::Queue => "queue",
            SpanKind::Plan => "plan",
            SpanKind::Prepare => "prepare",
            SpanKind::Launch => "launch",
            SpanKind::Execute => "execute",
            SpanKind::CacheHit(Tier::Plan) => "cache_hit_plan",
            SpanKind::CacheHit(Tier::Prepared) => "cache_hit_prepared",
            SpanKind::CacheHit(Tier::Result) => "cache_hit_result",
            SpanKind::CacheMiss(Tier::Plan) => "cache_miss_plan",
            SpanKind::CacheMiss(Tier::Prepared) => "cache_miss_prepared",
            SpanKind::CacheMiss(Tier::Result) => "cache_miss_result",
            SpanKind::CacheStore(Tier::Plan) => "cache_store_plan",
            SpanKind::CacheStore(Tier::Prepared) => "cache_store_prepared",
            SpanKind::CacheStore(Tier::Result) => "cache_store_result",
            SpanKind::Route => "route",
            SpanKind::MemberSend => "member_send",
        }
    }

    /// Chrome trace category (Perfetto track grouping).
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::WireDecode(_) | SpanKind::WireEncode(_) => "wire",
            SpanKind::Queue => "queue",
            SpanKind::Plan | SpanKind::Prepare => "sched",
            SpanKind::Launch | SpanKind::Execute => "exec",
            SpanKind::CacheHit(_) | SpanKind::CacheMiss(_) | SpanKind::CacheStore(_) => "cache",
            SpanKind::Route | SpanKind::MemberSend => "cluster",
        }
    }

    /// `true` for the kinds that must nest inside an [`SpanKind::Execute`]
    /// root (see [`validate_spans`]).
    pub fn is_child(self) -> bool {
        matches!(
            self,
            SpanKind::Plan
                | SpanKind::Prepare
                | SpanKind::Launch
                | SpanKind::CacheHit(_)
                | SpanKind::CacheMiss(_)
                | SpanKind::CacheStore(_)
        )
    }

    fn code(self) -> u64 {
        match self {
            SpanKind::WireDecode(_) => 1,
            SpanKind::WireEncode(_) => 2,
            SpanKind::Queue => 3,
            SpanKind::Plan => 4,
            SpanKind::Prepare => 5,
            SpanKind::Launch => 6,
            SpanKind::Execute => 7,
            SpanKind::CacheHit(_) => 8,
            SpanKind::CacheMiss(_) => 9,
            SpanKind::CacheStore(_) => 10,
            SpanKind::Route => 11,
            SpanKind::MemberSend => 12,
        }
    }

    fn tag(self) -> u64 {
        match self {
            SpanKind::WireDecode(c) | SpanKind::WireEncode(c) => match c {
                Codec::Json => 0,
                Codec::Frame => 1,
            },
            SpanKind::CacheHit(t) | SpanKind::CacheMiss(t) | SpanKind::CacheStore(t) => match t {
                Tier::Plan => 0,
                Tier::Prepared => 1,
                Tier::Result => 2,
            },
            _ => 0,
        }
    }

    fn from_codes(code: u64, tag: u64) -> Option<SpanKind> {
        let codec = match tag {
            0 => Codec::Json,
            1 => Codec::Frame,
            _ => Codec::Json, // validated below for wire kinds
        };
        let tier = match tag {
            0 => Tier::Plan,
            1 => Tier::Prepared,
            2 => Tier::Result,
            _ => return None,
        };
        Some(match code {
            1 if tag <= 1 => SpanKind::WireDecode(codec),
            2 if tag <= 1 => SpanKind::WireEncode(codec),
            3 => SpanKind::Queue,
            4 => SpanKind::Plan,
            5 => SpanKind::Prepare,
            6 => SpanKind::Launch,
            7 => SpanKind::Execute,
            8 => SpanKind::CacheHit(tier),
            9 => SpanKind::CacheMiss(tier),
            10 => SpanKind::CacheStore(tier),
            11 => SpanKind::Route,
            12 => SpanKind::MemberSend,
            _ => return None,
        })
    }
}

/// One recorded region or event on a request's timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// The ring ticket this span was recorded under (global order).
    pub seq: u64,
    /// The request's [`TraceId`] (0 = outside any request).
    pub trace_id: u64,
    /// What this span measures.
    pub kind: SpanKind,
    /// Start, microseconds since the process trace epoch.
    pub start_us: u64,
    /// Duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// The launched kernel, for [`SpanKind::Launch`] spans.
    pub op: Option<KernelOp>,
    /// Matrix side length, when known (0 otherwise).
    pub n: u64,
}

impl Span {
    /// End of the span, microseconds since the trace epoch.
    pub fn end_us(&self) -> u64 {
        self.start_us.saturating_add(self.dur_us)
    }

    /// Span name for rendering: the kind, with the kernel op appended for
    /// launches (`launch:matmul`).
    pub fn name(&self) -> String {
        match self.op {
            Some(op) => format!("{}:{}", self.kind.as_str(), op.name()),
            None => self.kind.as_str().to_string(),
        }
    }

    /// Pack kind/tag/op into the ring's meta word (+ the size word).
    /// Layout: bits 56–63 kind, 48–55 tag, 40–47 opcode, 0–31 op param.
    pub(crate) fn encode_meta(&self) -> (u64, u64) {
        let (opcode, param) = match self.op {
            None => (0u64, 0u64),
            Some(KernelOp::Matmul) => (1, 0),
            Some(KernelOp::Square) => (2, 0),
            Some(KernelOp::SquareChain(k)) => (3, k as u64),
            Some(KernelOp::SqMul) => (4, 0),
            Some(KernelOp::Pack2) => (5, 0),
            Some(KernelOp::StepSq) => (6, 0),
            Some(KernelOp::StepMul) => (7, 0),
            Some(KernelOp::Unpack0) => (8, 0),
            Some(KernelOp::Mma(g)) => (9, g as u64),
            // powers are capped at 2^30 by admission, so u32 suffices
            Some(KernelOp::Expm(p)) => (10, p.min(u32::MAX as u64)),
        };
        let meta = (self.kind.code() << 56)
            | (self.kind.tag() << 48)
            | (opcode << 40)
            | (param & 0xFFFF_FFFF);
        (meta, self.n)
    }

    /// Decode a ring slot back into a span. Bounds-checks every field and
    /// returns `None` for garbled slots (see [`ring`] module docs).
    pub(crate) fn decode(
        seq: u64,
        trace_id: u64,
        start_us: u64,
        dur_us: u64,
        meta: u64,
        n: u64,
    ) -> Option<Span> {
        let kind = SpanKind::from_codes(meta >> 56, (meta >> 48) & 0xFF)?;
        let param = meta & 0xFFFF_FFFF;
        let op = match (meta >> 40) & 0xFF {
            0 => None,
            1 => Some(KernelOp::Matmul),
            2 => Some(KernelOp::Square),
            3 => Some(KernelOp::SquareChain(param as u32)),
            4 => Some(KernelOp::SqMul),
            5 => Some(KernelOp::Pack2),
            6 => Some(KernelOp::StepSq),
            7 => Some(KernelOp::StepMul),
            8 => Some(KernelOp::Unpack0),
            9 => Some(KernelOp::Mma(param as u32)),
            10 => Some(KernelOp::Expm(param)),
            _ => return None,
        };
        start_us.checked_add(dur_us)?;
        Some(Span { seq, trace_id, kind, start_us, dur_us, op, n })
    }
}

// ---------------------------------------------------------------- clock

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (monotonic). All span
/// timestamps share this clock, so nesting comparisons are exact.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

// ---------------------------------------------------------------- recorder

static ENABLED: AtomicBool = AtomicBool::new(true);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAPACITY);
static SLOW_US: AtomicU64 = AtomicU64::new(0);
static RING: OnceLock<ring::Ring> = OnceLock::new();

/// Default flight-recorder capacity (spans). At 48 bytes/slot this is
/// ~200 KiB — roughly 400 requests of history at ~10 spans each.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

fn recorder() -> &'static ring::Ring {
    RING.get_or_init(|| ring::Ring::new(CAPACITY.load(Ordering::Relaxed)))
}

/// Apply [`crate::config::TraceSettings`] to the process-global recorder.
/// Call once at startup, before traffic: the ring is allocated lazily on
/// first use, and a capacity change after that point is ignored (the
/// enabled flag and slow threshold always apply).
pub fn configure(settings: &crate::config::TraceSettings) {
    CAPACITY.store(settings.ring_capacity.max(1), Ordering::Relaxed);
    ENABLED.store(settings.enabled, Ordering::Relaxed);
    SLOW_US.store(settings.slow_ms.saturating_mul(1_000), Ordering::Relaxed);
}

/// Toggle span recording (the flight recorder defaults on).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is the flight recorder recording?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Slow-request threshold in microseconds (0 = slow logging disabled).
pub fn slow_threshold_us() -> u64 {
    SLOW_US.load(Ordering::Relaxed)
}

/// Record one span into the flight recorder (no-op when disabled).
pub fn record(span: Span) {
    if enabled() {
        recorder().push(&span);
    }
}

/// Record a region that ends now: `start_us` from an earlier [`now_us`].
pub fn record_span(kind: SpanKind, trace: TraceId, start_us: u64, n: usize) {
    record_span_at(kind, trace, start_us, now_us(), n);
}

/// Record a region with an explicit end (for spans whose trace id is only
/// known after the region finished, e.g. wire decode).
pub fn record_span_at(kind: SpanKind, trace: TraceId, start_us: u64, end_us: u64, n: usize) {
    record(Span {
        seq: 0,
        trace_id: trace.get(),
        kind,
        start_us,
        dur_us: end_us.saturating_sub(start_us),
        op: None,
        n: n as u64,
    });
}

/// Record one kernel launch span.
pub fn record_launch(trace: TraceId, op: KernelOp, n: usize, start_us: u64) {
    record(Span {
        seq: 0,
        trace_id: trace.get(),
        kind: SpanKind::Launch,
        start_us,
        dur_us: now_us().saturating_sub(start_us),
        op: Some(op),
        n: n as u64,
    });
}

/// Record an instant event (cache hit/miss/store).
pub fn event(kind: SpanKind, trace: TraceId, n: usize) {
    let t = now_us();
    record(Span { seq: 0, trace_id: trace.get(), kind, start_us: t, dur_us: 0, op: None, n: n as u64 });
}

/// Snapshot the newest recorded spans, oldest first.
pub fn recent_spans() -> Vec<Span> {
    recorder().recent()
}

/// Total spans ever recorded (monotone).
pub fn spans_recorded() -> u64 {
    recorder().recorded()
}

// ------------------------------------------------------- request context

/// Per-request stages the deep layers bill time into via thread-locals
/// (the engine has no request in scope at prepare/launch sites).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Strategy/plan selection time.
    Plan,
    /// Cold `Backend::prepare` time.
    Prepare,
    /// Kernel launch time (sum over the request's launches).
    Launch,
}

thread_local! {
    static CURRENT: Cell<u64> = const { Cell::new(0) };
    static STAGES: Cell<[u64; 3]> = const { Cell::new([0; 3]) };
}

/// RAII scope marking "this thread is executing request `trace`".
/// Restores the previous context on drop, so nested executions (a worker
/// driving a sub-request) unwind correctly.
pub struct TraceScope {
    prev: u64,
    prev_stages: [u64; 3],
}

/// Enter a request's trace context: spans recorded by deeper layers on
/// this thread correlate to `trace`, and the stage accumulators reset.
pub fn enter(trace: TraceId) -> TraceScope {
    let prev = CURRENT.with(|c| c.replace(trace.get()));
    let prev_stages = STAGES.with(|s| s.replace([0; 3]));
    TraceScope { prev, prev_stages }
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
        STAGES.with(|s| s.set(self.prev_stages));
    }
}

/// The trace id of the request this thread is executing ([`TraceId::NONE`]
/// outside any request).
pub fn current() -> TraceId {
    TraceId(CURRENT.with(|c| c.get()))
}

/// Bill `dur_us` to a stage of the current request.
pub fn add_stage(stage: Stage, dur_us: u64) {
    STAGES.with(|s| {
        let mut v = s.get();
        v[stage as usize] = v[stage as usize].saturating_add(dur_us);
        s.set(v);
    });
}

/// Read-and-reset the current request's `[plan, prepare, launch]`
/// accumulators (microseconds). The executing worker drains these into
/// [`crate::runtime::ExecStats`] after the request completes.
pub fn take_stages() -> [u64; 3] {
    STAGES.with(|s| s.replace([0; 3]))
}

/// Serializes tests that toggle or assert on the process-global recorder
/// (a test disabling recording must not race tests asserting that their
/// spans landed).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------- checks

/// Structural validation of a span set — the "balanced span tree"
/// property the proptests and the trace smoke test assert:
///
/// * every span's `start + dur` does not overflow (start ≤ end);
/// * per trace id, at most one [`SpanKind::Execute`] root;
/// * every child-kind span (plan/prepare/launch/cache) of a trace that
///   has a root lies within the root's `[start, end]` window.
///
/// Spans with trace id 0 (outside any request) are only checked for
/// well-formed timestamps.
pub fn validate_spans(spans: &[Span]) -> Result<(), String> {
    use std::collections::HashMap;
    let mut roots: HashMap<u64, &Span> = HashMap::new();
    for s in spans {
        if s.start_us.checked_add(s.dur_us).is_none() {
            return Err(format!("span {} overflows its interval", s.name()));
        }
        if s.trace_id != 0 && s.kind == SpanKind::Execute {
            if let Some(prev) = roots.insert(s.trace_id, s) {
                return Err(format!(
                    "trace {} has two execute roots (seq {} and {})",
                    s.trace_id, prev.seq, s.seq
                ));
            }
        }
    }
    for s in spans {
        if s.trace_id == 0 || !s.kind.is_child() {
            continue;
        }
        if let Some(root) = roots.get(&s.trace_id) {
            if s.start_us < root.start_us || s.end_us() > root.end_us() {
                return Err(format!(
                    "trace {}: {} [{}, {}] escapes its execute root [{}, {}]",
                    s.trace_id,
                    s.name(),
                    s.start_us,
                    s.end_us(),
                    root.start_us,
                    root.end_us()
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, trace: u64, start: u64, dur: u64) -> Span {
        Span { seq: 0, trace_id: trace, kind, start_us: start, dur_us: dur, op: None, n: 8 }
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        assert_ne!(a, b);
        assert_ne!(a, TraceId::NONE);
        assert!(a.get() > 0 && b.get() > a.get());
    }

    #[test]
    fn meta_roundtrips_every_kind_and_op() {
        let kinds = [
            SpanKind::WireDecode(Codec::Json),
            SpanKind::WireDecode(Codec::Frame),
            SpanKind::WireEncode(Codec::Json),
            SpanKind::WireEncode(Codec::Frame),
            SpanKind::Queue,
            SpanKind::Plan,
            SpanKind::Prepare,
            SpanKind::Launch,
            SpanKind::Execute,
            SpanKind::CacheHit(Tier::Plan),
            SpanKind::CacheMiss(Tier::Prepared),
            SpanKind::CacheStore(Tier::Result),
            SpanKind::Route,
            SpanKind::MemberSend,
        ];
        let ops = [
            None,
            Some(KernelOp::Matmul),
            Some(KernelOp::Square),
            Some(KernelOp::SquareChain(4)),
            Some(KernelOp::SqMul),
            Some(KernelOp::Pack2),
            Some(KernelOp::StepSq),
            Some(KernelOp::StepMul),
            Some(KernelOp::Unpack0),
            Some(KernelOp::Mma(7)),
            Some(KernelOp::Expm(1024)),
        ];
        for kind in kinds {
            for op in ops {
                let s = Span {
                    seq: 9,
                    trace_id: 42,
                    kind,
                    start_us: 100,
                    dur_us: 7,
                    op,
                    n: 512,
                };
                let (meta, n) = s.encode_meta();
                let back = Span::decode(9, 42, 100, 7, meta, n).unwrap();
                assert_eq!(back, s, "{kind:?} {op:?}");
            }
        }
    }

    #[test]
    fn decode_rejects_garbled_meta() {
        assert!(Span::decode(0, 1, 0, 0, 0, 0).is_none(), "kind 0 is invalid");
        assert!(Span::decode(0, 1, 0, 0, 99 << 56, 0).is_none(), "unknown kind");
        assert!(Span::decode(0, 1, 0, 0, (6 << 56) | (99 << 40), 0).is_none(), "unknown op");
        assert!(Span::decode(0, 1, 0, 0, (8 << 56) | (7 << 48), 0).is_none(), "bad tier tag");
        assert!(Span::decode(0, 1, u64::MAX, 2, 6 << 56, 0).is_none(), "interval overflow");
    }

    #[test]
    fn scope_sets_and_restores_context() {
        assert_eq!(current(), TraceId::NONE);
        let outer = TraceId::mint();
        let scope = enter(outer);
        assert_eq!(current(), outer);
        add_stage(Stage::Launch, 5);
        {
            let inner = TraceId::mint();
            let _nested = enter(inner);
            assert_eq!(current(), inner);
            add_stage(Stage::Launch, 99); // billed to the nested scope
        }
        assert_eq!(current(), outer);
        add_stage(Stage::Plan, 2);
        assert_eq!(take_stages(), [2, 0, 5], "nested billing must not leak out");
        drop(scope);
        assert_eq!(current(), TraceId::NONE);
    }

    #[test]
    fn recording_lands_in_the_global_ring() {
        let _guard = test_guard();
        let before = spans_recorded();
        let t = TraceId::mint();
        let start = now_us();
        record_span(SpanKind::Execute, t, start, 8);
        event(SpanKind::CacheHit(Tier::Plan), t, 8);
        // other tests may record concurrently, so count is a lower bound
        // and the assertions filter on this test's fresh trace id
        assert!(spans_recorded() >= before + 2);
        let mine: Vec<Span> =
            recent_spans().into_iter().filter(|s| s.trace_id == t.get()).collect();
        assert_eq!(mine.len(), 2);
        assert_eq!(mine[0].kind, SpanKind::Execute);
        assert_eq!(mine[1].kind, SpanKind::CacheHit(Tier::Plan));
    }

    #[test]
    fn disabled_recorder_drops_spans() {
        let _guard = test_guard();
        let t = TraceId::mint();
        set_enabled(false);
        record_span(SpanKind::Queue, t, now_us(), 4);
        set_enabled(true);
        assert!(
            recent_spans().iter().all(|s| s.trace_id != t.get()),
            "span recorded while the recorder was disabled"
        );
    }

    #[test]
    fn validate_accepts_balanced_trees() {
        let spans = vec![
            span(SpanKind::WireDecode(Codec::Frame), 1, 0, 5),
            span(SpanKind::Queue, 1, 5, 10),
            span(SpanKind::Execute, 1, 15, 100),
            span(SpanKind::Plan, 1, 16, 2),
            span(SpanKind::Launch, 1, 20, 50),
            span(SpanKind::CacheMiss(Tier::Result), 1, 15, 0),
            span(SpanKind::WireEncode(Codec::Frame), 1, 115, 3),
            span(SpanKind::Execute, 2, 0, 10),
            span(SpanKind::Launch, 0, 999, 1), // untraced: timestamps only
        ];
        validate_spans(&spans).unwrap();
    }

    #[test]
    fn validate_rejects_double_roots_and_escaping_children() {
        let double = vec![span(SpanKind::Execute, 1, 0, 10), span(SpanKind::Execute, 1, 20, 10)];
        assert!(validate_spans(&double).unwrap_err().contains("two execute roots"));
        let escape = vec![span(SpanKind::Execute, 1, 10, 10), span(SpanKind::Launch, 1, 5, 30)];
        assert!(validate_spans(&escape).unwrap_err().contains("escapes"));
    }

    #[test]
    fn span_names_carry_the_kernel_op() {
        let mut s = span(SpanKind::Launch, 1, 0, 1);
        s.op = Some(KernelOp::SquareChain(4));
        assert_eq!(s.name(), "launch:square4");
        assert_eq!(span(SpanKind::Queue, 1, 0, 1).name(), "queue");
    }

    #[test]
    fn prop_random_span_sets_never_panic_validation() {
        use crate::util::prop::property;
        property("validate_spans is total", 128, |g| {
            let len = g.usize(0, 24);
            let spans: Vec<Span> = (0..len)
                .map(|_| {
                    let kind = match g.usize(0, 9) {
                        0 => SpanKind::WireDecode(Codec::Json),
                        1 => SpanKind::WireEncode(Codec::Frame),
                        2 => SpanKind::Queue,
                        3 => SpanKind::Plan,
                        4 => SpanKind::Prepare,
                        5 => SpanKind::Launch,
                        6 => SpanKind::Execute,
                        7 => SpanKind::CacheHit(Tier::Plan),
                        8 => SpanKind::CacheMiss(Tier::Result),
                        _ => SpanKind::CacheStore(Tier::Prepared),
                    };
                    Span {
                        seq: g.u64(0, 1000),
                        trace_id: g.u64(0, 4),
                        kind,
                        start_us: g.u64(0, 1000),
                        dur_us: g.u64(0, 1000),
                        op: None,
                        n: g.u64(0, 64),
                    }
                })
                .collect();
            // total function: returns Ok or Err, never panics
            let _ = validate_spans(&spans);
        });
    }
}
