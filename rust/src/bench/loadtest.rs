//! `matexp loadtest` — a concurrent-client load harness over the TCP
//! wire, plus the codec micro-benchmark and the persisted `BENCH_*.json`
//! snapshot format.
//!
//! The harness drives a running server (or one the CLI starts in-process)
//! with N clients on real sockets, each speaking one wire mode — JSON
//! array payloads, base64 payloads, or binary frames — and reports p50 /
//! p99 / mean latency, throughput, and wire-byte counts per mode. Closed
//! loop by default (each client fires its next request the moment the
//! previous one answers); an open loop with a fixed per-client arrival
//! rate is available via [`LoadtestConfig::rate`], where latency is
//! measured from the request's *scheduled* start so queueing delay is
//! charged to the server, not silently absorbed (no coordinated
//! omission).
//!
//! Results serialize to the repo's bench-trajectory format
//! ([`snapshot`] / [`validate_snapshot`]): one `BENCH_<pr>.json` per
//! load-bearing change, committed at the repo root so the trajectory of
//! serving performance is diffable over time.

use std::sync::Barrier;
use std::time::{Duration, Instant};

use crate::bench::stats::percentile;
use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::json_obj;
use crate::linalg::matrix::Matrix;
use crate::server::client::MatexpClient;
use crate::server::frame::Frame;
use crate::server::proto::{Payload, WireResponse, WireStats};
use crate::util::json::Json;

/// Identifier of the snapshot format written by [`snapshot`]. Version 2
/// added the per-stage latency breakdown (`modes[].stages`), sourced from
/// the server's trace layer via the stats stage fields. Version 3 added
/// the `members` block: per-member routed-request counts fetched from a
/// cluster router (empty when the target is a single server), the
/// affinity evidence a router benchmark is committed with.
pub const SNAPSHOT_SCHEMA: &str = "matexp-loadtest/3";

/// The previous snapshot schema, still accepted by [`validate_snapshot`]
/// so committed `BENCH_7`/`BENCH_8` artifacts keep gating CI.
pub const SNAPSHOT_SCHEMA_V2: &str = "matexp-loadtest/2";

/// Stage names of the per-request breakdown, in snapshot order (matching
/// the stats fields `queue_us` / `plan_us` / `prepare_us` / `launch_us` /
/// `wire_us`).
pub const STAGE_NAMES: [&str; 5] = ["queue", "plan", "prepare", "launch", "wire"];

/// One request's server-side stage breakdown, microseconds.
fn stage_sample(s: &WireStats) -> [u64; 5] {
    [s.queue_us, s.plan_us, s.prepare_us, s.launch_us, s.wire_us]
}

/// Which codec the load clients speak.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// JSON lines with plain `f32`-array payloads.
    Json,
    /// JSON lines with base64 payloads.
    Base64,
    /// Binary frames (negotiated per connection; the run fails if the
    /// server does not speak them).
    Binary,
}

impl WireMode {
    /// Canonical lowercase name (CLI / snapshot vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            WireMode::Json => "json",
            WireMode::Base64 => "base64",
            WireMode::Binary => "binary",
        }
    }

    /// Every mode, in snapshot order.
    pub fn all() -> [WireMode; 3] {
        [WireMode::Json, WireMode::Base64, WireMode::Binary]
    }
}

impl std::str::FromStr for WireMode {
    type Err = MatexpError;

    fn from_str(s: &str) -> Result<WireMode> {
        WireMode::all()
            .into_iter()
            .find(|m| m.as_str() == s.to_ascii_lowercase())
            .ok_or_else(|| {
                MatexpError::Config(format!("unknown wire mode {s:?} (json|base64|binary)"))
            })
    }
}

/// One load run's shape.
#[derive(Clone, Copy, Debug)]
pub struct LoadtestConfig {
    /// Concurrent client connections.
    pub clients: usize,
    /// Measured requests per client.
    pub requests: usize,
    /// Unmeasured warmup requests per client (fills caches, spins up
    /// workers, settles allocator state).
    pub warmup: usize,
    /// Matrix side length of every request.
    pub n: usize,
    /// Exponent `N` of every request.
    pub power: u64,
    /// Execution method of every request.
    pub method: Method,
    /// `Some(r)`: open loop, each client schedules arrivals at `r` req/s
    /// and latency runs from the scheduled start. `None`: closed loop.
    pub rate: Option<f64>,
    /// Seed for the per-client operand matrices.
    pub seed: u64,
}

impl Default for LoadtestConfig {
    fn default() -> LoadtestConfig {
        LoadtestConfig {
            clients: 4,
            requests: 25,
            warmup: 2,
            n: 64,
            power: 256,
            method: Method::Ours,
            rate: None,
            seed: 42,
        }
    }
}

impl LoadtestConfig {
    /// Basic shape validation (zero clients or requests measure nothing).
    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 || self.requests == 0 {
            return Err(MatexpError::Config(
                "loadtest needs at least 1 client and 1 request".into(),
            ));
        }
        if self.rate.is_some_and(|r| !r.is_finite() || r <= 0.0) {
            return Err(MatexpError::Config("--rate must be a positive number".into()));
        }
        Ok(())
    }
}

/// Aggregated result of one `(mode, config)` run.
#[derive(Clone, Debug)]
pub struct ModeReport {
    /// Wire mode the clients spoke.
    pub mode: WireMode,
    /// Total measured requests (clients × requests per client).
    pub requests: usize,
    /// Wall-clock seconds of the measured phase (slowest client; all
    /// clients start together on a barrier after warmup).
    pub wall_s: f64,
    /// Measured requests per second over `wall_s`.
    pub throughput_rps: f64,
    /// Median request latency, seconds.
    pub p50_s: f64,
    /// 99th-percentile request latency, seconds.
    pub p99_s: f64,
    /// Mean request latency, seconds.
    pub mean_s: f64,
    /// Fastest request, seconds.
    pub min_s: f64,
    /// Slowest request, seconds.
    pub max_s: f64,
    /// Bytes the clients wrote to the wire (requests), warmup included.
    pub wire_bytes_out: u64,
    /// Bytes the clients read off the wire (replies), warmup included.
    pub wire_bytes_in: u64,
    /// Per-stage server-side latency breakdown (one row per
    /// [`STAGE_NAMES`] entry), aggregated over the measured requests.
    pub stages: Vec<StageReport>,
}

/// Distribution of one server-side stage over a run's measured requests.
#[derive(Clone, Copy, Debug)]
pub struct StageReport {
    /// Stage name (one of [`STAGE_NAMES`]).
    pub stage: &'static str,
    /// Median stage time, microseconds.
    pub p50_us: f64,
    /// 99th-percentile stage time, microseconds.
    pub p99_us: f64,
    /// Mean stage time, microseconds.
    pub mean_us: f64,
}

/// Aggregate per-request stage samples into one [`StageReport`] per
/// stage. Zero samples (a run that measured nothing) yields all-zero
/// rows rather than NaNs.
fn aggregate_stages(samples: &[[u64; 5]]) -> Vec<StageReport> {
    STAGE_NAMES
        .iter()
        .enumerate()
        .map(|(k, stage)| {
            let mut col: Vec<f64> = samples.iter().map(|s| s[k] as f64).collect();
            col.sort_by(|a, b| a.partial_cmp(b).expect("NaN stage sample"));
            if col.is_empty() {
                return StageReport { stage, p50_us: 0.0, p99_us: 0.0, mean_us: 0.0 };
            }
            StageReport {
                stage,
                p50_us: percentile(&col, 0.50),
                p99_us: percentile(&col, 0.99),
                mean_us: col.iter().sum::<f64>() / col.len() as f64,
            }
        })
        .collect()
}

/// Run one wire mode against a live server at `addr`.
///
/// Every client connects, configures its codec (binary mode negotiates
/// frames and fails the run if the server declines), performs its warmup
/// requests, then parks on a barrier so the measured phase starts
/// simultaneously across clients.
pub fn run_mode(addr: &str, mode: WireMode, cfg: &LoadtestConfig) -> Result<ModeReport> {
    cfg.validate()?;
    let barrier = Barrier::new(cfg.clients);
    let per_client: Vec<Result<ClientRun>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|cid| {
                let barrier = &barrier;
                scope.spawn(move || run_client(addr, mode, cfg, cid as u64, barrier))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(MatexpError::Service("load client panicked".into())))
            })
            .collect()
    });

    let mut latencies: Vec<f64> = Vec::with_capacity(cfg.clients * cfg.requests);
    let mut stage_samples: Vec<[u64; 5]> = Vec::with_capacity(cfg.clients * cfg.requests);
    let (mut wall_s, mut bytes_out, mut bytes_in) = (0.0f64, 0u64, 0u64);
    for outcome in per_client {
        let run = outcome?;
        latencies.extend(run.latencies);
        stage_samples.extend(run.stages);
        wall_s = wall_s.max(run.wall_s);
        bytes_out += run.bytes_out;
        bytes_in += run.bytes_in;
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
    let total = latencies.len();
    Ok(ModeReport {
        mode,
        requests: total,
        wall_s,
        throughput_rps: total as f64 / wall_s.max(f64::MIN_POSITIVE),
        p50_s: percentile(&latencies, 0.50),
        p99_s: percentile(&latencies, 0.99),
        mean_s: latencies.iter().sum::<f64>() / total as f64,
        min_s: latencies[0],
        max_s: latencies[total - 1],
        wire_bytes_out: bytes_out,
        wire_bytes_in: bytes_in,
        stages: aggregate_stages(&stage_samples),
    })
}

/// One client's share of a run.
struct ClientRun {
    /// End-to-end latency of each measured request, seconds.
    latencies: Vec<f64>,
    /// Per-request server-side stage breakdowns, microseconds.
    stages: Vec<[u64; 5]>,
    /// Measured-phase wall seconds for this client.
    wall_s: f64,
    /// Wire bytes this client wrote.
    bytes_out: u64,
    /// Wire bytes this client read.
    bytes_in: u64,
}

fn run_client(
    addr: &str,
    mode: WireMode,
    cfg: &LoadtestConfig,
    cid: u64,
    barrier: &Barrier,
) -> Result<ClientRun> {
    let mut client = MatexpClient::connect(addr)?;
    match mode {
        WireMode::Json => {}
        WireMode::Base64 => client = client.with_base64(),
        WireMode::Binary => {
            if !client.negotiate_binary()? {
                return Err(MatexpError::Service(
                    "server declined binary frame negotiation".into(),
                ));
            }
        }
    }
    // spectral radius < 1 keeps A^N finite at any measured power
    let a = Matrix::random_spectral(cfg.n, 0.9, cfg.seed.wrapping_add(cid) + 1);
    for _ in 0..cfg.warmup {
        client.expm(&a, cfg.power, cfg.method)?;
    }
    barrier.wait();
    let t0 = Instant::now();
    let mut latencies = Vec::with_capacity(cfg.requests);
    let mut stages = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        let started = match cfg.rate {
            // open loop: requests are due on a fixed schedule, and
            // latency runs from the *due* time — a slow server eats into
            // later requests' budget instead of slowing the clock down
            Some(rate) => {
                let due = t0 + Duration::from_secs_f64(i as f64 / rate);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                due
            }
            None => Instant::now(),
        };
        let (_, stats) = client.expm(&a, cfg.power, cfg.method)?;
        latencies.push(started.elapsed().as_secs_f64());
        stages.push(stage_sample(&stats));
    }
    let (bytes_out, bytes_in) = client.wire_bytes();
    Ok(ClientRun {
        latencies,
        stages,
        wall_s: t0.elapsed().as_secs_f64(),
        bytes_out,
        bytes_in,
    })
}

/// One cluster member's share of a routed run (snapshot `members` rows).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemberSpread {
    /// Member address as the router names it.
    pub member: String,
    /// Requests the router sent it (affinity + least-load), lifetime.
    pub routed: u64,
}

/// Ask whatever serves `addr` for its per-member routed counts: a cluster
/// router's status/metrics document carries a `members` array, a plain
/// server's does not — so this returns the spread behind a router and an
/// empty vec (not an error) against a single server or on any wire
/// failure. Drives the snapshot's `members` block.
pub fn fetch_members(addr: &str) -> Vec<MemberSpread> {
    let Ok(mut client) = MatexpClient::connect(addr) else {
        return Vec::new();
    };
    let Ok(doc) = client.metrics() else {
        return Vec::new();
    };
    let Some(rows) = doc.get("members").and_then(Json::as_arr) else {
        return Vec::new();
    };
    rows.iter()
        .filter_map(|m| {
            Some(MemberSpread {
                member: m.get("member").and_then(Json::as_str)?.to_string(),
                routed: m.get("routed").and_then(Json::as_u64)?,
            })
        })
        .collect()
}

/// Round-trip codec timing at one matrix size: the JSON/base64 line codec
/// vs the binary frame codec, encode + decode of one full expm reply.
#[derive(Clone, Copy, Debug)]
pub struct CodecBench {
    /// Matrix side length measured.
    pub n: usize,
    /// Best-of-iters seconds for the JSON line with a base64 payload
    /// (the *faster* of the two line encodings — the honest baseline).
    pub json_b64_s: f64,
    /// Best-of-iters seconds for the binary frame.
    pub frame_s: f64,
    /// `json_b64_s / frame_s`.
    pub speedup: f64,
}

/// Measure one encode+decode round trip of an n×n expm reply in both
/// codecs, best of `iters` (the steady-state cost, robust to a stray
/// scheduler hiccup).
pub fn codec_roundtrip(n: usize, iters: usize) -> CodecBench {
    let m = Matrix::random(n, 7);
    let stats = WireStats {
        launches: 10,
        multiplies: 10,
        h2d_transfers: 1,
        d2h_transfers: 1,
        bytes_copied: (n * n * 8) as u64,
        buffers_recycled: 8,
        peak_resident_bytes: (n * n * 8) as u64,
        wall_s: 0.01,
        queue_us: 150,
        plan_us: 6,
        prepare_us: 80,
        launch_us: 700,
        wire_us: 30,
        per_device: Vec::new(),
    };
    let line_resp = WireResponse::Ok {
        result: Some(m.data().to_vec()),
        stats: Some(stats.clone()),
        metrics: None,
        payload: Payload::Base64,
        id: Some(1),
        frame: None,
    };
    let best = |f: &mut dyn FnMut()| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..iters.max(1) {
            let t = Instant::now();
            f();
            best = best.min(t.elapsed().as_secs_f64());
        }
        best
    };
    let json_b64_s = best(&mut || {
        let line = line_resp.encode().expect("finite payload encodes");
        let decoded = WireResponse::decode(&line).expect("own encoding decodes");
        std::hint::black_box(decoded);
    });
    let frame_resp =
        Frame::ExpmOk { id: 1, n, stats: stats.clone(), result: m.data().to_vec() };
    let frame_s = best(&mut || {
        let bytes = frame_resp.encode();
        let decoded = Frame::read_from(&mut &bytes[..], crate::server::frame::MAX_PAYLOAD)
            .expect("own encoding decodes");
        std::hint::black_box(decoded);
    });
    CodecBench { n, json_b64_s, frame_s, speedup: json_b64_s / frame_s.max(f64::MIN_POSITIVE) }
}

/// Serialize a finished run into the persisted `BENCH_<pr>.json` shape.
/// `members` is the per-member routed spread from [`fetch_members`]
/// (empty against a single server).
pub fn snapshot(
    bench_id: u64,
    cfg: &LoadtestConfig,
    modes: &[ModeReport],
    codec: &CodecBench,
    members: &[MemberSpread],
) -> Json {
    let mode_rows: Vec<Json> = modes
        .iter()
        .map(|r| {
            let stage_rows: Vec<Json> = r
                .stages
                .iter()
                .map(|s| {
                    json_obj![
                        ("stage", s.stage),
                        ("p50_us", s.p50_us),
                        ("p99_us", s.p99_us),
                        ("mean_us", s.mean_us),
                    ]
                })
                .collect();
            json_obj![
                ("mode", r.mode.as_str()),
                ("requests", r.requests),
                ("wall_s", r.wall_s),
                ("throughput_rps", r.throughput_rps),
                ("p50_s", r.p50_s),
                ("p99_s", r.p99_s),
                ("mean_s", r.mean_s),
                ("min_s", r.min_s),
                ("max_s", r.max_s),
                ("wire_bytes_out", r.wire_bytes_out),
                ("wire_bytes_in", r.wire_bytes_in),
                ("stages", Json::Arr(stage_rows)),
            ]
        })
        .collect();
    json_obj![
        ("schema", SNAPSHOT_SCHEMA),
        ("bench_id", bench_id),
        (
            "workload",
            json_obj![
                ("clients", cfg.clients),
                ("requests_per_client", cfg.requests),
                ("warmup_per_client", cfg.warmup),
                ("n", cfg.n),
                ("power", cfg.power),
                ("method", cfg.method.as_str()),
                (
                    "loop",
                    match cfg.rate {
                        Some(_) => "open",
                        None => "closed",
                    }
                ),
                ("rate_rps", cfg.rate.unwrap_or(0.0)),
            ]
        ),
        ("modes", Json::Arr(mode_rows)),
        (
            "members",
            Json::Arr(
                members
                    .iter()
                    .map(|m| json_obj![("member", m.member.as_str()), ("routed", m.routed)])
                    .collect()
            )
        ),
        (
            "codec_roundtrip",
            json_obj![
                ("n", codec.n),
                ("json_b64_s", codec.json_b64_s),
                ("frame_s", codec.frame_s),
                ("speedup", codec.speedup),
            ]
        ),
    ]
}

/// Validate a persisted snapshot (CI gates `BENCH_*.json` artifacts on
/// this, so a malformed or truncated snapshot fails the build instead of
/// silently polluting the trajectory).
pub fn validate_snapshot(v: &Json) -> Result<()> {
    let fail = |why: &str| Err(MatexpError::Config(format!("malformed loadtest snapshot: {why}")));
    let v3 = match v.get("schema").and_then(Json::as_str) {
        Some(SNAPSHOT_SCHEMA) => true,
        Some(SNAPSHOT_SCHEMA_V2) => false,
        _ => {
            return fail(&format!("schema must be {SNAPSHOT_SCHEMA:?} (or {SNAPSHOT_SCHEMA_V2:?})"))
        }
    };
    if v.get("bench_id").and_then(Json::as_u64).is_none() {
        return fail("missing numeric bench_id");
    }
    if v.get("workload").is_none() {
        return fail("missing workload");
    }
    let modes = match v.get("modes").and_then(Json::as_arr) {
        Some(m) if !m.is_empty() => m,
        _ => return fail("modes must be a non-empty array"),
    };
    for (i, mode) in modes.iter().enumerate() {
        if mode.get("mode").and_then(Json::as_str).is_none() {
            return fail(&format!("modes[{i}] missing mode name"));
        }
        for field in ["p50_s", "p99_s", "mean_s", "throughput_rps", "wall_s"] {
            match mode.get(field).and_then(Json::as_f64) {
                Some(x) if x.is_finite() && x > 0.0 => {}
                _ => return fail(&format!("modes[{i}].{field} must be finite and positive")),
            }
        }
        // schema v2: one stage row per STAGE_NAMES entry, in order, with
        // finite non-negative quantiles (zero is legitimate — e.g.
        // `prepare` on a warm cache)
        let stages = match mode.get("stages").and_then(Json::as_arr) {
            Some(s) if s.len() == STAGE_NAMES.len() => s,
            _ => {
                return fail(&format!(
                    "modes[{i}].stages must list all {} stages",
                    STAGE_NAMES.len()
                ))
            }
        };
        for (row, want) in stages.iter().zip(STAGE_NAMES) {
            if row.get("stage").and_then(Json::as_str) != Some(want) {
                return fail(&format!("modes[{i}].stages out of order (expected {want:?})"));
            }
            for field in ["p50_us", "p99_us", "mean_us"] {
                match row.get(field).and_then(Json::as_f64) {
                    Some(x) if x.is_finite() && x >= 0.0 => {}
                    _ => {
                        return fail(&format!(
                            "modes[{i}].stages[{want}].{field} must be finite and non-negative"
                        ))
                    }
                }
            }
        }
    }
    // schema v3: the members block is required (empty is fine — it means
    // "target was a single server"); each row pairs an address with its
    // routed count
    if v3 {
        let members = match v.get("members").and_then(Json::as_arr) {
            Some(m) => m,
            None => return fail("members must be an array (schema v3)"),
        };
        for (i, m) in members.iter().enumerate() {
            if m.get("member").and_then(Json::as_str).is_none() {
                return fail(&format!("members[{i}] missing member address"));
            }
            if m.get("routed").and_then(Json::as_u64).is_none() {
                return fail(&format!("members[{i}] missing numeric routed count"));
            }
        }
    }
    match v.get("codec_roundtrip").and_then(|c| c.get("speedup")).and_then(Json::as_f64) {
        Some(x) if x.is_finite() && x > 0.0 => {}
        _ => return fail("codec_roundtrip.speedup must be finite and positive"),
    }
    Ok(())
}

/// Render one run as the human table `matexp loadtest` prints.
pub fn render(modes: &[ModeReport], codec: &CodecBench) -> String {
    use crate::bench::format_secs;
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<8} {:>9} {:>11} {:>11} {:>11} {:>11} {:>12} {:>12}",
        "mode", "requests", "p50", "p99", "mean", "req/s", "bytes out", "bytes in"
    );
    for r in modes {
        let _ = writeln!(
            out,
            "{:<8} {:>9} {:>11} {:>11} {:>11} {:>11.1} {:>12} {:>12}",
            r.mode.as_str(),
            r.requests,
            format_secs(r.p50_s),
            format_secs(r.p99_s),
            format_secs(r.mean_s),
            r.throughput_rps,
            r.wire_bytes_out,
            r.wire_bytes_in,
        );
    }
    // per-stage server-side breakdown (from the trace layer, via the
    // stats stage fields each reply carries)
    let _ = writeln!(
        out,
        "\n{:<8} {:<9} {:>11} {:>11} {:>11}",
        "mode", "stage", "p50", "p99", "mean"
    );
    for r in modes {
        for s in &r.stages {
            let _ = writeln!(
                out,
                "{:<8} {:<9} {:>11} {:>11} {:>11}",
                r.mode.as_str(),
                s.stage,
                format_secs(s.p50_us / 1e6),
                format_secs(s.p99_us / 1e6),
                format_secs(s.mean_us / 1e6),
            );
        }
    }
    let _ = writeln!(
        out,
        "\ncodec round-trip at n={}: json+b64 {} vs frame {} ({:.1}x)",
        codec.n,
        format_secs(codec.json_b64_s),
        format_secs(codec.frame_s),
        codec.speedup,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(mode: WireMode) -> ModeReport {
        ModeReport {
            mode,
            requests: 100,
            wall_s: 2.0,
            throughput_rps: 50.0,
            p50_s: 0.01,
            p99_s: 0.05,
            mean_s: 0.015,
            min_s: 0.005,
            max_s: 0.06,
            wire_bytes_out: 1 << 20,
            wire_bytes_in: 1 << 21,
            stages: aggregate_stages(&[[120, 5, 0, 800, 30], [90, 4, 60, 750, 25]]),
        }
    }

    fn spread() -> Vec<MemberSpread> {
        vec![
            MemberSpread { member: "127.0.0.1:9401".into(), routed: 70 },
            MemberSpread { member: "127.0.0.1:9402".into(), routed: 30 },
        ]
    }

    #[test]
    fn snapshot_roundtrips_and_validates() {
        let cfg = LoadtestConfig::default();
        let codec = CodecBench { n: 64, json_b64_s: 1e-3, frame_s: 1e-4, speedup: 10.0 };
        let v = snapshot(
            9,
            &cfg,
            &[report(WireMode::Json), report(WireMode::Binary)],
            &codec,
            &spread(),
        );
        validate_snapshot(&v).unwrap();
        // survives a serialize → parse round trip (what CI actually reads)
        let reparsed = Json::parse(&v.to_string()).unwrap();
        validate_snapshot(&reparsed).unwrap();
        let text = v.to_string();
        assert!(text.contains("\"schema\":\"matexp-loadtest/3\""), "{text}");
        assert!(text.contains("\"p99_s\""), "{text}");
        // v2 carried the per-stage breakdown for every mode
        assert!(text.contains("\"stages\""), "{text}");
        assert!(text.contains("\"stage\":\"launch\""), "{text}");
        // v3 carries the per-member routed spread
        assert!(text.contains("\"member\":\"127.0.0.1:9401\""), "{text}");
        assert!(text.contains("\"routed\":70"), "{text}");
    }

    #[test]
    fn members_block_rules() {
        let cfg = LoadtestConfig::default();
        let codec = CodecBench { n: 64, json_b64_s: 1e-3, frame_s: 1e-4, speedup: 10.0 };
        // empty spread (single-server target) is a valid v3 snapshot
        let single = snapshot(9, &cfg, &[report(WireMode::Json)], &codec, &[]);
        validate_snapshot(&single).unwrap();
        // a v3 snapshot missing the block entirely is malformed…
        let routed = snapshot(9, &cfg, &[report(WireMode::Json)], &codec, &spread());
        let stripped = routed
            .to_string()
            .replace("\"members\":[{\"member\":\"127.0.0.1:9401\"", "\"membres\":[{\"member\":\"127.0.0.1:9401\"");
        assert_ne!(stripped, routed.to_string(), "replace must hit");
        assert!(validate_snapshot(&Json::parse(&stripped).unwrap()).is_err());
        // …as is a member row without its routed count
        let unrouted = routed.to_string().replace("\"routed\":70", "\"route\":70");
        assert_ne!(unrouted, routed.to_string(), "replace must hit");
        assert!(validate_snapshot(&Json::parse(&unrouted).unwrap()).is_err());
        // a committed v2 snapshot (no members block) still validates
        let v2 = single
            .to_string()
            .replace("\"schema\":\"matexp-loadtest/3\"", "\"schema\":\"matexp-loadtest/2\"");
        validate_snapshot(&Json::parse(&v2).unwrap()).unwrap();
    }

    #[test]
    fn stage_aggregation_and_validation() {
        let rows = aggregate_stages(&[[100, 10, 0, 500, 20], [200, 20, 0, 700, 40]]);
        assert_eq!(rows.len(), STAGE_NAMES.len());
        assert_eq!(rows[0].stage, "queue");
        assert!(rows[0].p50_us >= 100.0 && rows[0].p99_us <= 200.0);
        // the all-zero prepare column is legitimate (warm cache)
        assert_eq!(rows[2].p50_us, 0.0);
        // no samples → zero rows, not NaNs
        for row in aggregate_stages(&[]) {
            assert_eq!(row.mean_us, 0.0);
        }

        // a snapshot whose mode rows lack the stage table is malformed v2
        let cfg = LoadtestConfig::default();
        let codec = CodecBench { n: 64, json_b64_s: 1e-3, frame_s: 1e-4, speedup: 10.0 };
        let good = snapshot(7, &cfg, &[report(WireMode::Json)], &codec, &[]);
        let stripped = good.to_string().replace("\"stage\":\"launch\"", "\"stage\":\"lunch\"");
        assert_ne!(stripped, good.to_string(), "replace must hit");
        assert!(validate_snapshot(&Json::parse(&stripped).unwrap()).is_err());
    }

    #[test]
    fn validate_rejects_damage() {
        let cfg = LoadtestConfig::default();
        let codec = CodecBench { n: 64, json_b64_s: 1e-3, frame_s: 1e-4, speedup: 10.0 };
        let good = snapshot(6, &cfg, &[report(WireMode::Json)], &codec, &[]);

        assert!(validate_snapshot(&Json::parse("{}").unwrap()).is_err());
        assert!(validate_snapshot(&Json::parse(r#"{"schema":"nope"}"#).unwrap()).is_err());

        // empty modes
        assert!(validate_snapshot(&snapshot(6, &cfg, &[], &codec, &[])).is_err());

        // a zeroed p50 (a run that measured nothing) is malformed
        let zeroed = good.to_string().replace("\"p50_s\":0.01", "\"p50_s\":0");
        assert_ne!(zeroed, good.to_string(), "replace must hit");
        assert!(validate_snapshot(&Json::parse(&zeroed).unwrap()).is_err());

        // a NaN speedup (codec bench never ran) is malformed
        let mut bad_codec = codec;
        bad_codec.speedup = 0.0;
        assert!(validate_snapshot(&snapshot(6, &cfg, &[report(WireMode::Json)], &bad_codec, &[]))
            .is_err());
    }

    #[test]
    fn codec_roundtrip_measures_both_paths() {
        let c = codec_roundtrip(16, 3);
        assert_eq!(c.n, 16);
        assert!(c.json_b64_s > 0.0 && c.json_b64_s.is_finite());
        assert!(c.frame_s > 0.0 && c.frame_s.is_finite());
        assert!(c.speedup > 0.0);
    }

    #[test]
    fn config_validation() {
        assert!(LoadtestConfig::default().validate().is_ok());
        assert!(LoadtestConfig { clients: 0, ..Default::default() }.validate().is_err());
        assert!(LoadtestConfig { requests: 0, ..Default::default() }.validate().is_err());
        assert!(
            LoadtestConfig { rate: Some(0.0), ..Default::default() }.validate().is_err()
        );
        assert!(
            LoadtestConfig { rate: Some(f64::NAN), ..Default::default() }.validate().is_err()
        );
    }

    #[test]
    fn wire_mode_parses() {
        use std::str::FromStr;
        for m in WireMode::all() {
            assert_eq!(WireMode::from_str(m.as_str()).unwrap(), m);
        }
        assert!(WireMode::from_str("carrier-pigeon").is_err());
    }

    #[test]
    fn render_mentions_every_mode() {
        let codec = CodecBench { n: 64, json_b64_s: 1e-3, frame_s: 1e-4, speedup: 10.0 };
        let out = render(&[report(WireMode::Json), report(WireMode::Binary)], &codec);
        assert!(out.contains("json"), "{out}");
        assert!(out.contains("binary"), "{out}");
        assert!(out.contains("codec round-trip"), "{out}");
        // the per-stage table names every stage
        for stage in STAGE_NAMES {
            assert!(out.contains(stage), "missing stage {stage}: {out}");
        }
    }
}
