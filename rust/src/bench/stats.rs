//! Robust summary statistics for benchmark samples.

/// Summary of a sample set (seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (50th percentile, nearest-rank).
    pub median: f64,
    /// Population standard deviation.
    pub stddev: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// 95th percentile (nearest-rank).
    pub p95: f64,
}

impl Summary {
    /// Summarize a non-empty sample set.
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "no samples");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Summary {
            mean,
            median: percentile(&sorted, 0.5),
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p95: percentile(&sorted, 0.95),
        }
    }
}

/// Nearest-rank percentile of pre-sorted data.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_sample() {
        let s = Summary::from_samples(&[2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p95, 2.0);
    }

    #[test]
    fn known_distribution() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_of_even_count_is_nearest_rank() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.0); // nearest-rank lower median
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::from_samples(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::from_samples(&[]);
    }
}
