//! In-tree micro/macro benchmark harness (criterion replacement for the
//! offline build).
//!
//! `cargo bench` targets are plain `harness = false` binaries; each builds
//! a [`Runner`], registers measurements, and the runner handles warmup,
//! adaptive sample counts, robust statistics, and table rendering. The
//! experiment benches additionally print paper-vs-simulated-vs-measured
//! rows (see [`crate::experiments`]).

pub mod loadtest;
pub mod stats;

use std::time::{Duration, Instant};

pub use stats::Summary;

/// One measured quantity.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Robust statistics over the timed samples.
    pub summary: Summary,
    /// How many samples were taken.
    pub samples: usize,
}

/// Bench configuration (tweak per target; defaults favor the slow
/// end-to-end PJRT paths).
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations before sampling.
    pub warmup_iters: usize,
    /// Minimum timed samples.
    pub min_samples: usize,
    /// Maximum timed samples.
    pub max_samples: usize,
    /// Stop early when total sampling time exceeds this.
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 1,
            min_samples: 5,
            max_samples: 50,
            time_budget: Duration::from_secs(5),
        }
    }
}

/// Collects measurements and renders them.
pub struct Runner {
    /// Sampling policy (warmup, sample bounds, time budget).
    pub cfg: BenchConfig,
    title: String,
    results: Vec<Measurement>,
}

impl Runner {
    /// A runner with the default sampling policy.
    pub fn new(title: &str) -> Runner {
        Runner { cfg: BenchConfig::default(), title: title.to_string(), results: Vec::new() }
    }

    /// A runner with an explicit sampling policy.
    pub fn with_config(title: &str, cfg: BenchConfig) -> Runner {
        Runner { cfg, title: title.to_string(), results: Vec::new() }
    }

    /// Time `f` under the adaptive sampling policy and record it.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Summary {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.cfg.min_samples);
        let started = Instant::now();
        while samples.len() < self.cfg.max_samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= self.cfg.min_samples && started.elapsed() > self.cfg.time_budget {
                break;
            }
        }
        let summary = Summary::from_samples(&samples);
        self.results.push(Measurement {
            name: name.to_string(),
            summary,
            samples: samples.len(),
        });
        summary
    }

    /// Record an externally-measured value (e.g. a whole-table experiment
    /// row measured by the experiments module).
    pub fn record(&mut self, name: &str, seconds: f64) {
        self.results.push(Measurement {
            name: name.to_string(),
            summary: Summary::from_samples(&[seconds]),
            samples: 1,
        });
    }

    /// Everything measured so far, in registration order.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Render the classic bench table to stdout.
    pub fn report(&self) {
        println!("\n== {} ==", self.title);
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>8}",
            "benchmark", "median", "mean", "±stddev", "samples"
        );
        for m in &self.results {
            println!(
                "{:<48} {:>12} {:>12} {:>12} {:>8}",
                m.name,
                format_secs(m.summary.median),
                format_secs(m.summary.mean),
                format_secs(m.summary.stddev),
                m.samples
            );
        }
    }
}

/// Human-scaled seconds: ns/µs/ms/s.
pub fn format_secs(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", format_secs(-s));
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Prevent the optimizer from discarding a computed value
/// (`std::hint::black_box` wrapper, so benches read uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut r = Runner::with_config(
            "t",
            BenchConfig {
                warmup_iters: 1,
                min_samples: 3,
                max_samples: 5,
                time_budget: Duration::from_millis(200),
            },
        );
        let mut count = 0usize;
        let s = r.bench("noop", || {
            count += 1;
        });
        assert!(count >= 4, "warmup + min samples, got {count}");
        assert!(s.mean >= 0.0);
        assert_eq!(r.results().len(), 1);
    }

    #[test]
    fn time_budget_stops_early() {
        let mut r = Runner::with_config(
            "t",
            BenchConfig {
                warmup_iters: 0,
                min_samples: 2,
                max_samples: 1000,
                time_budget: Duration::from_millis(50),
            },
        );
        r.bench("sleepy", || std::thread::sleep(Duration::from_millis(30)));
        assert!(r.results()[0].samples < 10);
    }

    #[test]
    fn format_secs_scales() {
        assert_eq!(format_secs(2.5e-9), "2.5ns");
        assert_eq!(format_secs(2.5e-6), "2.5µs");
        assert_eq!(format_secs(2.5e-3), "2.50ms");
        assert_eq!(format_secs(2.5), "2.500s");
    }

    #[test]
    fn report_does_not_panic() {
        let mut r = Runner::new("demo");
        r.record("manual", 0.001);
        r.report();
    }
}
