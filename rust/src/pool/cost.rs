//! Cost-model work splitter: predict per-device throughput, assign shares
//! proportionally, and fall back to the fastest single device whenever a
//! split is predicted to lose.
//!
//! Simulated devices are predicted with their own analytic
//! [`GpuTimingModel`] (launch overhead + roofline kernel + PCIe
//! transfers — the model the [`crate::runtime::SimBackend`] clock runs
//! on, so predictions match execution exactly). CPU devices are
//! micro-calibrated at pool startup: one timed matmul yields an effective
//! seconds-per-FLOP, the D'Alberto (arXiv:1205.2927) recipe for static
//! heterogeneous splits.

use crate::pool::partition::TileGrid;
use crate::simulator::timing::GpuTimingModel;

/// Smallest tile side the auto splitter will consider: below this, launch
/// overhead dwarfs tile compute on every modeled device.
pub const MIN_AUTO_TILE: usize = 16;

/// Per-device execution-time predictor.
#[derive(Clone, Debug)]
pub enum DeviceCost {
    /// Analytic timing model (sim devices) — predictions match the
    /// device's simulated clock exactly.
    Model(GpuTimingModel),
    /// Micro-calibrated device (CPU): `fixed + 2·n³ · per_flop` seconds
    /// per multiply.
    Measured { fixed_s: f64, per_flop_s: f64 },
    /// Measured throughput curve (CPU with the autotuner on):
    /// `(n, seconds-per-multiply)` samples ascending in `n`, from
    /// [`crate::linalg::autotune::cpu_curve`]. Predictions interpolate
    /// log-log between samples and extrapolate cubically past the ends —
    /// unlike [`DeviceCost::Measured`], this sees the kernel crossovers
    /// (packed → SIMD → Strassen), so LPT stops mispredicting splits at
    /// sizes far from the single calibration point.
    Curve { samples: Vec<(usize, f64)> },
}

/// Seconds for one multiply at size `n` from a measured curve: exact at
/// samples, log-log interpolation between them, cubic (`2n³`) scaling
/// from the nearest end sample outside the measured range.
fn curve_multiply_s(samples: &[(usize, f64)], n: usize) -> f64 {
    assert!(!samples.is_empty(), "empty cost curve");
    let x = n.max(1) as f64;
    let (n0, s0) = samples[0];
    if x <= n0 as f64 {
        return s0 * (x / n0.max(1) as f64).powi(3);
    }
    let (nl, sl) = samples[samples.len() - 1];
    if x >= nl as f64 {
        return sl * (x / nl.max(1) as f64).powi(3);
    }
    for w in samples.windows(2) {
        let (na, sa) = w[0];
        let (nb, sb) = w[1];
        if x <= nb as f64 {
            let t = (x.ln() - (na.max(1) as f64).ln())
                / ((nb.max(1) as f64).ln() - (na.max(1) as f64).ln());
            return (sa.max(1e-12).ln() + t * (sb.max(1e-12).ln() - sa.max(1e-12).ln())).exp();
        }
    }
    sl
}

impl DeviceCost {
    /// Predicted seconds for one `mma{g}` tile job at tile side `t`:
    /// upload `2g` operand tiles, one launch of `g` multiplies, download
    /// the product tile. (Device-resident tile caching makes the real
    /// upload count a little lower; the prediction is an upper bound.)
    pub fn tile_job_s(&self, t: usize, g: usize) -> f64 {
        match self {
            DeviceCost::Model(m) => {
                m.eff_launch_overhead(t) + m.kernel_time(t, g) + m.transfer_time(t, 2 * g + 1)
            }
            DeviceCost::Measured { fixed_s, per_flop_s } => {
                fixed_s + 2.0 * (t as f64).powi(3) * g as f64 * per_flop_s
            }
            DeviceCost::Curve { samples } => g as f64 * curve_multiply_s(samples, t),
        }
    }

    /// Predicted seconds for one device-resident multiply at size `n`
    /// (no per-step transfers — buffers stay on the device).
    pub fn resident_multiply_s(&self, n: usize) -> f64 {
        match self {
            DeviceCost::Model(m) => m.eff_launch_overhead(n) + m.kernel_time(n, 1),
            DeviceCost::Measured { fixed_s, per_flop_s } => {
                fixed_s + 2.0 * (n as f64).powi(3) * per_flop_s
            }
            DeviceCost::Curve { samples } => curve_multiply_s(samples, n),
        }
    }

    /// Predicted seconds for one whole `A^N` request executed
    /// device-resident (`multiplies` multiplies, one upload + download).
    pub fn request_s(&self, n: usize, multiplies: usize) -> f64 {
        let transfers = match self {
            DeviceCost::Model(m) => m.transfer_time(n, 2),
            DeviceCost::Measured { .. } | DeviceCost::Curve { .. } => 0.0,
        };
        self.resident_multiply_s(n) * multiplies as f64 + transfers
    }
}

/// A concrete sharding of one multiply across the pool.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardPlan {
    /// Effective grid dimension (tiles per side).
    pub grid: usize,
    /// `assignment[bi * grid + bj]` = device index computing tile
    /// `(bi, bj)`.
    pub assignment: Vec<usize>,
    /// Predicted critical-path seconds for one sharded multiply.
    pub predicted_step_s: f64,
}

/// What the splitter decided for multiplies at one matrix size.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardDecision {
    /// Tile-shard every multiply across the pool.
    Shard(ShardPlan),
    /// Sharding is predicted to lose (launch-overhead-bound): run the
    /// whole plan device-resident on the fastest member.
    Single { device: usize, predicted_step_s: f64 },
}

/// Pick the grid + tile assignment minimizing the predicted makespan of
/// one multiply, or fall back to the fastest single device. A forced
/// grid (`cfg.pool.grid`) skips the fallback — tests and ablations use it
/// to pin the sharded path.
pub fn plan_shard(
    costs: &[DeviceCost],
    n: usize,
    max_grid: usize,
    forced_grid: Option<usize>,
) -> ShardDecision {
    assert!(!costs.is_empty(), "pool has no devices");
    let best_dev = fastest_device(costs, n);
    let single_s = costs[best_dev].resident_multiply_s(n);

    // an empty candidate list (max_grid < 2, nothing forced) means the
    // splitter may never shard — the configured cap is honored
    let grids: Vec<usize> = match forced_grid {
        Some(g) => vec![g.max(1)],
        None => (2..=max_grid).collect(),
    };
    let mut best: Option<ShardPlan> = None;
    for want_g in grids {
        let Ok(grid) = TileGrid::new(n, want_g) else { continue };
        let (g, t) = (grid.g(), grid.t());
        if forced_grid.is_none() && t < MIN_AUTO_TILE {
            continue;
        }
        let per_dev: Vec<f64> = costs.iter().map(|c| c.tile_job_s(t, g)).collect();
        let (assignment, makespan) =
            lpt_assign(costs.len(), grid.tiles(), |d, _| per_dev[d]);
        if best.as_ref().is_none_or(|b| makespan < b.predicted_step_s) {
            best = Some(ShardPlan { grid: g, assignment, predicted_step_s: makespan });
        }
    }
    match best {
        Some(p) if forced_grid.is_some() || p.predicted_step_s < single_s => {
            ShardDecision::Shard(p)
        }
        _ => ShardDecision::Single { device: best_dev, predicted_step_s: single_s },
    }
}

/// Greedy LPT scheduling over an arbitrary `(device, job) -> seconds`
/// cost function: jobs sorted by mean cost descending, each assigned to
/// the device minimizing its finish time. Returns
/// `(assignment[job] = device, makespan)`. Both the runtime splitter and
/// the scaling experiment's predictions go through this single
/// implementation so they cannot diverge.
pub fn lpt_assign<F>(devices: usize, jobs: usize, cost: F) -> (Vec<usize>, f64)
where
    F: Fn(usize, usize) -> f64,
{
    assert!(devices > 0, "pool has no devices");
    let mean: Vec<f64> = (0..jobs)
        .map(|j| (0..devices).map(|d| cost(d, j)).sum::<f64>() / devices as f64)
        .collect();
    let mut order: Vec<usize> = (0..jobs).collect();
    // longest-processing-time first, so big jobs don't straggle
    order.sort_by(|&x, &y| mean[y].partial_cmp(&mean[x]).expect("finite costs"));
    let mut load = vec![0.0f64; devices];
    let mut out = vec![0usize; jobs];
    for j in order {
        let mut best = 0;
        let mut best_finish = f64::INFINITY;
        for (d, l) in load.iter().enumerate() {
            let finish = l + cost(d, j);
            if finish < best_finish {
                best = d;
                best_finish = finish;
            }
        }
        out[j] = best;
        load[best] = best_finish;
    }
    (out, load.iter().cloned().fold(0.0, f64::max))
}

/// LPT assignment of whole requests to devices: returns
/// `assignment[request] = device`. `jobs` are `(n, multiplies)` pairs.
pub fn assign_requests(costs: &[DeviceCost], jobs: &[(usize, usize)]) -> Vec<usize> {
    lpt_assign(costs.len(), jobs.len(), |d, j| {
        let (n, m) = jobs[j];
        costs[d].request_s(n, m)
    })
    .0
}

/// Predicted makespan of a request assignment (experiments report this
/// next to the measured number).
pub fn request_makespan(
    costs: &[DeviceCost],
    jobs: &[(usize, usize)],
    assignment: &[usize],
) -> f64 {
    let mut load = vec![0.0f64; costs.len()];
    for (&(n, m), &d) in jobs.iter().zip(assignment) {
        load[d] += costs[d].request_s(n, m);
    }
    load.iter().cloned().fold(0.0, f64::max)
}

/// Device with the cheapest predicted device-resident multiply at size
/// `n` — the single source of the "fastest member" policy (the splitter's
/// fallback target and [`crate::pool::DevicePool::fastest_device`]).
pub fn fastest_device(costs: &[DeviceCost], n: usize) -> usize {
    let single: Vec<f64> = costs.iter().map(|c| c.resident_multiply_s(n)).collect();
    argmin(&single)
}

fn argmin(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x < xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::tables::calibrated_models;

    fn sim() -> DeviceCost {
        DeviceCost::Model(calibrated_models().0)
    }

    fn cpu(per_flop_s: f64) -> DeviceCost {
        DeviceCost::Measured { fixed_s: 0.0, per_flop_s }
    }

    #[test]
    fn lpt_splits_proportional_to_throughput() {
        // device 0 is 3x faster than device 1: of 16 equal requests it
        // should take ~12
        let costs = [cpu(1e-9), cpu(3e-9)];
        let jobs: Vec<(usize, usize)> = (0..16).map(|_| (64, 8)).collect();
        let assignment = assign_requests(&costs, &jobs);
        let fast = assignment.iter().filter(|&&d| d == 0).count();
        assert!((11..=13).contains(&fast), "fast device got {fast}/16");
        // makespan beats any single device
        let makespan = request_makespan(&costs, &jobs, &assignment);
        let solo: f64 = jobs.iter().map(|&(n, m)| costs[0].request_s(n, m)).sum();
        assert!(makespan < solo);
    }

    #[test]
    fn small_matrices_fall_back_to_single_device() {
        let costs = [sim(), sim(), sim(), sim()];
        // n=64 is launch-overhead-bound: sharding must lose
        match plan_shard(&costs, 64, 4, None) {
            ShardDecision::Single { predicted_step_s, .. } => {
                assert!(predicted_step_s > 0.0)
            }
            other => panic!("expected fallback, got {other:?}"),
        }
    }

    #[test]
    fn forced_grid_always_shards() {
        let costs = [sim(), sim()];
        match plan_shard(&costs, 64, 4, Some(2)) {
            ShardDecision::Shard(p) => {
                assert_eq!(p.grid, 2);
                assert_eq!(p.assignment.len(), 4);
                assert!(p.assignment.iter().all(|&d| d < 2));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn large_matrices_shard_across_sim_devices() {
        let costs = [sim(), sim(), sim(), sim()];
        match plan_shard(&costs, 1024, 4, None) {
            ShardDecision::Shard(p) => {
                // every device gets work and the step beats a single device
                let mut used: Vec<usize> = p.assignment.clone();
                used.sort_unstable();
                used.dedup();
                assert_eq!(used.len(), 4, "{:?}", p.assignment);
                let single = costs[0].resident_multiply_s(1024);
                assert!(
                    p.predicted_step_s < single,
                    "shard {} vs single {single}",
                    p.predicted_step_s
                );
            }
            other => panic!("expected shard at n=1024, got {other:?}"),
        }
    }

    #[test]
    fn curve_is_exact_at_samples_and_monotone_between() {
        let c = DeviceCost::Curve {
            samples: vec![(64, 1e-4), (128, 8e-4), (256, 6.4e-3)],
        };
        assert!((c.resident_multiply_s(64) - 1e-4).abs() < 1e-12);
        assert!((c.resident_multiply_s(256) - 6.4e-3).abs() < 1e-12);
        // between samples: strictly between the endpoints
        let mid = c.resident_multiply_s(96);
        assert!(mid > 1e-4 && mid < 8e-4, "{mid}");
        // outside the range: cubic scaling from the end samples
        let below = c.resident_multiply_s(32);
        assert!((below - 1e-4 / 8.0).abs() < 1e-9, "{below}");
        let above = c.resident_multiply_s(512);
        assert!((above - 6.4e-3 * 8.0).abs() < 1e-6, "{above}");
        // tile jobs scale with the multiply count
        let one = c.tile_job_s(64, 1);
        assert!((c.tile_job_s(64, 4) - 4.0 * one).abs() < 1e-12);
    }

    #[test]
    fn curve_feeds_lpt_like_any_other_cost() {
        // a curve 3x slower than the flat-cost device: LPT sides with the
        // flat device ~3:1, same as the measured/measured case above
        let curve = DeviceCost::Curve {
            samples: vec![(32, 2.0 * 32f64.powi(3) * 3e-9), (128, 2.0 * 128f64.powi(3) * 3e-9)],
        };
        let costs = [cpu(1e-9), curve];
        let jobs: Vec<(usize, usize)> = (0..16).map(|_| (64, 8)).collect();
        let assignment = assign_requests(&costs, &jobs);
        let fast = assignment.iter().filter(|&&d| d == 0).count();
        assert!((11..=13).contains(&fast), "fast device got {fast}/16");
    }

    #[test]
    fn slow_cpu_is_sidelined_not_harmful() {
        // a CPU orders of magnitude slower than the sim device must not
        // drag the split below the fast member (D'Alberto's criterion)
        let costs = [sim(), cpu(1e-8)];
        let single_sim = costs[0].resident_multiply_s(1024);
        match plan_shard(&costs, 1024, 4, None) {
            ShardDecision::Shard(p) => {
                assert!(p.predicted_step_s <= single_sim * 1.10, "{}", p.predicted_step_s)
            }
            ShardDecision::Single { predicted_step_s, .. } => {
                assert!(predicted_step_s <= single_sim * 1.10)
            }
        }
    }
}
