//! 2D block-row/column tile partitioner.
//!
//! A sharded multiply `C = A·B` is cut on a `g`×`g` grid of square tiles
//! of side `t = ceil(n/g)`; output tile `(i, j)` is the inner product
//! `Σ_k A(i,k)·B(k,j)`, which one device computes with a single `mma{g}`
//! launch. Edge tiles are zero-padded to keep every launch square —
//! zero rows/columns are inert under multiplication and addition, so the
//! padded product crops back to the exact result for *any* `n` and `g`.

use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;

/// A `g`×`g` block partition of an `n`×`n` matrix into `t`×`t` tiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    n: usize,
    g: usize,
    t: usize,
}

impl TileGrid {
    /// Partition size `n` on a `g`×`g` grid. `g` is clamped to `n` so no
    /// tile is entirely padding.
    pub fn new(n: usize, g: usize) -> Result<TileGrid> {
        if n == 0 {
            return Err(MatexpError::Plan("cannot tile an empty matrix".into()));
        }
        if g == 0 {
            return Err(MatexpError::Plan("tile grid must be >= 1".into()));
        }
        let g = g.min(n);
        let t = n.div_ceil(g);
        // re-derive g from the tile side so no band is pure padding
        // (n=5, g=4 → t=2 covers n in 3 bands, not 4)
        let g = n.div_ceil(t);
        Ok(TileGrid { n, g, t })
    }

    /// The partitioned matrix's side length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Grid dimension (tiles per side).
    pub fn g(&self) -> usize {
        self.g
    }

    /// Tile side (padded).
    pub fn t(&self) -> usize {
        self.t
    }

    /// Number of output tiles (`g²`).
    pub fn tiles(&self) -> usize {
        self.g * self.g
    }

    /// Rows (or columns) of real data in band `b` (the last band may be
    /// partly padding).
    fn band_len(&self, b: usize) -> usize {
        ((b + 1) * self.t).min(self.n) - (b * self.t).min(self.n)
    }

    /// Extract tile `(bi, bj)` as a zero-padded `t`×`t` matrix.
    pub fn extract(&self, m: &Matrix, bi: usize, bj: usize) -> Result<Matrix> {
        if m.n() != self.n {
            return Err(MatexpError::Plan(format!(
                "matrix is {}x{}, grid expects {}x{}",
                m.n(),
                m.n(),
                self.n,
                self.n
            )));
        }
        if bi >= self.g || bj >= self.g {
            return Err(MatexpError::Plan(format!(
                "tile ({bi},{bj}) out of a {}x{} grid",
                self.g, self.g
            )));
        }
        let rows = self.band_len(bi);
        let cols = self.band_len(bj);
        let mut out = Matrix::zeros(self.t);
        for r in 0..rows {
            let src_row = bi * self.t + r;
            let src = &m.data()[src_row * self.n + bj * self.t..][..cols];
            out.data_mut()[r * self.t..r * self.t + cols].copy_from_slice(src);
        }
        Ok(out)
    }

    /// Reassemble the `n`×`n` product from its `g²` tiles, cropping the
    /// padding. Every tile must be present exactly once.
    pub fn assemble(&self, tiles: &[((usize, usize), Matrix)]) -> Result<Matrix> {
        if tiles.len() != self.tiles() {
            return Err(MatexpError::Plan(format!(
                "assemble: got {} tiles, grid has {}",
                tiles.len(),
                self.tiles()
            )));
        }
        let mut out = Matrix::zeros(self.n);
        let mut seen = vec![false; self.tiles()];
        for ((bi, bj), tile) in tiles {
            let (bi, bj) = (*bi, *bj);
            if bi >= self.g || bj >= self.g {
                return Err(MatexpError::Plan(format!("assemble: bad tile ({bi},{bj})")));
            }
            if tile.n() != self.t {
                return Err(MatexpError::Plan(format!(
                    "assemble: tile ({bi},{bj}) is {}x{}, expected {}x{}",
                    tile.n(),
                    tile.n(),
                    self.t,
                    self.t
                )));
            }
            if std::mem::replace(&mut seen[bi * self.g + bj], true) {
                return Err(MatexpError::Plan(format!(
                    "assemble: duplicate tile ({bi},{bj})"
                )));
            }
            let rows = self.band_len(bi);
            let cols = self.band_len(bj);
            for r in 0..rows {
                let dst_row = bi * self.t + r;
                let src = &tile.data()[r * self.t..][..cols];
                out.data_mut()[dst_row * self.n + bj * self.t..][..cols]
                    .copy_from_slice(src);
            }
        }
        Ok(out)
    }

    /// The `mma{g}` operand tiles for output tile `(bi, bj)` of `A·B`:
    /// `[A(bi,0)..A(bi,g-1), B(0,bj)..B(g-1,bj)]`, with the grid position
    /// of each operand so callers can key device-resident tile caches.
    pub fn mma_operands(
        &self,
        a: &Matrix,
        b: &Matrix,
        bi: usize,
        bj: usize,
    ) -> Result<Vec<((usize, usize), Matrix)>> {
        let mut out = Vec::with_capacity(2 * self.g);
        for k in 0..self.g {
            out.push(((bi, k), self.extract(a, bi, k)?));
        }
        for k in 0..self.g {
            out.push(((k, bj), self.extract(b, k, bj)?));
        }
        Ok(out)
    }

    /// Host-side oracle for one output tile (tests and debugging): the
    /// padded `Σ_k A(bi,k)·B(k,bj)` computed with the naive matmul.
    pub fn tile_product(&self, a: &Matrix, b: &Matrix, bi: usize, bj: usize) -> Result<Matrix> {
        let mut acc = Matrix::zeros(self.t);
        for k in 0..self.g {
            let at = self.extract(a, bi, k)?;
            let bt = self.extract(b, k, bj)?;
            let prod = crate::linalg::naive::matmul_naive(&at, &bt);
            for (dst, src) in acc.data_mut().iter_mut().zip(prod.data()) {
                *dst += *src;
            }
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    #[test]
    fn extract_assemble_roundtrip() {
        for (n, g) in [(8usize, 2usize), (9, 2), (7, 3), (16, 4), (5, 8), (6, 1)] {
            let grid = TileGrid::new(n, g).unwrap();
            let m = Matrix::random(n, (n * 10 + g) as u64);
            let tiles: Vec<((usize, usize), Matrix)> = (0..grid.g())
                .flat_map(|i| (0..grid.g()).map(move |j| (i, j)))
                .map(|(i, j)| ((i, j), grid.extract(&m, i, j).unwrap()))
                .collect();
            assert_eq!(grid.assemble(&tiles).unwrap(), m, "n={n} g={g}");
        }
    }

    #[test]
    fn tile_products_assemble_to_the_full_product() {
        for (n, g) in [(12usize, 2usize), (10, 3), (9, 4)] {
            let grid = TileGrid::new(n, g).unwrap();
            let a = Matrix::random(n, 3);
            let b = Matrix::random(n, 4);
            let want = matmul_naive(&a, &b);
            let tiles: Vec<((usize, usize), Matrix)> = (0..grid.g())
                .flat_map(|i| (0..grid.g()).map(move |j| (i, j)))
                .map(|(i, j)| ((i, j), grid.tile_product(&a, &b, i, j).unwrap()))
                .collect();
            let got = grid.assemble(&tiles).unwrap();
            assert!(
                got.approx_eq(&want, 1e-4, 1e-4),
                "n={n} g={g}: diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn grid_clamps_and_rejects_degenerates() {
        assert!(TileGrid::new(0, 2).is_err());
        assert!(TileGrid::new(8, 0).is_err());
        let g = TileGrid::new(3, 9).unwrap();
        assert_eq!(g.g(), 3, "grid clamped to n");
        assert_eq!(g.t(), 1);
    }

    #[test]
    fn assemble_rejects_missing_and_duplicate_tiles() {
        let grid = TileGrid::new(8, 2).unwrap();
        let m = Matrix::random(8, 1);
        let t00 = grid.extract(&m, 0, 0).unwrap();
        assert!(grid.assemble(&[((0, 0), t00.clone())]).is_err(), "missing tiles");
        let dup: Vec<_> = (0..4).map(|_| ((0usize, 0usize), t00.clone())).collect();
        assert!(grid.assemble(&dup).is_err(), "duplicates");
    }

    #[test]
    fn operand_list_shape() {
        let grid = TileGrid::new(10, 3).unwrap();
        let a = Matrix::random(10, 5);
        let b = Matrix::random(10, 6);
        let ops = grid.mma_operands(&a, &b, 1, 2).unwrap();
        assert_eq!(ops.len(), 6);
        // first g operands walk A's block-row, last g walk B's block-column
        assert_eq!(ops[0].0, (1, 0));
        assert_eq!(ops[2].0, (1, 2));
        assert_eq!(ops[3].0, (0, 2));
        assert_eq!(ops[5].0, (2, 2));
    }
}
