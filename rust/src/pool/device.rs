//! Pool device workers: one OS thread per device, each owning its own
//! backend engine (backends may be `!Send`, so engines are built *inside*
//! the worker thread), pulling jobs from per-device queues with work
//! stealing.
//!
//! Jobs are plain data (host matrices + a reply channel), never closures,
//! so nothing `!Send` crosses a thread boundary. Tile jobs keep a small
//! device-resident cache of the tiles this device produced last step —
//! the next squaring reuses them without re-uploading, which is the
//! paper's residency discipline applied across devices.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmRequest, ExpmResponse};
use crate::error::Result;
use crate::linalg::matrix::Matrix;
use crate::plan::Plan;
use crate::pool::PoolDeviceKind;
use crate::runtime::engine::DeviceStats;
use crate::runtime::{
    AnyBackend, AnyBuffer, Backend, CpuBackend, Engine, ExecStats, KernelOp, SimBackend,
};

/// Device-resident tiles a worker keeps between steps (1 MiB per tile at
/// t=512; the cap bounds memory while covering a device's share of one
/// sharded step).
const TILE_CACHE_CAP: usize = 32;

/// Identifies one tile of one intermediate matrix: `(matrix id, bi, bj)`.
/// Matrix ids are allocated by the pool, unique per produced value.
pub(crate) type TileKey = (u64, usize, usize);

pub(crate) struct TileJob {
    /// [`KernelOp::Mma`] of the grid width (plain data, like the rest of
    /// the job — no strings cross the thread boundary).
    pub op: KernelOp,
    /// Tile side.
    pub t: usize,
    /// Operand tiles in launch order, each with its cache key.
    pub inputs: Vec<(TileKey, Matrix)>,
    /// Cache key of the produced tile.
    pub out_key: TileKey,
    /// Grid position of the produced tile.
    pub tile: (usize, usize),
    pub reply: SyncSender<TileDone>,
}

pub(crate) struct TileDone {
    pub device: usize,
    pub tile: (usize, usize),
    pub result: Result<Matrix>,
    pub stats: DeviceStats,
}

pub(crate) struct PlanJob {
    pub a: Matrix,
    pub plan: Plan,
    pub reply: SyncSender<ExecDone>,
}

pub(crate) struct PackedJob {
    pub a: Matrix,
    pub power: u64,
    pub reply: SyncSender<ExecDone>,
}

pub(crate) struct ExecDone {
    pub device: usize,
    pub result: Result<(Matrix, ExecStats)>,
}

pub(crate) struct RequestJob {
    pub req: ExpmRequest,
    pub reply: SyncSender<RequestDone>,
}

pub(crate) struct RequestDone {
    pub device: usize,
    pub id: u64,
    pub result: Result<ExpmResponse>,
}

pub(crate) struct CalibrateJob {
    /// Probe tile side.
    pub t: usize,
    /// Seconds for one warm matmul launch + result download at side `t`
    /// (simulated seconds on a timing-model device).
    pub reply: SyncSender<Result<f64>>,
}

pub(crate) enum JobPayload {
    Tile(TileJob),
    PlanExec(PlanJob),
    PackedExec(PackedJob),
    Request(RequestJob),
    Calibrate(CalibrateJob),
}

pub(crate) struct Job {
    pub payload: JobPayload,
    /// Whether an idle device may steal this job (whole requests yes;
    /// tile shards are pinned — their placement is the cost model's call).
    pub stealable: bool,
}

/// Per-device running totals (pool observability).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeviceAccum {
    /// Jobs this device completed.
    pub jobs: u64,
    /// Jobs it stole from other devices' queues.
    pub steals: u64,
    /// Kernel launches it performed.
    pub launches: u64,
    /// Seconds it was busy (simulated on timing-model devices).
    pub busy_s: f64,
    /// Host-edge bytes this device's data path copied.
    pub bytes_copied: u64,
    /// Launch outputs this device served from recycled arena buffers.
    pub buffers_recycled: u64,
}

/// The shared per-device queues + shutdown flag.
pub(crate) struct Shared {
    lanes: Mutex<Lanes>,
    cv: Condvar,
}

struct Lanes {
    queues: Vec<VecDeque<Job>>,
    shutdown: bool,
}

impl Shared {
    pub fn new(devices: usize) -> Shared {
        Shared {
            lanes: Mutex::new(Lanes {
                queues: (0..devices).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, lane: usize, job: Job) {
        let mut l = self.lanes.lock().expect("pool queues poisoned");
        l.queues[lane].push_back(job);
        drop(l);
        self.cv.notify_all();
    }

    pub fn depths(&self) -> Vec<usize> {
        let l = self.lanes.lock().expect("pool queues poisoned");
        l.queues.iter().map(VecDeque::len).collect()
    }

    pub fn shutdown(&self) {
        let mut l = self.lanes.lock().expect("pool queues poisoned");
        l.shutdown = true;
        drop(l);
        self.cv.notify_all();
    }

    /// Next job for device `lane`: its own queue first, else steal the
    /// rearmost stealable job from the longest other queue (pinned tile
    /// jobs are never stolen, but they don't shield stealable work queued
    /// ahead of them), else block. Returns `(job, stolen)`; `None` means
    /// shutdown and drained.
    fn next(&self, lane: usize) -> Option<(Job, bool)> {
        let mut l = self.lanes.lock().expect("pool queues poisoned");
        loop {
            if let Some(job) = l.queues[lane].pop_front() {
                return Some((job, false));
            }
            // (lane, queue length, index of its rearmost stealable job)
            let mut victim: Option<(usize, usize, usize)> = None;
            for (i, q) in l.queues.iter().enumerate() {
                if i == lane {
                    continue;
                }
                let Some(idx) = q.iter().rposition(|j| j.stealable) else { continue };
                if victim.is_none_or(|(_, best, _)| q.len() > best) {
                    victim = Some((i, q.len(), idx));
                }
            }
            if let Some((i, _, idx)) = victim {
                let job = l.queues[i].remove(idx).expect("rposition is in range");
                return Some((job, true));
            }
            if l.shutdown {
                return None;
            }
            l = self.cv.wait(l).expect("pool queues poisoned");
        }
    }
}

/// FIFO-bounded map of device-resident tiles this worker produced.
struct TileCache {
    cap: usize,
    order: VecDeque<TileKey>,
    map: HashMap<TileKey, AnyBuffer>,
}

impl TileCache {
    fn new(cap: usize) -> TileCache {
        TileCache { cap, order: VecDeque::new(), map: HashMap::new() }
    }

    fn get(&self, key: &TileKey) -> Option<&AnyBuffer> {
        self.map.get(key)
    }

    fn insert(&mut self, key: TileKey, buf: AnyBuffer) {
        if self.map.insert(key, buf).is_none() {
            self.order.push_back(key);
        }
        while self.order.len() > self.cap {
            let old = self.order.pop_front().expect("len checked");
            self.map.remove(&old);
        }
    }
}

/// Build the engine a pool device runs on.
fn build_device_engine(kind: PoolDeviceKind, cfg: &MatexpConfig) -> Engine<AnyBackend> {
    match kind {
        PoolDeviceKind::Cpu => Engine::new(AnyBackend::Cpu(CpuBackend::new(cfg.cpu_algo))),
        PoolDeviceKind::Sim => {
            // the paper-calibrated C2050 model, same as `--backend sim`,
            // so pool stats are comparable to single-device sim stats
            let (model, _) = crate::experiments::tables::calibrated_models();
            Engine::new(AnyBackend::Sim(SimBackend::new(model)))
        }
    }
}

/// The worker loop: build the engine in-thread, signal readiness, then
/// serve jobs until shutdown.
pub(crate) fn device_loop(
    idx: usize,
    kind: PoolDeviceKind,
    cfg: MatexpConfig,
    shared: Arc<Shared>,
    accum: Arc<Vec<Mutex<DeviceAccum>>>,
    ready: SyncSender<std::result::Result<(), String>>,
) {
    let mut engine = build_device_engine(kind, &cfg);
    let name = format!("{}#{idx}", kind.as_str());
    let _ = ready.send(Ok(()));
    // release the startup channel NOW: if a sibling worker dies before
    // sending, the pool's readiness recv must see a disconnect instead of
    // blocking on senders parked in long-lived worker loops
    drop(ready);
    let mut cache = TileCache::new(TILE_CACHE_CAP);
    // accounting happens BEFORE the reply is sent, so a caller that
    // collected every reply reads consistent pool metrics
    let update = |cost: JobCost, stolen: bool| {
        let mut acc = accum[idx].lock().expect("pool accum poisoned");
        acc.jobs += 1;
        acc.launches += cost.launches;
        acc.busy_s += cost.busy_s;
        acc.bytes_copied += cost.bytes_copied;
        acc.buffers_recycled += cost.buffers_recycled;
        if stolen {
            acc.steals += 1;
        }
    };
    while let Some((job, stolen)) = shared.next(idx) {
        match job.payload {
            JobPayload::Tile(tj) => {
                let reply = tj.reply.clone();
                let done = run_tile(&mut engine, &mut cache, idx, &name, tj);
                update(JobCost::of_device(&done.stats), stolen);
                let _ = reply.send(done);
            }
            JobPayload::PlanExec(pj) => {
                let result = engine.run_plan(&pj.a, &pj.plan);
                update(JobCost::of_exec(&result), stolen);
                let _ = pj.reply.send(ExecDone { device: idx, result });
            }
            JobPayload::PackedExec(pj) => {
                let result = engine.run_packed(&pj.a, pj.power);
                update(JobCost::of_exec(&result), stolen);
                let _ = pj.reply.send(ExecDone { device: idx, result });
            }
            JobPayload::Request(rj) => {
                let result =
                    crate::coordinator::worker::execute_request(&mut engine, &cfg, &rj.req);
                let cost = match &result {
                    Ok(resp) => JobCost::of_stats(&resp.stats),
                    Err(_) => JobCost::default(),
                };
                update(cost, stolen);
                let _ = rj.reply.send(RequestDone { device: idx, id: rj.req.id, result });
            }
            JobPayload::Calibrate(cj) => {
                let result = run_calibration(&mut engine, cj.t);
                update(JobCost { launches: 1, ..JobCost::default() }, stolen);
                let _ = cj.reply.send(result);
            }
        }
    }
}

/// What one job cost this device (for the accumulated pool metrics).
#[derive(Default)]
struct JobCost {
    launches: u64,
    busy_s: f64,
    bytes_copied: u64,
    buffers_recycled: u64,
}

impl JobCost {
    fn of_stats(stats: &ExecStats) -> JobCost {
        JobCost {
            launches: stats.launches as u64,
            busy_s: stats.wall_s,
            bytes_copied: stats.bytes_copied,
            buffers_recycled: stats.buffers_recycled,
        }
    }

    fn of_device(stats: &DeviceStats) -> JobCost {
        JobCost {
            launches: stats.launches as u64,
            busy_s: stats.wall_s,
            bytes_copied: stats.bytes_copied,
            buffers_recycled: stats.buffers_recycled,
        }
    }

    fn of_exec(result: &Result<(Matrix, ExecStats)>) -> JobCost {
        match result {
            Ok((_, stats)) => JobCost::of_stats(stats),
            Err(_) => JobCost::default(),
        }
    }
}

/// One tile job: upload operands not already resident, one fused launch,
/// download the product tile, cache its buffer for the next step.
/// Returns the completed reply; the caller sends it after accounting.
fn run_tile(
    engine: &mut Engine<AnyBackend>,
    cache: &mut TileCache,
    idx: usize,
    name: &str,
    job: TileJob,
) -> TileDone {
    let TileJob { op, t, inputs, out_key, tile, reply: _reply } = job;
    let mut stats = DeviceStats { device: name.to_string(), ..DeviceStats::default() };
    let result = (|| -> Result<Matrix> {
        // tier-2 prepared cache: warm tile sizes skip prepare entirely
        engine.prepare_cached(op, t)?;
        let be = engine.backend_mut();
        let _ = be.take_sim_time();
        let _ = be.take_residency();
        let t0 = Instant::now();
        let mut fresh: HashMap<TileKey, AnyBuffer> = HashMap::new();
        let mut bufs = Vec::with_capacity(inputs.len());
        for (key, data) in inputs {
            let buf = if let Some(b) = cache.get(&key) {
                b.clone() // device-resident from the previous step: no upload
            } else if let Some(b) = fresh.get(&key) {
                b.clone() // duplicate operand within this launch
            } else {
                let b = be.upload(data)?;
                stats.h2d_transfers += 1;
                fresh.insert(key, b.clone());
                b
            };
            bufs.push(buf);
        }
        let out = be.launch(op, t, &bufs)?;
        stats.launches += 1;
        stats.multiplies += op.multiplies();
        let m = be.download(&out, t)?;
        stats.d2h_transfers += 1;
        stats.wall_s = be.take_sim_time().unwrap_or_else(|| t0.elapsed().as_secs_f64());
        let residency = be.take_residency();
        stats.bytes_copied = residency.bytes_copied;
        stats.buffers_recycled = residency.buffers_recycled;
        stats.peak_resident_bytes = residency.peak_resident_bytes;
        cache.insert(out_key, out);
        Ok(m)
    })();
    TileDone { device: idx, tile, result, stats }
}

/// Micro-calibration probe: seconds for one warm matmul launch (+ result
/// download) at tile side `t` on this device.
fn run_calibration(engine: &mut Engine<AnyBackend>, t: usize) -> Result<f64> {
    let be = engine.backend_mut();
    be.prepare(KernelOp::Matmul, t)?;
    let a = Matrix::random(t, 0xCA11B8A7E);
    let b = Matrix::random(t, 0xCA11B8A7F);
    let ba = be.upload(a)?;
    let bb = be.upload(b)?;
    let _ = be.launch(KernelOp::Matmul, t, &[ba.clone(), bb.clone()])?; // warm
    let _ = be.take_sim_time();
    let t0 = Instant::now();
    let out = be.launch(KernelOp::Matmul, t, &[ba, bb])?;
    let _ = be.download(&out, t)?;
    let secs = be.take_sim_time().unwrap_or_else(|| t0.elapsed().as_secs_f64());
    Ok(secs.max(1e-9))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_cache_evicts_fifo() {
        let mut c = TileCache::new(2);
        let arena = crate::runtime::BufferArena::new();
        let buf = || {
            AnyBuffer::Host(crate::runtime::CpuBuffer::Mat(std::rc::Rc::new(
                arena.adopt(Matrix::zeros(2)),
            )))
        };
        c.insert((1, 0, 0), buf());
        c.insert((2, 0, 0), buf());
        assert!(c.get(&(1, 0, 0)).is_some());
        c.insert((3, 0, 0), buf());
        assert!(c.get(&(1, 0, 0)).is_none(), "oldest evicted");
        assert!(c.get(&(2, 0, 0)).is_some());
        assert!(c.get(&(3, 0, 0)).is_some());
        // re-inserting an existing key must not grow the order queue
        c.insert((3, 0, 0), buf());
        assert_eq!(c.order.len(), 2);
    }

    #[test]
    fn shared_queue_steals_from_longest_stealable() {
        let s = Shared::new(3);
        let dummy = |stealable: bool| Job {
            payload: JobPayload::Calibrate(CalibrateJob {
                t: 4,
                reply: std::sync::mpsc::sync_channel(1).0,
            }),
            stealable,
        };
        s.push(0, dummy(true));
        s.push(0, dummy(true));
        s.push(1, dummy(false));
        // device 2 owns nothing: it must steal from lane 0 (lane 1's job
        // is pinned)
        let (_, stolen) = s.next(2).expect("steals");
        assert!(stolen);
        assert_eq!(s.depths(), vec![1, 1, 0]);
        // device 1 takes its own job even though it is pinned
        let (_, stolen) = s.next(1).expect("own job");
        assert!(!stolen);
        s.shutdown();
        // drain: lane 0 still hands out its own queued job after shutdown
        let (_, stolen) = s.next(0).expect("drains after shutdown");
        assert!(!stolen);
        assert!(s.next(2).is_none(), "nothing stealable left");
    }

    #[test]
    fn steal_reaches_jobs_behind_pinned_work() {
        let s = Shared::new(2);
        let dummy = |stealable: bool| Job {
            payload: JobPayload::Calibrate(CalibrateJob {
                t: 4,
                reply: std::sync::mpsc::sync_channel(1).0,
            }),
            stealable,
        };
        s.push(0, dummy(true));
        s.push(0, dummy(false)); // pinned at the back must not shield it
        let (_, stolen) = s.next(1).expect("steals the shielded job");
        assert!(stolen);
        assert_eq!(s.depths(), vec![1, 0]);
        s.shutdown();
        assert!(s.next(1).is_none(), "only pinned work remains");
        let (_, stolen) = s.next(0).expect("owner still drains its pinned job");
        assert!(!stolen);
    }
}
