//! [`PoolEngine`] — the multi-device counterpart of
//! [`crate::runtime::Engine`]: the same [`crate::exec::Executor`]
//! submission surface, executed by a [`DevicePool`]. (The legacy
//! `expm`/`expm_packed` shims were removed in 0.4.0 — submit through the
//! surface.)
//!
//! Dispatch per call:
//! * small matrices (`n < pool.shard_min_n`) run whole on the fastest
//!   device (request-parallel territory — sharding tiny multiplies only
//!   buys launch overhead);
//! * large matrices consult the cost-model splitter: tile-shard every
//!   multiply of the plan, or fall back to the fastest single device when
//!   the split is predicted to lose.

use std::sync::Arc;

use crate::cache::ResultCachePolicy;
use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmRequest, ExpmResponse};
use crate::coordinator::scheduler::{self, PoolDispatch, Strategy};
use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::plan::{Plan, Step};
use crate::pool::cost::{ShardDecision, ShardPlan};
use crate::pool::pool::DevicePool;
use crate::runtime::ExecStats;
use crate::trace;

/// Plan executor over a heterogeneous device pool. Cheap to clone-share:
/// the pool lives behind an `Arc` and all methods take `&self` (the pool
/// serializes per-device work on its own threads), so one pool can back
/// many coordinator workers.
pub struct PoolEngine {
    pool: Arc<DevicePool>,
}

impl PoolEngine {
    /// Build a pool from the config (`cfg.pool.devices` et al.).
    pub fn from_config(cfg: &MatexpConfig) -> Result<PoolEngine> {
        Ok(PoolEngine { pool: Arc::new(DevicePool::new(cfg)?) })
    }

    /// Wrap an existing (possibly shared) pool.
    pub fn with_pool(pool: Arc<DevicePool>) -> PoolEngine {
        PoolEngine { pool }
    }

    /// The pool this engine submits to.
    pub fn pool(&self) -> &Arc<DevicePool> {
        &self.pool
    }

    /// Human-readable description of the pool's membership.
    pub fn platform(&self) -> String {
        self.pool.platform()
    }

    /// Replay `plan` across the pool (see module docs for dispatch).
    pub(crate) fn run_plan(&self, a: &Matrix, plan: &Plan) -> Result<(Matrix, ExecStats)> {
        plan.validate()?;
        let n = a.n();
        if n == 0 {
            return Err(MatexpError::Linalg("cannot exponentiate an empty matrix".into()));
        }
        let cfg = self.pool.config();
        if cfg.pool.grid.is_none() && n < cfg.pool.shard_min_n {
            let device = self.pool.fastest_device(n);
            return self.pool.run_plan_on(device, a, plan);
        }
        match self.pool.shard_decision(n) {
            ShardDecision::Single { device, .. } => self.pool.run_plan_on(device, a, plan),
            ShardDecision::Shard(sp) => self.expm_sharded(a, plan, &sp),
        }
    }

    /// Packed-state exponentiation. On the sharded path the packed pair
    /// buffer cannot span devices, so the pool replays the equivalent
    /// binary plan with sharded multiplies instead; the single-device
    /// fallback keeps the true packed discipline.
    pub(crate) fn run_packed(&self, a: &Matrix, power: u64) -> Result<(Matrix, ExecStats)> {
        if power == 0 {
            return Err(MatexpError::Plan("power must be >= 1".into()));
        }
        let n = a.n();
        let cfg = self.pool.config();
        if cfg.pool.grid.is_none() && n < cfg.pool.shard_min_n {
            let device = self.pool.fastest_device(n);
            return self.pool.run_packed_on(device, a, power);
        }
        match self.pool.shard_decision(n) {
            ShardDecision::Single { device, .. } => self.pool.run_packed_on(device, a, power),
            ShardDecision::Shard(sp) => {
                self.expm_sharded(a, &Plan::binary(power, false), &sp)
            }
        }
    }

    /// Replay `plan` with every multiply sharded across the pool per `sp`.
    /// Registers live on the host between steps; each step's wall time is
    /// the slowest device's share (a reassembly barrier), and steps add.
    /// Crate-visible so the scaling experiment can measure the sharded
    /// path explicitly, bypassing the dispatch policy.
    pub(crate) fn expm_sharded(
        &self,
        a: &Matrix,
        plan: &Plan,
        sp: &ShardPlan,
    ) -> Result<(Matrix, ExecStats)> {
        let mut stats = ExecStats::default();
        let mut regs: Vec<Option<(Matrix, u64)>> = vec![None; plan.n_regs];
        regs[0] = Some((a.clone(), self.pool.next_key()));
        for step in &plan.steps {
            match *step {
                Step::Copy { dst, src } => regs[dst] = regs[src].clone(),
                Step::Mul { dst, lhs, rhs } => {
                    let x = regs[lhs].clone().expect("validated");
                    let y = regs[rhs].clone().expect("validated");
                    regs[dst] = Some(self.sharded_mul(&x, &y, sp, &mut stats)?);
                }
                Step::SqMul { acc, base } => {
                    let x = regs[acc].clone().expect("validated");
                    let y = regs[base].clone().expect("validated");
                    // acc first, against the OLD base, exactly like the
                    // single-device engine (aliasing-safe)
                    regs[acc] = Some(self.sharded_mul(&x, &y, sp, &mut stats)?);
                    regs[base] = Some(self.sharded_mul(&y, &y, sp, &mut stats)?);
                }
                Step::SquareChain { reg, k } => {
                    for _ in 0..k {
                        let x = regs[reg].clone().expect("validated");
                        regs[reg] = Some(self.sharded_mul(&x, &x, sp, &mut stats)?);
                    }
                }
            }
        }
        let (result, _) = regs[plan.result].take().expect("validated: result written");
        Ok((result, stats))
    }

    fn sharded_mul(
        &self,
        lhs: &(Matrix, u64),
        rhs: &(Matrix, u64),
        sp: &ShardPlan,
        stats: &mut ExecStats,
    ) -> Result<(Matrix, u64)> {
        let out_key = self.pool.next_key();
        let (m, step) =
            self.pool.sharded_matmul(&lhs.0, &rhs.0, lhs.1, rhs.1, out_key, sp)?;
        stats.merge(&step);
        Ok((m, out_key))
    }

    /// Execute one admitted request (the coordinator worker's pool path):
    /// large single requests tile-shard, everything else runs whole on one
    /// device. By value — the matrix is shipped to a device thread either
    /// way, so borrowing would only force an extra deep copy. Applies the
    /// execution surface's shared contract checks (deadline preflight,
    /// late completion, tolerance) and the shared result-cache policy
    /// (tier 3): the tile-sharded disciplines consult/store here (a warm
    /// hit answers before any device is consulted); whole-request
    /// dispatch consults inside the device's `worker::execute_request`,
    /// under the same key either way.
    pub fn execute_request(&self, req: ExpmRequest) -> Result<ExpmResponse> {
        crate::exec::check_deadline(req.deadline)?;
        let (deadline, tolerance) = (req.deadline, req.tolerance);
        let cfg = self.pool.config();
        // the result-cache consult happens on exactly ONE level per
        // request: here for the tile-sharded disciplines this method runs
        // itself, and inside `worker::execute_request` on the device
        // thread for everything shipped whole — so pooled requests never
        // double-count misses or pay a redundant digest+store
        match scheduler::pool_dispatch(req.n(), 1, cfg) {
            // the tile-sharded arms execute on THIS thread, so they own
            // the request's trace scope (root `Execute` span + the plan
            // stage); whole-request dispatch ships to a device thread,
            // whose `worker::execute_request` enters the scope there.
            // Tile launches run on device threads outside the scope, so
            // they record as untraced (trace 0) launch spans.
            PoolDispatch::TileShard => {
                let scope = trace::enter(req.trace);
                let exec_start = trace::now_us();
                let plan_t0 = trace::now_us();
                let strategy = scheduler::strategy_for(&req, cfg);
                trace::add_stage(trace::Stage::Plan, trace::now_us().saturating_sub(plan_t0));
                match strategy {
                    Strategy::DeviceResident(plan) => {
                        let cache = ResultCachePolicy::for_request(cfg, &req);
                        if let Some(resp) = cache.lookup(req.id) {
                            trace::record_span(
                                trace::SpanKind::Execute,
                                req.trace,
                                exec_start,
                                req.n(),
                            );
                            return crate::exec::enforce(deadline, tolerance, resp);
                        }
                        let kind = plan.kind;
                        let (result, mut stats) = self.run_plan(&req.matrix, &plan)?;
                        let [plan_us, prepare_us, launch_us] = trace::take_stages();
                        stats.plan_us = plan_us;
                        stats.prepare_us = prepare_us;
                        stats.launch_us = launch_us;
                        let resp = crate::exec::enforce(
                            deadline,
                            tolerance,
                            ExpmResponse {
                                id: req.id,
                                result,
                                stats,
                                method: req.method,
                                plan_kind: Some(kind),
                            },
                        )?;
                        cache.store(&resp);
                        trace::record_span(
                            trace::SpanKind::Execute,
                            req.trace,
                            exec_start,
                            req.n(),
                        );
                        Ok(resp)
                    }
                    Strategy::Packed => {
                        let cache = ResultCachePolicy::for_request(cfg, &req);
                        if let Some(resp) = cache.lookup(req.id) {
                            trace::record_span(
                                trace::SpanKind::Execute,
                                req.trace,
                                exec_start,
                                req.n(),
                            );
                            return crate::exec::enforce(deadline, tolerance, resp);
                        }
                        let (result, mut stats) = self.run_packed(&req.matrix, req.power)?;
                        let [plan_us, prepare_us, launch_us] = trace::take_stages();
                        stats.plan_us = plan_us;
                        stats.prepare_us = prepare_us;
                        stats.launch_us = launch_us;
                        let resp = crate::exec::enforce(
                            deadline,
                            tolerance,
                            ExpmResponse {
                                id: req.id,
                                result,
                                stats,
                                method: req.method,
                                plan_kind: None,
                            },
                        )?;
                        cache.store(&resp);
                        trace::record_span(
                            trace::SpanKind::Execute,
                            req.trace,
                            exec_start,
                            req.n(),
                        );
                        Ok(resp)
                    }
                    // fused / naive-roundtrip / plan-roundtrip / cpu-seq
                    // disciplines are single-device by definition: run
                    // whole (the device-side worker applies the cache
                    // policy AND owns the trace scope — drop ours first
                    // so its stage billing is not nested away)
                    _ => {
                        drop(scope);
                        self.run_whole_request(req)
                            .and_then(|resp| crate::exec::enforce(deadline, tolerance, resp))
                    }
                }
            }
            PoolDispatch::RequestParallel => self
                .run_whole_request(req)
                .and_then(|resp| crate::exec::enforce(deadline, tolerance, resp)),
        }
    }

    /// A batch of admitted requests, request-parallel with work stealing.
    pub fn execute_batch(
        &self,
        reqs: Vec<ExpmRequest>,
    ) -> Vec<(u64, Result<ExpmResponse>)> {
        self.pool.execute_requests(reqs)
    }

    fn run_whole_request(&self, req: ExpmRequest) -> Result<ExpmResponse> {
        let mut replies = self.pool.execute_requests(vec![req]);
        match replies.pop() {
            Some((_, outcome)) => outcome,
            None => Err(MatexpError::Service("pool returned no reply".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self, CpuAlgo};
    use crate::pool::PoolDeviceKind;
    use crate::runtime::BackendKind;

    fn pool_cfg(devices: Vec<PoolDeviceKind>) -> MatexpConfig {
        let mut cfg = MatexpConfig::default();
        cfg.backend = BackendKind::Pool;
        cfg.pool.devices = devices;
        cfg
    }

    fn oracle(a: &Matrix, power: u64) -> Matrix {
        linalg::expm::expm(a, power, CpuAlgo::Ikj).unwrap()
    }

    #[test]
    fn small_requests_run_whole_on_one_device() {
        let cfg = pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu]);
        let engine = PoolEngine::from_config(&cfg).unwrap();
        let a = Matrix::random_spectral(12, 0.95, 3);
        let plan = Plan::binary(100, true);
        let (got, stats) = engine.run_plan(&a, &plan).unwrap();
        assert!(got.approx_eq(&oracle(&a, 100), 1e-4, 1e-4));
        // whole plan on one device: the engine invariants carry over
        assert_eq!(stats.launches, plan.launches());
        assert_eq!(stats.per_device.len(), 1);
        assert_eq!(stats.per_device[0].launches, stats.launches);
    }

    #[test]
    fn forced_grid_shards_every_plan_kind() {
        let mut cfg = pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu]);
        cfg.pool.grid = Some(2);
        let engine = PoolEngine::from_config(&cfg).unwrap();
        let a = Matrix::random_spectral(20, 0.95, 7);
        for power in [1u64, 2, 13, 100] {
            let want = oracle(&a, power);
            for plan in [
                Plan::binary(power, false),
                Plan::binary(power, true),
                Plan::chained(power, &[4, 2]),
                Plan::addition_chain(power),
            ] {
                let (got, stats) = engine.run_plan(&a, &plan).unwrap();
                assert!(
                    got.approx_eq(&want, 1e-3, 1e-3),
                    "{:?} N={power}: diff {}",
                    plan.kind,
                    got.max_abs_diff(&want)
                );
                // every logical multiply became 4 tile launches (2x2 grid)
                assert_eq!(stats.launches, 4 * plan.multiplies(), "{:?}", plan.kind);
                let launch_sum: usize = stats.per_device.iter().map(|d| d.launches).sum();
                assert_eq!(launch_sum, stats.launches, "{:?}", plan.kind);
            }
        }
    }

    #[test]
    fn sharded_packed_falls_back_to_binary_plan() {
        let mut cfg = pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu]);
        cfg.pool.grid = Some(2);
        let engine = PoolEngine::from_config(&cfg).unwrap();
        let a = Matrix::random_spectral(16, 0.9, 9);
        let (got, stats) = engine.run_packed(&a, 100).unwrap();
        assert!(got.approx_eq(&oracle(&a, 100), 1e-3, 1e-3));
        assert_eq!(stats.launches, 4 * Plan::binary(100, false).multiplies());
    }

    #[test]
    fn execute_request_covers_all_methods() {
        use crate::coordinator::request::Method;
        let cfg = pool_cfg(vec![PoolDeviceKind::Cpu, PoolDeviceKind::Cpu]);
        let engine = PoolEngine::from_config(&cfg).unwrap();
        let a = Matrix::random_spectral(8, 0.9, 5);
        let want = oracle(&a, 13);
        for method in [
            Method::Ours,
            Method::OursPacked,
            Method::OursChained,
            Method::AdditionChain,
            Method::NaiveGpu,
            Method::PlanRoundtrip,
            Method::CpuSeq,
        ] {
            let req = ExpmRequest::new(1, a.clone(), 13, method);
            let resp = engine.execute_request(req).unwrap();
            assert!(
                resp.result.approx_eq(&want, 1e-3, 1e-3),
                "{method} diverges, diff {}",
                resp.result.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn rejects_empty_matrix_and_power_zero() {
        let cfg = pool_cfg(vec![PoolDeviceKind::Cpu]);
        let engine = PoolEngine::from_config(&cfg).unwrap();
        assert!(engine.run_plan(&Matrix::zeros(0), &Plan::binary(4, false)).is_err());
        assert!(engine.run_packed(&Matrix::identity(4), 0).is_err());
    }
}
