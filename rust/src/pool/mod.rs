//! Heterogeneous device pool — sharded multi-device execution.
//!
//! The paper's title promises *heterogeneous* highly parallel execution;
//! a single [`crate::runtime::Engine`] over one backend never delivers
//! that. This layer does: a [`DevicePool`] owns N backend instances (any
//! mix of CPU and simulated-C2050 devices, each on its own worker thread
//! because backends may be `!Send`), and a [`PoolEngine`] serves the same
//! [`crate::exec::Executor`] submission surface across all of them.
//!
//! Two dispatch disciplines, chosen by the scheduler
//! ([`crate::coordinator::scheduler::pool_dispatch`]):
//!
//! * **Tile-shard** (large single requests): every multiply of the plan is
//!   partitioned on a 2D block grid ([`TileGrid`]); each device computes
//!   whole output tiles with one fused `mma{g}` launch per tile (the
//!   block-row × block-column inner product in a single dispatch), the
//!   host reassembles, and the next step redistributes. This is the
//!   static-split design of D'Alberto's APU+GPU fast matmul
//!   (arXiv:1205.2927) and the multi-GPU tiling of Clark's QCD solvers
//!   (arXiv:0912.2268).
//! * **Request-parallel** (batches of small matrices): whole requests land
//!   on per-device queues sized by the cost model; idle devices steal from
//!   the longest queue.
//!
//! The **cost-model splitter** ([`cost`]) predicts per-device throughput —
//! reusing [`crate::simulator::timing::GpuTimingModel`] for sim devices
//! and a startup micro-calibration for CPU devices — assigns shares
//! proportionally (LPT), and falls back to the fastest single device
//! whenever sharding is predicted to lose (small matrices are launch-
//! overhead-bound, so the fallback is common and correct: a split must
//! never underperform its fastest member).
//!
//! [`crate::runtime::ExecStats::per_device`] carries the per-device
//! launch/transfer/wall breakdown of every pooled execution.

pub mod cost;
pub mod device;
pub mod engine;
pub mod partition;
#[allow(clippy::module_inception)]
pub mod pool;

pub use cost::{DeviceCost, ShardDecision, ShardPlan};
pub use engine::PoolEngine;
pub use partition::TileGrid;
pub use pool::{DevicePool, DeviceUtil, PoolMetrics};

use crate::error::{MatexpError, Result};

/// What kind of device a pool slot holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PoolDeviceKind {
    /// Pure-Rust CPU device ([`crate::runtime::CpuBackend`]).
    Cpu,
    /// Calibrated Tesla C2050 timing model ([`crate::runtime::SimBackend`]).
    Sim,
}

impl PoolDeviceKind {
    /// Canonical lowercase name (CLI/config vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            PoolDeviceKind::Cpu => "cpu",
            PoolDeviceKind::Sim => "sim",
        }
    }
}

impl std::str::FromStr for PoolDeviceKind {
    type Err = MatexpError;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Ok(PoolDeviceKind::Cpu),
            "sim" => Ok(PoolDeviceKind::Sim),
            other => Err(MatexpError::Config(format!(
                "unknown pool device {other:?} (cpu|sim)"
            ))),
        }
    }
}

impl std::fmt::Display for PoolDeviceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Parse a comma-separated device list (`"sim,sim,cpu"` — CLI flag form).
pub fn parse_device_list(s: &str) -> Result<Vec<PoolDeviceKind>> {
    use std::str::FromStr;
    let devices = s
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(PoolDeviceKind::from_str)
        .collect::<Result<Vec<_>>>()?;
    if devices.is_empty() {
        return Err(MatexpError::Config("empty pool device list".into()));
    }
    Ok(devices)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn device_kind_roundtrip() {
        for k in [PoolDeviceKind::Cpu, PoolDeviceKind::Sim] {
            assert_eq!(PoolDeviceKind::from_str(k.as_str()).unwrap(), k);
        }
        assert!(PoolDeviceKind::from_str("tpu").is_err());
        assert_eq!(PoolDeviceKind::from_str("SIM").unwrap(), PoolDeviceKind::Sim);
    }

    #[test]
    fn device_list_parses() {
        assert_eq!(
            parse_device_list("sim, sim,cpu").unwrap(),
            vec![PoolDeviceKind::Sim, PoolDeviceKind::Sim, PoolDeviceKind::Cpu]
        );
        assert!(parse_device_list("").is_err());
        assert!(parse_device_list("sim,gpu").is_err());
    }
}
