//! [`DevicePool`] — N heterogeneous backends behind per-device queues.
//!
//! The pool spawns one worker thread per configured device
//! ([`super::device`]), calibrates a cost model for each
//! ([`super::cost`]), and offers two entry points: sharded multiplies
//! (tile jobs fanned across devices, product reassembled on the host) and
//! whole-request execution (per-device queues with work stealing).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmRequest, ExpmResponse};
use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::plan::Plan;
use crate::pool::cost::{self, DeviceCost, ShardDecision, ShardPlan};
use crate::pool::device::{
    CalibrateJob, DeviceAccum, ExecDone, Job, JobPayload, PackedJob, PlanJob, RequestJob,
    Shared, TileDone, TileJob, TileKey,
};
use crate::pool::partition::TileGrid;
use crate::pool::PoolDeviceKind;
use crate::runtime::engine::{DeviceStats, ExecStats};
use crate::runtime::KernelOp;

/// Tile side of the CPU micro-calibration probe (small enough to be
/// instant even in debug builds, big enough to measure the cubic term).
const CALIBRATION_TILE: usize = 48;

/// How long to wait on a device reply before declaring it dead.
const REPLY_TIMEOUT: Duration = Duration::from_secs(300);

/// Per-device utilization snapshot (pool observability).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceUtil {
    /// Device name (`sim#1`, `cpu#0`).
    pub name: String,
    /// What kind of device it is.
    pub kind: PoolDeviceKind,
    /// Jobs this device completed.
    pub jobs: u64,
    /// Jobs it stole from other devices' queues.
    pub steals: u64,
    /// Kernel launches it performed.
    pub launches: u64,
    /// Seconds it was busy (simulated on timing-model devices).
    pub busy_s: f64,
    /// Host-edge bytes its data path copied.
    pub bytes_copied: u64,
    /// Launch outputs it served from recycled arena buffers.
    pub buffers_recycled: u64,
    /// Jobs currently waiting in its queue.
    pub queue_depth: usize,
}

/// Point-in-time pool metrics.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolMetrics {
    /// One utilization snapshot per pool device.
    pub devices: Vec<DeviceUtil>,
}

/// A pool of heterogeneous devices, each on its own worker thread.
pub struct DevicePool {
    shared: Arc<Shared>,
    names: Vec<String>,
    kinds: Vec<PoolDeviceKind>,
    costs: Vec<DeviceCost>,
    accum: Arc<Vec<Mutex<DeviceAccum>>>,
    cfg: MatexpConfig,
    next_key: AtomicU64,
    workers: Vec<JoinHandle<()>>,
}

impl DevicePool {
    /// Spawn the configured devices (`cfg.pool.devices`), wait until every
    /// worker built its backend, and micro-calibrate the CPU members.
    pub fn new(cfg: &MatexpConfig) -> Result<DevicePool> {
        let kinds = cfg.pool.devices.clone();
        if kinds.is_empty() {
            return Err(MatexpError::Config(
                "pool.devices must name at least one device".into(),
            ));
        }
        let shared = Arc::new(Shared::new(kinds.len()));
        let accum: Arc<Vec<Mutex<DeviceAccum>>> =
            Arc::new((0..kinds.len()).map(|_| Mutex::new(DeviceAccum::default())).collect());
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(), String>>(kinds.len());
        let mut workers = Vec::with_capacity(kinds.len());
        // collect spawn errors instead of `?`-ing out: the pool struct must
        // be constructed before any early return so its Drop can shut down
        // and join whatever already spawned (no thread leak)
        let mut failure: Option<String> = None;
        for (idx, kind) in kinds.iter().enumerate() {
            let kind = *kind;
            let cfg_w = cfg.clone();
            let shared_w = Arc::clone(&shared);
            let accum_w = Arc::clone(&accum);
            let ready_w = ready_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("matexp-pool-{}{idx}", kind.as_str()))
                .spawn(move || {
                    crate::pool::device::device_loop(idx, kind, cfg_w, shared_w, accum_w, ready_w)
                });
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    failure = Some(format!("could not spawn device thread: {e}"));
                    break;
                }
            }
        }
        drop(ready_tx);
        for _ in 0..workers.len() {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => failure = Some(msg),
                Err(_) => failure = Some("pool device died during startup".into()),
            }
        }
        let names: Vec<String> =
            kinds.iter().enumerate().map(|(i, k)| format!("{}#{i}", k.as_str())).collect();
        let mut pool = DevicePool {
            shared,
            names,
            kinds: kinds.clone(),
            costs: Vec::new(),
            accum,
            cfg: cfg.clone(),
            next_key: AtomicU64::new(1),
            workers,
        };
        if let Some(msg) = failure {
            // pool drops below: shutdown + join, no thread leak
            return Err(MatexpError::Service(format!("pool device failed to start: {msg}")));
        }
        pool.costs = pool.calibrate(&kinds)?;
        Ok(pool)
    }

    /// One cost model per device: the analytic C2050 model for sim
    /// devices, a measured probe for CPU devices. When the runtime
    /// autotuner has already recorded a per-size throughput curve
    /// ([`crate::linalg::autotune::cpu_curve`]), CPU devices use it
    /// instead of the single-point extrapolation — the calibration probe
    /// still runs (it doubles as the device-thread warmup and keeps the
    /// job accounting identical either way).
    fn calibrate(&self, kinds: &[PoolDeviceKind]) -> Result<Vec<DeviceCost>> {
        let mut costs = Vec::with_capacity(kinds.len());
        for (idx, kind) in kinds.iter().enumerate() {
            match kind {
                PoolDeviceKind::Sim => {
                    let (model, _) = crate::experiments::tables::calibrated_models();
                    costs.push(DeviceCost::Model(model));
                }
                PoolDeviceKind::Cpu => {
                    let (tx, rx) = sync_channel(1);
                    self.shared.push(
                        idx,
                        Job {
                            payload: JobPayload::Calibrate(CalibrateJob {
                                t: CALIBRATION_TILE,
                                reply: tx,
                            }),
                            stealable: false,
                        },
                    );
                    let secs = rx
                        .recv_timeout(REPLY_TIMEOUT)
                        .map_err(|_| {
                            MatexpError::Service(format!(
                                "pool device {} never answered calibration",
                                self.names[idx]
                            ))
                        })??;
                    let curve = crate::linalg::autotune::cpu_curve();
                    if curve.len() >= 2 {
                        costs.push(DeviceCost::Curve { samples: curve });
                    } else {
                        let flops = 2.0 * (CALIBRATION_TILE as f64).powi(3);
                        costs.push(DeviceCost::Measured {
                            fixed_s: 0.0,
                            per_flop_s: secs / flops,
                        });
                    }
                }
            }
        }
        Ok(costs)
    }

    /// Number of devices in the pool.
    pub fn device_count(&self) -> usize {
        self.names.len()
    }

    /// Device names, in configuration order (`cpu#0`, `sim#1`, …).
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Device kinds, in configuration order.
    pub fn kinds(&self) -> &[PoolDeviceKind] {
        &self.kinds
    }

    /// Per-device cost models (the splitter's inputs).
    pub fn costs(&self) -> &[DeviceCost] {
        &self.costs
    }

    /// The configuration the pool was built from.
    pub fn config(&self) -> &MatexpConfig {
        &self.cfg
    }

    /// Human-readable description of the pool's membership.
    pub fn platform(&self) -> String {
        let list: Vec<&str> = self.kinds.iter().map(|k| k.as_str()).collect();
        format!("device pool [{}] (cost-model splitter + work stealing)", list.join(", "))
    }

    /// Fresh matrix id for tile-cache keying.
    pub(crate) fn next_key(&self) -> u64 {
        self.next_key.fetch_add(1, Ordering::Relaxed)
    }

    /// Splitter decision for multiplies at size `n` (honors the forced
    /// grid in `cfg.pool.grid`).
    pub fn shard_decision(&self, n: usize) -> ShardDecision {
        cost::plan_shard(&self.costs, n, self.cfg.pool.max_grid, self.cfg.pool.grid)
    }

    /// Device with the cheapest predicted resident multiply at size `n`.
    pub fn fastest_device(&self, n: usize) -> usize {
        cost::fastest_device(&self.costs, n)
    }

    /// One multiply `A·B`, sharded across the pool per `plan`: each output
    /// tile is one pinned `mma{g}` job on its assigned device; the host
    /// reassembles. `a_key`/`b_key`/`out_key` identify the matrices for
    /// device-resident tile caching (allocate with [`Self::next_key`]).
    ///
    /// Wall time is the critical path: max over devices of their summed
    /// tile-job time for this step.
    pub fn sharded_matmul(
        &self,
        a: &Matrix,
        b: &Matrix,
        a_key: u64,
        b_key: u64,
        out_key: u64,
        plan: &ShardPlan,
    ) -> Result<(Matrix, ExecStats)> {
        let n = a.n();
        if b.n() != n {
            return Err(MatexpError::Linalg("sharded_matmul size mismatch".into()));
        }
        let grid = TileGrid::new(n, plan.grid)?;
        let g = grid.g();
        if plan.assignment.len() != grid.tiles() {
            return Err(MatexpError::Plan(format!(
                "shard plan has {} assignments for a {}-tile grid",
                plan.assignment.len(),
                grid.tiles()
            )));
        }
        if let Some(&bad) = plan.assignment.iter().find(|&&d| d >= self.device_count()) {
            return Err(MatexpError::Plan(format!(
                "shard plan names device {bad}, pool has {}",
                self.device_count()
            )));
        }
        let op = KernelOp::Mma(g as u32);
        let (tx, rx) = sync_channel::<TileDone>(grid.tiles());
        for bi in 0..g {
            for bj in 0..g {
                let device = plan.assignment[bi * g + bj];
                let operands = grid.mma_operands(a, b, bi, bj)?;
                let inputs: Vec<(TileKey, Matrix)> = operands
                    .into_iter()
                    .enumerate()
                    .map(|(pos, ((ti, tj), m))| {
                        let src = if pos < g { a_key } else { b_key };
                        ((src, ti, tj), m)
                    })
                    .collect();
                self.shared.push(
                    device,
                    Job {
                        payload: JobPayload::Tile(TileJob {
                            op,
                            t: grid.t(),
                            inputs,
                            out_key: (out_key, bi, bj),
                            tile: (bi, bj),
                            reply: tx.clone(),
                        }),
                        stealable: false,
                    },
                );
            }
        }
        drop(tx);
        let mut tiles: Vec<((usize, usize), Matrix)> = Vec::with_capacity(grid.tiles());
        let mut stats = ExecStats::default();
        let mut device_wall = vec![0.0f64; self.device_count()];
        let mut first_err: Option<MatexpError> = None;
        for _ in 0..grid.tiles() {
            let done = rx.recv_timeout(REPLY_TIMEOUT).map_err(|_| {
                MatexpError::Service("pool device dropped a tile job".into())
            })?;
            stats.launches += done.stats.launches;
            stats.multiplies += done.stats.multiplies;
            stats.h2d_transfers += done.stats.h2d_transfers;
            stats.d2h_transfers += done.stats.d2h_transfers;
            stats.bytes_copied += done.stats.bytes_copied;
            stats.buffers_recycled += done.stats.buffers_recycled;
            device_wall[done.device] += done.stats.wall_s;
            stats.merge_device(&done.stats);
            match done.result {
                Ok(m) => tiles.push((done.tile, m)),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        stats.wall_s = device_wall.iter().cloned().fold(0.0, f64::max);
        // devices hold their tile buffers concurrently, so the pool's
        // resident high-water mark is the SUM of per-device peaks (each
        // already the max over that device's jobs), not the busiest
        // device's peak alone
        stats.peak_resident_bytes =
            stats.per_device.iter().map(|d| d.peak_resident_bytes).sum();
        Ok((grid.assemble(&tiles)?, stats))
    }

    /// Run whole requests across the pool: per-device queues sized by the
    /// cost model (LPT), stealable by idle devices. Returns
    /// `(request id, outcome)` in completion order; every response's
    /// `stats.per_device` names the device that served it.
    pub fn execute_requests(
        &self,
        reqs: Vec<ExpmRequest>,
    ) -> Vec<(u64, Result<ExpmResponse>)> {
        if reqs.is_empty() {
            return Vec::new();
        }
        let jobs: Vec<(usize, usize)> = reqs
            .iter()
            .map(|r| (r.n(), Plan::binary(r.power.max(1), false).multiplies().max(1)))
            .collect();
        let assignment = cost::assign_requests(&self.costs, &jobs);
        let count = reqs.len();
        // outstanding ids, so a dead device's requests error under their
        // OWN ids (the coordinator's reply map is keyed by id — a made-up
        // id would leave the real caller waiting forever)
        let mut pending: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let (tx, rx) = sync_channel(count);
        for (req, &device) in reqs.into_iter().zip(&assignment) {
            self.shared.push(
                device,
                Job {
                    payload: JobPayload::Request(RequestJob { req, reply: tx.clone() }),
                    stealable: true,
                },
            );
        }
        drop(tx);
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            match rx.recv_timeout(REPLY_TIMEOUT) {
                Ok(done) => {
                    pending.retain(|&id| id != done.id);
                    let device = done.device;
                    let result = done.result.map(|mut resp| {
                        resp.stats =
                            self.tag_single(device, std::mem::take(&mut resp.stats));
                        resp
                    });
                    out.push((done.id, result));
                }
                Err(_) => break, // device gone: fail whatever is left, by id
            }
        }
        for id in pending {
            out.push((
                id,
                Err(MatexpError::Service("pool device dropped a request".into())),
            ));
        }
        out
    }

    /// Replay a whole plan device-resident on one device.
    pub(crate) fn run_plan_on(
        &self,
        device: usize,
        a: &Matrix,
        plan: &Plan,
    ) -> Result<(Matrix, ExecStats)> {
        let (tx, rx) = sync_channel(1);
        self.shared.push(
            device,
            Job {
                payload: JobPayload::PlanExec(PlanJob {
                    a: a.clone(),
                    plan: plan.clone(),
                    reply: tx,
                }),
                stealable: false,
            },
        );
        let done: ExecDone = rx.recv_timeout(REPLY_TIMEOUT).map_err(|_| {
            MatexpError::Service("pool device dropped a plan execution".into())
        })?;
        done.result.map(|(m, stats)| (m, self.tag_single(device, stats)))
    }

    /// Packed-state exponentiation on one device.
    pub(crate) fn run_packed_on(
        &self,
        device: usize,
        a: &Matrix,
        power: u64,
    ) -> Result<(Matrix, ExecStats)> {
        let (tx, rx) = sync_channel(1);
        self.shared.push(
            device,
            Job {
                payload: JobPayload::PackedExec(PackedJob { a: a.clone(), power, reply: tx }),
                stealable: false,
            },
        );
        let done: ExecDone = rx.recv_timeout(REPLY_TIMEOUT).map_err(|_| {
            MatexpError::Service("pool device dropped a packed execution".into())
        })?;
        done.result.map(|(m, stats)| (m, self.tag_single(device, stats)))
    }

    /// Attach the single-device breakdown to a whole-run's stats.
    fn tag_single(&self, device: usize, mut stats: ExecStats) -> ExecStats {
        stats.per_device = vec![DeviceStats {
            device: self.names[device].clone(),
            launches: stats.launches,
            multiplies: stats.multiplies,
            h2d_transfers: stats.h2d_transfers,
            d2h_transfers: stats.d2h_transfers,
            bytes_copied: stats.bytes_copied,
            buffers_recycled: stats.buffers_recycled,
            peak_resident_bytes: stats.peak_resident_bytes,
            wall_s: stats.wall_s,
        }];
        stats
    }

    /// Live utilization: per-device job/steal/launch/busy totals plus
    /// current queue depths.
    pub fn metrics(&self) -> PoolMetrics {
        let depths = self.shared.depths();
        let devices = self
            .names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let acc = self.accum[i].lock().expect("pool accum poisoned").clone();
                DeviceUtil {
                    name: name.clone(),
                    kind: self.kinds[i],
                    jobs: acc.jobs,
                    steals: acc.steals,
                    launches: acc.launches,
                    busy_s: acc.busy_s,
                    bytes_copied: acc.bytes_copied,
                    buffers_recycled: acc.buffers_recycled,
                    queue_depth: depths.get(i).copied().unwrap_or(0),
                }
            })
            .collect();
        PoolMetrics { devices }
    }
}

impl Drop for DevicePool {
    fn drop(&mut self) {
        self.shared.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Method;
    use crate::linalg::naive::matmul_naive;

    fn cpu_pool(devices: usize) -> DevicePool {
        let mut cfg = MatexpConfig::default();
        cfg.backend = crate::runtime::BackendKind::Pool;
        cfg.pool.devices = vec![PoolDeviceKind::Cpu; devices];
        DevicePool::new(&cfg).unwrap()
    }

    #[test]
    fn sharded_matmul_matches_oracle_and_counts_devices() {
        let pool = cpu_pool(2);
        let a = Matrix::random(24, 11);
        let b = Matrix::random(24, 12);
        let plan = ShardPlan {
            grid: 2,
            assignment: vec![0, 1, 0, 1],
            predicted_step_s: 0.0,
        };
        let (got, stats) = pool
            .sharded_matmul(&a, &b, pool.next_key(), pool.next_key(), pool.next_key(), &plan)
            .unwrap();
        let want = matmul_naive(&a, &b);
        assert!(got.approx_eq(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
        // 4 tiles, one mma2 launch each, split across both devices
        assert_eq!(stats.launches, 4);
        assert_eq!(stats.multiplies, 8);
        assert_eq!(stats.per_device.len(), 2);
        let launch_sum: usize = stats.per_device.iter().map(|d| d.launches).sum();
        assert_eq!(launch_sum, stats.launches);
        assert!(stats.wall_s >= 0.0);
    }

    #[test]
    fn bad_shard_plans_are_rejected() {
        let pool = cpu_pool(1);
        let a = Matrix::random(8, 1);
        let plan = ShardPlan { grid: 2, assignment: vec![0, 0, 0, 5], predicted_step_s: 0.0 };
        assert!(pool.sharded_matmul(&a, &a, 1, 1, 2, &plan).is_err(), "unknown device");
        let plan = ShardPlan { grid: 2, assignment: vec![0], predicted_step_s: 0.0 };
        assert!(pool.sharded_matmul(&a, &a, 1, 1, 2, &plan).is_err(), "short assignment");
    }

    #[test]
    fn request_batch_runs_and_tags_devices() {
        let pool = cpu_pool(2);
        let reqs: Vec<ExpmRequest> = (0..6)
            .map(|i| {
                ExpmRequest::new(i + 1, Matrix::random_spectral(16, 0.9, i + 1), 13, Method::Ours)
            })
            .collect();
        let oracle: Vec<Matrix> = reqs
            .iter()
            .map(|r| crate::linalg::expm::expm(&r.matrix, 13, crate::linalg::CpuAlgo::Naive).unwrap())
            .collect();
        let mut replies = pool.execute_requests(reqs);
        assert_eq!(replies.len(), 6);
        replies.sort_by_key(|(id, _)| *id);
        for (i, (id, outcome)) in replies.iter().enumerate() {
            assert_eq!(*id, i as u64 + 1);
            let resp = outcome.as_ref().expect("request served");
            assert!(resp.result.approx_eq(&oracle[i], 1e-3, 1e-3));
            assert_eq!(resp.stats.per_device.len(), 1);
            assert_eq!(resp.stats.per_device[0].launches, resp.stats.launches);
        }
        let metrics = pool.metrics();
        let jobs: u64 = metrics.devices.iter().map(|d| d.jobs).sum();
        // 6 requests + 2 calibration probes
        assert_eq!(jobs, 8);
    }

    #[test]
    fn idle_device_steals_queued_requests() {
        let pool = cpu_pool(2);
        // bypass the splitter: pile every request onto device 0 so device
        // 1 can only get work by stealing
        let (tx, rx) = sync_channel(8);
        for i in 0..8u64 {
            pool.shared.push(
                0,
                Job {
                    payload: JobPayload::Request(RequestJob {
                        req: ExpmRequest::new(
                            i,
                            Matrix::random_spectral(48, 0.9, i + 1),
                            64,
                            Method::Ours,
                        ),
                        reply: tx.clone(),
                    }),
                    stealable: true,
                },
            );
        }
        drop(tx);
        let mut served = 0;
        while let Ok(done) = rx.recv_timeout(REPLY_TIMEOUT) {
            assert!(done.result.is_ok());
            served += 1;
        }
        assert_eq!(served, 8);
        let metrics = pool.metrics();
        let steals: u64 = metrics.devices.iter().map(|d| d.steals).sum();
        assert!(steals > 0, "device 1 never stole: {metrics:?}");
        assert!(metrics.devices[1].jobs > 1, "{metrics:?}");
    }

    #[test]
    fn empty_pool_rejected() {
        let mut cfg = MatexpConfig::default();
        cfg.pool.devices.clear();
        assert!(DevicePool::new(&cfg).is_err());
    }
}
