//! Tier 2 — the [`PreparedSet`]: `Backend::prepare` runs once per
//! `(KernelOp, n)` per backend instance.
//!
//! Engines call `prepare` before every timed region so compilation never
//! pollutes a measurement — which means a warm engine re-prepares the
//! same ops on every request. The set records which `(op, n)` pairs this
//! backend has already prepared successfully and skips the call on warm
//! launches. It lives **inside** [`crate::runtime::Engine`] (one per
//! backend instance — prepared state is per-backend, not per-process),
//! which is exactly what makes the policy shared: the bare engine, every
//! pool device worker and every coordinator worker drive the same
//! `Engine` prepare path.
//!
//! Only *successful* prepares are recorded: a failed or
//! [`crate::error::MatexpError::UnsupportedOp`] prepare is retried on the
//! next request, preserving warmup's optional-op policy.
//!
//! Per-instance counters feed the process-wide totals reported by
//! [`super::stats::snapshot`].

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::runtime::op::KernelOp;

static GLOBAL_HITS: AtomicU64 = AtomicU64::new(0);
static GLOBAL_MISSES: AtomicU64 = AtomicU64::new(0);

/// Which `(op, n)` pairs one backend instance has successfully prepared.
#[derive(Debug, Default)]
pub struct PreparedSet {
    set: HashSet<(KernelOp, usize)>,
    hits: u64,
    misses: u64,
}

impl PreparedSet {
    /// An empty set (nothing prepared yet).
    pub fn new() -> PreparedSet {
        PreparedSet::default()
    }

    /// `true` — and one warm hit counted — when `(op, n)` was already
    /// prepared on this backend, so the caller may skip `prepare`.
    pub fn check(&mut self, op: KernelOp, n: usize) -> bool {
        if self.set.contains(&(op, n)) {
            self.hits += 1;
            GLOBAL_HITS.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Record one *successful* prepare of `(op, n)` (a cold miss).
    pub fn record(&mut self, op: KernelOp, n: usize) {
        if self.set.insert((op, n)) {
            self.misses += 1;
            GLOBAL_MISSES.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Distinct `(op, n)` pairs prepared on this backend.
    pub fn len(&self) -> usize {
        self.set.len()
    }

    /// `true` when nothing has been prepared yet.
    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }

    /// Warm skips on this backend instance.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cold prepares on this backend instance.
    pub fn misses(&self) -> u64 {
        self.misses
    }
}

/// Process-wide `(hits, misses)` across every engine's prepared set.
pub(crate) fn global_counters() -> (u64, u64) {
    (GLOBAL_HITS.load(Ordering::Relaxed), GLOBAL_MISSES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_then_warm() {
        let mut set = PreparedSet::new();
        assert!(!set.check(KernelOp::Matmul, 64), "first sighting is cold");
        set.record(KernelOp::Matmul, 64);
        assert!(set.check(KernelOp::Matmul, 64), "second sighting is warm");
        assert_eq!((set.hits(), set.misses()), (1, 1));
        assert_eq!(set.len(), 1);
    }

    #[test]
    fn op_and_size_both_key() {
        let mut set = PreparedSet::new();
        set.record(KernelOp::Matmul, 64);
        assert!(!set.check(KernelOp::Matmul, 128), "same op, other size: cold");
        assert!(!set.check(KernelOp::Square, 64), "other op, same size: cold");
        set.record(KernelOp::SquareChain(4), 64);
        assert!(!set.check(KernelOp::SquareChain(2), 64), "chain length is part of the op");
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn duplicate_record_counts_once() {
        let mut set = PreparedSet::new();
        set.record(KernelOp::Pack2, 8);
        set.record(KernelOp::Pack2, 8);
        assert_eq!(set.misses(), 1);
        assert_eq!(set.len(), 1);
    }
}
