//! Tier 3 — the content-addressed [`ResultCache`]: repeated hot requests
//! are answered without touching a device.
//!
//! A request's answer depends on exactly the matrix *bytes*, the power,
//! the method and (for plan selection) the tolerance — so the key is a
//! 128-bit digest of the operand plus those fields, with the tolerance
//! coarsened to an order-of-magnitude **bucket** (`⌊log10 tol⌋`): entries
//! never serve across differing buckets, because a tighter tolerance may
//! pin a different (more conservative) plan whose reassociation produces
//! different bits.
//!
//! Entries are whole result matrices, so the cache evicts **LRU against a
//! byte budget** (`--cache-budget-mb`), not an entry count: one n=1024
//! answer weighs 4 MiB, a thousand n=32 answers weigh the same. Each
//! entry is charged its payload **plus** [`ResultCache::ENTRY_OVERHEAD`]
//! for the key and bookkeeping it pins, so thousands of tiny results
//! cannot overshoot the budget through uncounted metadata. When the
//! persistence tier is active ([`crate::store`]), the budget **spills**
//! demoted entries to disk instead of deleting the work.
//!
//! The tier is opt-in ([`crate::config::CacheSettings::results`]): a hit
//! reports zero launches/transfers, which is the point for serving and a
//! trap for experiments. Submissions pinning an explicit plan are never
//! cached or served (see [`ResultCachePolicy::for_request`]).
//!
//! ```
//! use matexp::cache::{ResultCache, ResultKey};
//! use matexp::coordinator::request::Method;
//! use matexp::linalg::matrix::Matrix;
//!
//! // budget-eviction semantics, on a private instance: a 16x16 result
//! // weighs 16*16*4 = 1 KiB of payload plus the fixed per-entry
//! // overhead charge, so a 1.5 KiB budget holds one entry but not two
//! let cache = ResultCache::new(1536);
//! let a = Matrix::random(16, 1);
//! let b = Matrix::random(16, 2);
//! let key_a = ResultKey::for_parts(&a, 64, Method::Ours, None);
//! let key_b = ResultKey::for_parts(&b, 64, Method::Ours, None);
//! cache.insert(key_a, &a, Method::Ours, None);
//! cache.insert(key_b, &b, Method::Ours, None);
//! // the budget holds one entry: inserting b evicted a (LRU)
//! assert_eq!(cache.len(), 1);
//! assert_eq!(cache.evictions(), 1);
//! assert!(cache.get(&key_a).is_none());
//! assert_eq!(cache.get(&key_b).unwrap().result, b);
//! assert!(cache.bytes() <= 1536);
//! ```

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::cache::CacheControl;
use crate::config::MatexpConfig;
use crate::coordinator::request::{ExecStats, ExpmRequest, ExpmResponse, Method};
use crate::linalg::matrix::Matrix;
use crate::plan::PlanKind;
use crate::trace;

/// Bucket for "no tolerance requested" — distinct from every real bucket
/// (an untoleranced request may take the aggressive chained plan).
const NO_TOLERANCE_BUCKET: i64 = i64::MAX;

/// 128-bit content digest of a matrix payload: two independent FNV-1a
/// streams over the f32 bit patterns, folded two lanes per step so the
/// hot path stays cheap even in debug builds.
pub(crate) fn digest_f32(data: &[f32]) -> (u64, u64) {
    const OFF1: u64 = 0xcbf2_9ce4_8422_2325;
    const OFF2: u64 = 0x6c62_272e_07bb_0142;
    const PRIME1: u64 = 0x0000_0100_0000_01b3;
    const PRIME2: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut h1 = OFF1 ^ (data.len() as u64);
    let mut h2 = OFF2 ^ (data.len() as u64).rotate_left(32);
    let mut chunks = data.chunks_exact(2);
    for pair in &mut chunks {
        let w = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
        h1 = (h1 ^ w).wrapping_mul(PRIME1);
        h2 = (h2 ^ w.rotate_left(32)).wrapping_mul(PRIME2);
    }
    if let [last] = chunks.remainder() {
        let w = last.to_bits() as u64;
        h1 = (h1 ^ w).wrapping_mul(PRIME1);
        h2 = (h2 ^ w.rotate_left(32)).wrapping_mul(PRIME2);
    }
    (h1, h2)
}

/// Order-of-magnitude tolerance bucket: `⌊log10 tol⌋` (computed in f64 so
/// the boundary is deterministic), or [`NO_TOLERANCE_BUCKET`] for `None`.
/// Non-positive/non-finite tolerances never reach here — admission
/// rejects them.
pub(crate) fn tolerance_bucket(tol: Option<f32>) -> i64 {
    match tol {
        Some(t) if t > 0.0 && t.is_finite() => (t as f64).log10().floor() as i64,
        _ => NO_TOLERANCE_BUCKET,
    }
}

/// Digest of the configuration knobs that change the *bits* an execution
/// produces (backend/pool layout picks the substrate, `cpu_algo` the
/// summation order, the plan toggles the reassociation). Keyed into
/// [`ResultKey`] so differently-configured executors sharing the
/// process-wide cache never cross-serve.
fn config_fingerprint(cfg: &MatexpConfig) -> u64 {
    fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
    let mut h = fnv(0xcbf2_9ce4_8422_2325, cfg.backend.as_str().as_bytes());
    h = fnv(h, cfg.cpu_algo.name().as_bytes());
    h = fnv(h, &[cfg.use_square_chains as u8, cfg.fused_sqmul as u8]);
    h = fnv(h, &cfg.pool.shard_min_n.to_le_bytes());
    h = fnv(h, &cfg.pool.grid.map(|g| g + 1).unwrap_or(0).to_le_bytes());
    h = fnv(h, &cfg.pool.max_grid.to_le_bytes());
    for d in &cfg.pool.devices {
        h = fnv(h, d.as_str().as_bytes());
    }
    h
}

/// Content-addressed identity of one servable answer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ResultKey {
    digest: (u64, u64),
    n: usize,
    power: u64,
    method: Method,
    tol_bucket: i64,
    /// The scheduler's conservative-plan predicate — tolerances on either
    /// side of [`crate::coordinator::scheduler::CONSERVATIVE_TOL`] select
    /// different plans, so they must never share an entry even when they
    /// fall in the same decade bucket.
    conservative: bool,
    /// [`config_fingerprint`] of the serving config (0 for standalone
    /// [`ResultKey::for_parts`] keys on private cache instances).
    cfg_digest: u64,
}

impl ResultKey {
    /// Key for `matrix^power` under `method` at `tolerance` (bucketed),
    /// outside any serving configuration — for private [`ResultCache`]
    /// instances (tests, demos, ablations) where one fixed executor owns
    /// the cache.
    pub fn for_parts(
        matrix: &Matrix,
        power: u64,
        method: Method,
        tolerance: Option<f32>,
    ) -> ResultKey {
        ResultKey {
            digest: digest_f32(matrix.data()),
            n: matrix.n(),
            power,
            method,
            tol_bucket: tolerance_bucket(tolerance),
            conservative: crate::coordinator::scheduler::is_conservative(tolerance),
            cfg_digest: 0,
        }
    }

    /// Key for an admitted request under `cfg` — what the shared
    /// process-wide cache uses. Includes the config fingerprint, so two
    /// executors with different substrates/plan policies never serve each
    /// other's bits.
    pub fn for_request(cfg: &MatexpConfig, req: &ExpmRequest) -> ResultKey {
        let mut key = ResultKey::for_parts(&req.matrix, req.power, req.method, req.tolerance);
        key.cfg_digest = config_fingerprint(cfg);
        key
    }

    /// Matrix dimension this key was computed for (sizes the payload a
    /// store entry may carry).
    pub(crate) fn n(&self) -> usize {
        self.n
    }

    /// 128-bit store address: the content digest with every remaining
    /// identity component (n, power, method, tolerance bucket,
    /// conservative boundary, config fingerprint) folded in with the same
    /// dual-FNV primes, so distinct keys address distinct store entries.
    pub(crate) fn store_digest(&self) -> (u64, u64) {
        const PRIME1: u64 = 0x0000_0100_0000_01b3;
        const PRIME2: u64 = 0x9e37_79b9_7f4a_7c15;
        let (mut h1, mut h2) = self.digest;
        let words = [
            self.n as u64,
            self.power,
            self.method as u64,
            self.tol_bucket as u64,
            self.conservative as u64,
            self.cfg_digest,
        ];
        for w in words {
            h1 = (h1 ^ w).wrapping_mul(PRIME1);
            h2 = (h2 ^ w.rotate_left(32)).wrapping_mul(PRIME2);
        }
        (h1, h2)
    }

    /// Serialize every key field for embedding in a store payload —
    /// [`ResultKey::from_bytes`] is the exact inverse, and the store
    /// verifies the decoded key against the requested one so an
    /// addressing collision can never serve foreign bits.
    pub(crate) fn to_bytes(&self) -> [u8; KEY_BYTES] {
        let mut out = [0u8; KEY_BYTES];
        out[0..8].copy_from_slice(&self.digest.0.to_le_bytes());
        out[8..16].copy_from_slice(&self.digest.1.to_le_bytes());
        out[16..24].copy_from_slice(&(self.n as u64).to_le_bytes());
        out[24..32].copy_from_slice(&self.power.to_le_bytes());
        out[32] = self.method as u8;
        out[33..41].copy_from_slice(&self.tol_bucket.to_le_bytes());
        out[41] = self.conservative as u8;
        out[42..50].copy_from_slice(&self.cfg_digest.to_le_bytes());
        out
    }

    /// Inverse of [`ResultKey::to_bytes`]; `None` for short buffers or
    /// non-canonical method/bool tags.
    pub(crate) fn from_bytes(b: &[u8]) -> Option<ResultKey> {
        if b.len() < KEY_BYTES {
            return None;
        }
        let u64_at =
            |at: usize| u64::from_le_bytes(b[at..at + 8].try_into().expect("length checked"));
        let method = *Method::all().get(b[32] as usize)?;
        let conservative = match b[41] {
            0 => false,
            1 => true,
            _ => return None,
        };
        Some(ResultKey {
            digest: (u64_at(0), u64_at(8)),
            n: u64_at(16) as usize,
            power: u64_at(24),
            method,
            tol_bucket: u64_at(33) as i64,
            conservative,
            cfg_digest: u64_at(42),
        })
    }
}

/// Byte length of [`ResultKey::to_bytes`].
pub(crate) const KEY_BYTES: usize = 50;

/// What a warm hit hands back (plus the hit-side stats the policy adds).
#[derive(Clone, Debug)]
pub struct CachedExpm {
    /// The cached answer, bit-identical to the cold run that produced it.
    pub result: Matrix,
    /// Method of the producing run (always equals the request's — the
    /// method is part of the key).
    pub method: Method,
    /// Planner of the producing run, echoed so warm responses report the
    /// same `plan_kind` as cold ones.
    pub plan_kind: Option<PlanKind>,
}

struct Entry {
    value: CachedExpm,
    bytes: u64,
    last_used: u64,
}

struct ResultInner {
    map: HashMap<ResultKey, Entry>,
    /// Recency index: `last_used` tick → key (ticks are unique), so the
    /// LRU victim is `pop_first()` — O(log n) per eviction instead of a
    /// full-map scan under the serving-path lock.
    order: BTreeMap<u64, ResultKey>,
    bytes: u64,
    budget: u64,
    tick: u64,
    /// When set (a persistent store is active), budget-driven demotions
    /// hand their entries to [`crate::store::spill_result`] instead of
    /// dropping the work — see [`ResultCache::set_spill`].
    spill: bool,
}

/// LRU, byte-budgeted result cache (tier 3). See the module docs.
pub struct ResultCache {
    inner: Mutex<ResultInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
}

/// Default byte budget of the process-wide instance until a config sets
/// one (256 MiB, matching [`crate::config::CacheSettings::budget_mb`]).
const DEFAULT_BUDGET_BYTES: u64 = 256 << 20;

impl ResultCache {
    /// An empty cache that evicts LRU entries to stay within
    /// `budget_bytes` of stored result payloads.
    pub fn new(budget_bytes: u64) -> ResultCache {
        ResultCache {
            inner: Mutex::new(ResultInner {
                map: HashMap::new(),
                order: BTreeMap::new(),
                bytes: 0,
                budget: budget_bytes,
                tick: 0,
                spill: false,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Fixed budget charge per entry on top of the matrix payload: the
    /// key, the entry struct (cached matrix handle, byte count, recency
    /// tick) and both index slots that pin it. Counting this is what
    /// keeps thousands of tiny results from overshooting the byte budget
    /// through uncounted metadata.
    pub const ENTRY_OVERHEAD: u64 = (std::mem::size_of::<ResultKey>()
        + std::mem::size_of::<Entry>()
        + 2 * std::mem::size_of::<(u64, ResultKey)>()) as u64;

    /// The process-wide instance the executors share.
    pub fn global() -> &'static ResultCache {
        static GLOBAL: OnceLock<ResultCache> = OnceLock::new();
        GLOBAL.get_or_init(|| ResultCache::new(DEFAULT_BUDGET_BYTES))
    }

    /// Retarget the byte budget, evicting (or spilling) LRU entries if
    /// the cache now exceeds it.
    pub fn set_budget(&self, budget_bytes: u64) {
        let mut guard = self.inner.lock().expect("result cache poisoned");
        let inner = &mut *guard;
        let mut spilled = Vec::new();
        if inner.budget != budget_bytes {
            inner.budget = budget_bytes;
            let (evicted, demoted) = Self::evict_to_fit(inner, 0);
            spilled = demoted;
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        drop(guard);
        for (key, value) in &spilled {
            crate::store::spill_result(key, value);
        }
    }

    /// Route budget-driven demotions to the persistent store
    /// ([`crate::store::spill_result`]) instead of dropping them. Set on
    /// the process-wide instance whenever a store is active; private
    /// instances default to plain eviction.
    pub fn set_spill(&self, spill: bool) {
        self.inner.lock().expect("result cache poisoned").spill = spill;
    }

    /// Evict least-recently-used entries until `incoming` more bytes fit
    /// the budget; returns how many entries were evicted plus the demoted
    /// entries themselves when spilling is on (the caller hands them to
    /// the store *after* releasing the lock). O(log n) per eviction via
    /// the recency index.
    fn evict_to_fit(
        inner: &mut ResultInner,
        incoming: u64,
    ) -> (u64, Vec<(ResultKey, CachedExpm)>) {
        let mut evicted = 0;
        let mut spilled = Vec::new();
        while inner.bytes + incoming > inner.budget && !inner.map.is_empty() {
            let (_, oldest) = inner.order.pop_first().expect("order mirrors map");
            let gone = inner.map.remove(&oldest).expect("order mirrors map");
            inner.bytes -= gone.bytes;
            evicted += 1;
            if inner.spill {
                spilled.push((oldest, gone.value));
            }
        }
        (evicted, spilled)
    }

    /// The cached answer for `key`, refreshing its recency. Counts a hit
    /// or a miss.
    pub fn get(&self, key: &ResultKey) -> Option<CachedExpm> {
        let mut guard = self.inner.lock().expect("result cache poisoned");
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(entry) => {
                inner.order.remove(&entry.last_used);
                entry.last_used = tick;
                inner.order.insert(tick, *key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store (or overwrite) the answer for `key`, evicting (or spilling)
    /// LRU entries to respect the budget. An answer bigger than the whole
    /// budget is dropped on the floor rather than flushing everything
    /// else. Each entry is charged its payload plus
    /// [`ResultCache::ENTRY_OVERHEAD`].
    pub fn insert(
        &self,
        key: ResultKey,
        result: &Matrix,
        method: Method,
        plan_kind: Option<PlanKind>,
    ) {
        let bytes =
            (result.data().len() * std::mem::size_of::<f32>()) as u64 + Self::ENTRY_OVERHEAD;
        let mut guard = self.inner.lock().expect("result cache poisoned");
        let inner = &mut *guard;
        if bytes > inner.budget {
            return;
        }
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= old.bytes;
            inner.order.remove(&old.last_used);
        }
        let (evicted, spilled) = Self::evict_to_fit(inner, bytes);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(
            key,
            Entry {
                value: CachedExpm { result: result.clone(), method, plan_kind },
                bytes,
                last_used: tick,
            },
        );
        inner.order.insert(tick, key);
        inner.bytes += bytes;
        self.inserts.fetch_add(1, Ordering::Relaxed);
        drop(guard);
        for (key, value) in &spilled {
            crate::store::spill_result(key, value);
        }
    }

    /// The `limit` most recently used entries, newest first — what the
    /// cluster artifact pull ([`crate::store::export_hot`]) ships to a
    /// joining member.
    pub fn export_recent(&self, limit: usize) -> Vec<(ResultKey, CachedExpm)> {
        let guard = self.inner.lock().expect("result cache poisoned");
        guard
            .order
            .iter()
            .rev()
            .take(limit)
            .map(|(_, key)| (*key, guard.map[key].value.clone()))
            .collect()
    }

    /// Cached entries.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("result cache poisoned").map.len()
    }

    /// `true` when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of result payloads currently held (≤ the budget, always).
    pub fn bytes(&self) -> u64 {
        self.inner.lock().expect("result cache poisoned").bytes
    }

    /// The active byte budget.
    pub fn budget(&self) -> u64 {
        self.inner.lock().expect("result cache poisoned").budget
    }

    /// Warm serves since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that found nothing since construction.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Stores since construction.
    pub fn inserts(&self) -> u64 {
        self.inserts.load(Ordering::Relaxed)
    }

    /// Entries evicted by the byte budget since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Drop every entry (counters keep their totals).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("result cache poisoned");
        inner.map.clear();
        inner.order.clear();
        inner.bytes = 0;
    }
}

/// One request's relationship to the result tier, resolved once at the
/// execution chokepoints so every executor applies identical semantics.
pub enum ResultCachePolicy {
    /// The tier does not apply: disabled by config, bypassed by the
    /// submission, or the request pins an explicit plan (pinning a plan
    /// means the caller wants the run, not the answer).
    Disabled,
    /// `CacheControl::Use`: serve warm, store cold.
    ReadWrite(ResultKey),
    /// `CacheControl::Refresh`: recompute, then overwrite the entry.
    WriteOnly(ResultKey),
}

impl ResultCachePolicy {
    /// Resolve the policy for one admitted request under `cfg`, syncing
    /// the global cache's budget to the config.
    pub fn for_request(cfg: &MatexpConfig, req: &ExpmRequest) -> ResultCachePolicy {
        if !cfg.cache.results || req.plan.is_some() || !req.cache.writes() {
            return ResultCachePolicy::Disabled;
        }
        ResultCache::global().set_spill(crate::store::active().is_some());
        ResultCache::global().set_budget(cfg.cache.budget_bytes());
        let key = ResultKey::for_request(cfg, req);
        if req.cache.reads() {
            ResultCachePolicy::ReadWrite(key)
        } else {
            ResultCachePolicy::WriteOnly(key)
        }
    }

    /// Serve the request from cache if the policy and the cache allow it:
    /// from the in-memory tier, or — on a memory miss with a persistent
    /// store active — from a checksum-verified store entry promoted back
    /// into memory ([`crate::store::load_result`]). The response reports
    /// zero launches/transfers and the measured serve time as `wall_s` —
    /// a hit never touches a device.
    pub fn lookup(&self, id: u64) -> Option<ExpmResponse> {
        let ResultCachePolicy::ReadWrite(key) = self else { return None };
        let t0 = Instant::now();
        let warm = ResultCache::global().get(key).or_else(|| crate::store::load_result(key));
        let hit = match warm {
            Some(hit) => {
                trace::event(trace::SpanKind::CacheHit(trace::Tier::Result), trace::current(), key.n);
                hit
            }
            None => {
                trace::event(trace::SpanKind::CacheMiss(trace::Tier::Result), trace::current(), key.n);
                return None;
            }
        };
        Some(ExpmResponse {
            id,
            result: hit.result,
            stats: ExecStats { wall_s: t0.elapsed().as_secs_f64(), ..ExecStats::default() },
            method: hit.method,
            plan_kind: hit.plan_kind,
        })
    }

    /// Store a freshly computed response, when the policy allows writes.
    /// Write-through: with a persistent store active the entry is also
    /// persisted immediately, so a warm restart can serve it with zero
    /// launches even if it is never demoted from memory.
    pub fn store(&self, resp: &ExpmResponse) {
        let key = match self {
            ResultCachePolicy::Disabled => return,
            ResultCachePolicy::ReadWrite(key) | ResultCachePolicy::WriteOnly(key) => key,
        };
        ResultCache::global().insert(*key, &resp.result, resp.method, resp.plan_kind);
        crate::store::persist_result(key, &resp.result, resp.method, resp.plan_kind);
        trace::event(trace::SpanKind::CacheStore(trace::Tier::Result), trace::current(), key.n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheControl;

    fn mat(n: usize, seed: u64) -> Matrix {
        Matrix::random(n, seed)
    }

    fn key(m: &Matrix, power: u64) -> ResultKey {
        ResultKey::for_parts(m, power, Method::Ours, None)
    }

    #[test]
    fn digest_is_content_sensitive_and_deterministic() {
        let a = mat(8, 1);
        let mut b = a.clone();
        assert_eq!(digest_f32(a.data()), digest_f32(b.data()));
        b.set(7, 7, b.get(7, 7) + 1.0);
        assert_ne!(digest_f32(a.data()), digest_f32(b.data()));
        // odd-length tails participate
        assert_ne!(digest_f32(&[1.0, 2.0, 3.0]), digest_f32(&[1.0, 2.0]));
        assert_ne!(digest_f32(&[1.0, 2.0, 3.0]), digest_f32(&[1.0, 2.0, 4.0]));
        // -0.0 and 0.0 are different bit patterns, so different content
        assert_ne!(digest_f32(&[0.0]), digest_f32(&[-0.0]));
    }

    #[test]
    fn key_covers_every_identity_component() {
        let m = mat(8, 2);
        let base = key(&m, 64);
        assert_eq!(base, key(&m, 64));
        assert_ne!(base, key(&m, 65));
        assert_ne!(base, ResultKey::for_parts(&m, 64, Method::OursPacked, None));
        assert_ne!(base, ResultKey::for_parts(&m, 64, Method::Ours, Some(1e-3)));
        assert_ne!(base, key(&mat(8, 3), 64));
    }

    #[test]
    fn request_keys_cover_config_and_the_conservative_boundary() {
        let mut cfg = MatexpConfig::default();
        cfg.cache.results = true;
        let req = ExpmRequest::new(1, mat(8, 77), 64, Method::Ours);
        let base = ResultKey::for_request(&cfg, &req);
        assert_eq!(base, ResultKey::for_request(&cfg, &req), "deterministic");
        // a different execution substrate must never share an entry
        let mut other = cfg.clone();
        other.cpu_algo = crate::linalg::expm::CpuAlgo::Ikj;
        assert_ne!(base, ResultKey::for_request(&other, &req));
        let mut other = cfg.clone();
        other.use_square_chains = false;
        assert_ne!(base, ResultKey::for_request(&other, &req));
        let mut other = cfg.clone();
        other.backend = crate::runtime::BackendKind::Pool;
        assert_ne!(base, ResultKey::for_request(&other, &req));
        // the conservative-plan boundary splits keys even inside one
        // tolerance decade: 1e-6 runs the chained plan, 5e-7 the binary
        let mut loose = req.clone();
        loose.tolerance = Some(1e-6);
        let mut tight = req.clone();
        tight.tolerance = Some(5e-7);
        assert_ne!(
            ResultKey::for_request(&cfg, &loose),
            ResultKey::for_request(&cfg, &tight),
            "keys must not cross the conservative-plan boundary"
        );
    }

    #[test]
    fn tolerance_buckets_are_order_of_magnitude() {
        let b = |t| tolerance_bucket(Some(t));
        assert_eq!(b(2e-4), b(5e-4), "same decade, same bucket");
        assert_ne!(b(1e-3), b(1e-5), "different decades differ");
        assert_ne!(tolerance_bucket(None), b(1.0), "no-tolerance is its own bucket");
        // deterministic across calls
        assert_eq!(b(1e-4), b(1e-4));
    }

    #[test]
    fn get_insert_roundtrip_and_counters() {
        let cache = ResultCache::new(1 << 20);
        let m = mat(8, 4);
        let k = key(&m, 16);
        assert!(cache.get(&k).is_none());
        cache.insert(k, &m, Method::Ours, Some(PlanKind::Chained));
        let hit = cache.get(&k).expect("warm");
        assert_eq!(hit.result, m, "bit-identical payload");
        assert_eq!(hit.plan_kind, Some(PlanKind::Chained));
        assert_eq!((cache.hits(), cache.misses(), cache.inserts()), (1, 1, 1));
        assert_eq!(cache.bytes(), 8 * 8 * 4 + ResultCache::ENTRY_OVERHEAD);
    }

    #[test]
    fn lru_eviction_respects_recency() {
        // budget fits exactly two 4x4 entries (64 payload bytes each,
        // plus the per-entry overhead charge)
        let cache = ResultCache::new(2 * (64 + ResultCache::ENTRY_OVERHEAD));
        let (a, b, c) = (mat(4, 1), mat(4, 2), mat(4, 3));
        cache.insert(key(&a, 2), &a, Method::Ours, None);
        cache.insert(key(&b, 2), &b, Method::Ours, None);
        // touch a so b is the LRU entry
        assert!(cache.get(&key(&a, 2)).is_some());
        cache.insert(key(&c, 2), &c, Method::Ours, None);
        assert!(cache.get(&key(&b, 2)).is_none(), "LRU entry evicted");
        assert!(cache.get(&key(&a, 2)).is_some(), "recently used survives");
        assert!(cache.get(&key(&c, 2)).is_some());
        assert_eq!(cache.evictions(), 1);
        assert!(cache.bytes() <= cache.budget());
    }

    #[test]
    fn oversized_entries_do_not_flush_the_cache() {
        // room for the small 4x4 entry (64 B + overhead) but not the
        // 16x16 one (1024 B + overhead)
        let cache = ResultCache::new(ResultCache::ENTRY_OVERHEAD + 200);
        let small = mat(4, 1);
        cache.insert(key(&small, 2), &small, Method::Ours, None);
        let huge = mat(16, 2);
        cache.insert(key(&huge, 2), &huge, Method::Ours, None);
        assert_eq!(cache.len(), 1, "oversized insert dropped, small entry kept");
        assert!(cache.get(&key(&small, 2)).is_some());
    }

    #[test]
    fn shrinking_the_budget_evicts() {
        let cache = ResultCache::new(1 << 20);
        for s in 0..4 {
            let m = mat(8, s);
            cache.insert(key(&m, 2), &m, Method::Ours, None);
        }
        assert_eq!(cache.len(), 4);
        cache.set_budget(2 * (8 * 8 * 4 + ResultCache::ENTRY_OVERHEAD));
        assert_eq!(cache.len(), 2, "shrunk budget evicts down to what fits");
        assert!(cache.bytes() <= cache.budget());
    }

    #[test]
    fn byte_accounting_matches_the_exact_model_for_tiny_entries() {
        // the regression this guards: counting only matrix payloads let
        // thousands of tiny results overshoot the budget through
        // uncounted key/entry metadata (~ENTRY_OVERHEAD per entry, 15x
        // the payload of a 2x2 result)
        let per_entry = 2 * 2 * 4 + ResultCache::ENTRY_OVERHEAD;
        let capacity = 100u64;
        let cache = ResultCache::new(capacity * per_entry);
        for s in 0..4000 {
            let m = mat(2, s);
            cache.insert(key(&m, 2), &m, Method::Ours, None);
        }
        assert_eq!(cache.len() as u64, capacity, "exactly the modeled capacity");
        assert_eq!(cache.bytes(), capacity * per_entry, "bytes match the exact model");
        assert!(cache.bytes() <= cache.budget());
        assert_eq!(cache.evictions(), 4000 - capacity, "each overflow evicts exactly one");
    }

    #[test]
    fn export_recent_returns_newest_first() {
        let cache = ResultCache::new(1 << 20);
        let mats: Vec<Matrix> = (0..3).map(mat8).collect();
        for m in &mats {
            cache.insert(key(m, 2), m, Method::Ours, None);
        }
        // touch the oldest so recency order is 0, 2, 1
        assert!(cache.get(&key(&mats[0], 2)).is_some());
        let hot = cache.export_recent(2);
        assert_eq!(hot.len(), 2);
        assert_eq!(hot[0].0, key(&mats[0], 2), "most recently used first");
        assert_eq!(hot[1].0, key(&mats[2], 2));
        assert_eq!(hot[0].1.result, mats[0], "payload rides along");
        assert_eq!(cache.export_recent(10).len(), 3, "limit caps, never pads");
    }

    fn mat8(seed: u64) -> Matrix {
        mat(8, seed)
    }

    #[test]
    fn key_bytes_roundtrip_and_store_digests_separate() {
        let m = mat(8, 5);
        let keys = [
            key(&m, 64),
            key(&m, 65),
            ResultKey::for_parts(&m, 64, Method::OursPacked, None),
            ResultKey::for_parts(&m, 64, Method::Ours, Some(1e-3)),
        ];
        let mut digests = Vec::new();
        for k in &keys {
            assert_eq!(ResultKey::from_bytes(&k.to_bytes()), Some(*k), "bit-exact roundtrip");
            digests.push(k.store_digest());
            assert_eq!(k.store_digest(), k.store_digest(), "deterministic");
        }
        digests.sort_unstable();
        digests.dedup();
        assert_eq!(digests.len(), keys.len(), "distinct keys, distinct store addresses");
        // decoding rejects short buffers and non-canonical tags
        assert_eq!(ResultKey::from_bytes(&[0u8; 10]), None);
        let mut bad_method = keys[0].to_bytes();
        bad_method[32] = 200;
        assert_eq!(ResultKey::from_bytes(&bad_method), None);
        let mut bad_bool = keys[0].to_bytes();
        bad_bool[41] = 7;
        assert_eq!(ResultKey::from_bytes(&bad_bool), None);
    }

    #[test]
    fn policy_disabled_paths() {
        let mut cfg = MatexpConfig::default();
        let req = ExpmRequest::new(1, mat(8, 9), 4, Method::Ours);
        // disabled by config (the default)
        assert!(matches!(
            ResultCachePolicy::for_request(&cfg, &req),
            ResultCachePolicy::Disabled
        ));
        cfg.cache.results = true;
        assert!(matches!(
            ResultCachePolicy::for_request(&cfg, &req),
            ResultCachePolicy::ReadWrite(_)
        ));
        // a plan override opts out of the tier entirely
        let mut pinned = req.clone();
        pinned.plan = Some(crate::plan::Plan::binary(4, false));
        assert!(matches!(
            ResultCachePolicy::for_request(&cfg, &pinned),
            ResultCachePolicy::Disabled
        ));
        // per-submission bypass / refresh
        let mut bypass = req.clone();
        bypass.cache = CacheControl::Bypass;
        assert!(matches!(
            ResultCachePolicy::for_request(&cfg, &bypass),
            ResultCachePolicy::Disabled
        ));
        let mut refresh = req.clone();
        refresh.cache = CacheControl::Refresh;
        assert!(matches!(
            ResultCachePolicy::for_request(&cfg, &refresh),
            ResultCachePolicy::WriteOnly(_)
        ));
    }
}
