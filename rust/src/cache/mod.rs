//! # The multi-tier caching subsystem
//!
//! The paper's 1000× speedup is an *amortization* claim: the expensive
//! parts of serving `A^N` — choosing the launch schedule, compiling the
//! kernels, and (for repeated hot requests) the execution itself — are
//! fixed per shape, yet a naive server re-pays them on every request.
//! This module eliminates that redundant work with three independent
//! tiers, each keyed by exactly what makes its artifact reusable:
//!
//! | tier | cache | key | scope | skips |
//! |---|---|---|---|---|
//! | 1 | [`PlanCache`] | `(n, power, plan kind, method)` | process-wide | the planner |
//! | 2 | [`PreparedSet`] | `(KernelOp, n)` | per engine/backend | `Backend::prepare` |
//! | 3 | [`ResultCache`] | content digest + `n` + power + method + tolerance bucket | process-wide | the whole execution |
//!
//! Every executor — [`crate::runtime::Engine`], [`crate::pool::PoolEngine`]
//! (and each of its pool devices), [`crate::coordinator::worker::WorkerEngine`]
//! and the serving [`crate::coordinator::service::ServiceHandle`] — shares
//! one policy: tier 1 sits inside the scheduler's strategy dispatch, tier 2
//! inside the engine's `prepare` path, and tier 3 inside the two request
//! chokepoints ([`crate::coordinator::worker::execute_request`] and
//! [`crate::pool::PoolEngine::execute_request`]), so warm-path semantics
//! cannot drift between the sync engine, the device pool and the service.
//!
//! Per-submission control rides on [`CacheControl`]
//! ([`crate::exec::Submission::cache`]): `Use` (the default) reads and
//! populates, `Bypass` neither reads nor populates the plan/result tiers
//! (tier 2 is per-engine state and stays warm), `Refresh` recomputes and
//! overwrites. Plan caching defaults on
//! ([`crate::config::CacheSettings::plans`]); result caching is opt-in
//! (`--cache-results`, [`crate::config::CacheSettings::results`]) because
//! a served-from-cache response reports zero launches — experiments that
//! measure execution must not silently stop executing. A submission with
//! an explicit [`crate::exec::Submission::plan`] override never touches
//! the result tier for the same reason: pinning a plan means the caller
//! wants the run, not the answer.
//!
//! The result tier is **content-addressed** (a 128-bit digest of the
//! matrix bytes, plus a fingerprint of the execution config) with LRU
//! eviction against a byte budget (`--cache-budget-mb`); entries never
//! serve across differing tolerance buckets, across the
//! conservative-plan boundary, or between differently-configured
//! executors. Hit/miss/eviction counters for all three tiers are process
//! totals ([`stats::snapshot`]), surfaced in the server `metrics`
//! response and the `expm` CLI output.
//!
//! `experiment --ablate-cache` (ablation A6) quantifies each tier; see
//! [`crate::experiments::ablations`].

pub mod plan;
pub mod prepared;
pub mod result;
pub mod stats;

pub use plan::{PlanCache, PlanKey};
pub use prepared::PreparedSet;
pub use result::{CachedExpm, ResultCache, ResultCachePolicy, ResultKey};
pub use stats::CacheCounters;

/// Per-submission cache directive, carried by
/// [`crate::exec::Submission::cache`] into every tier.
///
/// ```
/// use matexp::prelude::*;
///
/// // an ablation arm that must observe the real execution every time
/// let sub = Submission::expm(Matrix::identity(8), 64).cache(CacheControl::Bypass);
/// assert_eq!(sub.cache, CacheControl::Bypass);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum CacheControl {
    /// Read warm entries and populate cold ones — the default.
    #[default]
    Use,
    /// Neither read nor populate the plan and result tiers: plans are
    /// rebuilt, results recomputed, nothing stored. (Tier 2 — the
    /// per-backend prepared set — is engine state, not a per-request
    /// choice: prepared executables stay prepared.)
    Bypass,
    /// Recompute everything and overwrite the cached entries (cache
    /// invalidation by hand, for operators who changed something the keys
    /// cannot see).
    Refresh,
}

impl CacheControl {
    /// Canonical lowercase name (logs and CLI output).
    pub fn as_str(self) -> &'static str {
        match self {
            CacheControl::Use => "use",
            CacheControl::Bypass => "bypass",
            CacheControl::Refresh => "refresh",
        }
    }

    /// Every directive, for exhaustive tests.
    pub fn all() -> [CacheControl; 3] {
        [CacheControl::Use, CacheControl::Bypass, CacheControl::Refresh]
    }

    /// May this directive serve a cached entry?
    pub(crate) fn reads(self) -> bool {
        self == CacheControl::Use
    }

    /// May this directive store a computed entry?
    pub(crate) fn writes(self) -> bool {
        self != CacheControl::Bypass
    }
}

impl std::fmt::Display for CacheControl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_semantics() {
        assert!(CacheControl::Use.reads() && CacheControl::Use.writes());
        assert!(!CacheControl::Bypass.reads() && !CacheControl::Bypass.writes());
        assert!(!CacheControl::Refresh.reads() && CacheControl::Refresh.writes());
        assert_eq!(CacheControl::default(), CacheControl::Use);
        for c in CacheControl::all() {
            assert!(!c.as_str().is_empty());
            assert_eq!(c.to_string(), c.as_str());
        }
    }
}
