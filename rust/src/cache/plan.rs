//! Tier 1 — the [`PlanCache`]: the planner runs once per shape.
//!
//! Launch plans are pure functions of `(power, plan kind)`; the scheduler
//! nevertheless used to rebuild one per request. This tier memoizes the
//! built [`Plan`] under [`PlanKey`] — `(n, power, kind, method)`, the
//! full shape of the strategy decision — behind a process-wide cache
//! shared by every executor (the scheduler is the one place plans are
//! born, so one cache covers the sync engine, the pool and the service).
//!
//! Plans are small (O(log N) steps), so the cache stores them by value
//! and hands out clones; a FIFO cap bounds the table when a workload
//! sweeps many distinct powers.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::cache::CacheControl;
use crate::coordinator::request::Method;
use crate::plan::{Plan, PlanKind};
use crate::trace;

/// Everything that determines which plan the scheduler would build.
///
/// `n` does not change the plan's steps today, but it is part of the
/// strategy decision's shape (a future size-aware planner would fold it
/// in), so it keys the cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Matrix side length of the requests this plan serves.
    pub n: usize,
    /// The exponent the plan computes.
    pub power: u64,
    /// Which planner family built it (binary / chained / addition-chain…).
    pub kind: PlanKind,
    /// The execution method the strategy dispatch chose it for.
    pub method: Method,
}

/// Entries kept before FIFO eviction kicks in. Plans are tiny, so this
/// bounds memory at well under a megabyte while covering any realistic
/// working set of `(n, power)` shapes.
const PLAN_CACHE_CAP: usize = 4096;

struct PlanInner {
    map: HashMap<PlanKey, Plan>,
    /// Insertion order, for FIFO eviction at [`PLAN_CACHE_CAP`].
    order: VecDeque<PlanKey>,
    cap: usize,
}

/// Memoized launch plans (tier 1). See the module docs.
pub struct PlanCache {
    inner: Mutex<PlanInner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// An empty cache holding at most `cap` plans.
    pub fn new(cap: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(PlanInner {
                map: HashMap::new(),
                order: VecDeque::new(),
                cap: cap.max(1),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The process-wide instance every executor shares.
    pub fn global() -> &'static PlanCache {
        static GLOBAL: OnceLock<PlanCache> = OnceLock::new();
        GLOBAL.get_or_init(|| PlanCache::new(PLAN_CACHE_CAP))
    }

    /// The plan for `key`, built by `build` on a miss (or whenever `ctl`
    /// forbids reading). `Bypass` neither reads nor writes and leaves the
    /// counters untouched; `Refresh` rebuilds and overwrites.
    pub fn fetch(&self, key: PlanKey, ctl: CacheControl, build: impl FnOnce() -> Plan) -> Plan {
        if !ctl.writes() {
            // Bypass: the caller asked for an uncached planner run.
            return build();
        }
        if ctl.reads() {
            let inner = self.inner.lock().expect("plan cache poisoned");
            if let Some(plan) = inner.map.get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                trace::event(trace::SpanKind::CacheHit(trace::Tier::Plan), trace::current(), key.n);
                return plan.clone();
            }
        }
        trace::event(trace::SpanKind::CacheMiss(trace::Tier::Plan), trace::current(), key.n);
        let plan = build();
        self.misses.fetch_add(1, Ordering::Relaxed);
        trace::event(trace::SpanKind::CacheStore(trace::Tier::Plan), trace::current(), key.n);
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        if inner.map.insert(key, plan.clone()).is_none() {
            inner.order.push_back(key);
        }
        while inner.order.len() > inner.cap {
            let old = inner.order.pop_front().expect("len checked");
            inner.map.remove(&old);
        }
        plan
    }

    /// Plans currently held.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("plan cache poisoned").map.len()
    }

    /// `true` when no plan is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Served-from-cache count since process start.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Planner-ran count since process start (`Bypass` runs not included).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Drop every cached plan (counters keep their totals).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        inner.map.clear();
        inner.order.clear();
    }
}

/// The scheduler's entry point: fetch (or build) the plan for one
/// admitted request through the global cache, honoring the config toggle
/// and the submission's [`CacheControl`].
pub(crate) fn plan_for(
    key: PlanKey,
    ctl: CacheControl,
    enabled: bool,
    build: impl FnOnce() -> Plan,
) -> Plan {
    if !enabled {
        return build();
    }
    let plan = PlanCache::global().fetch(key, ctl, build);
    crate::store::persist_plan(&key, &plan);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn key(power: u64) -> PlanKey {
        PlanKey { n: 64, power, kind: PlanKind::Binary, method: Method::Ours }
    }

    #[test]
    fn second_fetch_hits_and_skips_the_builder() {
        let cache = PlanCache::new(16);
        let builds = AtomicUsize::new(0);
        let build = || {
            builds.fetch_add(1, Ordering::Relaxed);
            Plan::binary(100, false)
        };
        let a = cache.fetch(key(100), CacheControl::Use, build);
        let b = cache.fetch(key(100), CacheControl::Use, || unreachable!("must hit"));
        assert_eq!(a, b);
        assert_eq!(builds.load(Ordering::Relaxed), 1);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn bypass_never_stores_and_counts_nothing() {
        let cache = PlanCache::new(16);
        let _ = cache.fetch(key(64), CacheControl::Bypass, || Plan::binary(64, false));
        assert!(cache.is_empty());
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
    }

    #[test]
    fn refresh_rebuilds_and_overwrites() {
        let cache = PlanCache::new(16);
        let _ = cache.fetch(key(64), CacheControl::Use, || Plan::binary(64, false));
        // refresh replaces the entry even though one exists
        let refreshed =
            cache.fetch(key(64), CacheControl::Refresh, || Plan::binary(64, true));
        assert_eq!(refreshed.kind, PlanKind::BinaryFused);
        let served = cache.fetch(key(64), CacheControl::Use, || unreachable!("must hit"));
        assert_eq!(served.kind, PlanKind::BinaryFused);
        assert_eq!(cache.len(), 1, "overwrite, not duplicate");
    }

    #[test]
    fn distinct_key_components_miss() {
        let cache = PlanCache::new(16);
        let build = |p| move || Plan::binary(p, false);
        let _ = cache.fetch(key(100), CacheControl::Use, build(100));
        let mut other = key(100);
        other.n = 128;
        let _ = cache.fetch(other, CacheControl::Use, build(100));
        let mut other = key(100);
        other.method = Method::PlanRoundtrip;
        let _ = cache.fetch(other, CacheControl::Use, build(100));
        assert_eq!(cache.misses(), 3, "n and method are both part of the key");
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn fifo_cap_bounds_the_table() {
        let cache = PlanCache::new(4);
        for power in 1..=10u64 {
            let _ = cache.fetch(key(power), CacheControl::Use, || Plan::binary(power, false));
        }
        assert_eq!(cache.len(), 4);
        // the oldest entries are gone: power 1 rebuilds
        let _ = cache.fetch(key(1), CacheControl::Use, || Plan::binary(1, false));
        assert_eq!(cache.misses(), 11);
    }

    #[test]
    fn clear_drops_entries_but_keeps_totals() {
        let cache = PlanCache::new(16);
        let _ = cache.fetch(key(8), CacheControl::Use, || Plan::binary(8, false));
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.misses(), 1);
    }
}
