//! Process-wide cache telemetry: one snapshot over all three tiers.
//!
//! The counters aggregate the global [`super::PlanCache`] and
//! [`super::ResultCache`] instances plus every engine's
//! [`super::PreparedSet`]. They feed the coordinator's metrics snapshot
//! (and through it the server's `metrics` wire response) and the `expm`
//! CLI's cache line, so hit rates are observable wherever the stats
//! already flow.

use crate::cache::{plan::PlanCache, prepared, result::ResultCache};
use crate::json_obj;
use crate::util::json::Json;

/// Point-in-time totals for every cache tier (process-wide).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Plans served from the plan cache.
    pub plan_hits: u64,
    /// Plans built by the planner (bypass runs not counted).
    pub plan_misses: u64,
    /// `Backend::prepare` calls skipped by warm prepared sets.
    pub prepared_hits: u64,
    /// Cold prepares recorded across all engines.
    pub prepared_misses: u64,
    /// Requests answered from the result cache.
    pub result_hits: u64,
    /// Result-cache lookups that found nothing.
    pub result_misses: u64,
    /// Results stored.
    pub result_inserts: u64,
    /// Entries evicted by the byte budget.
    pub result_evictions: u64,
    /// Result entries currently held.
    pub result_entries: u64,
    /// Result payload bytes currently held.
    pub result_bytes: u64,
}

impl CacheCounters {
    /// Serialize for the server `metrics` response.
    pub fn to_json(&self) -> Json {
        json_obj![
            ("plan_hits", self.plan_hits),
            ("plan_misses", self.plan_misses),
            ("prepared_hits", self.prepared_hits),
            ("prepared_misses", self.prepared_misses),
            ("result_hits", self.result_hits),
            ("result_misses", self.result_misses),
            ("result_inserts", self.result_inserts),
            ("result_evictions", self.result_evictions),
            ("result_entries", self.result_entries),
            ("result_bytes", self.result_bytes),
        ]
    }
}

/// Snapshot the process-wide cache counters (all three tiers).
pub fn snapshot() -> CacheCounters {
    let plans = PlanCache::global();
    let results = ResultCache::global();
    let (prepared_hits, prepared_misses) = prepared::global_counters();
    CacheCounters {
        plan_hits: plans.hits(),
        plan_misses: plans.misses(),
        prepared_hits,
        prepared_misses,
        result_hits: results.hits(),
        result_misses: results.misses(),
        result_inserts: results.inserts(),
        result_evictions: results.evictions(),
        result_entries: results.len() as u64,
        result_bytes: results.bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_serializes_every_tier() {
        let s = snapshot();
        let j = s.to_json().to_string();
        for field in [
            "plan_hits",
            "prepared_misses",
            "result_hits",
            "result_evictions",
            "result_bytes",
        ] {
            assert!(j.contains(field), "{field} missing from {j}");
        }
    }

    #[test]
    fn counters_are_monotone_across_snapshots() {
        let before = snapshot();
        // drive the global plan cache once
        let key = crate::cache::PlanKey {
            n: 3,
            power: 77,
            kind: crate::plan::PlanKind::Binary,
            method: crate::coordinator::request::Method::Ours,
        };
        let _ = PlanCache::global().fetch(key, crate::cache::CacheControl::Use, || {
            crate::plan::Plan::binary(77, false)
        });
        let after = snapshot();
        assert!(after.plan_hits + after.plan_misses > before.plan_hits + before.plan_misses);
    }
}
