//! Exponentiation *launch plans* — the paper's contribution, reified.
//!
//! A [`Plan`] is the exact sequence of kernel launches the coordinator will
//! replay against the AOT matmul executables, expressed over a small
//! register file of device-resident buffers (register 0 always holds the
//! input `A`). The three planners mirror the paper:
//!
//! * [`Plan::naive`]    — §4.2: `N - 1` launches, one multiply each.
//! * [`Plan::binary`]   — §4.3: square-and-multiply, `⌊log₂N⌋ +
//!   popcount(N) − 1` multiplies; optionally with the fused `sqmul`
//!   executable so a square+multiply pair costs one launch.
//! * [`Plan::chained`]  — binary with runs of squarings folded into the
//!   fused `square2`/`square4` executables (§4.3.8 pushed further).
//! * [`chain::addition_chain`] — extension: shorter-than-binary plans from
//!   power-tree addition chains.
//!
//! Plans are *data*: they can be costed ([`cost`]), replayed on the CPU,
//! on PJRT buffers, on the timing simulator, or on modular scalars (the
//! proptest oracle).

pub mod binary;
pub mod chain;
pub mod cost;
pub mod naive;
pub mod step;

pub use cost::PlanCost;
pub use step::Step;

use crate::error::{MatexpError, Result};

/// Which planner produced a plan (for logs/metrics/benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanKind {
    /// §4.2: one multiply per step, `N − 1` of them.
    Naive,
    /// §4.3: square-and-multiply.
    Binary,
    /// Binary with fused `SqMul` square+multiply launches.
    BinaryFused,
    /// Binary with squaring runs folded into `square{k}` launches.
    Chained,
    /// Power-tree addition chain (≤ binary multiply count).
    AdditionChain,
    /// Binary squaring schedule whose multiplies are intended for the
    /// Strassen fast-multiply kernel (selected above the autotuned
    /// crossover — see [`crate::linalg::autotune`]). The *schedule* is
    /// identical to [`PlanKind::Binary`]; the kind marks the dispatch
    /// intent for logs, caching and metrics.
    Strassen,
}

impl std::fmt::Display for PlanKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            PlanKind::Naive => "naive",
            PlanKind::Binary => "binary",
            PlanKind::BinaryFused => "binary-fused",
            PlanKind::Chained => "chained",
            PlanKind::AdditionChain => "addition-chain",
            PlanKind::Strassen => "strassen",
        };
        f.write_str(s)
    }
}

/// A launch schedule computing `A^power`.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    /// The exponent this plan computes.
    pub power: u64,
    /// Which planner produced it.
    pub kind: PlanKind,
    /// The launch schedule, in execution order.
    pub steps: Vec<Step>,
    /// Number of registers (device buffers) the plan needs; register 0 is
    /// the input.
    pub n_regs: usize,
    /// Register holding `A^power` after the last step.
    pub result: usize,
}

impl Plan {
    /// Paper §4.2: multiply by `A` exactly `power - 1` times.
    pub fn naive(power: u64) -> Plan {
        naive::naive_plan(power)
    }

    /// Paper §4.3: square-and-multiply. With `fused`, a square+multiply
    /// pair becomes one `SqMul` launch.
    pub fn binary(power: u64, fused: bool) -> Plan {
        binary::binary_plan(power, fused)
    }

    /// Binary plan with squaring runs folded into `square2`/`square4`
    /// launches (`chains` = available fused chain lengths, e.g. `[4, 2]`).
    pub fn chained(power: u64, chains: &[u32]) -> Plan {
        binary::chained_plan(power, chains)
    }

    /// Extension: power-tree addition chain (≤ binary multiply count).
    pub fn addition_chain(power: u64) -> Plan {
        chain::addition_chain_plan(power)
    }

    /// Square-and-multiply schedule tagged for the Strassen fast-multiply
    /// kernel: same steps as [`Plan::binary`], but the kind tells the
    /// executor/caches that large multiplies should take the
    /// trade-multiplies-for-adds path above the tuned crossover.
    pub fn strassen(power: u64) -> Plan {
        let mut plan = binary::binary_plan(power, false);
        plan.kind = PlanKind::Strassen;
        plan
    }

    /// Number of kernel launches (the paper's headline cost).
    pub fn launches(&self) -> usize {
        self.steps.iter().filter(|s| s.is_launch()).count()
    }

    /// Number of matrix multiplies across all launches.
    pub fn multiplies(&self) -> usize {
        self.steps.iter().map(|s| s.multiplies()).sum()
    }

    /// Validate internal consistency (register bounds, result written).
    pub fn validate(&self) -> Result<()> {
        if self.power == 0 {
            return Err(MatexpError::Plan("power must be >= 1".into()));
        }
        if self.result >= self.n_regs {
            return Err(MatexpError::Plan(format!(
                "result register {} out of bounds ({} regs)",
                self.result, self.n_regs
            )));
        }
        let mut written = vec![false; self.n_regs];
        written[0] = true; // input
        for (idx, step) in self.steps.iter().enumerate() {
            for r in step.reads() {
                if r >= self.n_regs {
                    return Err(MatexpError::Plan(format!("step {idx}: read of bad reg {r}")));
                }
                if !written[r] {
                    return Err(MatexpError::Plan(format!(
                        "step {idx}: {step:?} reads uninitialized reg {r}"
                    )));
                }
            }
            for w in step.writes() {
                if w >= self.n_regs {
                    return Err(MatexpError::Plan(format!("step {idx}: write to bad reg {w}")));
                }
                written[w] = true;
            }
        }
        if !written[self.result] {
            return Err(MatexpError::Plan("result register never written".into()));
        }
        Ok(())
    }

    /// Replay the plan over any multiplicative type: `mul(x, y) = x·y`.
    ///
    /// This single evaluator serves the CPU substrate (`T = Matrix`), the
    /// proptest oracle (`T = u64` modular scalars) and the simulator.
    pub fn eval<T: Clone, F: FnMut(&T, &T) -> T>(&self, input: T, mut mul: F) -> Result<T> {
        self.validate()?;
        let mut regs: Vec<Option<T>> = vec![None; self.n_regs];
        regs[0] = Some(input);
        for step in &self.steps {
            match *step {
                Step::Copy { dst, src } => {
                    let v = regs[src].clone();
                    regs[dst] = v;
                }
                Step::Mul { dst, lhs, rhs } => {
                    let v = mul(
                        regs[lhs].as_ref().expect("validated"),
                        regs[rhs].as_ref().expect("validated"),
                    );
                    regs[dst] = Some(v);
                }
                Step::SqMul { acc, base } => {
                    let new_acc = mul(
                        regs[acc].as_ref().expect("validated"),
                        regs[base].as_ref().expect("validated"),
                    );
                    let new_base = {
                        let b = regs[base].as_ref().expect("validated");
                        mul(b, b)
                    };
                    regs[acc] = Some(new_acc);
                    regs[base] = Some(new_base);
                }
                Step::SquareChain { reg, k } => {
                    for _ in 0..k {
                        let b = regs[reg].as_ref().expect("validated");
                        let sq = mul(b, b);
                        regs[reg] = Some(sq);
                    }
                }
            }
        }
        regs[self.result]
            .take()
            .ok_or_else(|| MatexpError::Plan("result register empty".into()))
    }

    /// Replay over modular scalars — cheap ground truth for any power.
    pub fn eval_mod(&self, base: u64, modulus: u64) -> Result<u64> {
        self.eval(base % modulus, |x, y| (x * y) % modulus)
    }
}

/// `base^power mod modulus` by an independent method (binary on scalars) —
/// the oracle plans are checked against.
pub fn mod_pow(mut base: u64, mut power: u64, modulus: u64) -> u64 {
    let mut acc = 1u64 % modulus;
    base %= modulus;
    while power > 0 {
        if power & 1 == 1 {
            acc = acc * base % modulus;
        }
        base = base * base % modulus;
        power >>= 1;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: u64 = 1_000_003; // prime, small enough that products fit u64

    fn check_all_kinds(power: u64) {
        let want = mod_pow(3, power, M);
        for plan in [
            Plan::naive(power),
            Plan::binary(power, false),
            Plan::binary(power, true),
            Plan::chained(power, &[4, 2]),
            Plan::addition_chain(power),
            Plan::strassen(power),
        ] {
            plan.validate().unwrap();
            assert_eq!(
                plan.eval_mod(3, M).unwrap(),
                want,
                "kind={:?} power={power}",
                plan.kind
            );
        }
    }

    #[test]
    fn all_planners_correct_small() {
        for p in 1..=64 {
            check_all_kinds(p);
        }
    }

    #[test]
    fn all_planners_correct_paper_powers() {
        for p in [64, 100, 127, 128, 255, 256, 511, 512, 777, 1023, 1024] {
            check_all_kinds(p);
        }
    }

    #[test]
    fn binary_multiplies_formula() {
        for p in 1u64..=1024 {
            let plan = Plan::binary(p, false);
            let expected = (63 - p.leading_zeros()) as usize + p.count_ones() as usize - 1;
            assert_eq!(plan.multiplies(), expected, "p={p}");
        }
    }

    #[test]
    fn naive_multiplies_is_power_minus_one() {
        for p in [1u64, 2, 5, 64, 513] {
            assert_eq!(Plan::naive(p).multiplies(), (p - 1) as usize);
        }
    }

    #[test]
    fn fused_binary_never_more_launches() {
        for p in 1u64..=1024 {
            assert!(
                Plan::binary(p, true).launches() <= Plan::binary(p, false).launches(),
                "p={p}"
            );
        }
    }

    #[test]
    fn chained_never_more_launches_than_binary() {
        for p in 1u64..=1024 {
            assert!(
                Plan::chained(p, &[4, 2]).launches() <= Plan::binary(p, false).launches(),
                "p={p}"
            );
        }
    }

    #[test]
    fn addition_chain_never_more_multiplies_than_binary() {
        for p in 1u64..=1024 {
            assert!(
                Plan::addition_chain(p).multiplies() <= Plan::binary(p, false).multiplies(),
                "p={p}"
            );
        }
    }

    #[test]
    fn power_of_two_binary_is_pure_squarings() {
        for k in 0..=10 {
            let p = 1u64 << k;
            let plan = Plan::binary(p, false);
            assert_eq!(plan.multiplies(), k as usize, "p={p}");
        }
    }

    #[test]
    fn validate_rejects_bad_register() {
        let plan = Plan {
            power: 2,
            kind: PlanKind::Binary,
            steps: vec![Step::Mul { dst: 1, lhs: 0, rhs: 5 }],
            n_regs: 2,
            result: 1,
        };
        assert!(plan.validate().is_err());
    }

    #[test]
    fn validate_rejects_uninitialized_read() {
        let plan = Plan {
            power: 2,
            kind: PlanKind::Binary,
            steps: vec![Step::Mul { dst: 1, lhs: 2, rhs: 0 }],
            n_regs: 3,
            result: 1,
        };
        assert!(plan.validate().is_err());
    }

    #[test]
    fn mod_pow_matches_u128_naive() {
        for p in 0..50u64 {
            let want = (0..p).fold(1u128, |acc, _| acc * 7 % M as u128) as u64;
            assert_eq!(mod_pow(7, p, M), want);
        }
    }
}
