//! Paper §4.2: the naive GPU schedule — "Call the GPU kernel N times from
//! the host code". `N - 1` launches, each multiplying the accumulator by
//! the original matrix.

use crate::plan::{Plan, PlanKind, Step};

/// Registers: 0 = input `A` (never overwritten), 1 = accumulator.
pub fn naive_plan(power: u64) -> Plan {
    assert!(power >= 1, "power must be >= 1");
    let mut steps = Vec::with_capacity(power as usize);
    steps.push(Step::Copy { dst: 1, src: 0 });
    for _ in 1..power {
        steps.push(Step::Mul { dst: 1, lhs: 1, rhs: 0 });
    }
    Plan {
        power,
        kind: PlanKind::Naive,
        steps,
        n_regs: 2,
        result: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::mod_pow;

    #[test]
    fn launches_equal_power_minus_one() {
        for p in [1u64, 2, 10, 64, 1024] {
            let plan = naive_plan(p);
            assert_eq!(plan.launches(), (p - 1) as usize);
            assert_eq!(plan.multiplies(), (p - 1) as usize);
        }
    }

    #[test]
    fn evaluates_correctly() {
        let m = 999_983u64;
        for p in 1..200u64 {
            assert_eq!(naive_plan(p).eval_mod(5, m).unwrap(), mod_pow(5, p, m));
        }
    }

    #[test]
    fn input_register_preserved() {
        // every Mul reads reg 0 as rhs, so reg 0 must never be written
        let plan = naive_plan(50);
        for s in &plan.steps {
            assert!(!s.writes().contains(&0), "{s:?} clobbers the input");
        }
    }

    #[test]
    #[should_panic]
    fn power_zero_panics() {
        naive_plan(0);
    }
}
