//! Plan cost model: launches, multiplies, transfers — the quantities the
//! paper's §4.3.8 argues about ("the data is offloaded only log(N) times").

use crate::plan::Plan;

/// Cost of executing a plan for an `n x n` matrix under a given execution
/// discipline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanCost {
    /// Kernel launches (host → device dispatches).
    pub launches: usize,
    /// Matrix multiplies (2·n³ flops each).
    pub multiplies: usize,
    /// Host→device matrix transfers.
    pub h2d_transfers: usize,
    /// Device→host matrix transfers.
    pub d2h_transfers: usize,
    /// Total floating-point operations.
    pub flops: f64,
    /// Total bytes moved over the host↔device link.
    pub transfer_bytes: f64,
}

impl PlanCost {
    /// Cost with device-resident buffers (the paper's "Our Approach"):
    /// upload the input once, download the result once.
    pub fn device_resident(plan: &Plan, n: usize) -> PlanCost {
        Self::build(plan, n, 1, 1)
    }

    /// Cost with a host round-trip per launch (the naive §4.2 discipline:
    /// every launch uploads its operands and downloads its result).
    pub fn per_launch_roundtrip(plan: &Plan, n: usize) -> PlanCost {
        // each launch moves 2 operands in, 1 result out
        Self::build(plan, n, 2 * plan.launches(), plan.launches())
    }

    fn build(plan: &Plan, n: usize, h2d: usize, d2h: usize) -> PlanCost {
        let multiplies = plan.multiplies();
        let bytes_per_matrix = (n * n * std::mem::size_of::<f32>()) as f64;
        PlanCost {
            launches: plan.launches(),
            multiplies,
            h2d_transfers: h2d,
            d2h_transfers: d2h,
            flops: 2.0 * (n as f64).powi(3) * multiplies as f64,
            transfer_bytes: bytes_per_matrix * (h2d + d2h) as f64,
        }
    }

    /// The paper's headline ratio: naive launches / our launches.
    pub fn launch_ratio(naive: &PlanCost, ours: &PlanCost) -> f64 {
        naive.launches as f64 / ours.launches.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::Plan;

    #[test]
    fn naive_1024_vs_binary_1024() {
        let naive = Plan::naive(1024);
        let ours = Plan::binary(1024, false);
        let cn = PlanCost::per_launch_roundtrip(&naive, 64);
        let co = PlanCost::device_resident(&ours, 64);
        assert_eq!(cn.launches, 1023);
        assert_eq!(co.launches, 10);
        assert_eq!(co.h2d_transfers, 1);
        assert_eq!(co.d2h_transfers, 1);
        // the paper's ~100x regime at n=64, N=1024 (Table 2: 89.58x)
        let ratio = PlanCost::launch_ratio(&cn, &co);
        assert!(ratio > 100.0, "{ratio}");
    }

    #[test]
    fn flops_scale_with_n_cubed() {
        let plan = Plan::binary(256, false);
        let c64 = PlanCost::device_resident(&plan, 64);
        let c128 = PlanCost::device_resident(&plan, 128);
        assert!((c128.flops / c64.flops - 8.0).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_transfers_scale_with_launches() {
        let plan = Plan::naive(100);
        let c = PlanCost::per_launch_roundtrip(&plan, 32);
        assert_eq!(c.h2d_transfers, 2 * 99);
        assert_eq!(c.d2h_transfers, 99);
        assert_eq!(c.transfer_bytes, (32.0 * 32.0 * 4.0) * (3 * 99) as f64);
    }
}
