//! Paper §4.3 "Our Approach": square-and-multiply (binary exponentiation).
//!
//! LSB-first walk of the exponent bits: maintain `base = A^(2^i)` in
//! register 0 and fold it into the accumulator (register 1) on set bits.
//! Multiplies = `⌊log₂N⌋ + popcount(N) − 1` — the `log(N)` the paper's
//! abstract claims, vs `N − 1` for the naive schedule.

use crate::plan::{Plan, PlanKind, Step};

const BASE: usize = 0;
const ACC: usize = 1;

/// Abstract op stream before register assignment / fusion.
#[derive(Clone, Copy, PartialEq, Debug)]
enum Op {
    /// acc = base (first set bit)
    Init,
    /// acc *= base
    MulAcc,
    /// base *= base
    Square,
}

fn op_stream(power: u64) -> Vec<Op> {
    assert!(power >= 1, "power must be >= 1");
    let mut ops = Vec::new();
    let mut p = power;
    let mut first = true;
    while p > 0 {
        if p & 1 == 1 {
            ops.push(if first { Op::Init } else { Op::MulAcc });
            first = false;
        }
        p >>= 1;
        if p > 0 {
            ops.push(Op::Square);
        }
    }
    ops
}

/// Square-and-multiply plan. With `fused = true`, adjacent
/// (`MulAcc`, `Square`) pairs become one [`Step::SqMul`] launch against
/// the fused `sqmul` artifact — same multiply count, fewer launches.
pub fn binary_plan(power: u64, fused: bool) -> Plan {
    let ops = op_stream(power);
    let mut steps = Vec::with_capacity(ops.len());
    let mut i = 0;
    while i < ops.len() {
        match ops[i] {
            Op::Init => steps.push(Step::Copy { dst: ACC, src: BASE }),
            Op::MulAcc if fused && i + 1 < ops.len() && ops[i + 1] == Op::Square => {
                steps.push(Step::SqMul { acc: ACC, base: BASE });
                i += 2;
                continue;
            }
            Op::MulAcc => steps.push(Step::Mul { dst: ACC, lhs: ACC, rhs: BASE }),
            Op::Square => steps.push(Step::Mul { dst: BASE, lhs: BASE, rhs: BASE }),
        }
        i += 1;
    }
    Plan {
        power,
        kind: if fused { PlanKind::BinaryFused } else { PlanKind::Binary },
        steps,
        n_regs: 2,
        result: if power == 1 { BASE } else { ACC },
    }
}

/// Binary plan with *runs of squarings* folded into fused
/// `square{k}` launches. `chains` lists the available fused chain lengths
/// (e.g. `[4, 2]` for the shipped `square4`/`square2` artifacts), tried
/// longest-first; leftovers fall back to single squarings.
pub fn chained_plan(power: u64, chains: &[u32]) -> Plan {
    let mut chains: Vec<u32> = chains.iter().copied().filter(|&k| k >= 2).collect();
    chains.sort_unstable_by(|a, b| b.cmp(a));
    let ops = op_stream(power);
    let mut steps = Vec::new();
    let mut i = 0;
    while i < ops.len() {
        match ops[i] {
            Op::Init => {
                steps.push(Step::Copy { dst: ACC, src: BASE });
                i += 1;
            }
            Op::MulAcc => {
                steps.push(Step::Mul { dst: ACC, lhs: ACC, rhs: BASE });
                i += 1;
            }
            Op::Square => {
                // measure the run of consecutive squarings
                let mut run = 0;
                while i + run < ops.len() && ops[i + run] == Op::Square {
                    run += 1;
                }
                let mut remaining = run as u32;
                for &k in &chains {
                    while remaining >= k {
                        steps.push(Step::SquareChain { reg: BASE, k });
                        remaining -= k;
                    }
                }
                for _ in 0..remaining {
                    steps.push(Step::Mul { dst: BASE, lhs: BASE, rhs: BASE });
                }
                i += run;
            }
        }
    }
    Plan {
        power,
        kind: PlanKind::Chained,
        steps,
        n_regs: 2,
        result: if power == 1 { BASE } else { ACC },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::mod_pow;

    const M: u64 = 1_000_003;

    #[test]
    fn power_one_is_zero_launches() {
        for plan in [binary_plan(1, false), binary_plan(1, true), chained_plan(1, &[2])] {
            assert_eq!(plan.launches(), 0, "{:?}", plan.kind);
            assert_eq!(plan.eval_mod(9, M).unwrap(), 9);
        }
    }

    #[test]
    fn exhaustive_correctness_to_2048() {
        for p in 1..=2048u64 {
            let want = mod_pow(2, p, M);
            assert_eq!(binary_plan(p, false).eval_mod(2, M).unwrap(), want, "p={p}");
            assert_eq!(binary_plan(p, true).eval_mod(2, M).unwrap(), want, "fused p={p}");
            assert_eq!(chained_plan(p, &[4, 2]).eval_mod(2, M).unwrap(), want, "chained p={p}");
        }
    }

    #[test]
    fn fused_launch_count() {
        // p = 0b1010101: squarings 6, mulaccs 3 (+init). Non-fused: 9
        // launches. Fused: the two mid-exponent MulAccs are each followed
        // by a Square and fuse; the final MulAcc (MSB) has no trailing
        // Square, so 9 − 2 = 7 launches.
        let p = 0b1010101;
        assert_eq!(binary_plan(p, false).launches(), 9);
        assert_eq!(binary_plan(p, true).launches(), 7);
        // multiply count identical
        assert_eq!(binary_plan(p, true).multiplies(), binary_plan(p, false).multiplies());
    }

    #[test]
    fn chained_pow2_uses_long_chains() {
        // 1024 = 2^10: runs of 10 squarings -> two square4 + one square2
        let plan = chained_plan(1024, &[4, 2]);
        assert_eq!(plan.launches(), 3);
        assert_eq!(plan.multiplies(), 10);
    }

    #[test]
    fn chained_without_chains_equals_binary() {
        for p in [3u64, 64, 100, 511] {
            assert_eq!(
                chained_plan(p, &[]).launches(),
                binary_plan(p, false).launches(),
                "p={p}"
            );
        }
    }

    #[test]
    fn table_powers_multiply_counts() {
        // the paper's log(N) claim, exact: floor(log2) + popcount - 1
        for (p, want) in [(64u64, 6), (128, 7), (256, 8), (512, 9), (1024, 10)] {
            assert_eq!(binary_plan(p, false).multiplies(), want, "p={p}");
        }
    }

    #[test]
    fn chain_lengths_shorter_than_two_ignored() {
        let plan = chained_plan(16, &[1, 0]);
        assert_eq!(plan.launches(), binary_plan(16, false).launches());
    }
}
