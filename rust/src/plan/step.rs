//! Plan steps: the launch vocabulary the runtime engine understands.
//!
//! Each launch-step maps 1:1 onto a typed kernel
//! ([`crate::runtime::KernelOp`], backed by an AOT executable on the PJRT
//! backend); `Copy` is host-side buffer aliasing and costs nothing on the
//! device.

use crate::runtime::op::KernelOp;

/// One step of a [`crate::plan::Plan`], over register indices.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// `regs[dst] = regs[src]` — host-side aliasing, zero launches.
    Copy { dst: usize, src: usize },
    /// `regs[dst] = regs[lhs] · regs[rhs]` — one `matmul` (or `square`
    /// when `lhs == rhs`) launch.
    Mul { dst: usize, lhs: usize, rhs: usize },
    /// Fused binary-exponentiation step: `regs[acc] · regs[base]` and
    /// `regs[base]²` in ONE `sqmul` launch (two multiplies).
    SqMul { acc: usize, base: usize },
    /// `regs[reg] = regs[reg]^(2^k)` in one `square{k}` launch
    /// (`k` multiplies); the engine requires a matching artifact.
    SquareChain { reg: usize, k: u32 },
}

impl Step {
    /// Does this step cost a kernel launch?
    pub fn is_launch(&self) -> bool {
        !matches!(self, Step::Copy { .. })
    }

    /// Matrix multiplies performed by this step.
    pub fn multiplies(&self) -> usize {
        match self {
            Step::Copy { .. } => 0,
            Step::Mul { .. } => 1,
            Step::SqMul { .. } => 2,
            Step::SquareChain { k, .. } => *k as usize,
        }
    }

    /// Registers read by this step.
    pub fn reads(&self) -> Vec<usize> {
        match *self {
            Step::Copy { src, .. } => vec![src],
            Step::Mul { lhs, rhs, .. } => vec![lhs, rhs],
            Step::SqMul { acc, base } => vec![acc, base],
            Step::SquareChain { reg, .. } => vec![reg],
        }
    }

    /// Registers written by this step.
    pub fn writes(&self) -> Vec<usize> {
        match *self {
            Step::Copy { dst, .. } => vec![dst],
            Step::Mul { dst, .. } => vec![dst],
            Step::SqMul { acc, base } => vec![acc, base],
            Step::SquareChain { reg, .. } => vec![reg],
        }
    }

    /// Kernel this step launches (`None` for host-side steps).
    pub fn op(&self) -> Option<KernelOp> {
        match self {
            Step::Copy { .. } => None,
            Step::Mul { lhs, rhs, .. } if lhs == rhs => Some(KernelOp::Square),
            Step::Mul { .. } => Some(KernelOp::Matmul),
            Step::SqMul { .. } => Some(KernelOp::SqMul),
            Step::SquareChain { k, .. } => Some(KernelOp::SquareChain(*k)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_and_multiply_accounting() {
        assert!(!Step::Copy { dst: 1, src: 0 }.is_launch());
        assert_eq!(Step::Copy { dst: 1, src: 0 }.multiplies(), 0);
        assert_eq!(Step::Mul { dst: 1, lhs: 0, rhs: 0 }.multiplies(), 1);
        assert_eq!(Step::SqMul { acc: 1, base: 0 }.multiplies(), 2);
        assert_eq!(Step::SquareChain { reg: 0, k: 4 }.multiplies(), 4);
    }

    #[test]
    fn op_per_step() {
        assert_eq!(Step::Mul { dst: 1, lhs: 0, rhs: 0 }.op().unwrap(), KernelOp::Square);
        assert_eq!(Step::Mul { dst: 1, lhs: 1, rhs: 0 }.op().unwrap(), KernelOp::Matmul);
        assert_eq!(Step::SqMul { acc: 1, base: 0 }.op().unwrap(), KernelOp::SqMul);
        assert_eq!(
            Step::SquareChain { reg: 0, k: 2 }.op().unwrap(),
            KernelOp::SquareChain(2)
        );
        assert!(Step::Copy { dst: 1, src: 0 }.op().is_none());
        // step multiplies agree with the kernel's own accounting
        for step in [
            Step::Mul { dst: 1, lhs: 0, rhs: 0 },
            Step::SqMul { acc: 1, base: 0 },
            Step::SquareChain { reg: 0, k: 4 },
        ] {
            assert_eq!(step.multiplies(), step.op().unwrap().multiplies());
        }
    }

    #[test]
    fn reads_writes_cover_all_variants() {
        let s = Step::SqMul { acc: 3, base: 5 };
        assert_eq!(s.reads(), vec![3, 5]);
        assert_eq!(s.writes(), vec![3, 5]);
        let c = Step::Copy { dst: 2, src: 0 };
        assert_eq!(c.reads(), vec![0]);
        assert_eq!(c.writes(), vec![2]);
    }
}
