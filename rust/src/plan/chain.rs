//! Extension: addition-chain exponentiation via Knuth's power tree.
//!
//! The binary method is not optimal: e.g. `A^15` costs 6 multiplies
//! binary but 5 via the chain `1,2,3,6,12,15`. The power tree yields
//! (near-)optimal chains for all exponents we serve (N ≤ 4096). Listed as
//! future work relative to the paper — the paper stops at binary.
//!
//! The planner falls back to the binary plan in the rare cases where the
//! power tree is not shorter, so [`addition_chain_plan`] is never worse.

use std::collections::HashMap;

use crate::plan::{binary, Plan, PlanKind, Step};

/// Compute an addition chain `1 = c_0 < c_1 < … < c_m = power` via the
/// power-tree method, returning the chain values in order.
pub fn power_tree_chain(power: u64) -> Vec<u64> {
    assert!(power >= 1, "power must be >= 1");
    // parent pointers in the power tree; grown breadth-first until `power`
    // appears.
    let mut parent: HashMap<u64, u64> = HashMap::new();
    parent.insert(1, 0);
    let mut frontier = vec![1u64];
    while !parent.contains_key(&power) {
        let mut next = Vec::new();
        for &n in &frontier {
            // path from n back to the root
            let mut path = Vec::new();
            let mut cur = n;
            while cur != 0 {
                path.push(cur);
                cur = parent[&cur];
            }
            // children n + p for p along the path, ROOT FIRST (n+1 first) —
            // Knuth's canonical ordering; largest-first builds a different
            // (worse) tree, e.g. 6 multiplies for 15 instead of 5.
            for &p in path.iter().rev() {
                let child = n + p;
                if child <= power * 2 && !parent.contains_key(&child) {
                    parent.insert(child, n);
                    next.push(child);
                }
            }
        }
        assert!(!next.is_empty(), "power tree stalled before {power}");
        frontier = next;
    }
    let mut chain = Vec::new();
    let mut cur = power;
    while cur != 0 {
        chain.push(cur);
        cur = parent[&cur];
    }
    chain.reverse();
    chain
}

/// Largest exponent the power-tree search explores. BFS cost grows
/// superlinearly (62 ms at 2^20) while the saving over binary stays a
/// handful of multiplies; beyond this the planner falls back to binary.
pub const POWER_TREE_LIMIT: u64 = 1 << 16;

/// Build a [`Plan`] from the power-tree chain; falls back to the binary
/// plan when the chain is not strictly shorter (or the exponent exceeds
/// [`POWER_TREE_LIMIT`]).
pub fn addition_chain_plan(power: u64) -> Plan {
    if power > POWER_TREE_LIMIT {
        return Plan {
            kind: PlanKind::AdditionChain,
            ..binary::binary_plan(power, false)
        };
    }
    let chain = power_tree_chain(power);
    let chain_muls = chain.len() - 1;
    let binary_fallback = binary::binary_plan(power, false);
    if chain_muls >= binary_fallback.multiplies() {
        return Plan { kind: PlanKind::AdditionChain, ..binary_fallback };
    }

    // register r holds A^chain[r]; register 0 is the input (chain[0] = 1).
    let mut reg_of: HashMap<u64, usize> = HashMap::new();
    reg_of.insert(1, 0);
    let mut steps = Vec::with_capacity(chain_muls);
    for (idx, &value) in chain.iter().enumerate().skip(1) {
        let prev = chain[idx - 1];
        let other = value - prev; // power-tree children are n + ancestor(n)
        let lhs = reg_of[&prev];
        let rhs = *reg_of
            .get(&other)
            .unwrap_or_else(|| panic!("chain element {value} = {prev} + {other}: {other} missing"));
        let dst = idx; // fresh register per chain element
        steps.push(Step::Mul { dst, lhs, rhs });
        reg_of.insert(value, dst);
    }
    Plan {
        power,
        kind: PlanKind::AdditionChain,
        steps,
        n_regs: chain.len(),
        result: chain.len() - 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::mod_pow;

    #[test]
    fn chain_is_valid_addition_chain() {
        for p in 1..=1024u64 {
            let chain = power_tree_chain(p);
            assert_eq!(*chain.first().unwrap(), 1);
            assert_eq!(*chain.last().unwrap(), p);
            for (i, &v) in chain.iter().enumerate().skip(1) {
                // each element is the sum of the previous and some earlier one
                let prev = chain[i - 1];
                let other = v - prev;
                assert!(
                    chain[..i].contains(&other),
                    "p={p}: {v} = {prev} + {other}, {other} not in chain"
                );
            }
        }
    }

    #[test]
    fn known_improvements_over_binary() {
        // classic cases where addition chains beat square-and-multiply.
        // The power tree is near-optimal, not optimal: l(255)=10 and
        // l(1023)=11 exist, but the tree yields 11 and 13 — still well
        // under binary's 14 and 18.
        for (p, binary_muls, chain_max) in [(15u64, 6, 5), (33, 6, 6), (255, 14, 11), (1023, 18, 13)] {
            let b = binary::binary_plan(p, false).multiplies();
            assert_eq!(b, binary_muls, "binary p={p}");
            let c = addition_chain_plan(p).multiplies();
            assert!(c <= chain_max, "chain p={p}: {c} > {chain_max}");
        }
        // strict improvement where it matters
        assert!(addition_chain_plan(255).multiplies() < 14);
        assert!(addition_chain_plan(1023).multiplies() < 18);
    }

    #[test]
    fn evaluates_correctly_exhaustive() {
        const M: u64 = 999_983;
        for p in 1..=1024u64 {
            let plan = addition_chain_plan(p);
            plan.validate().unwrap();
            assert_eq!(plan.eval_mod(3, M).unwrap(), mod_pow(3, p, M), "p={p}");
        }
    }

    #[test]
    fn register_count_stays_small() {
        for p in 1..=4096u64 {
            let plan = addition_chain_plan(p);
            assert!(plan.n_regs <= 20, "p={p}: {} regs", plan.n_regs);
        }
    }

    #[test]
    fn power_one_trivial() {
        let plan = addition_chain_plan(1);
        assert_eq!(plan.multiplies(), 0);
        assert_eq!(plan.eval_mod(42, 997).unwrap(), 42);
    }
}
