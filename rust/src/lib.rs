//! # matexp — heterogeneous highly parallel matrix exponentiation
//!
//! Reproduction of *"Heterogeneous Highly Parallel Implementation of Matrix
//! Exponentiation Using GPU"* (IJDPS vol. 3 no. 2, 2012) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   square-and-multiply launch scheduler ([`plan`]) emitting the typed
//!   kernel IR ([`runtime::KernelOp`]), a pluggable execution layer
//!   ([`runtime::Backend`]) with a buffer-residency arena
//!   ([`runtime::BufferArena`]: zero-copy uploads, recycled launch
//!   outputs, residency counters) replayed by a generic engine
//!   ([`runtime::Engine`]), a serving coordinator with a dynamic batcher
//!   ([`coordinator`]) and a TCP front-end ([`server`]).
//! * **Layer 2/1 (python/compile)** — JAX compute graphs calling the tiled
//!   Pallas matmul kernel, AOT-lowered to HLO text in `artifacts/`.
//! * **Substrates** — a sequential/blocked/threaded CPU linear-algebra
//!   library ([`linalg`], the paper's CPU baseline) and an analytic Tesla
//!   C2050 timing model ([`simulator`], the substitute for the 2012
//!   testbed).
//!
//! Three execution backends ship:
//!
//! * [`runtime::CpuBackend`] — pure Rust; the **default**, needs no
//!   artifacts, no GPU, no external crates. `cargo test` runs the full
//!   suite against it on any machine.
//! * [`runtime::SimBackend`] — the calibrated C2050 timing model, so the
//!   paper's Tables 2–5 reproduce without hardware.
//! * [`runtime::PjrtBackend`] *(cargo feature `xla`)* — AOT HLO artifacts
//!   (`make artifacts`) executed on PJRT with device-resident buffers.
//!
//! …and above them the **heterogeneous device pool** ([`pool`]), the
//! paper's title promise made real: N cpu/sim devices on their own worker
//! threads, a 2D tile partitioner that shards one multiply across all of
//! them (fused `mma{g}` tile launches, host reassembly), and a cost-model
//! splitter that sizes each device's share — falling back to the fastest
//! single device whenever a split would lose.
//!
//! ```text
//!                    ┌──────────── coordinator (batcher, scheduler) ───────────┐
//!                    │                                                         │
//!    Engine<B>  ◀────┤ single-backend path          pool path ├────▶ PoolEngine │
//!        │           └─────────────────────────────────────────────────┬───────┘
//!     KernelOp (typed launch IR: Matmul, SqMul, Mma(g), …)              │
//!        │                                                              │
//!   CpuBackend │ SimBackend │ PjrtBackend              DevicePool: [cpu#0] [sim#1] [sim#2] …
//!        │      (one device, device-resident plans)     tile shards + request stealing
//!   BufferArena (zero-copy upload, recycled outputs,
//!                bytes_copied / buffers_recycled / peak_resident stats)
//! ```
//!
//! The launch vocabulary is **typed end to end**: every backend, the
//! engine and the pool dispatch on [`runtime::KernelOp`] — op name
//! strings exist only at the artifact/wire edge
//! ([`runtime::KernelOp::name`] / [`runtime::KernelOp::parse`]), so
//! adding a kernel is one enum variant, checked by the compiler at every
//! site, instead of string matches scattered across five files. See the
//! op table in [`runtime::op`].
//!
//! # Execution surface
//!
//! Every executor — [`runtime::Engine`], [`pool::PoolEngine`], and the
//! serving [`coordinator::ServiceHandle`] — accepts the same typed
//! [`exec::Submission`] through [`exec::Executor::submit`] and answers
//! with an [`exec::JobHandle`] (`wait` / `try_result` / `cancel`,
//! deadline expiry). On the service, submission is asynchronous: no
//! thread parks per in-flight request, and the TCP wire pipelines many
//! id-tagged requests over one connection.
//!
//! # Caching
//!
//! Behind the surface sits a three-tier caching subsystem ([`cache`])
//! shared by every executor: a **plan cache** (the planner runs once per
//! `(n, power, kind, method)` shape), a per-backend
//! **prepared-executable cache** (`Backend::prepare` runs once per
//! `(op, n)`), and an opt-in **content-addressed result cache** (repeated
//! hot requests answered without touching a device; LRU against a byte
//! budget, never across tolerance buckets). Per-submission control:
//! [`exec::Submission::cache`] with [`cache::CacheControl`]
//! (`Use`/`Bypass`/`Refresh`); per-deployment control:
//! [`config::CacheSettings`] / `--cache-results` / `--cache-budget-mb`.
//! `experiment --ablate-cache` (A6) quantifies each tier.
//!
//! A guided tour of how these layers fit together — module
//! responsibilities, the config → exec → coordinator → pool → runtime →
//! backend map, and end-to-end data-flow walkthroughs — lives in
//! `ARCHITECTURE.md` at the crate root.
//!
//! Quick start (pure Rust, runs as-is):
//!
//! ```
//! use matexp::prelude::*;
//!
//! let mut engine = Engine::cpu(CpuAlgo::Blocked);
//! let a = Matrix::random_spectral(64, 0.99, 42);
//! let resp = engine
//!     .run(Submission::expm(a, 512).plan(Plan::binary(512, true)))
//!     .unwrap();
//! // device-resident discipline: log(N) launches, TWO host crossings
//! assert_eq!(resp.stats.launches, Plan::binary(512, true).launches());
//! assert_eq!((resp.stats.h2d_transfers, resp.stats.d2h_transfers), (1, 1));
//! // …whose bytes are ALL the data path copies (buffer-residency layer)
//! assert_eq!(resp.stats.bytes_copied, 2 * 64 * 64 * 4);
//! assert!(resp.result.is_finite());
//! println!("A^512 in {} launches", resp.stats.launches);
//! ```
//!
//! The **identical submission** served by a multi-device pool
//! (`stats.per_device` breaks the work down):
//!
//! ```
//! use matexp::prelude::*;
//!
//! let mut cfg = MatexpConfig::default();
//! cfg.backend = BackendKind::Pool;
//! cfg.pool.devices = vec![PoolDeviceKind::Sim, PoolDeviceKind::Sim];
//!
//! let a = Matrix::random_spectral(32, 0.99, 42);
//! let single = Engine::cpu(CpuAlgo::Blocked)
//!     .run(Submission::expm(a.clone(), 512))
//!     .unwrap();
//! let mut pool = PoolEngine::from_config(&cfg).unwrap();
//! let pooled = pool.run(Submission::expm(a, 512)).unwrap();
//! assert!(pooled.result.approx_eq(&single.result, 1e-3, 1e-3));
//! assert!(!pooled.stats.per_device.is_empty()); // who did the work
//! ```
//!
//! Migration from the legacy per-discipline entry points (deprecated in
//! 0.3.0, **removed** in 0.4.0):
//!
//! | old entry point | new submission |
//! |---|---|
//! | `engine.expm(&a, &plan)` | `engine.run(Submission::expm(a, n).plan(plan))` |
//! | `engine.expm_packed(&a, n)` | `engine.run(Submission::expm(a, n).method(Method::OursPacked))` |
//! | `engine.expm_naive_roundtrip(&a, n)` | `engine.run(Submission::expm(a, n).method(Method::NaiveGpu))` |
//! | `engine.expm_plan_roundtrip(&a, &plan)` | `engine.run(Submission::expm(a, n).method(Method::PlanRoundtrip).plan(plan))` |
//! | `engine.expm_fused_artifact(&a, n)` | `engine.run(Submission::expm(a, n).method(Method::FusedArtifact))` |
//! | `pool.expm(&a, &plan)` / `pool.expm_packed(&a, n)` | same submissions via `pool.run(..)` |
//! | `service.submit(m, n, method)` | `service.submit_job(Submission::expm(m, n).method(method))?.wait()` |
//!
//! The same code runs on any backend — swap `Engine::cpu(..)` for
//! `Engine::sim()` (predicted 2012 wall-clock in `stats.wall_s`) or, with
//! `--features xla` and artifacts built, `Engine::pjrt(&registry, variant)`.

#![warn(missing_docs)]

pub mod bench;
pub mod cache;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod linalg;
pub mod plan;
pub mod pool;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod store;
pub mod trace;
pub mod util;

/// One-stop imports for examples and downstream users.
pub mod prelude {
    pub use crate::cache::{CacheControl, ResultCache};
    pub use crate::config::{CacheSettings, MatexpConfig, StoreSettings};
    pub use crate::coordinator::{
        request::{ExecStats, ExpmRequest, ExpmResponse, Method},
        service::Service,
    };
    pub use crate::error::{MatexpError, Result};
    pub use crate::exec::{Capabilities, Executor, JobHandle, Priority, Submission};
    pub use crate::linalg::expm::CpuAlgo;
    pub use crate::linalg::matrix::Matrix;
    pub use crate::plan::{Plan, PlanKind, Step};
    pub use crate::pool::{DevicePool, PoolDeviceKind, PoolEngine, TileGrid};
    pub use crate::runtime::{
        artifacts::ArtifactRegistry, AnyBackend, AnyEngine, Backend, BackendKind, BufferArena,
        CpuBackend, CpuEngine, DeviceStats, Engine, KernelOp, ResidencyStats, SimBackend,
        SimEngine, Variant,
    };
    pub use crate::simulator::device::DeviceSpec;
    pub use crate::store::{ArtifactKind, ArtifactStore, Sink, StoreKey};
    pub use crate::trace::TraceId;
}
