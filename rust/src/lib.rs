//! # matexp — heterogeneous highly parallel matrix exponentiation
//!
//! Reproduction of *"Heterogeneous Highly Parallel Implementation of Matrix
//! Exponentiation Using GPU"* (IJDPS vol. 3 no. 2, 2012) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   square-and-multiply launch scheduler ([`plan`]), the device-resident
//!   buffer engine ([`runtime::engine`]), a serving coordinator with a
//!   dynamic batcher ([`coordinator`]) and a TCP front-end ([`server`]).
//! * **Layer 2/1 (python/compile)** — JAX compute graphs calling the tiled
//!   Pallas matmul kernel, AOT-lowered to HLO text in `artifacts/`.
//! * **Substrates** — a sequential/blocked/threaded CPU linear-algebra
//!   library ([`linalg`], the paper's CPU baseline) and an analytic Tesla
//!   C2050 timing model ([`simulator`], the substitute for the 2012
//!   testbed).
//!
//! Quick start (artifacts built by `make artifacts`):
//!
//! ```no_run
//! use matexp::prelude::*;
//!
//! let cfg = MatexpConfig::default();
//! let registry = ArtifactRegistry::discover(&cfg.artifacts_dir).unwrap();
//! let mut engine = Engine::new(&registry, cfg.variant).unwrap();
//! let a = Matrix::random_spectral(64, 0.99, 42);
//! let plan = Plan::binary(512, true);
//! let (pow, stats) = engine.expm(&a, &plan).unwrap();
//! println!("A^512 in {} launches ({} multiplies)", stats.launches, stats.multiplies);
//! # let _ = pow;
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod plan;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod util;

/// One-stop imports for examples and downstream users.
pub mod prelude {
    pub use crate::config::MatexpConfig;
    pub use crate::coordinator::{
        request::{ExecStats, ExpmRequest, ExpmResponse, Method},
        service::Service,
    };
    pub use crate::error::{MatexpError, Result};
    pub use crate::linalg::matrix::Matrix;
    pub use crate::plan::{Plan, PlanKind, Step};
    pub use crate::runtime::{artifacts::ArtifactRegistry, engine::Engine, Variant};
    pub use crate::simulator::device::DeviceSpec;
}
