//! Wire protocol: newline-delimited JSON messages.
//!
//! Encoding/decoding is hand-rolled over [`crate::util::json`] (the
//! offline build has no serde); matrix payloads use the `f32`-array fast
//! path so a 512×512 request doesn't allocate 262k boxed values.

use std::str::FromStr;

use crate::cache::CacheControl;
use crate::coordinator::request::{ExecStats, ExpmResponse, Method};
use crate::error::{MatexpError, Result};
use crate::json_obj;
use crate::linalg::matrix::Matrix;
use crate::util::base64;
use crate::util::json::{write_f32_array, Json};

/// Matrix payload encoding on the wire.
///
/// `Json` is the readable default; `Base64` packs the row-major f32s as
/// little-endian bytes (`"matrix_b64"` / `"result_b64"` fields) — 1/3 the
/// bytes and ~10x the codec speed at n=512, and bit-exact. The server
/// replies in whatever encoding the request used.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Payload {
    /// Readable JSON `f32` arrays — the default.
    #[default]
    Json,
    /// Little-endian f32 bytes, base64-packed (compact and bit-exact).
    Base64,
}

/// Rendering of a `metrics` reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MetricsFormat {
    /// Structured JSON snapshot (the default, and the legacy behavior).
    #[default]
    Json,
    /// Prometheus text exposition ([`crate::trace::prometheus::render`]),
    /// carried on the wire as a JSON string.
    Prometheus,
}

impl MetricsFormat {
    /// Canonical lowercase name (`format` field on the wire).
    pub fn as_str(self) -> &'static str {
        match self {
            MetricsFormat::Json => "json",
            MetricsFormat::Prometheus => "prometheus",
        }
    }
}

/// Cluster-management actions carried by the `cluster` wire op
/// (`{"op":"cluster","action":"drain","addr":"host:port"}`).
///
/// `Join`/`Leave`/`Drain` address a [`crate::cluster::Router`];
/// a member server answers `Status` (and accepts `Drain` against
/// itself) but rejects membership changes — those are router state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterAction {
    /// Add a member (`addr` required) to the router's set.
    Join,
    /// Remove a member (`addr` required) immediately, no drain.
    Leave,
    /// Stop routing new work to a member (`addr` required at the
    /// router, absent when sent to the member itself), wait for its
    /// in-flight work, then detach it.
    Drain,
    /// Report the cluster (or member) status document.
    Status,
    /// Pull hot artifacts. Sent to a member (`addr` absent) it answers
    /// its hottest store artifacts (results/autotune/plans, base64
    /// payloads); sent to a router (`addr` absent too) it aggregates the
    /// members' exports. With `addr` set, the receiver pulls FROM that
    /// peer and installs the artifacts into its own warm tiers — how a
    /// joining member warms itself from the owner member's store.
    Pull,
}

impl ClusterAction {
    /// Canonical lowercase name (`action` field on the wire).
    pub fn as_str(self) -> &'static str {
        match self {
            ClusterAction::Join => "join",
            ClusterAction::Leave => "leave",
            ClusterAction::Drain => "drain",
            ClusterAction::Status => "status",
            ClusterAction::Pull => "pull",
        }
    }
}

impl FromStr for ClusterAction {
    type Err = MatexpError;
    fn from_str(s: &str) -> Result<ClusterAction> {
        match s {
            "join" => Ok(ClusterAction::Join),
            "leave" => Ok(ClusterAction::Leave),
            "drain" => Ok(ClusterAction::Drain),
            "status" => Ok(ClusterAction::Status),
            "pull" => Ok(ClusterAction::Pull),
            other => Err(MatexpError::Service(format!("unknown cluster action {other:?}"))),
        }
    }
}

/// One request line.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    /// Compute `matrix^power`. `matrix` is row-major, length `n*n`.
    ///
    /// `id` is the **client-chosen request id**: when present, the server
    /// pipelines — many `Expm` lines may be in flight on one connection
    /// and each response line echoes its request's id (responses can
    /// arrive out of submission order). When absent (legacy one-shot
    /// peers), the server answers in order before reading further.
    Expm {
        /// Matrix side length.
        n: usize,
        /// The exponent `N`.
        power: u64,
        /// Execution method the server should use.
        method: Method,
        /// Row-major operand, length `n * n`.
        matrix: Vec<f32>,
        /// How `matrix` travels on the wire (the reply mirrors it).
        payload: Payload,
        /// Client-chosen request id (pipelining), if any.
        id: Option<u64>,
        /// Per-request cache directive (absent on the wire = `Use`, the
        /// legacy behavior). The router also reads this to route
        /// `Bypass` traffic least-load instead of by content affinity.
        cache: CacheControl,
    },
    /// Service metrics snapshot, rendered per the requested format
    /// (absent on the wire = JSON, which legacy peers always get).
    Metrics {
        /// Reply rendering: structured JSON or Prometheus text.
        format: MetricsFormat,
    },
    /// Dump the server's recent trace spans as a Chrome trace-event
    /// document (the flight-recorder egress behind `matexp trace`).
    Trace,
    /// Liveness check.
    Ping,
    /// Capability negotiation: the client announces the highest binary
    /// frame version it speaks ([`crate::server::frame::VERSION`] for
    /// this build, 0 for JSON-only). The server answers `ok` with a
    /// `frame` field carrying the version both sides share (the min), or
    /// — on pre-frame servers — an `unknown op` error, which the client
    /// treats as "JSON lines only". Either way the connection stays up.
    Hello {
        /// Highest frame version the client can speak.
        frame_version: u32,
    },
    /// Cluster management (`{"op":"cluster","action":...,"addr":...}`):
    /// membership changes and drains against a router, status/drain
    /// against a member. Replies carry the status document in the ok
    /// reply's `metrics` payload slot.
    Cluster {
        /// What to do.
        action: ClusterAction,
        /// The member address the action targets, where one is needed.
        addr: Option<String>,
    },
}

/// One device's share of a pooled execution, on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireDeviceStats {
    /// Device name (`sim#1`, `cpu#0`).
    pub device: String,
    /// Kernel launches this device performed.
    pub launches: usize,
    /// Matrix multiplies this device performed.
    pub multiplies: usize,
    /// Host→device transfers this device performed.
    pub h2d_transfers: usize,
    /// Device→host transfers this device performed.
    pub d2h_transfers: usize,
    /// Host-edge bytes this device's data path copied.
    pub bytes_copied: u64,
    /// Launch outputs served from recycled arena buffers.
    pub buffers_recycled: u64,
    /// Seconds this device was busy (simulated on timing-model devices).
    pub wall_s: f64,
}

/// Stats subset that crosses the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct WireStats {
    /// Kernel launches of the whole execution.
    pub launches: usize,
    /// Matrix multiplies performed.
    pub multiplies: usize,
    /// Host→device matrix transfers.
    pub h2d_transfers: usize,
    /// Device→host matrix transfers.
    pub d2h_transfers: usize,
    /// Host-edge bytes the data path copied (two edge transfers on the
    /// device-resident disciplines; O(launches·n²) on clone-per-launch).
    pub bytes_copied: u64,
    /// Launch outputs served from recycled arena buffers.
    pub buffers_recycled: u64,
    /// High-water mark of resident device-buffer bytes.
    pub peak_resident_bytes: u64,
    /// Wall-clock seconds (simulated on timing-model backends).
    pub wall_s: f64,
    /// Microseconds queued before a worker picked the request up.
    pub queue_us: u64,
    /// Microseconds in strategy/plan selection.
    pub plan_us: u64,
    /// Microseconds in cold `prepare` calls (warm cache hits bill zero).
    pub prepare_us: u64,
    /// Microseconds inside kernel launches, summed over the launch chain.
    pub launch_us: u64,
    /// Microseconds spent on the server's wire edge for this request.
    pub wire_us: u64,
    /// Per-device breakdown (empty off the pool backend).
    pub per_device: Vec<WireDeviceStats>,
}

impl From<ExecStats> for WireStats {
    fn from(s: ExecStats) -> Self {
        WireStats {
            launches: s.launches,
            multiplies: s.multiplies,
            h2d_transfers: s.h2d_transfers,
            d2h_transfers: s.d2h_transfers,
            bytes_copied: s.bytes_copied,
            buffers_recycled: s.buffers_recycled,
            peak_resident_bytes: s.peak_resident_bytes,
            wall_s: s.wall_s,
            queue_us: s.queue_us,
            plan_us: s.plan_us,
            prepare_us: s.prepare_us,
            launch_us: s.launch_us,
            wire_us: s.wire_us,
            per_device: s
                .per_device
                .iter()
                .map(|d| WireDeviceStats {
                    device: d.device.clone(),
                    launches: d.launches,
                    multiplies: d.multiplies,
                    h2d_transfers: d.h2d_transfers,
                    d2h_transfers: d.d2h_transfers,
                    bytes_copied: d.bytes_copied,
                    buffers_recycled: d.buffers_recycled,
                    wall_s: d.wall_s,
                })
                .collect(),
        }
    }
}

impl WireStats {
    /// Serialize into the response line's `stats` object.
    pub fn to_json(&self) -> Json {
        let per_device: Vec<Json> = self
            .per_device
            .iter()
            .map(|d| {
                json_obj![
                    ("device", d.device.as_str()),
                    ("launches", d.launches),
                    ("multiplies", d.multiplies),
                    ("h2d_transfers", d.h2d_transfers),
                    ("d2h_transfers", d.d2h_transfers),
                    ("bytes_copied", d.bytes_copied),
                    ("buffers_recycled", d.buffers_recycled),
                    ("wall_s", d.wall_s),
                ]
            })
            .collect();
        json_obj![
            ("launches", self.launches),
            ("multiplies", self.multiplies),
            ("h2d_transfers", self.h2d_transfers),
            ("d2h_transfers", self.d2h_transfers),
            ("bytes_copied", self.bytes_copied),
            ("buffers_recycled", self.buffers_recycled),
            ("peak_resident_bytes", self.peak_resident_bytes),
            ("wall_s", self.wall_s),
            ("queue_us", self.queue_us),
            ("plan_us", self.plan_us),
            ("prepare_us", self.prepare_us),
            ("launch_us", self.launch_us),
            ("wire_us", self.wire_us),
            ("per_device", Json::Arr(per_device)),
        ]
    }

    /// Decode a response line's `stats` object (legacy-tolerant: fields
    /// newer peers add decode to zero/empty).
    pub fn from_json(v: &Json) -> Result<WireStats> {
        let want = |name: &str| -> Result<&Json> {
            v.get(name)
                .ok_or_else(|| MatexpError::Service(format!("stats missing {name:?}")))
        };
        let per_device = match v.get("per_device").and_then(Json::as_arr) {
            Some(items) => items
                .iter()
                .map(|d| WireDeviceStats {
                    device: d
                        .get("device")
                        .and_then(Json::as_str)
                        .unwrap_or("?")
                        .to_string(),
                    launches: d.get("launches").and_then(Json::as_usize).unwrap_or(0),
                    multiplies: d.get("multiplies").and_then(Json::as_usize).unwrap_or(0),
                    h2d_transfers: d
                        .get("h2d_transfers")
                        .and_then(Json::as_usize)
                        .unwrap_or(0),
                    d2h_transfers: d
                        .get("d2h_transfers")
                        .and_then(Json::as_usize)
                        .unwrap_or(0),
                    bytes_copied: d.get("bytes_copied").and_then(Json::as_u64).unwrap_or(0),
                    buffers_recycled: d
                        .get("buffers_recycled")
                        .and_then(Json::as_u64)
                        .unwrap_or(0),
                    wall_s: d.get("wall_s").and_then(Json::as_f64).unwrap_or(0.0),
                })
                .collect(),
            None => Vec::new(),
        };
        Ok(WireStats {
            launches: want("launches")?.as_usize().unwrap_or(0),
            multiplies: want("multiplies")?.as_usize().unwrap_or(0),
            h2d_transfers: want("h2d_transfers")?.as_usize().unwrap_or(0),
            d2h_transfers: want("d2h_transfers")?.as_usize().unwrap_or(0),
            // legacy stats blocks without the residency fields decode to 0
            bytes_copied: v.get("bytes_copied").and_then(Json::as_u64).unwrap_or(0),
            buffers_recycled: v.get("buffers_recycled").and_then(Json::as_u64).unwrap_or(0),
            peak_resident_bytes: v
                .get("peak_resident_bytes")
                .and_then(Json::as_u64)
                .unwrap_or(0),
            wall_s: want("wall_s")?.as_f64().unwrap_or(0.0),
            // legacy stats blocks without the stage breakdown decode to 0
            queue_us: v.get("queue_us").and_then(Json::as_u64).unwrap_or(0),
            plan_us: v.get("plan_us").and_then(Json::as_u64).unwrap_or(0),
            prepare_us: v.get("prepare_us").and_then(Json::as_u64).unwrap_or(0),
            launch_us: v.get("launch_us").and_then(Json::as_u64).unwrap_or(0),
            wire_us: v.get("wire_us").and_then(Json::as_u64).unwrap_or(0),
            per_device,
        })
    }
}

/// One response line.
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    /// A successful reply (`"status":"ok"`); which payload fields are
    /// present depends on the request (`expm` / `metrics` / `ping`).
    Ok {
        /// Row-major result matrix, for `expm` replies.
        result: Option<Vec<f32>>,
        /// Execution stats, for `expm` replies.
        stats: Option<WireStats>,
        /// Metrics snapshot JSON, for `metrics` replies.
        metrics: Option<Json>,
        /// How `result` is encoded on the wire (mirrors the request).
        payload: Payload,
        /// Echo of the request's client-chosen id (pipelined requests
        /// only; legacy one-shot responses carry none).
        id: Option<u64>,
        /// Negotiated binary frame version, on `hello` replies only
        /// (`None` everywhere else, and on replies from pre-frame
        /// servers, which never saw a `hello` they understood).
        frame: Option<u32>,
    },
    /// A failed reply (`"status":"error"`).
    Error {
        /// Human-readable error text.
        message: String,
        /// Machine-readable error class (`admission` = fix your request,
        /// `deadline` = retry with a looser deadline, `config`,
        /// `service` = the service's problem), so remote clients keep
        /// the typed distinction [`MatexpError`] draws.
        kind: String,
        /// Echo of the request's client-chosen id, when it had one.
        id: Option<u64>,
    },
}

impl WireRequest {
    /// Encode as one JSON line (no trailing newline). Errors if a JSON
    /// payload contains NaN/±Inf (not representable in JSON — use the
    /// base64 payload, which is bit-exact for any value).
    pub fn encode(&self) -> Result<String> {
        Ok(match self {
            WireRequest::Ping => r#"{"op":"ping"}"#.to_string(),
            // JSON format encodes exactly as the legacy line, so old
            // servers keep answering plain metrics requests
            WireRequest::Metrics { format: MetricsFormat::Json } => {
                r#"{"op":"metrics"}"#.to_string()
            }
            WireRequest::Metrics { format } => {
                format!(r#"{{"op":"metrics","format":"{}"}}"#, format.as_str())
            }
            WireRequest::Trace => r#"{"op":"trace"}"#.to_string(),
            WireRequest::Hello { frame_version } => {
                format!(r#"{{"op":"hello","frame":{frame_version}}}"#)
            }
            WireRequest::Cluster { action, addr } => {
                let mut s = format!(r#"{{"op":"cluster","action":"{}""#, action.as_str());
                if let Some(addr) = addr {
                    s.push_str(&format!(r#","addr":{}"#, Json::from(addr.as_str())));
                }
                s.push('}');
                s
            }
            WireRequest::Expm { n, power, method, matrix, payload, id, cache } => {
                let mut s = format!(
                    r#"{{"op":"expm","n":{n},"power":{power},"method":"{}","#,
                    method.as_str()
                );
                if let Some(id) = id {
                    s.push_str(&format!(r#""id":{id},"#));
                }
                // `use` is the implicit legacy default: emitting nothing
                // keeps these lines byte-compatible with older peers
                if *cache != CacheControl::Use {
                    s.push_str(&format!(r#""cache":"{}","#, cache.as_str()));
                }
                match payload {
                    Payload::Json => {
                        s.push_str("\"matrix\":");
                        write_f32_array(matrix, &mut s)?;
                    }
                    Payload::Base64 => {
                        s.push_str("\"matrix_b64\":\"");
                        s.push_str(&base64::encode_f32(matrix));
                        s.push('"');
                    }
                }
                s.push('}');
                s
            }
        })
    }

    /// Decode one JSON line.
    pub fn decode(line: &str) -> Result<WireRequest> {
        let v = Json::parse(line)?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| MatexpError::Service("request missing \"op\"".into()))?;
        match op {
            "ping" => Ok(WireRequest::Ping),
            "metrics" => Ok(WireRequest::Metrics {
                // an absent (or unrecognized) format is the legacy JSON
                format: match v.get("format").and_then(Json::as_str) {
                    Some("prometheus") => MetricsFormat::Prometheus,
                    _ => MetricsFormat::Json,
                },
            }),
            "trace" => Ok(WireRequest::Trace),
            "hello" => Ok(WireRequest::Hello {
                // a hello without a frame field is a JSON-only peer
                frame_version: v.get("frame").and_then(Json::as_u64).unwrap_or(0) as u32,
            }),
            "expm" => {
                let n = v
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| MatexpError::Service("expm: bad \"n\"".into()))?;
                let power = v
                    .get("power")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| MatexpError::Service("expm: bad \"power\"".into()))?;
                let method = Method::from_str(
                    v.get("method")
                        .and_then(Json::as_str)
                        .ok_or_else(|| MatexpError::Service("expm: bad \"method\"".into()))?,
                )?;
                let (matrix, payload) = if let Some(b64) = v.get("matrix_b64") {
                    let text = b64.as_str().ok_or_else(|| {
                        MatexpError::Service("expm: \"matrix_b64\" not a string".into())
                    })?;
                    let m = base64::decode_f32(text).ok_or_else(|| {
                        MatexpError::Service("expm: bad base64 matrix".into())
                    })?;
                    (m, Payload::Base64)
                } else {
                    let m = v
                        .get("matrix")
                        .and_then(Json::as_f32_vec)
                        .ok_or_else(|| MatexpError::Service("expm: bad \"matrix\"".into()))?;
                    (m, Payload::Json)
                };
                let id = v.get("id").and_then(Json::as_u64);
                // tolerant like the metrics format: an unrecognized
                // directive degrades to the legacy `use`
                let cache = match v.get("cache").and_then(Json::as_str) {
                    Some("bypass") => CacheControl::Bypass,
                    Some("refresh") => CacheControl::Refresh,
                    _ => CacheControl::Use,
                };
                Ok(WireRequest::Expm { n, power, method, matrix, payload, id, cache })
            }
            "cluster" => {
                let action = ClusterAction::from_str(
                    v.get("action")
                        .and_then(Json::as_str)
                        .ok_or_else(|| MatexpError::Service("cluster: bad \"action\"".into()))?,
                )?;
                let addr = v.get("addr").and_then(Json::as_str).map(str::to_string);
                Ok(WireRequest::Cluster { action, addr })
            }
            other => Err(MatexpError::Service(format!("unknown op {other:?}"))),
        }
    }

    /// Decode the matrix payload of an `Expm` request.
    pub fn matrix(&self) -> Result<Matrix> {
        match self {
            WireRequest::Expm { n, matrix, .. } => Matrix::from_vec(*n, matrix.clone()),
            _ => Err(MatexpError::Service("not an expm request".into())),
        }
    }
}

impl WireResponse {
    /// Build the reply line for a served `expm` request.
    pub fn from_expm(resp: &ExpmResponse, payload: Payload) -> WireResponse {
        WireResponse::Ok {
            result: Some(resp.result.data().to_vec()),
            stats: Some(resp.stats.clone().into()),
            metrics: None,
            payload,
            id: None,
            frame: None,
        }
    }

    /// A generic service-kind error line.
    pub fn error(msg: impl Into<String>) -> WireResponse {
        WireResponse::Error { message: msg.into(), kind: "service".into(), id: None }
    }

    /// Typed error → wire error, preserving the error class.
    pub fn from_error(e: &MatexpError) -> WireResponse {
        WireResponse::Error { message: e.to_string(), kind: error_kind(e).into(), id: None }
    }

    /// The `ok` reply to a `hello`: echoes the frame version both sides
    /// share (0 = JSON lines only).
    pub fn hello_ack(frame_version: u32) -> WireResponse {
        WireResponse::Ok {
            result: None,
            stats: None,
            metrics: None,
            payload: Payload::Json,
            id: None,
            frame: Some(frame_version),
        }
    }

    /// Wire error → typed error (the client side of [`Self::from_error`]).
    pub fn to_typed_error(kind: &str, message: String) -> MatexpError {
        match kind {
            "admission" => MatexpError::Admission(message),
            "config" => MatexpError::Config(message),
            "deadline" => MatexpError::Deadline(message),
            _ => MatexpError::Service(message),
        }
    }

    /// The empty-ok reply to a `ping`.
    pub fn pong() -> WireResponse {
        WireResponse::Ok {
            result: None,
            stats: None,
            metrics: None,
            payload: Payload::Json,
            id: None,
            frame: None,
        }
    }

    /// The response's echoed request id, whichever variant it is.
    pub fn id(&self) -> Option<u64> {
        match self {
            WireResponse::Ok { id, .. } | WireResponse::Error { id, .. } => *id,
        }
    }

    /// Stamp the echoed request id (builder-style).
    pub fn with_id(mut self, new_id: Option<u64>) -> WireResponse {
        match &mut self {
            WireResponse::Ok { id, .. } | WireResponse::Error { id, .. } => *id = new_id,
        }
        self
    }

    /// Encode as one JSON line (no trailing newline). Errors if a JSON
    /// result payload contains NaN/±Inf (e.g. an overflowed power) —
    /// callers report the typed error instead of emitting a corrupted
    /// array; the base64 payload carries non-finite values bit-exactly.
    pub fn encode(&self) -> Result<String> {
        Ok(match self {
            WireResponse::Error { message, kind, id } => {
                let mut obj = json_obj![
                    ("status", "error"),
                    ("kind", kind.as_str()),
                    ("message", message.as_str())
                ];
                if let (Some(id), Json::Obj(fields)) = (id, &mut obj) {
                    fields.insert("id".to_string(), Json::from(*id));
                }
                obj.to_string()
            }
            WireResponse::Ok { result, stats, metrics, payload, id, frame } => {
                let mut s = String::from(r#"{"status":"ok""#);
                if let Some(id) = id {
                    s.push_str(&format!(r#","id":{id}"#));
                }
                if let Some(v) = frame {
                    s.push_str(&format!(r#","frame":{v}"#));
                }
                if let Some(data) = result {
                    match payload {
                        Payload::Json => {
                            s.push_str(r#","result":"#);
                            write_f32_array(data, &mut s)?;
                        }
                        Payload::Base64 => {
                            s.push_str(r#","result_b64":""#);
                            s.push_str(&base64::encode_f32(data));
                            s.push('"');
                        }
                    }
                }
                if let Some(st) = stats {
                    s.push_str(r#","stats":"#);
                    s.push_str(&st.to_json().to_string());
                }
                if let Some(m) = metrics {
                    s.push_str(r#","metrics":"#);
                    s.push_str(&m.to_string());
                }
                s.push('}');
                s
            }
        })
    }

    /// Decode one JSON line.
    pub fn decode(line: &str) -> Result<WireResponse> {
        let v = Json::parse(line)?;
        match v.get("status").and_then(Json::as_str) {
            Some("ok") => {
                let (result, payload) = if let Some(b64) = v.get("result_b64") {
                    let text = b64.as_str().ok_or_else(|| {
                        MatexpError::Service("\"result_b64\" not a string".into())
                    })?;
                    let data = base64::decode_f32(text).ok_or_else(|| {
                        MatexpError::Service("bad base64 result".into())
                    })?;
                    (Some(data), Payload::Base64)
                } else {
                    (v.get("result").and_then(Json::as_f32_vec), Payload::Json)
                };
                Ok(WireResponse::Ok {
                    result,
                    stats: match v.get("stats") {
                        Some(s) => Some(WireStats::from_json(s)?),
                        None => None,
                    },
                    metrics: v.get("metrics").cloned(),
                    payload,
                    id: v.get("id").and_then(Json::as_u64),
                    frame: v.get("frame").and_then(Json::as_u64).map(|v| v as u32),
                })
            }
            Some("error") => Ok(WireResponse::Error {
                message: v
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("<no message>")
                    .to_string(),
                kind: v
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("service")
                    .to_string(),
                id: v.get("id").and_then(Json::as_u64),
            }),
            _ => Err(MatexpError::Service("response missing \"status\"".into())),
        }
    }
}

/// Typed error → wire error class, shared by the JSON line codec
/// ([`WireResponse::from_error`]) and the binary frame codec
/// ([`crate::server::frame::Frame::from_error`]): `admission` = fix your
/// request, `deadline` = retry with a looser deadline, `config`,
/// `service` = the service's problem.
pub fn error_kind(e: &MatexpError) -> &'static str {
    match e {
        MatexpError::Admission(_) => "admission",
        MatexpError::Config(_) => "config",
        MatexpError::Deadline(_) => "deadline",
        _ => "service",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expm_roundtrip() {
        let r = WireRequest::Expm {
            n: 2,
            power: 8,
            method: Method::Ours,
            matrix: vec![1.0; 4],
            payload: Payload::Json,
            id: None,
            cache: CacheControl::Use,
        };
        let s = r.encode().unwrap();
        assert!(s.contains("\"op\":\"expm\""), "{s}");
        assert_eq!(WireRequest::decode(&s).unwrap(), r);
    }

    #[test]
    fn expm_base64_roundtrip() {
        let r = WireRequest::Expm {
            n: 2,
            power: 8,
            method: Method::Ours,
            matrix: vec![0.1, -2.5, 3.0, f32::MIN_POSITIVE],
            payload: Payload::Base64,
            id: None,
            cache: CacheControl::Use,
        };
        let s = r.encode().unwrap();
        assert!(s.contains("matrix_b64"), "{s}");
        assert!(!s.contains("\"matrix\""), "{s}");
        assert_eq!(WireRequest::decode(&s).unwrap(), r);
        // payload is bit-exact through base64
        let resp = WireResponse::Ok {
            result: Some(vec![0.1, f32::MAX, -0.0]),
            stats: None,
            metrics: None,
            payload: Payload::Base64,
            id: None,
            frame: None,
        };
        assert_eq!(WireResponse::decode(&resp.encode().unwrap()).unwrap(), resp);
    }

    #[test]
    fn non_finite_json_payload_is_a_typed_error_but_base64_is_exact() {
        let make = |payload| WireResponse::Ok {
            result: Some(vec![1.0, f32::NAN, f32::INFINITY]),
            stats: None,
            metrics: None,
            payload,
            id: None,
            frame: None,
        };
        // JSON has no NaN/Inf: encoding must refuse, not corrupt
        assert!(make(Payload::Json).encode().is_err());
        // base64 carries the same values bit-exactly
        let resp = make(Payload::Base64);
        match WireResponse::decode(&resp.encode().unwrap()).unwrap() {
            WireResponse::Ok { result: Some(data), .. } => {
                assert_eq!(data[0], 1.0);
                assert!(data[1].is_nan());
                assert_eq!(data[2], f32::INFINITY);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ping_metrics_roundtrip() {
        for r in [
            WireRequest::Ping,
            WireRequest::Metrics { format: MetricsFormat::Json },
            WireRequest::Metrics { format: MetricsFormat::Prometheus },
            WireRequest::Trace,
            WireRequest::Cluster { action: ClusterAction::Status, addr: None },
            WireRequest::Cluster { action: ClusterAction::Drain, addr: Some("h:1".into()) },
        ] {
            assert_eq!(WireRequest::decode(&r.encode().unwrap()).unwrap(), r);
        }
        // the JSON-format request is byte-identical to the legacy line
        let line = WireRequest::Metrics { format: MetricsFormat::Json }.encode().unwrap();
        assert_eq!(line, r#"{"op":"metrics"}"#);
        // an unrecognized format degrades to JSON instead of erroring
        match WireRequest::decode(r#"{"op":"metrics","format":"yaml"}"#).unwrap() {
            WireRequest::Metrics { format } => assert_eq!(format, MetricsFormat::Json),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn cluster_op_roundtrips_every_action() {
        for action in
            [
                ClusterAction::Join,
                ClusterAction::Leave,
                ClusterAction::Drain,
                ClusterAction::Status,
                ClusterAction::Pull,
            ]
        {
            for addr in [None, Some("10.0.0.7:7070".to_string())] {
                let r = WireRequest::Cluster { action, addr: addr.clone() };
                let line = r.encode().unwrap();
                assert!(line.contains(r#""op":"cluster""#), "{line}");
                assert_eq!(line.contains("addr"), addr.is_some(), "{line}");
                assert_eq!(WireRequest::decode(&line).unwrap(), r);
            }
        }
        // an unknown action is a typed decode error, like an unknown op
        assert!(WireRequest::decode(r#"{"op":"cluster","action":"explode"}"#).is_err());
        assert!(WireRequest::decode(r#"{"op":"cluster"}"#).is_err());
    }

    #[test]
    fn cache_directive_roundtrips_and_defaults_to_use() {
        let mut r = WireRequest::Expm {
            n: 2,
            power: 4,
            method: Method::Ours,
            matrix: vec![1.0; 4],
            payload: Payload::Json,
            id: Some(3),
            cache: CacheControl::Bypass,
        };
        let line = r.encode().unwrap();
        assert!(line.contains(r#""cache":"bypass""#), "{line}");
        assert_eq!(WireRequest::decode(&line).unwrap(), r);
        if let WireRequest::Expm { cache, .. } = &mut r {
            *cache = CacheControl::Refresh;
        }
        let line = r.encode().unwrap();
        assert!(line.contains(r#""cache":"refresh""#), "{line}");
        assert_eq!(WireRequest::decode(&line).unwrap(), r);
        // the default `use` is implicit: absent on the wire, so encoded
        // lines stay byte-compatible with pre-cluster peers...
        if let WireRequest::Expm { cache, .. } = &mut r {
            *cache = CacheControl::Use;
        }
        assert!(!r.encode().unwrap().contains("cache"), "{:?}", r.encode());
        // ...and absent (or unrecognized) directives decode to `use`
        for line in [
            r#"{"op":"expm","n":2,"power":4,"method":"ours","matrix":[1,1,1,1]}"#,
            r#"{"op":"expm","n":2,"power":4,"method":"ours","cache":"warp","matrix":[1,1,1,1]}"#,
        ] {
            match WireRequest::decode(line).unwrap() {
                WireRequest::Expm { cache, .. } => assert_eq!(cache, CacheControl::Use),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn hello_negotiation_roundtrips() {
        let r = WireRequest::Hello { frame_version: 1 };
        let line = r.encode().unwrap();
        assert!(line.contains(r#""op":"hello""#), "{line}");
        assert_eq!(WireRequest::decode(&line).unwrap(), r);
        // a hello without the frame field decodes as a JSON-only peer
        match WireRequest::decode(r#"{"op":"hello"}"#).unwrap() {
            WireRequest::Hello { frame_version } => assert_eq!(frame_version, 0),
            other => panic!("{other:?}"),
        }
        // the ack carries the negotiated version; plain oks carry none
        let ack = WireResponse::hello_ack(1);
        let line = ack.encode().unwrap();
        assert!(line.contains(r#""frame":1"#), "{line}");
        match WireResponse::decode(&line).unwrap() {
            WireResponse::Ok { frame, .. } => assert_eq!(frame, Some(1)),
            other => panic!("{other:?}"),
        }
        assert!(!WireResponse::pong().encode().unwrap().contains("frame"));
    }

    #[test]
    fn response_roundtrip() {
        let resp = WireResponse::Ok {
            result: Some(vec![1.0, 2.0]),
            stats: Some(WireStats {
                launches: 3,
                multiplies: 4,
                h2d_transfers: 1,
                d2h_transfers: 1,
                bytes_copied: 2048,
                buffers_recycled: 7,
                peak_resident_bytes: 4096,
                wall_s: 0.5,
                queue_us: 120,
                plan_us: 8,
                prepare_us: 300,
                launch_us: 450,
                wire_us: 25,
                per_device: Vec::new(),
            }),
            metrics: None,
            payload: Payload::Json,
            id: None,
            frame: None,
        };
        let line = resp.encode().unwrap();
        assert!(line.contains("bytes_copied"), "{line}");
        assert!(line.contains("peak_resident_bytes"), "{line}");
        assert_eq!(WireResponse::decode(&line).unwrap(), resp);
    }

    #[test]
    fn per_device_stats_roundtrip() {
        let resp = WireResponse::Ok {
            result: None,
            stats: Some(WireStats {
                launches: 8,
                multiplies: 16,
                h2d_transfers: 12,
                d2h_transfers: 4,
                bytes_copied: 65536,
                buffers_recycled: 12,
                peak_resident_bytes: 1 << 20,
                wall_s: 0.25,
                queue_us: 0,
                plan_us: 4,
                prepare_us: 0,
                launch_us: 900,
                wire_us: 10,
                per_device: vec![
                    WireDeviceStats {
                        device: "sim#0".into(),
                        launches: 5,
                        multiplies: 10,
                        h2d_transfers: 7,
                        d2h_transfers: 2,
                        bytes_copied: 40960,
                        buffers_recycled: 8,
                        wall_s: 0.25,
                    },
                    WireDeviceStats {
                        device: "cpu#1".into(),
                        launches: 3,
                        multiplies: 6,
                        h2d_transfers: 5,
                        d2h_transfers: 2,
                        bytes_copied: 24576,
                        buffers_recycled: 4,
                        wall_s: 0.1,
                    },
                ],
            }),
            metrics: None,
            payload: Payload::Json,
            id: None,
            frame: None,
        };
        let line = resp.encode().unwrap();
        assert!(line.contains("per_device"), "{line}");
        assert!(line.contains("sim#0"), "{line}");
        assert_eq!(WireResponse::decode(&line).unwrap(), resp);
        // stats blocks without the newer fields decode to an empty
        // breakdown and zeroed residency counters (legacy peers)
        let legacy = r#"{"launches":1,"multiplies":1,"h2d_transfers":1,"d2h_transfers":1,"wall_s":0.1}"#;
        let stats = WireStats::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert!(stats.per_device.is_empty());
        assert_eq!(stats.bytes_copied, 0);
        assert_eq!(stats.peak_resident_bytes, 0);
        // ...and without the stage breakdown it decodes to zeros too
        assert_eq!(stats.queue_us, 0);
        assert_eq!(stats.launch_us, 0);
        assert_eq!(stats.wire_us, 0);
    }

    #[test]
    fn bad_matrix_length_rejected() {
        let r = WireRequest::Expm {
            n: 3,
            power: 2,
            method: Method::Ours,
            matrix: vec![0.0; 4],
            payload: Payload::Json,
            id: None,
            cache: CacheControl::Use,
        };
        assert!(r.matrix().is_err());
    }

    #[test]
    fn error_serializes_with_status_tag() {
        let s = WireResponse::error("nope").encode().unwrap();
        assert!(s.contains("\"status\":\"error\""), "{s}");
        match WireResponse::decode(&s).unwrap() {
            WireResponse::Error { message, kind, id } => {
                assert_eq!(message, "nope");
                assert_eq!(kind, "service");
                assert_eq!(id, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn admission_errors_keep_their_kind_across_the_wire() {
        let e = MatexpError::Admission("matrix too big".into());
        let s = WireResponse::from_error(&e).encode().unwrap();
        assert!(s.contains("\"kind\":\"admission\""), "{s}");
        match WireResponse::decode(&s).unwrap() {
            WireResponse::Error { message, kind, .. } => {
                let typed = WireResponse::to_typed_error(&kind, message);
                assert!(matches!(typed, MatexpError::Admission(_)), "{typed:?}");
            }
            other => panic!("{other:?}"),
        }
        // legacy error lines without a kind stay service errors
        match WireResponse::decode(r#"{"status":"error","message":"x"}"#).unwrap() {
            WireResponse::Error { kind, .. } => assert_eq!(kind, "service"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_requests_rejected() {
        for bad in [
            "{}",
            r#"{"op":"warp"}"#,
            r#"{"op":"expm","n":"x","power":1,"method":"ours","matrix":[]}"#,
            "not json",
        ] {
            assert!(WireRequest::decode(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn pipelined_ids_roundtrip_and_legacy_lines_still_decode() {
        // request id survives encode/decode
        let r = WireRequest::Expm {
            n: 2,
            power: 4,
            method: Method::Ours,
            matrix: vec![1.0; 4],
            payload: Payload::Json,
            id: Some(41),
            cache: CacheControl::Use,
        };
        let line = r.encode().unwrap();
        assert!(line.contains(r#""id":41"#), "{line}");
        assert_eq!(WireRequest::decode(&line).unwrap(), r);

        // response ids survive both variants
        let ok = WireResponse::pong().with_id(Some(7));
        assert_eq!(ok.id(), Some(7));
        let decoded = WireResponse::decode(&ok.encode().unwrap()).unwrap();
        assert_eq!(decoded.id(), Some(7));
        let err = WireResponse::error("nope").with_id(Some(9));
        let decoded = WireResponse::decode(&err.encode().unwrap()).unwrap();
        assert_eq!(decoded.id(), Some(9));

        // legacy one-shot lines (no id anywhere) decode to id: None
        let legacy_req = r#"{"op":"expm","n":2,"power":4,"method":"ours","matrix":[1,1,1,1]}"#;
        match WireRequest::decode(legacy_req).unwrap() {
            WireRequest::Expm { id, .. } => assert_eq!(id, None),
            other => panic!("{other:?}"),
        }
        let legacy_resp = r#"{"status":"ok"}"#;
        assert_eq!(WireResponse::decode(legacy_resp).unwrap().id(), None);
        // and encoding without an id emits no id field at all
        let plain = WireResponse::pong().encode().unwrap();
        assert!(!plain.contains("\"id\""), "{plain}");
    }

    #[test]
    fn deadline_errors_keep_their_kind_across_the_wire() {
        let e = MatexpError::Deadline("job 3 missed its deadline".into());
        let s = WireResponse::from_error(&e).encode().unwrap();
        assert!(s.contains("\"kind\":\"deadline\""), "{s}");
        match WireResponse::decode(&s).unwrap() {
            WireResponse::Error { message, kind, .. } => {
                let typed = WireResponse::to_typed_error(&kind, message);
                assert!(matches!(typed, MatexpError::Deadline(_)), "{typed:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn encoded_lines_are_single_line() {
        let r = WireRequest::Expm {
            n: 2,
            power: 3,
            method: Method::NaiveGpu,
            matrix: vec![0.5; 4],
            payload: Payload::Base64,
            id: None,
            cache: CacheControl::Use,
        };
        assert!(!r.encode().unwrap().contains('\n'));
        assert!(!WireResponse::pong().encode().unwrap().contains('\n'));
    }
}
