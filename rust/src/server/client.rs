//! Blocking client for the JSON-lines protocol, with pipelining: `submit`
//! writes a request line tagged with a client-chosen id and returns a
//! ticket immediately; `wait` resolves tickets in ANY order, stashing
//! whatever other replies arrive in between. One connection carries many
//! in-flight requests — the wire mirror of
//! [`crate::exec::JobHandle`]'s submit/wait split.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::server::proto::{Payload, WireRequest, WireResponse, WireStats};
use crate::util::json::Json;

/// Blocking TCP client.
pub struct MatexpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Matrix payload encoding for requests (server mirrors it back).
    payload: Payload,
    /// Next client-chosen request id for pipelined submissions.
    next_id: u64,
    /// Replies that arrived while waiting on a different ticket.
    pending: HashMap<u64, WireResponse>,
    /// Tickets already resolved — a second `wait` on one must error, not
    /// block forever on a reply that will never come again. Bounded: ids
    /// below `resolved_floor` are all resolved (ids are assigned as a
    /// strictly increasing counter), so the set holds only the
    /// out-of-order frontier and is pruned as the floor advances.
    resolved: HashSet<u64>,
    resolved_floor: u64,
}

/// Ticket for one in-flight pipelined request (resolve with
/// [`MatexpClient::wait`], in any order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PendingExpm {
    id: u64,
    n: usize,
}

impl PendingExpm {
    /// The client-chosen request id on the wire.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl MatexpClient {
    /// Connect to a `matexp serve` endpoint (`host:port`).
    pub fn connect(addr: &str) -> Result<MatexpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // request lines must not sit in Nagle's buffer
        let reader = BufReader::new(stream.try_clone()?);
        Ok(MatexpClient {
            reader,
            writer: stream,
            payload: Payload::Json,
            next_id: 1,
            pending: HashMap::new(),
            resolved: HashSet::new(),
            resolved_floor: 1,
        })
    }

    /// Use the compact base64 payload encoding (bit-exact, 1/3 the wire
    /// bytes, ~10x the codec speed for large matrices).
    pub fn with_base64(mut self) -> MatexpClient {
        self.payload = Payload::Base64;
        self
    }

    fn send(&mut self, req: &WireRequest) -> Result<()> {
        let mut line = req.encode()?.into_bytes();
        line.push(b'\n');
        self.writer.write_all(&line)?;
        Ok(())
    }

    fn read_response(&mut self) -> Result<WireResponse> {
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        if buf.is_empty() {
            return Err(MatexpError::Service("server closed the connection".into()));
        }
        WireResponse::decode(buf.trim_end())
    }

    /// Read until a response WITHOUT an id arrives (the reply to a legacy
    /// one-shot request), stashing any pipelined replies that land first.
    fn recv_unidentified(&mut self) -> Result<WireResponse> {
        loop {
            let resp = self.read_response()?;
            match resp.id() {
                Some(rid) => {
                    self.pending.insert(rid, resp);
                }
                None => return Ok(resp),
            }
        }
    }

    fn roundtrip(&mut self, req: &WireRequest) -> Result<WireResponse> {
        self.send(req)?;
        self.recv_unidentified()
    }

    /// Submit `matrix^power` without waiting: the request is written with
    /// a client-chosen id and a ticket comes back immediately. Resolve it
    /// with [`Self::wait`] — in any order relative to other tickets.
    pub fn submit(&mut self, matrix: &Matrix, power: u64, method: Method) -> Result<PendingExpm> {
        let id = self.next_id;
        let req = WireRequest::Expm {
            n: matrix.n(),
            power,
            method,
            matrix: matrix.data().to_vec(),
            payload: self.payload,
            id: Some(id),
        };
        // consume the id only once the line is actually on the wire: an
        // encode failure (non-finite JSON payload) must not burn an id
        // that would then sit below the resolved-floor watermark forever
        self.send(&req)?;
        self.next_id += 1;
        Ok(PendingExpm { id, n: matrix.n() })
    }

    /// Resolve one ticket: returns its result as soon as its reply line
    /// arrives, buffering replies to other in-flight tickets meanwhile.
    /// A ticket resolves once; waiting on it again is a typed error.
    pub fn wait(&mut self, job: &PendingExpm) -> Result<(Matrix, WireStats)> {
        if job.id < self.resolved_floor || self.resolved.contains(&job.id) {
            return Err(MatexpError::Service(format!(
                "ticket {} already resolved",
                job.id
            )));
        }
        loop {
            if let Some(resp) = self.pending.remove(&job.id) {
                self.mark_resolved(job.id);
                return Self::expm_payload(resp, job.n);
            }
            let resp = self.read_response()?;
            match resp.id() {
                Some(rid) => {
                    self.pending.insert(rid, resp);
                }
                None => {
                    return Err(MatexpError::Service(
                        "server sent an un-identified reply while pipelined \
                         requests were in flight"
                            .into(),
                    ))
                }
            }
        }
    }

    /// Compute `matrix^power` remotely — the one-shot convenience (and
    /// the legacy no-id wire path): submit + wait in one call.
    pub fn expm(
        &mut self,
        matrix: &Matrix,
        power: u64,
        method: Method,
    ) -> Result<(Matrix, WireStats)> {
        let req = WireRequest::Expm {
            n: matrix.n(),
            power,
            method,
            matrix: matrix.data().to_vec(),
            payload: self.payload,
            id: None,
        };
        let resp = self.roundtrip(&req)?;
        Self::expm_payload(resp, matrix.n())
    }

    fn mark_resolved(&mut self, id: u64) {
        self.resolved.insert(id);
        while self.resolved.remove(&self.resolved_floor) {
            self.resolved_floor += 1;
        }
    }

    fn expm_payload(resp: WireResponse, n: usize) -> Result<(Matrix, WireStats)> {
        match resp {
            WireResponse::Ok { result: Some(data), stats: Some(stats), .. } => {
                Ok((Matrix::from_vec(n, data)?, stats))
            }
            WireResponse::Ok { .. } => Err(MatexpError::Service("malformed ok response".into())),
            WireResponse::Error { message, kind, .. } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        match self.roundtrip(&WireRequest::Ping)? {
            WireResponse::Ok { .. } => Ok(()),
            WireResponse::Error { message, kind, .. } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }

    /// Server metrics snapshot as parsed JSON.
    pub fn metrics(&mut self) -> Result<Json> {
        match self.roundtrip(&WireRequest::Metrics)? {
            WireResponse::Ok { metrics: Some(v), .. } => Ok(v),
            WireResponse::Ok { .. } => Err(MatexpError::Service("no metrics in response".into())),
            WireResponse::Error { message, kind, .. } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }
}
