//! Minimal blocking client for the JSON-lines protocol (examples/tests).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::server::proto::{Payload, WireRequest, WireResponse, WireStats};
use crate::util::json::Json;

/// Blocking TCP client.
pub struct MatexpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Matrix payload encoding for requests (server mirrors it back).
    payload: Payload,
}

impl MatexpClient {
    pub fn connect(addr: &str) -> Result<MatexpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // request lines must not sit in Nagle's buffer
        let reader = BufReader::new(stream.try_clone()?);
        Ok(MatexpClient { reader, writer: stream, payload: Payload::Json })
    }

    /// Use the compact base64 payload encoding (bit-exact, 1/3 the wire
    /// bytes, ~10x the codec speed for large matrices).
    pub fn with_base64(mut self) -> MatexpClient {
        self.payload = Payload::Base64;
        self
    }

    fn roundtrip(&mut self, req: &WireRequest) -> Result<WireResponse> {
        let mut line = req.encode()?.into_bytes();
        line.push(b'\n');
        self.writer.write_all(&line)?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        if buf.is_empty() {
            return Err(MatexpError::Service("server closed the connection".into()));
        }
        WireResponse::decode(buf.trim_end())
    }

    /// Compute `matrix^power` remotely.
    pub fn expm(&mut self, matrix: &Matrix, power: u64, method: Method) -> Result<(Matrix, WireStats)> {
        let req = WireRequest::Expm {
            n: matrix.n(),
            power,
            method,
            matrix: matrix.data().to_vec(),
            payload: self.payload,
        };
        match self.roundtrip(&req)? {
            WireResponse::Ok { result: Some(data), stats: Some(stats), .. } => {
                Ok((Matrix::from_vec(matrix.n(), data)?, stats))
            }
            WireResponse::Ok { .. } => Err(MatexpError::Service("malformed ok response".into())),
            WireResponse::Error { message, kind } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        match self.roundtrip(&WireRequest::Ping)? {
            WireResponse::Ok { .. } => Ok(()),
            WireResponse::Error { message, kind } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }

    /// Server metrics snapshot as parsed JSON.
    pub fn metrics(&mut self) -> Result<Json> {
        match self.roundtrip(&WireRequest::Metrics)? {
            WireResponse::Ok { metrics: Some(v), .. } => Ok(v),
            WireResponse::Ok { .. } => Err(MatexpError::Service("no metrics in response".into())),
            WireResponse::Error { message, kind } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }
}
