//! Blocking client for the wire protocol, with pipelining: `submit`
//! writes a request tagged with a client-chosen id and returns a ticket
//! immediately; `wait` resolves tickets in ANY order, stashing whatever
//! other replies arrive in between. One connection carries many
//! in-flight requests — the wire mirror of
//! [`crate::exec::JobHandle`]'s submit/wait split.
//!
//! The client speaks JSON lines by default and upgrades to binary frames
//! after [`MatexpClient::negotiate_binary`] (a JSON `hello` the server
//! acks with its frame version; pre-frame servers answer an error and
//! the client simply stays on JSON — same socket, no reconnect).
//!
//! A dead connection is **poisoned**: EOF, a protocol violation, or a
//! failed read/write marks the client broken and every call from then on
//! — including `wait` on tickets submitted earlier — returns
//! [`MatexpError::Disconnected`] instead of blocking on a socket that
//! will never answer.
//!
//! Opt-in **auto-reconnect** ([`MatexpClient::with_reconnect`]) softens
//! that: the next *send* on a poisoned client redials the original
//! address with capped, jittered exponential backoff and carries on —
//! but tickets from before the break stay lost (their `wait` returns a
//! typed [`MatexpError::Disconnected`]; a reconnect can never invent the
//! replies a dead server owed). The cluster router leans on this to ride
//! out member restarts without rebuilding its egress pool.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use crate::cache::CacheControl;
use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::server::frame::{self, Frame};
use crate::server::proto::{
    ClusterAction, MetricsFormat, Payload, WireRequest, WireResponse, WireStats,
};
use crate::util::json::Json;

/// Backoff schedule for [`MatexpClient::with_reconnect`]: attempt `k`
/// sleeps `min(base_ms << k, max_ms)` plus up to 50% random jitter, and
/// after `max_attempts` consecutive failures the client stays poisoned
/// with a typed "exhausted" [`MatexpError::Disconnected`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReconnectPolicy {
    /// Consecutive dial failures tolerated before giving up.
    pub max_attempts: u32,
    /// First retry delay in milliseconds (doubles per attempt).
    pub base_ms: u64,
    /// Ceiling on any single retry delay in milliseconds.
    pub max_ms: u64,
}

impl Default for ReconnectPolicy {
    /// 5 attempts, 50 ms doubling to a 2 s cap — rides out a process
    /// restart without hammering a host that is actually gone.
    fn default() -> ReconnectPolicy {
        ReconnectPolicy { max_attempts: 5, base_ms: 50, max_ms: 2_000 }
    }
}

/// Blocking TCP client.
pub struct MatexpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// The `host:port` this client dialed — what auto-reconnect redials.
    addr: String,
    /// Matrix payload encoding for JSON-line requests (server mirrors it
    /// back). Ignored on the binary frame path, which is always raw f32.
    payload: Payload,
    /// Submit expm requests as binary frames (after a successful
    /// [`Self::negotiate_binary`]).
    binary: bool,
    /// Once set, the connection is dead and every call fails fast with
    /// [`MatexpError::Disconnected`] carrying this reason.
    poisoned: Option<String>,
    /// Next client-chosen request id for pipelined submissions.
    next_id: u64,
    /// Replies that arrived while waiting on a different ticket.
    pending: HashMap<u64, WireResponse>,
    /// Tickets already resolved — a second `wait` on one must error, not
    /// block forever on a reply that will never come again. Bounded: ids
    /// below `resolved_floor` are all resolved (ids are assigned as a
    /// strictly increasing counter), so the set holds only the
    /// out-of-order frontier and is pruned as the floor advances.
    resolved: HashSet<u64>,
    resolved_floor: u64,
    /// Wire bytes written / read over this connection's lifetime.
    bytes_out: u64,
    bytes_in: u64,
    /// When set, a poisoned connection redials instead of failing fast.
    reconnect: Option<ReconnectPolicy>,
    /// Successful reconnects performed so far.
    reconnects: u64,
    /// Ids below this were submitted on a connection that has since been
    /// replaced — their replies died with the old socket, so `wait`
    /// returns a typed loss instead of blocking on the new one.
    epoch_floor: u64,
}

/// Ticket for one in-flight pipelined request (resolve with
/// [`MatexpClient::wait`], in any order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PendingExpm {
    id: u64,
    n: usize,
}

impl PendingExpm {
    /// The client-chosen request id on the wire.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl MatexpClient {
    /// Connect to a `matexp serve` endpoint (`host:port`).
    pub fn connect(addr: &str) -> Result<MatexpClient> {
        let (reader, writer) = Self::dial(addr)?;
        Ok(MatexpClient {
            reader,
            writer,
            addr: addr.to_string(),
            payload: Payload::Json,
            binary: false,
            poisoned: None,
            next_id: 1,
            pending: HashMap::new(),
            resolved: HashSet::new(),
            resolved_floor: 1,
            bytes_out: 0,
            bytes_in: 0,
            reconnect: None,
            reconnects: 0,
            epoch_floor: 1,
        })
    }

    /// One TCP dial, shared by `connect` and auto-reconnect.
    fn dial(addr: &str) -> Result<(BufReader<TcpStream>, TcpStream)> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // request lines must not sit in Nagle's buffer
        Ok((BufReader::new(stream.try_clone()?), stream))
    }

    /// Redial the original address automatically when the connection
    /// breaks, per `policy` (see [`ReconnectPolicy`]).
    pub fn with_reconnect(mut self, policy: ReconnectPolicy) -> MatexpClient {
        self.reconnect = Some(policy);
        self
    }

    /// Successful automatic reconnects over this client's lifetime.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Use the compact base64 payload encoding on JSON lines (bit-exact,
    /// 1/3 the wire bytes, ~10x the codec speed for large matrices).
    pub fn with_base64(mut self) -> MatexpClient {
        self.payload = Payload::Base64;
        self
    }

    /// Negotiate the binary frame codec: send a JSON `hello`, and if the
    /// server acks a frame version ≥ 1, submit expm requests as binary
    /// frames from here on (replies come back binary too). Returns
    /// whether the upgrade happened — `false` against pre-frame servers,
    /// which answer `unknown op`; the connection stays up on JSON lines
    /// either way.
    pub fn negotiate_binary(&mut self) -> Result<bool> {
        self.send(&WireRequest::Hello { frame_version: u32::from(frame::VERSION) })?;
        match self.recv_unidentified()? {
            WireResponse::Ok { frame: Some(v), .. } if v >= 1 => {
                self.binary = true;
                Ok(true)
            }
            WireResponse::Ok { .. } | WireResponse::Error { .. } => Ok(false),
        }
    }

    /// Whether expm requests currently go out as binary frames.
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// Wire traffic over this connection's lifetime: `(bytes written,
    /// bytes read)` — what the load harness's per-request byte counters
    /// are built from.
    pub fn wire_bytes(&self) -> (u64, u64) {
        (self.bytes_out, self.bytes_in)
    }

    /// Fail fast once the connection is poisoned.
    fn guard(&self) -> Result<()> {
        match &self.poisoned {
            Some(why) => Err(MatexpError::Disconnected(why.clone())),
            None => Ok(()),
        }
    }

    /// Mark the connection dead and return the typed error. Every
    /// outstanding ticket's next `wait` (and any later call) gets the
    /// same [`MatexpError::Disconnected`].
    fn poison(&mut self, why: impl Into<String>) -> MatexpError {
        let why = why.into();
        self.poisoned = Some(why.clone());
        MatexpError::Disconnected(why)
    }

    /// If the connection is poisoned and a reconnect policy is set,
    /// redial before the next write. In-flight tickets are NOT replayed:
    /// `pending` is dropped and `epoch_floor` advances past every id the
    /// old connection handed out, so their `wait` fails typed instead of
    /// pairing pre-break tickets with post-break replies.
    fn ensure_connected(&mut self) -> Result<()> {
        let policy = match (&self.poisoned, self.reconnect) {
            (Some(_), Some(p)) => p,
            _ => return Ok(()),
        };
        // spread a fleet's redials: jitter each delay by up to 50%,
        // seeded from the clock (determinism is worthless here — every
        // client backing off in lockstep is the failure mode)
        let seed = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| u64::from(d.subsec_nanos()))
            .unwrap_or(0x9e37_79b9)
            | 1;
        let mut rng = crate::linalg::rand::XorShift64::new(seed);
        let mut attempt: u32 = 0;
        loop {
            match Self::dial(&self.addr) {
                Ok((reader, writer)) => {
                    self.reader = reader;
                    self.writer = writer;
                    self.poisoned = None;
                    self.pending.clear();
                    self.epoch_floor = self.next_id;
                    self.reconnects += 1;
                    if self.binary {
                        // the frame upgrade was per-connection state
                        self.binary = false;
                        self.binary = self.negotiate_binary()?;
                    }
                    return Ok(());
                }
                Err(_) => {
                    attempt += 1;
                    if attempt >= policy.max_attempts {
                        return Err(self.poison(format!(
                            "reconnect to {} exhausted after {} attempts",
                            self.addr, policy.max_attempts
                        )));
                    }
                    let backoff = policy
                        .base_ms
                        .saturating_mul(1u64 << (attempt - 1).min(20))
                        .min(policy.max_ms);
                    let jitter = rng.next_below(backoff / 2 + 1);
                    std::thread::sleep(Duration::from_millis(backoff + jitter));
                }
            }
        }
    }

    fn send_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        self.ensure_connected()?;
        self.guard()?;
        if let Err(e) = self.writer.write_all(bytes) {
            return Err(self.poison(format!("write failed: {e}")));
        }
        self.bytes_out += bytes.len() as u64;
        Ok(())
    }

    fn send(&mut self, req: &WireRequest) -> Result<()> {
        let mut line = req.encode()?.into_bytes();
        line.push(b'\n');
        self.send_bytes(&line)
    }

    fn read_response(&mut self) -> Result<WireResponse> {
        self.guard()?;
        // one-byte peek dispatches the codec, mirroring the server
        let first = match self.reader.fill_buf() {
            Ok([]) => return Err(self.poison("server closed the connection")),
            Ok(buf) => buf[0],
            Err(e) => return Err(self.poison(format!("read failed: {e}"))),
        };
        if first == frame::MAGIC[0] {
            let (f, wire_bytes) = match Frame::read_from(&mut self.reader, frame::MAX_PAYLOAD) {
                Ok(ok) => ok,
                // any frame damage poisons: the byte stream is untrustworthy
                Err(e) => return Err(self.poison(format!("bad frame from server: {e}"))),
            };
            self.bytes_in += wire_bytes as u64;
            match f {
                Frame::ExpmOk { id, stats, result, .. } => Ok(WireResponse::Ok {
                    result: Some(result),
                    stats: Some(stats),
                    metrics: None,
                    payload: self.payload,
                    id: Some(id),
                    frame: None,
                }),
                Frame::Error { id, kind, message } => {
                    Ok(WireResponse::Error { message, kind, id })
                }
                Frame::Expm { .. } => {
                    Err(self.poison("server sent a request frame as a reply"))
                }
            }
        } else {
            let mut buf = String::new();
            match self.reader.read_line(&mut buf) {
                Ok(0) => Err(self.poison("server closed the connection")),
                Ok(k) => {
                    self.bytes_in += k as u64;
                    WireResponse::decode(buf.trim_end())
                }
                Err(e) => Err(self.poison(format!("read failed: {e}"))),
            }
        }
    }

    /// Read until a response WITHOUT an id arrives (the reply to a legacy
    /// one-shot request), stashing any pipelined replies that land first.
    fn recv_unidentified(&mut self) -> Result<WireResponse> {
        loop {
            let resp = self.read_response()?;
            match resp.id() {
                Some(rid) => {
                    self.pending.insert(rid, resp);
                }
                None => return Ok(resp),
            }
        }
    }

    fn roundtrip(&mut self, req: &WireRequest) -> Result<WireResponse> {
        self.send(req)?;
        self.recv_unidentified()
    }

    /// Submit `matrix^power` without waiting: the request goes out tagged
    /// with a client-chosen id (as a binary frame once negotiated, a JSON
    /// line otherwise) and a ticket comes back immediately. Resolve it
    /// with [`Self::wait`] — in any order relative to other tickets.
    pub fn submit(&mut self, matrix: &Matrix, power: u64, method: Method) -> Result<PendingExpm> {
        let id = self.next_id;
        // consume the id only once the request is actually on the wire: an
        // encode failure (non-finite JSON payload) must not burn an id
        // that would then sit below the resolved-floor watermark forever
        if self.binary {
            let f = Frame::Expm {
                id,
                n: matrix.n(),
                power,
                method,
                matrix: matrix.data().to_vec(),
            };
            self.send_bytes(&f.encode())?;
        } else {
            let req = WireRequest::Expm {
                n: matrix.n(),
                power,
                method,
                matrix: matrix.data().to_vec(),
                payload: self.payload,
                id: Some(id),
                cache: CacheControl::Use,
            };
            self.send(&req)?;
        }
        self.next_id += 1;
        Ok(PendingExpm { id, n: matrix.n() })
    }

    /// Resolve one ticket: returns its result as soon as its reply
    /// arrives, buffering replies to other in-flight tickets meanwhile.
    /// A ticket resolves once; waiting on it again is a typed error. On a
    /// poisoned connection (EOF or protocol violation, now or during an
    /// earlier call) every unresolved ticket's wait returns
    /// [`MatexpError::Disconnected`].
    pub fn wait(&mut self, job: &PendingExpm) -> Result<(Matrix, WireStats)> {
        if job.id < self.resolved_floor || self.resolved.contains(&job.id) {
            return Err(MatexpError::Service(format!(
                "ticket {} already resolved",
                job.id
            )));
        }
        // submitted before a reconnect replaced the connection: the old
        // socket died owing this reply, and the new one never will send it
        if job.id < self.epoch_floor {
            return Err(MatexpError::Disconnected(format!(
                "ticket {} was lost to a reconnect",
                job.id
            )));
        }
        loop {
            if let Some(resp) = self.pending.remove(&job.id) {
                self.mark_resolved(job.id);
                return Self::expm_payload(resp, job.n);
            }
            let resp = self.read_response()?;
            match resp.id() {
                Some(rid) => {
                    self.pending.insert(rid, resp);
                }
                // an id-less reply mid-pipeline can't be routed to ANY
                // ticket — the stream's reply pairing is broken, so the
                // whole connection is poisoned, not just this wait
                None => {
                    return Err(self.poison(
                        "server sent an un-identified reply while pipelined \
                         requests were in flight",
                    ))
                }
            }
        }
    }

    /// Compute `matrix^power` remotely — the one-shot convenience. On a
    /// binary-negotiated connection this is submit + wait on a frame; on
    /// JSON it is the legacy no-id wire path.
    pub fn expm(
        &mut self,
        matrix: &Matrix,
        power: u64,
        method: Method,
    ) -> Result<(Matrix, WireStats)> {
        self.expm_cached(matrix, power, method, CacheControl::Use)
    }

    /// [`Self::expm`] with an explicit result-cache directive. `Use`
    /// rides the binary frame path when negotiated; `Bypass`/`Refresh`
    /// always go as a JSON line (the frame codec has no cache slot —
    /// directives are rare, byte efficiency is for the hot path).
    pub fn expm_cached(
        &mut self,
        matrix: &Matrix,
        power: u64,
        method: Method,
        cache: CacheControl,
    ) -> Result<(Matrix, WireStats)> {
        if self.binary && cache == CacheControl::Use {
            let ticket = self.submit(matrix, power, method)?;
            return self.wait(&ticket);
        }
        let req = WireRequest::Expm {
            n: matrix.n(),
            power,
            method,
            matrix: matrix.data().to_vec(),
            payload: self.payload,
            id: None,
            cache,
        };
        let resp = self.roundtrip(&req)?;
        Self::expm_payload(resp, matrix.n())
    }

    fn mark_resolved(&mut self, id: u64) {
        self.resolved.insert(id);
        while self.resolved.remove(&self.resolved_floor) {
            self.resolved_floor += 1;
        }
    }

    fn expm_payload(resp: WireResponse, n: usize) -> Result<(Matrix, WireStats)> {
        match resp {
            WireResponse::Ok { result: Some(data), stats: Some(stats), .. } => {
                Ok((Matrix::from_vec(n, data)?, stats))
            }
            WireResponse::Ok { .. } => Err(MatexpError::Service("malformed ok response".into())),
            WireResponse::Error { message, kind, .. } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        match self.roundtrip(&WireRequest::Ping)? {
            WireResponse::Ok { .. } => Ok(()),
            WireResponse::Error { message, kind, .. } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }

    /// Server metrics snapshot as parsed JSON.
    pub fn metrics(&mut self) -> Result<Json> {
        self.ok_payload(&WireRequest::Metrics { format: MetricsFormat::Json })
    }

    /// Server metrics in Prometheus text exposition format.
    pub fn metrics_prometheus(&mut self) -> Result<String> {
        let v = self.ok_payload(&WireRequest::Metrics { format: MetricsFormat::Prometheus })?;
        match v.as_str() {
            Some(text) => Ok(text.to_string()),
            None => Err(MatexpError::Service("prometheus metrics not a string".into())),
        }
    }

    /// The server's recent trace spans as a Chrome trace-event document
    /// (parsed JSON, ready to pretty-print into a Perfetto-loadable file).
    pub fn trace_dump(&mut self) -> Result<Json> {
        self.ok_payload(&WireRequest::Trace)
    }

    /// Issue a `cluster` membership op (join/leave/drain/status) and
    /// return the peer's status document. Against a router this drives
    /// membership; against a member, `drain`/`status` manage that one
    /// node and join/leave answer a typed error.
    pub fn cluster(&mut self, action: ClusterAction, addr: Option<&str>) -> Result<Json> {
        self.ok_payload(&WireRequest::Cluster { action, addr: addr.map(str::to_string) })
    }

    /// Round-trip a payload-bearing control op and unwrap its `metrics`
    /// field (the ok-reply payload slot shared by `metrics` and `trace`).
    fn ok_payload(&mut self, req: &WireRequest) -> Result<Json> {
        match self.roundtrip(req)? {
            WireResponse::Ok { metrics: Some(v), .. } => Ok(v),
            WireResponse::Ok { .. } => Err(MatexpError::Service("no payload in response".into())),
            WireResponse::Error { message, kind, .. } => {
                Err(WireResponse::to_typed_error(&kind, message))
            }
        }
    }
}
