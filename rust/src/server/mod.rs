//! TCP front-end: newline-delimited JSON and binary frames over one
//! socket.
//!
//! The deployment face of the coordinator — what turns the paper's kernel
//! study into a service ("supercomputer at every desk", §1). Two codecs
//! share each connection: one JSON object per line (the readable default
//! and the legacy contract, [`proto`]) and a length-prefixed binary
//! frame format ([`frame`]) that carries matrices as raw little-endian
//! `f32` bytes — no base64, no intermediate `String` — negotiated per
//! connection with a JSON `hello`. The server dispatches by peeking one
//! byte per message.

pub mod client;
pub mod frame;
pub mod proto;
pub mod server;

pub use client::{MatexpClient, ReconnectPolicy};
pub use frame::Frame;
pub use proto::{ClusterAction, WireRequest, WireResponse, WireStats};
pub use server::{serve, serve_background, Server};
