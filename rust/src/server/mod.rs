//! TCP front-end: newline-delimited JSON over a socket.
//!
//! The deployment face of the coordinator — what turns the paper's kernel
//! study into a service ("supercomputer at every desk", §1). Wire format
//! is deliberately simple: one JSON object per line, both directions.

pub mod client;
pub mod proto;
pub mod server;

pub use client::MatexpClient;
pub use proto::{WireRequest, WireResponse, WireStats};
pub use server::serve;
