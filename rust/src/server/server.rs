//! TCP server on std::net: a connection-handler thread pool in front of
//! the coordinator.
//!
//! Connections are **pipelined**: a request carrying a client-chosen id
//! is submitted asynchronously ([`ServiceHandle::submit_with_id`]) and
//! the reader keeps reading — many requests ride one connection
//! concurrently, and each completion is written (tagged with its id) as
//! soon as its worker finishes, in whatever order that happens. A
//! per-connection completion pump drains one shared reply channel;
//! requests *without* an id keep the legacy one-shot contract: answered
//! in order before the next line is read.
//!
//! Both wire codecs ride one socket: the reader peeks a single byte per
//! message — [`frame::MAGIC`]'s first byte (≥ 0x80) means a binary
//! frame, anything else a JSON line — so a client may interleave binary
//! frames, pipelined JSON lines, and legacy id-less JSON lines freely.
//! Replies mirror the codec of their request.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::cache::CacheControl;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::Method;
use crate::coordinator::service::ServiceHandle;
use crate::error::{MatexpError, Result};
use crate::exec::{JobReply, Submission};
use crate::json_obj;
use crate::linalg::matrix::Matrix;
use crate::runtime::arena::BufferArena;
use crate::server::frame::{self, Frame};
use crate::server::proto::{ClusterAction, MetricsFormat, Payload, WireRequest, WireResponse};
use crate::trace;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

/// Live connections by connection id, so [`Server::shutdown`] can cut
/// their sockets and unblock the read loops.
type ConnRegistry = Arc<Mutex<HashMap<u64, TcpStream>>>;

/// A running server: bound address + accept-loop thread + the shutdown
/// plumbing ([`Server::shutdown`] stops it; dropping it does too).
pub struct Server {
    local_addr: SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    conns: ConnRegistry,
    /// Set by a `cluster drain` wire op: stop admitting new expm work
    /// (typed [`MatexpError::Admission`]) while in-flight jobs finish.
    draining: Arc<AtomicBool>,
}

impl Server {
    /// The address the listener actually bound (tests bind port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Whether a `cluster drain` op has put this server into drain mode
    /// (new expm submissions refused, in-flight work completing).
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Block until the accept loop exits — "serve until shut down" (from
    /// another thread holding the server, or process death).
    pub fn join(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Stop serving: unblock the accept loop, cut every live connection
    /// (their read loops see EOF, their completion pumps drain), and join
    /// all server threads. Idempotent; `Drop` calls it too, so tests that
    /// simply drop the `Server` no longer leak the listener and threads.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        let Some(thread) = self.accept_thread.take() else {
            return; // already shut down (or joined)
        };
        self.stop.store(true, Ordering::SeqCst);
        // cut live connections first so their handler threads (which the
        // accept thread's pool joins on exit) are guaranteed to unblock
        for (_, stream) in self.conns.lock().expect("conn registry poisoned").drain() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        // a throwaway connection unblocks the accept loop so it can see
        // the stop flag; it exits before handling the stream
        let _ = TcpStream::connect(self.local_addr);
        let _ = thread.join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Bind `addr` and serve connections in the background; returns
/// immediately with the bound address (tests bind port 0). The returned
/// [`Server`] owns the listener: dropping it (or calling
/// [`Server::shutdown`]) stops serving — hold it for the server's
/// lifetime.
///
/// `conn_threads` bounds concurrent connections; requests beyond that
/// queue at accept. Each connection thread reads messages (JSON lines or
/// binary frames) and submits them asynchronously; replies are written
/// by the connection's completion pump as workers finish.
pub fn serve_background(
    service: Arc<ServiceHandle>,
    addr: &str,
    conn_threads: usize,
) -> Result<Server> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    let pool = ThreadPool::new(conn_threads, "matexp-conn");
    let stop = Arc::new(AtomicBool::new(false));
    let conns: ConnRegistry = Arc::new(Mutex::new(HashMap::new()));
    // one drain flag shared by every connection: a `cluster drain` op on
    // any of them switches the whole server to refusing new work
    let draining = Arc::new(AtomicBool::new(false));
    let accept_thread = {
        let stop = Arc::clone(&stop);
        let conns = Arc::clone(&conns);
        let draining = Arc::clone(&draining);
        std::thread::Builder::new()
            .name("matexp-accept".into())
            .spawn(move || {
                let next_conn = AtomicU64::new(0);
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break; // pool drop below joins the handler threads
                    }
                    // a transient accept failure (EMFILE, aborted
                    // handshake, ECONNRESET) must not kill the listener:
                    // log and keep serving — one bad connection is that
                    // connection's problem, not the server's
                    let stream = match stream {
                        Ok(s) => s,
                        Err(e) => {
                            eprintln!("accept error (continuing): {e}");
                            continue;
                        }
                    };
                    let cid = next_conn.fetch_add(1, Ordering::Relaxed);
                    if let Ok(clone) = stream.try_clone() {
                        conns.lock().expect("conn registry poisoned").insert(cid, clone);
                    }
                    let service = Arc::clone(&service);
                    let stop = Arc::clone(&stop);
                    let conns = Arc::clone(&conns);
                    let draining = Arc::clone(&draining);
                    pool.execute(move || {
                        let peer = stream
                            .peer_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "<unknown>".into());
                        let outcome = handle_connection(&service, stream, &draining);
                        conns.lock().expect("conn registry poisoned").remove(&cid);
                        // a cut socket during shutdown is expected noise
                        if let Err(e) = outcome {
                            if !stop.load(Ordering::SeqCst) {
                                eprintln!("connection {peer}: {e}");
                            }
                        }
                    });
                }
            })?
    };
    Ok(Server { local_addr, accept_thread: Some(accept_thread), stop, conns, draining })
}

/// Serve until shut down. Binds `addr`, prints the bound address, then
/// blocks on the accept loop.
pub fn serve(service: Arc<ServiceHandle>, addr: &str, conn_threads: usize) -> Result<()> {
    let server = serve_background(service, addr, conn_threads)?;
    println!("matexp serving on {}", server.local_addr());
    server.join();
    Ok(())
}

/// Which codec a pipelined reply must be written in (mirrors its
/// request's codec).
#[derive(Clone, Copy, Debug)]
enum ReplyWire {
    /// JSON line, with this matrix payload encoding.
    Line(Payload),
    /// Binary frame.
    Frame,
}

impl ReplyWire {
    /// The codec tag this reply's wire spans carry.
    fn codec(self) -> trace::Codec {
        match self {
            ReplyWire::Line(_) => trace::Codec::Json,
            ReplyWire::Frame => trace::Codec::Frame,
        }
    }
}

/// Per-request bookkeeping for one pipelined job on one connection.
struct InflightEntry {
    /// Client-chosen request id (echoed on the reply).
    cid: u64,
    /// Codec the reply must be written in.
    wire: ReplyWire,
    /// The submission's trace id (raw), for the reply's wire spans.
    trace: u64,
    /// Request decode cost, carried into the reply's `wire_us` stage.
    decode_us: u64,
    /// Matrix side length (span annotation).
    n: usize,
}

/// In-flight pipelined jobs on one connection, by service id.
type Inflight = Arc<Mutex<HashMap<u64, InflightEntry>>>;

fn handle_connection(
    service: &ServiceHandle,
    stream: TcpStream,
    draining: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true)?; // message-oriented RPC: don't let Nagle batch replies
    // one writer lock per connection: the reader (inline replies) and the
    // completion pump (pipelined replies) interleave whole messages only
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
    let metrics = service.metrics_shared();
    let (done_tx, done_rx) = channel::<(u64, JobReply)>();
    // result buffers flow back from the pump to the reader's wire arena,
    // so the next frame decode reuses them instead of allocating fresh
    let (recycle_tx, recycle_rx) = channel::<Vec<f32>>();
    let pump = {
        let writer = Arc::clone(&writer);
        let inflight = Arc::clone(&inflight);
        let metrics = Arc::clone(&metrics);
        std::thread::Builder::new()
            .name("matexp-conn-pump".into())
            .spawn(move || completion_pump(done_rx, &inflight, &writer, &metrics, &recycle_tx))
            .map_err(MatexpError::Io)?
    };
    let outcome =
        read_loop(service, reader, &writer, &inflight, &done_tx, &metrics, &recycle_rx, draining);
    // dropping the reader's sender lets the pump exit once every entry the
    // service still holds (clones of done_tx) has been completed
    drop(done_tx);
    let _ = pump.join();
    outcome
}

#[allow(clippy::too_many_arguments)]
fn read_loop(
    service: &ServiceHandle,
    mut reader: BufReader<TcpStream>,
    writer: &Mutex<TcpStream>,
    inflight: &Inflight,
    done_tx: &Sender<(u64, JobReply)>,
    metrics: &Metrics,
    recycle_rx: &Receiver<Vec<f32>>,
    draining: &AtomicBool,
) -> Result<()> {
    // per-connection wire arena: frame payloads decode straight into
    // recycled result buffers (the arena is !Send and stays on this
    // thread; the pump feeds it through `recycle_rx`)
    let wire_arena = BufferArena::new();
    loop {
        // one-byte peek dispatches the codec: no JSON line (nor any ASCII
        // text) starts with the frame magic's first byte
        let first = match reader.fill_buf() {
            Ok([]) => return Ok(()), // clean EOF between messages
            Ok(buf) => buf[0],
            Err(e) => return Err(e.into()),
        };
        if first == frame::MAGIC[0] {
            read_one_frame(
                service,
                &mut reader,
                writer,
                inflight,
                done_tx,
                metrics,
                &wire_arena,
                recycle_rx,
                draining,
            )?;
        } else {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                return Ok(());
            }
            metrics.wire_bytes_in_total.fetch_add(line.len() as u64, Ordering::Relaxed);
            let line = line.trim_end_matches(['\r', '\n']);
            if line.trim().is_empty() {
                continue;
            }
            read_one_line(service, line, writer, inflight, done_tx, metrics, draining)?;
        }
    }
}

/// Handle one JSON line (any op). Decode failures are answered on the
/// line codec with the id salvaged best-effort from the raw text, so a
/// pipelined client's ticket still resolves (to a typed error) instead
/// of waiting forever on a reply that would otherwise carry no id.
#[allow(clippy::too_many_arguments)]
fn read_one_line(
    service: &ServiceHandle,
    line: &str,
    writer: &Mutex<TcpStream>,
    inflight: &Inflight,
    done_tx: &Sender<(u64, JobReply)>,
    metrics: &Metrics,
    draining: &AtomicBool,
) -> Result<()> {
    let decode_start = trace::now_us();
    match WireRequest::decode(line) {
        Err(e) => {
            let id = salvage_line_id(line);
            write_line(writer, &WireResponse::error(format!("bad request: {e}")).with_id(id), metrics)
        }
        Ok(WireRequest::Ping) => write_line(writer, &WireResponse::pong(), metrics),
        Ok(WireRequest::Hello { frame_version }) => {
            let negotiated = frame_version.min(u32::from(frame::VERSION));
            write_line(writer, &WireResponse::hello_ack(negotiated), metrics)
        }
        Ok(WireRequest::Metrics { format }) => {
            let payload = match format {
                MetricsFormat::Json => service.metrics().to_json(),
                // Prometheus text exposition travels as a JSON string
                MetricsFormat::Prometheus => {
                    Json::from(trace::prometheus::render(&service.metrics()))
                }
            };
            let resp = WireResponse::Ok {
                result: None,
                stats: None,
                metrics: Some(payload),
                payload: Payload::Json,
                id: None,
                frame: None,
            };
            write_line(writer, &resp, metrics)
        }
        Ok(WireRequest::Trace) => {
            // flight-recorder egress: the ring's recent spans as one
            // Chrome trace-event document
            let doc = trace::chrome::export(&trace::recent_spans());
            let resp = WireResponse::Ok {
                result: None,
                stats: None,
                metrics: Some(doc),
                payload: Payload::Json,
                id: None,
                frame: None,
            };
            write_line(writer, &resp, metrics)
        }
        Ok(WireRequest::Cluster { action, addr }) => {
            // member-side cluster surface: drain, status and artifact
            // pull — the router owns membership, a member can't join
            // itself anywhere
            let resp = match action {
                ClusterAction::Drain => {
                    draining.store(true, Ordering::SeqCst);
                    member_status(draining)
                }
                ClusterAction::Status => member_status(draining),
                ClusterAction::Pull => match addr {
                    // export our hottest store artifacts for a peer
                    None => member_artifacts(),
                    // pull FROM the named peer, install into warm tiers
                    Some(peer) => match pull_from_peer(&peer) {
                        Ok(n) => ok_doc(json_obj![("role", "member"), ("pulled", n)]),
                        Err(e) => WireResponse::from_error(&e),
                    },
                },
                ClusterAction::Join | ClusterAction::Leave => {
                    WireResponse::from_error(&MatexpError::Service(
                        "cluster membership ops are handled by the router, not members".into(),
                    ))
                }
            };
            write_line(writer, &resp, metrics)
        }
        Ok(req @ WireRequest::Expm { .. }) => {
            handle_expm(service, req, decode_start, writer, inflight, done_tx, metrics, draining)
        }
    }
}

/// Wrap a JSON document in the ok-reply payload slot shared with
/// `metrics` and `trace`.
fn ok_doc(doc: Json) -> WireResponse {
    WireResponse::Ok {
        result: None,
        stats: None,
        metrics: Some(doc),
        payload: Payload::Json,
        id: None,
        frame: None,
    }
}

/// A member's `cluster status` reply: its role and drain state.
fn member_status(draining: &AtomicBool) -> WireResponse {
    ok_doc(json_obj![("role", "member"), ("draining", draining.load(Ordering::SeqCst))])
}

/// A member's `cluster pull` reply: its hottest store artifacts
/// (results / autotune table / memoized plans as self-describing base64
/// payloads), for a joining peer to install into its own warm tiers.
fn member_artifacts() -> WireResponse {
    ok_doc(json_obj![
        ("role", "member"),
        ("artifacts", crate::store::export_hot(crate::store::HOT_EXPORT_LIMIT)),
    ])
}

/// Pull hot artifacts FROM `peer` and install them into this process's
/// warm tiers (and persistent store, when one is configured). Returns
/// how many artifacts were installed; corrupt or undecodable artifacts
/// are skipped, not errors.
fn pull_from_peer(peer: &str) -> Result<usize> {
    let mut client = crate::server::client::MatexpClient::connect(peer)?;
    let doc = client.cluster(ClusterAction::Pull, None)?;
    Ok(crate::store::install(&doc))
}

/// Handle one binary frame. Framing damage (bad header, truncation,
/// oversized length) poisons the byte stream: reply best-effort, then
/// propagate the error so the connection closes. Content damage inside a
/// well-delimited payload gets an error frame (with the id salvaged from
/// the payload prefix when possible) and the connection keeps serving.
///
/// Expm requests take the zero-copy path: the payload prefix is split off
/// with [`frame::decode_expm_prefix`] and the matrix bytes land directly
/// in a `wire_arena` buffer — recycled from an earlier reply whenever one
/// is pooled — instead of an always-fresh `Vec<f32>`.
#[allow(clippy::too_many_arguments)]
fn read_one_frame(
    service: &ServiceHandle,
    reader: &mut BufReader<TcpStream>,
    writer: &Mutex<TcpStream>,
    inflight: &Inflight,
    done_tx: &Sender<(u64, JobReply)>,
    metrics: &Metrics,
    wire_arena: &BufferArena,
    recycle_rx: &Receiver<Vec<f32>>,
    draining: &AtomicBool,
) -> Result<()> {
    let (kind, payload) = match frame::read_raw(reader, frame::MAX_PAYLOAD) {
        Ok(raw) => raw,
        Err(e) => {
            let _ = write_frame(writer, &Frame::from_error(&e, None), metrics);
            return Err(e);
        }
    };
    metrics
        .wire_bytes_in_total
        .fetch_add((frame::HEADER_LEN + payload.len()) as u64, Ordering::Relaxed);
    metrics.frames_total.fetch_add(1, Ordering::Relaxed);
    // decode cost starts once the payload is fully off the socket (the
    // read above is network wait, not codec work)
    let decode_start = trace::now_us();
    if kind == frame::KIND_EXPM {
        return match frame::decode_expm_prefix(&payload) {
            Ok((h, bytes)) => {
                // pool any result buffers the pump handed back since the
                // last request, so this decode can reuse one
                for buf in recycle_rx.try_iter() {
                    let side = (buf.len() as f64).sqrt().round() as usize;
                    if let Ok(m) = Matrix::from_vec(side, buf) {
                        drop(wire_arena.adopt(m)); // drop → free list
                    }
                }
                let mut out = wire_arena.alloc(h.n);
                frame::fill_f32s(bytes, out.matrix_mut().data_mut());
                if wire_arena.take().buffers_recycled > 0 {
                    metrics
                        .wire_bytes_recycled_total
                        .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                }
                submit_pipelined(
                    service,
                    out.into_matrix(),
                    h.power,
                    h.method,
                    CacheControl::Use,
                    h.id,
                    ReplyWire::Frame,
                    decode_start,
                    writer,
                    inflight,
                    done_tx,
                    metrics,
                    draining,
                )
            }
            Err(e) => {
                let id = frame::salvage_id(kind, &payload);
                write_frame(writer, &Frame::from_error(&e, id), metrics)
            }
        };
    }
    match Frame::decode(kind, &payload) {
        // a client has no business sending reply frames; answer and move on
        Ok(other) => {
            let e = MatexpError::Service(format!(
                "unexpected frame kind {} from client",
                other.kind()
            ));
            write_frame(writer, &Frame::from_error(&e, other.id()), metrics)
        }
        Err(e) => {
            let id = frame::salvage_id(kind, &payload);
            write_frame(writer, &Frame::from_error(&e, id), metrics)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_expm(
    service: &ServiceHandle,
    req: WireRequest,
    decode_start: u64,
    writer: &Mutex<TcpStream>,
    inflight: &Inflight,
    done_tx: &Sender<(u64, JobReply)>,
    metrics: &Metrics,
    draining: &AtomicBool,
) -> Result<()> {
    let WireRequest::Expm { power, method, payload, id: client_id, cache, .. } = &req else {
        unreachable!("handle_expm is only called with Expm requests");
    };
    let (power, method, payload, client_id, cache) =
        (*power, *method, *payload, *client_id, *cache);
    let matrix = match req.matrix() {
        Ok(m) => m,
        Err(e) => {
            return write_line(writer, &WireResponse::from_error(&e).with_id(client_id), metrics);
        }
    };
    match client_id {
        // pipelined: same path as binary frames, replying on the line codec
        Some(cid) => submit_pipelined(
            service,
            matrix,
            power,
            method,
            cache,
            cid,
            ReplyWire::Line(payload),
            decode_start,
            writer,
            inflight,
            done_tx,
            metrics,
            draining,
        ),
        // legacy one-shot peer: block and answer in order, as before
        None => {
            if draining.load(Ordering::SeqCst) {
                let e =
                    MatexpError::Admission("server is draining: not accepting new work".into());
                return write_line(writer, &WireResponse::from_error(&e), metrics);
            }
            let n = matrix.n();
            let submission = Submission::expm(matrix, power).method(method).cache(cache);
            // the trace id exists only from here; the decode span is
            // recorded retroactively against the measured start
            let t = submission.trace;
            let decode_end = trace::now_us();
            trace::record_span_at(
                trace::SpanKind::WireDecode(trace::Codec::Json),
                t,
                decode_start,
                decode_end,
                n,
            );
            let decode_us = decode_end.saturating_sub(decode_start);
            let resp = match service.submit_job(submission) {
                Ok(mut job) => match job.wait() {
                    // reply in the encoding the request used; typed errors
                    // (admission vs service) keep their kind on the wire
                    Ok(mut r) => {
                        r.stats.wire_us = decode_us;
                        WireResponse::from_expm(&r, payload)
                    }
                    Err(e) => WireResponse::from_error(&e),
                },
                Err(e) => WireResponse::from_error(&e),
            };
            let t0 = trace::now_us();
            let wrote = write_line(writer, &resp, metrics);
            trace::record_span_at(
                trace::SpanKind::WireEncode(trace::Codec::Json),
                t,
                t0,
                trace::now_us(),
                n,
            );
            wrote
        }
    }
}

/// Submit one pipelined expm (either codec): register the connection
/// bookkeeping under a reserved service id FIRST, so a worker reply can
/// never race past it; a failed submit answers inline on the request's
/// codec.
#[allow(clippy::too_many_arguments)]
fn submit_pipelined(
    service: &ServiceHandle,
    matrix: Matrix,
    power: u64,
    method: Method,
    cache: CacheControl,
    cid: u64,
    wire: ReplyWire,
    decode_start: u64,
    writer: &Mutex<TcpStream>,
    inflight: &Inflight,
    done_tx: &Sender<(u64, JobReply)>,
    metrics: &Metrics,
    draining: &AtomicBool,
) -> Result<()> {
    // drain gate: in-flight jobs finish, new ones answer a typed refusal
    // the router (or any client) can distinguish from overload
    if draining.load(Ordering::SeqCst) {
        let e = MatexpError::Admission("server is draining: not accepting new work".into());
        return write_reply_error(writer, &e, cid, wire, metrics);
    }
    let n = matrix.n();
    let submission = Submission::expm(matrix, power).method(method).cache(cache);
    // the trace id is minted with the submission; the decode span is
    // recorded retroactively against the measured start
    let trace_id = submission.trace;
    let decode_end = trace::now_us();
    trace::record_span_at(
        trace::SpanKind::WireDecode(wire.codec()),
        trace_id,
        decode_start,
        decode_end,
        n,
    );
    let sid = service.reserve_id();
    inflight.lock().expect("inflight map poisoned").insert(
        sid,
        InflightEntry {
            cid,
            wire,
            trace: trace_id.get(),
            decode_us: decode_end.saturating_sub(decode_start),
            n,
        },
    );
    if let Err(e) = service.submit_with_id(sid, submission, done_tx.clone()) {
        inflight.lock().expect("inflight map poisoned").remove(&sid);
        write_reply_error(writer, &e, cid, wire, metrics)?;
    }
    Ok(())
}

/// Write a typed error as an id-tagged reply in the given codec.
fn write_reply_error(
    writer: &Mutex<TcpStream>,
    e: &MatexpError,
    cid: u64,
    wire: ReplyWire,
    metrics: &Metrics,
) -> Result<()> {
    match wire {
        ReplyWire::Line(_) => {
            write_line(writer, &WireResponse::from_error(e).with_id(Some(cid)), metrics)
        }
        ReplyWire::Frame => write_frame(writer, &Frame::from_error(e, Some(cid)), metrics),
    }
}

/// Drain worker completions for one connection, writing each as soon as
/// it lands — in the codec its request arrived in. Exits when every
/// sender is gone (reader finished AND no in-flight job still holds a
/// clone) or the peer stops reading.
fn completion_pump(
    done_rx: Receiver<(u64, JobReply)>,
    inflight: &Mutex<HashMap<u64, InflightEntry>>,
    writer: &Mutex<TcpStream>,
    metrics: &Metrics,
    recycle: &Sender<Vec<f32>>,
) {
    while let Ok((sid, reply)) = done_rx.recv() {
        let Some(entry) = inflight.lock().expect("inflight map poisoned").remove(&sid) else {
            continue; // withdrawn (failed submit) — nothing to write
        };
        let InflightEntry { cid: client_id, wire, trace: trace_raw, decode_us, n } = entry;
        let encode_start = trace::now_us();
        let wrote = match (wire, reply) {
            (ReplyWire::Line(payload), Ok(mut r)) => {
                // the stage breakdown's wire edge is the request decode
                // cost — the encode below happens after the stats are
                // serialized, so it lands in the trace span instead
                r.stats.wire_us = decode_us;
                write_line(writer, &WireResponse::from_expm(&r, payload).with_id(Some(client_id)), metrics)
            }
            // typed error → wire error with its kind (deadline, admission…)
            (ReplyWire::Line(_), Err(e)) => {
                write_line(writer, &WireResponse::from_error(&e).with_id(Some(client_id)), metrics)
            }
            (ReplyWire::Frame, Ok(mut r)) => {
                r.stats.wire_us = decode_us;
                // the binary reply consumes the response: the result's
                // buffer is moved onto the wire encoder, not re-cloned
                let n = r.result.n();
                let f = Frame::ExpmOk {
                    id: client_id,
                    n,
                    stats: r.stats.into(),
                    result: r.result.into_vec(),
                };
                let wrote = write_frame(writer, &f, metrics);
                // encode copied the bytes out; hand the buffer back to
                // the reader's wire arena for the next request decode
                // (best-effort — the reader may already be gone)
                if let Frame::ExpmOk { result, .. } = f {
                    let _ = recycle.send(result);
                }
                wrote
            }
            (ReplyWire::Frame, Err(e)) => {
                write_frame(writer, &Frame::from_error(&e, Some(client_id)), metrics)
            }
        };
        trace::record_span_at(
            trace::SpanKind::WireEncode(wire.codec()),
            trace::TraceId::from_raw(trace_raw),
            encode_start,
            trace::now_us(),
            n,
        );
        if wrote.is_err() {
            return; // peer gone; remaining completions have no reader
        }
    }
}

/// Encode + write one response line under the connection's writer lock
/// (an unencodable payload degrades to a wire error with the same id).
fn write_line(writer: &Mutex<TcpStream>, resp: &WireResponse, metrics: &Metrics) -> Result<()> {
    let encoded = resp.encode().unwrap_or_else(|e| {
        WireResponse::error(format!("unencodable response: {e}"))
            .with_id(resp.id())
            .encode()
            .expect("error responses contain no payload")
    });
    let mut out = encoded.into_bytes();
    out.push(b'\n');
    metrics.wire_bytes_out_total.fetch_add(out.len() as u64, Ordering::Relaxed);
    let mut w = writer.lock().expect("connection writer poisoned");
    w.write_all(&out)?;
    Ok(())
}

/// Encode + write one binary frame under the connection's writer lock.
fn write_frame(writer: &Mutex<TcpStream>, f: &Frame, metrics: &Metrics) -> Result<()> {
    let out = f.encode();
    metrics.wire_bytes_out_total.fetch_add(out.len() as u64, Ordering::Relaxed);
    metrics.frames_total.fetch_add(1, Ordering::Relaxed);
    let mut w = writer.lock().expect("connection writer poisoned");
    w.write_all(&out)?;
    Ok(())
}

/// Best-effort `id` recovery from a request line that failed to decode:
/// parseable JSON yields its `id` field; otherwise a raw scan for an
/// `"id": <digits>` fragment. `None` when the text holds no usable id —
/// the error reply then goes out id-less, exactly as before.
fn salvage_line_id(line: &str) -> Option<u64> {
    if let Ok(v) = Json::parse(line) {
        return v.get("id").and_then(Json::as_u64);
    }
    let bytes = line.as_bytes();
    let key = b"\"id\"";
    if bytes.len() < key.len() {
        return None;
    }
    for start in 0..=bytes.len() - key.len() {
        if &bytes[start..start + key.len()] != key {
            continue;
        }
        let mut j = start + key.len();
        while bytes.get(j).is_some_and(u8::is_ascii_whitespace) {
            j += 1;
        }
        if bytes.get(j) != Some(&b':') {
            continue;
        }
        j += 1;
        while bytes.get(j).is_some_and(u8::is_ascii_whitespace) {
            j += 1;
        }
        let digits = j;
        while bytes.get(j).is_some_and(u8::is_ascii_digit) {
            j += 1;
        }
        if j > digits {
            if let Ok(id) = line[digits..j].parse() {
                return Some(id);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn salvage_from_valid_json() {
        assert_eq!(salvage_line_id(r#"{"op":"nope","id":42}"#), Some(42));
        assert_eq!(salvage_line_id(r#"{"op":"nope"}"#), None);
    }

    #[test]
    fn salvage_from_corrupt_text() {
        // truncated JSON — unparseable, but the id fragment is intact
        assert_eq!(salvage_line_id(r#"{"op":"expm","id":7,"n":"BRO"#), Some(7));
        assert_eq!(salvage_line_id(r#"{"id" : 31, garbage"#), Some(31));
        assert_eq!(salvage_line_id("total garbage"), None);
        assert_eq!(salvage_line_id(r#"{"id":x}"#), None); // non-numeric id
        assert_eq!(salvage_line_id(r#"{"id":99999999999999999999999}"#), None); // overflow
    }
}
