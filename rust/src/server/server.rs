//! TCP server on std::net: a connection-handler thread pool in front of
//! the coordinator. PJRT work happens on the coordinator's worker threads;
//! connection threads only parse lines and block on `submit`.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;

use crate::coordinator::service::ServiceHandle;
use crate::error::Result;
use crate::server::proto::{Payload, WireRequest, WireResponse};
use crate::util::threadpool::ThreadPool;

/// A running server: bound address + accept-loop thread.
pub struct Server {
    local_addr: SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Block until the accept loop exits (it runs until the process dies,
    /// so this is effectively "serve forever").
    pub fn join(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Bind `addr` and serve connections in the background; returns
/// immediately with the bound address (tests bind port 0).
///
/// `conn_threads` bounds concurrent connections; requests beyond that
/// queue at accept. Each connection is handled synchronously —
/// line in, line out.
pub fn serve_background(
    service: Arc<ServiceHandle>,
    addr: &str,
    conn_threads: usize,
) -> Result<Server> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    let pool = ThreadPool::new(conn_threads, "matexp-conn");
    let accept_thread = std::thread::Builder::new()
        .name("matexp-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                // a transient accept failure (EMFILE, aborted handshake,
                // ECONNRESET) must not kill the listener: log and keep
                // serving — one bad connection is that connection's
                // problem, not the server's
                let stream = match stream {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("accept error (continuing): {e}");
                        continue;
                    }
                };
                let service = Arc::clone(&service);
                pool.execute(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "<unknown>".into());
                    if let Err(e) = handle_connection(&service, stream) {
                        eprintln!("connection {peer}: {e}");
                    }
                });
            }
        })?;
    Ok(Server { local_addr, accept_thread: Some(accept_thread) })
}

/// Serve until the process is killed. Binds `addr`, prints the bound
/// address, then blocks.
pub fn serve(service: Arc<ServiceHandle>, addr: &str, conn_threads: usize) -> Result<()> {
    let server = serve_background(service, addr, conn_threads)?;
    println!("matexp serving on {}", server.local_addr());
    server.join();
    Ok(())
}

fn handle_connection(service: &ServiceHandle, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?; // line-oriented RPC: don't let Nagle batch replies
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match WireRequest::decode(&line) {
            Ok(req) => dispatch(service, req),
            Err(e) => WireResponse::error(format!("bad request: {e}")),
        };
        // an unencodable payload (non-finite result in a JSON payload)
        // degrades to a wire error; error responses always encode
        let encoded = response.encode().unwrap_or_else(|e| {
            WireResponse::error(format!("unencodable response: {e}"))
                .encode()
                .expect("error responses contain no payload")
        });
        let mut out = encoded.into_bytes();
        out.push(b'\n');
        writer.write_all(&out)?;
    }
    Ok(())
}

fn dispatch(service: &ServiceHandle, req: WireRequest) -> WireResponse {
    match req {
        WireRequest::Ping => WireResponse::pong(),
        WireRequest::Metrics => WireResponse::Ok {
            result: None,
            stats: None,
            metrics: Some(service.metrics().to_json()),
            payload: Payload::Json,
        },
        WireRequest::Expm { power, method, payload, .. } => {
            let matrix = match req.matrix() {
                Ok(m) => m,
                Err(e) => return WireResponse::from_error(&e),
            };
            match service.submit(matrix, power, method) {
                // reply in the encoding the request used; typed errors
                // (admission vs service) keep their kind on the wire
                Ok(resp) => WireResponse::from_expm(&resp, payload),
                Err(e) => WireResponse::from_error(&e),
            }
        }
    }
}
