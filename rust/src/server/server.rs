//! TCP server on std::net: a connection-handler thread pool in front of
//! the coordinator.
//!
//! Connections are **pipelined**: a request carrying a client-chosen id
//! is submitted asynchronously ([`ServiceHandle::submit_with_id`]) and
//! the reader keeps reading — many requests ride one connection
//! concurrently, and each completion is written (tagged with its id) as
//! soon as its worker finishes, in whatever order that happens. A
//! per-connection completion pump drains one shared reply channel;
//! requests *without* an id keep the legacy one-shot contract: answered
//! in order before the next line is read.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::coordinator::service::ServiceHandle;
use crate::error::{MatexpError, Result};
use crate::exec::{JobReply, Submission};
use crate::server::proto::{Payload, WireRequest, WireResponse};
use crate::util::threadpool::ThreadPool;

/// A running server: bound address + accept-loop thread.
pub struct Server {
    local_addr: SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// The address the listener actually bound (tests bind port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Block until the accept loop exits (it runs until the process dies,
    /// so this is effectively "serve forever").
    pub fn join(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Bind `addr` and serve connections in the background; returns
/// immediately with the bound address (tests bind port 0).
///
/// `conn_threads` bounds concurrent connections; requests beyond that
/// queue at accept. Each connection thread reads lines and submits them
/// asynchronously; replies are written by the connection's completion
/// pump as workers finish.
pub fn serve_background(
    service: Arc<ServiceHandle>,
    addr: &str,
    conn_threads: usize,
) -> Result<Server> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    let pool = ThreadPool::new(conn_threads, "matexp-conn");
    let accept_thread = std::thread::Builder::new()
        .name("matexp-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                // a transient accept failure (EMFILE, aborted handshake,
                // ECONNRESET) must not kill the listener: log and keep
                // serving — one bad connection is that connection's
                // problem, not the server's
                let stream = match stream {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("accept error (continuing): {e}");
                        continue;
                    }
                };
                let service = Arc::clone(&service);
                pool.execute(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "<unknown>".into());
                    if let Err(e) = handle_connection(&service, stream) {
                        eprintln!("connection {peer}: {e}");
                    }
                });
            }
        })?;
    Ok(Server { local_addr, accept_thread: Some(accept_thread) })
}

/// Serve until the process is killed. Binds `addr`, prints the bound
/// address, then blocks.
pub fn serve(service: Arc<ServiceHandle>, addr: &str, conn_threads: usize) -> Result<()> {
    let server = serve_background(service, addr, conn_threads)?;
    println!("matexp serving on {}", server.local_addr());
    server.join();
    Ok(())
}

/// In-flight pipelined jobs on one connection:
/// service id → (client-chosen id, payload encoding to reply in).
type Inflight = Arc<Mutex<HashMap<u64, (u64, Payload)>>>;

fn handle_connection(service: &ServiceHandle, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?; // line-oriented RPC: don't let Nagle batch replies
    // one writer lock per connection: the reader (inline replies) and the
    // completion pump (pipelined replies) interleave whole lines only
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
    let (done_tx, done_rx) = channel::<(u64, JobReply)>();
    let pump = {
        let writer = Arc::clone(&writer);
        let inflight = Arc::clone(&inflight);
        std::thread::Builder::new()
            .name("matexp-conn-pump".into())
            .spawn(move || completion_pump(done_rx, &inflight, &writer))
            .map_err(MatexpError::Io)?
    };
    let outcome = read_loop(service, reader, &writer, &inflight, &done_tx);
    // dropping the reader's sender lets the pump exit once every entry the
    // service still holds (clones of done_tx) has been completed
    drop(done_tx);
    let _ = pump.join();
    outcome
}

fn read_loop(
    service: &ServiceHandle,
    reader: BufReader<TcpStream>,
    writer: &Mutex<TcpStream>,
    inflight: &Inflight,
    done_tx: &Sender<(u64, JobReply)>,
) -> Result<()> {
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match WireRequest::decode(&line) {
            Err(e) => write_line(writer, &WireResponse::error(format!("bad request: {e}")))?,
            Ok(WireRequest::Ping) => write_line(writer, &WireResponse::pong())?,
            Ok(WireRequest::Metrics) => {
                let resp = WireResponse::Ok {
                    result: None,
                    stats: None,
                    metrics: Some(service.metrics().to_json()),
                    payload: Payload::Json,
                    id: None,
                };
                write_line(writer, &resp)?;
            }
            Ok(req @ WireRequest::Expm { .. }) => {
                handle_expm(service, req, writer, inflight, done_tx)?;
            }
        }
    }
    Ok(())
}

fn handle_expm(
    service: &ServiceHandle,
    req: WireRequest,
    writer: &Mutex<TcpStream>,
    inflight: &Inflight,
    done_tx: &Sender<(u64, JobReply)>,
) -> Result<()> {
    let WireRequest::Expm { power, method, payload, id: client_id, .. } = &req else {
        unreachable!("handle_expm is only called with Expm requests");
    };
    let (power, method, payload, client_id) = (*power, *method, *payload, *client_id);
    let matrix = match req.matrix() {
        Ok(m) => m,
        Err(e) => {
            return write_line(writer, &WireResponse::from_error(&e).with_id(client_id));
        }
    };
    let submission = Submission::expm(matrix, power).method(method);
    match client_id {
        // pipelined: register the connection bookkeeping under a reserved
        // service id FIRST, so a worker reply can never race past it
        Some(cid) => {
            let sid = service.reserve_id();
            inflight.lock().expect("inflight map poisoned").insert(sid, (cid, payload));
            if let Err(e) = service.submit_with_id(sid, submission, done_tx.clone()) {
                inflight.lock().expect("inflight map poisoned").remove(&sid);
                write_line(writer, &WireResponse::from_error(&e).with_id(Some(cid)))?;
            }
        }
        // legacy one-shot peer: block and answer in order, as before
        None => {
            let resp = match service.submit_job(submission) {
                Ok(mut job) => match job.wait() {
                    // reply in the encoding the request used; typed errors
                    // (admission vs service) keep their kind on the wire
                    Ok(r) => WireResponse::from_expm(&r, payload),
                    Err(e) => WireResponse::from_error(&e),
                },
                Err(e) => WireResponse::from_error(&e),
            };
            write_line(writer, &resp)?;
        }
    }
    Ok(())
}

/// Drain worker completions for one connection, writing each as soon as
/// it lands. Exits when every sender is gone (reader finished AND no
/// in-flight job still holds a clone) or the peer stops reading.
fn completion_pump(
    done_rx: Receiver<(u64, JobReply)>,
    inflight: &Mutex<HashMap<u64, (u64, Payload)>>,
    writer: &Mutex<TcpStream>,
) {
    while let Ok((sid, reply)) = done_rx.recv() {
        let Some((client_id, payload)) = inflight.lock().expect("inflight map poisoned").remove(&sid)
        else {
            continue; // withdrawn (failed submit) — nothing to write
        };
        let resp = match reply {
            Ok(r) => WireResponse::from_expm(&r, payload),
            // typed error → wire error with its kind (deadline, admission…)
            Err(e) => WireResponse::from_error(&e),
        }
        .with_id(Some(client_id));
        if write_line(writer, &resp).is_err() {
            return; // peer gone; remaining completions have no reader
        }
    }
}

/// Encode + write one response line under the connection's writer lock
/// (an unencodable payload degrades to a wire error with the same id).
fn write_line(writer: &Mutex<TcpStream>, resp: &WireResponse) -> Result<()> {
    let encoded = resp.encode().unwrap_or_else(|e| {
        WireResponse::error(format!("unencodable response: {e}"))
            .with_id(resp.id())
            .encode()
            .expect("error responses contain no payload")
    });
    let mut out = encoded.into_bytes();
    out.push(b'\n');
    let mut w = writer.lock().expect("connection writer poisoned");
    w.write_all(&out)?;
    Ok(())
}
