//! Length-prefixed binary frames: the zero-copy sibling of the JSON line
//! codec in [`crate::server::proto`].
//!
//! A frame is a fixed 12-byte header followed by a payload, everything
//! little-endian:
//!
//! ```text
//! +--------+--------+--------+--------+
//! | 0xB5   |  'M'   |  'X'   |  'F'   |   magic (first byte >= 0x80, so a
//! +--------+--------+--------+--------+   frame can never be confused with
//! | ver=1  | kind   | reserved (=0)   |   the first byte of a JSON line)
//! +--------+--------+-----------------+
//! | payload length (u32, LE)          |
//! +-----------------------------------+
//! | payload ...                       |
//! +-----------------------------------+
//! ```
//!
//! Payload layouts by `kind`:
//!
//! * `1` — expm request: `id:u64 | power:u64 | n:u32 | method_len:u8 |
//!   method:utf8 | matrix:(n*n)×f32`
//! * `2` — expm ok: `id:u64 | n:u32 | stats_len:u32 | stats:utf8-JSON |
//!   result:(n*n)×f32`
//! * `3` — error: `has_id:u8 | id:u64 | kind_len:u8 | kind:utf8 |
//!   msg_len:u32 | message:utf8`
//!
//! The matrix travels as raw little-endian `f32` bytes — no base64, no
//! intermediate `String` — and decodes straight into a `Vec<f32>` that
//! [`crate::linalg::matrix::Matrix::from_vec`] (and from there the
//! engine's arena-adopting upload path) takes by value. Binary expm
//! requests always carry an id: the frame path is pipelined-only, the
//! legacy ordered one-shot contract stays on JSON lines.
//!
//! Error handling is split in two deliberate layers: [`read_raw`] fails
//! only on *framing* damage (bad magic/version, truncated stream,
//! oversized length) — those poison the byte stream and the connection
//! must close — while [`Frame::decode`] fails on *content* damage inside
//! one well-delimited payload, which the connection survives (the server
//! answers with an error frame, salvaging the request id via
//! [`salvage_id`] when the prefix is intact).

use std::io::Read;
use std::str::FromStr;

use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::server::proto::WireStats;
use crate::util::json::Json;

/// Frame preamble. The first byte is ≥ 0x80 so the serving loop can
/// dispatch frame-vs-JSON-line by peeking a single byte: no JSON line
/// (nor any ASCII text) ever starts with it.
pub const MAGIC: [u8; 4] = [0xB5, b'M', b'X', b'F'];

/// Wire format version this build speaks (negotiated via the JSON
/// `hello` op; see [`crate::server::proto::WireRequest::Hello`]).
pub const VERSION: u8 = 1;

/// Fixed header size in bytes (magic + version + kind + reserved + len).
pub const HEADER_LEN: usize = 12;

/// Default ceiling on a frame's payload length (256 MiB — comfortably
/// above the largest admissible matrix, far below an attacker-chosen
/// 4 GiB allocation). [`read_raw`] rejects longer frames up front.
pub const MAX_PAYLOAD: u32 = 256 * 1024 * 1024;

/// Payload kind tag of an expm request frame.
pub const KIND_EXPM: u8 = 1;
/// Payload kind tag of a successful expm reply frame.
pub const KIND_EXPM_OK: u8 = 2;
/// Payload kind tag of an error reply frame.
pub const KIND_ERROR: u8 = 3;

/// One binary wire message (either direction).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Compute `matrix^power` — the binary sibling of
    /// [`crate::server::proto::WireRequest::Expm`]. Always pipelined
    /// (carries a client-chosen id).
    Expm {
        /// Client-chosen request id (echoed on the reply frame).
        id: u64,
        /// Matrix side length.
        n: usize,
        /// The exponent `N`.
        power: u64,
        /// Execution method the server should use.
        method: Method,
        /// Row-major operand, length `n * n`, bit-exact on the wire.
        matrix: Vec<f32>,
    },
    /// A successful expm reply.
    ExpmOk {
        /// Echo of the request id.
        id: u64,
        /// Matrix side length.
        n: usize,
        /// Execution stats (as the same JSON object the line codec uses,
        /// so both codecs share one stats schema).
        stats: WireStats,
        /// Row-major result, length `n * n`, bit-exact on the wire.
        result: Vec<f32>,
    },
    /// A failed reply (mirrors [`crate::server::proto::WireResponse::Error`]).
    Error {
        /// Echo of the request id, when it could be recovered.
        id: Option<u64>,
        /// Machine-readable error class (`admission`, `deadline`, …).
        kind: String,
        /// Human-readable error text.
        message: String,
    },
}

/// Little-endian payload cursor with typed truncation errors.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, len: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(len).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => Err(MatexpError::Service(format!(
                "frame payload truncated reading {what} ({len} bytes at offset {}, {} available)",
                self.pos,
                self.buf.len() - self.pos
            ))),
        }
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn str(&mut self, len: usize, what: &str) -> Result<&'a str> {
        std::str::from_utf8(self.take(len, what)?)
            .map_err(|_| MatexpError::Service(format!("frame: {what} is not UTF-8")))
    }

    /// n*n little-endian f32s, decoded straight into an owned `Vec<f32>`.
    fn f32_matrix(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let count = n
            .checked_mul(n)
            .ok_or_else(|| MatexpError::Service(format!("frame: {what} side {n} overflows")))?;
        let bytes = self.take(
            count
                .checked_mul(4)
                .ok_or_else(|| MatexpError::Service(format!("frame: {what} too large")))?,
            what,
        )?;
        let mut out = Vec::with_capacity(count);
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        Ok(out)
    }

    /// Reject trailing garbage: a payload must be exactly its fields.
    fn finish(&self, kind: u8) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(MatexpError::Service(format!(
                "frame kind {kind}: {} trailing bytes after payload",
                self.buf.len() - self.pos
            )))
        }
    }
}

fn push_f32s(out: &mut Vec<u8>, data: &[f32]) {
    out.reserve(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// The fixed-field prefix of an expm request payload, decoded without
/// touching the matrix bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpmHeader {
    /// Client-chosen request id.
    pub id: u64,
    /// The exponent `N`.
    pub power: u64,
    /// Matrix side length.
    pub n: usize,
    /// Execution method the server should use.
    pub method: Method,
}

/// Split an expm request payload into its decoded prefix and the raw
/// little-endian matrix bytes (length-checked: exactly `n·n·4`). This is
/// the zero-copy entry the server's wire edge uses — the matrix bytes can
/// be decoded with [`fill_f32s`] straight into a recycled arena buffer
/// instead of a fresh `Vec<f32>`. [`Frame::decode`] delegates here so
/// there is exactly one parser for the layout.
pub fn decode_expm_prefix(payload: &[u8]) -> Result<(ExpmHeader, &[u8])> {
    let mut c = Cursor::new(payload);
    let id = c.u64("id")?;
    let power = c.u64("power")?;
    let n = c.u32("n")? as usize;
    let mlen = c.u8("method length")? as usize;
    let method = Method::from_str(c.str(mlen, "method")?)?;
    let count = n
        .checked_mul(n)
        .ok_or_else(|| MatexpError::Service(format!("frame: matrix side {n} overflows")))?;
    let bytes = c.take(
        count
            .checked_mul(4)
            .ok_or_else(|| MatexpError::Service("frame: matrix too large".into()))?,
        "matrix",
    )?;
    c.finish(KIND_EXPM)?;
    Ok((ExpmHeader { id, power, n, method }, bytes))
}

/// Decode little-endian `f32` bytes into a caller-provided buffer
/// (`bytes.len()` must be exactly `4 · out.len()` — guaranteed when
/// `bytes` came from [`decode_expm_prefix`] and `out` is `n·n` long).
pub fn fill_f32s(bytes: &[u8], out: &mut [f32]) {
    assert_eq!(bytes.len(), out.len() * 4, "fill_f32s: length mismatch");
    for (dst, src) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
    }
}

impl Frame {
    /// Kind tag this frame encodes as.
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Expm { .. } => KIND_EXPM,
            Frame::ExpmOk { .. } => KIND_EXPM_OK,
            Frame::Error { .. } => KIND_ERROR,
        }
    }

    /// The frame's request id, when it carries one.
    pub fn id(&self) -> Option<u64> {
        match self {
            Frame::Expm { id, .. } | Frame::ExpmOk { id, .. } => Some(*id),
            Frame::Error { id, .. } => *id,
        }
    }

    /// Build an error frame from a typed error, keeping its wire kind
    /// (the binary mirror of
    /// [`crate::server::proto::WireResponse::from_error`]).
    pub fn from_error(e: &MatexpError, id: Option<u64>) -> Frame {
        Frame::Error {
            id,
            kind: crate::server::proto::error_kind(e).to_string(),
            message: e.to_string(),
        }
    }

    /// Encode header + payload into one byte vector, ready to write.
    pub fn encode(&self) -> Vec<u8> {
        let mut payload: Vec<u8> = Vec::new();
        match self {
            Frame::Expm { id, n, power, method, matrix } => {
                payload.extend_from_slice(&id.to_le_bytes());
                payload.extend_from_slice(&power.to_le_bytes());
                payload.extend_from_slice(&(*n as u32).to_le_bytes());
                let m = method.as_str().as_bytes();
                payload.push(m.len() as u8);
                payload.extend_from_slice(m);
                push_f32s(&mut payload, matrix);
            }
            Frame::ExpmOk { id, n, stats, result } => {
                payload.extend_from_slice(&id.to_le_bytes());
                payload.extend_from_slice(&(*n as u32).to_le_bytes());
                let stats = stats.to_json().to_string();
                payload.extend_from_slice(&(stats.len() as u32).to_le_bytes());
                payload.extend_from_slice(stats.as_bytes());
                push_f32s(&mut payload, result);
            }
            Frame::Error { id, kind, message } => {
                payload.push(u8::from(id.is_some()));
                payload.extend_from_slice(&id.unwrap_or(0).to_le_bytes());
                payload.push(kind.len().min(255) as u8);
                payload.extend_from_slice(&kind.as_bytes()[..kind.len().min(255)]);
                payload.extend_from_slice(&(message.len() as u32).to_le_bytes());
                payload.extend_from_slice(message.as_bytes());
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.kind());
        out.extend_from_slice(&0u16.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Decode one payload previously delimited by [`read_raw`]. Failures
    /// here are *content* errors: the stream framing is intact and the
    /// connection may keep serving.
    pub fn decode(kind: u8, payload: &[u8]) -> Result<Frame> {
        let mut c = Cursor::new(payload);
        let frame = match kind {
            KIND_EXPM => {
                // one parser for the layout: the zero-copy prefix
                // splitter, followed by a fresh-buffer fill
                let (h, bytes) = decode_expm_prefix(payload)?;
                let mut matrix = vec![0.0f32; h.n * h.n];
                fill_f32s(bytes, &mut matrix);
                return Ok(Frame::Expm {
                    id: h.id,
                    n: h.n,
                    power: h.power,
                    method: h.method,
                    matrix,
                });
            }
            KIND_EXPM_OK => {
                let id = c.u64("id")?;
                let n = c.u32("n")? as usize;
                let slen = c.u32("stats length")? as usize;
                let stats = WireStats::from_json(&Json::parse(c.str(slen, "stats")?)?)?;
                let result = c.f32_matrix(n, "result")?;
                Frame::ExpmOk { id, n, stats, result }
            }
            KIND_ERROR => {
                let has_id = c.u8("has_id")?;
                let id = c.u64("id")?;
                let klen = c.u8("kind length")? as usize;
                let kind = c.str(klen, "error kind")?.to_string();
                let mlen = c.u32("message length")? as usize;
                let message = c.str(mlen, "message")?.to_string();
                Frame::Error { id: (has_id != 0).then_some(id), kind, message }
            }
            other => {
                return Err(MatexpError::Service(format!("unknown frame kind {other}")));
            }
        };
        c.finish(kind)?;
        Ok(frame)
    }

    /// Read + decode one whole frame (client-side convenience). Returns
    /// the frame and the number of wire bytes it occupied.
    pub fn read_from(r: &mut impl Read, max_payload: u32) -> Result<(Frame, usize)> {
        let (kind, payload) = read_raw(r, max_payload)?;
        let wire_bytes = HEADER_LEN + payload.len();
        Ok((Frame::decode(kind, &payload)?, wire_bytes))
    }
}

/// Read one frame's header + payload bytes off the stream. Failures here
/// are *framing* errors (bad magic/version, truncation, oversized
/// length): the byte stream is no longer trustworthy and the caller must
/// close the connection.
pub fn read_raw(r: &mut impl Read, max_payload: u32) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).map_err(truncated("frame header"))?;
    if header[..4] != MAGIC {
        return Err(MatexpError::Service(format!(
            "bad frame magic {:02x?}",
            &header[..4]
        )));
    }
    if header[4] != VERSION {
        return Err(MatexpError::Service(format!(
            "unsupported frame version {} (this build speaks {VERSION})",
            header[4]
        )));
    }
    if header[6] != 0 || header[7] != 0 {
        return Err(MatexpError::Service("nonzero reserved bytes in frame header".into()));
    }
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]);
    if len > max_payload {
        return Err(MatexpError::Service(format!(
            "oversized frame: payload {len} bytes exceeds the {max_payload}-byte cap"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(truncated("frame payload"))?;
    Ok((header[5], payload))
}

/// Best-effort request-id recovery from a damaged payload, so the error
/// reply can still be routed to the waiting ticket. The id prefix sits at
/// a fixed offset in every kind, so any payload long enough yields it.
pub fn salvage_id(kind: u8, payload: &[u8]) -> Option<u64> {
    let at = |off: usize| -> Option<u64> {
        let b = payload.get(off..off + 8)?;
        Some(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    };
    match kind {
        KIND_EXPM | KIND_EXPM_OK => at(0),
        KIND_ERROR if payload.first() == Some(&1) => at(1),
        _ => None,
    }
}

/// Map `read_exact`'s EOF to a typed truncation error (anything else
/// stays an I/O error).
fn truncated(what: &'static str) -> impl Fn(std::io::Error) -> MatexpError {
    move |e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            MatexpError::Service(format!("truncated {what}: connection cut mid-frame"))
        } else {
            MatexpError::Io(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> WireStats {
        WireStats {
            launches: 3,
            multiplies: 5,
            h2d_transfers: 1,
            d2h_transfers: 1,
            bytes_copied: 2048,
            buffers_recycled: 2,
            peak_resident_bytes: 1 << 16,
            wall_s: 0.125,
            queue_us: 40,
            plan_us: 3,
            prepare_us: 0,
            launch_us: 200,
            wire_us: 9,
            per_device: Vec::new(),
        }
    }

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.encode();
        let (got, wire) = Frame::read_from(&mut &bytes[..], MAX_PAYLOAD).unwrap();
        assert_eq!(wire, bytes.len());
        got
    }

    #[test]
    fn expm_request_roundtrips() {
        let f = Frame::Expm {
            id: 42,
            n: 2,
            power: 100,
            method: Method::Ours,
            matrix: vec![1.0, -2.5, 0.0, 3.25],
        };
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn expm_ok_roundtrips_with_stats() {
        let f = Frame::ExpmOk { id: 7, n: 2, stats: stats(), result: vec![0.5; 4] };
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn error_frames_roundtrip_with_and_without_id() {
        for id in [None, Some(9u64)] {
            let f = Frame::Error { id, kind: "admission".into(), message: "too big".into() };
            assert_eq!(roundtrip(&f), f);
        }
    }

    #[test]
    fn non_finite_values_are_bit_exact() {
        // the whole point of the binary path: NaN/±Inf/subnormals travel
        // unchanged, where the JSON array codec must refuse them
        let weird = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1e-42, -0.0, f32::MIN_POSITIVE, 1.0, 2.0, 3.0];
        let f = Frame::Expm { id: 1, n: 3, power: 2, method: Method::CpuSeq, matrix: weird.clone() };
        match roundtrip(&f) {
            Frame::Expm { matrix, .. } => {
                for (a, b) in weird.iter().zip(&matrix) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn n1_edge_roundtrips() {
        let f = Frame::Expm { id: 1, n: 1, power: 1, method: Method::Ours, matrix: vec![2.0] };
        assert_eq!(roundtrip(&f), f);
        let f = Frame::ExpmOk { id: 1, n: 1, stats: stats(), result: vec![2.0] };
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn truncation_at_every_cut_is_a_typed_error() {
        let bytes = Frame::Expm {
            id: 3,
            n: 2,
            power: 8,
            method: Method::Ours,
            matrix: vec![1.0; 4],
        }
        .encode();
        for cut in 0..bytes.len() {
            let err = Frame::read_from(&mut &bytes[..cut], MAX_PAYLOAD).unwrap_err();
            assert!(
                matches!(err, MatexpError::Service(_)),
                "cut {cut}: {err}"
            );
        }
    }

    #[test]
    fn oversized_frame_rejected_before_allocation() {
        let mut bytes = Frame::Error { id: None, kind: "service".into(), message: "x".into() }
            .encode();
        bytes[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = Frame::read_from(&mut &bytes[..], MAX_PAYLOAD).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
        // a small cap rejects even modest frames (servers can tighten it)
        let small = Frame::Expm { id: 1, n: 4, power: 2, method: Method::Ours, matrix: vec![0.0; 16] }
            .encode();
        let err = Frame::read_from(&mut &small[..], 8).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
    }

    #[test]
    fn bad_magic_version_reserved_rejected() {
        let good = Frame::Error { id: None, kind: "service".into(), message: "x".into() }.encode();
        for (offset, value, needle) in [
            (0usize, 0x7Bu8, "magic"),    // '{' — a JSON line where a frame should be
            (4, 2, "version"),
            (6, 1, "reserved"),
        ] {
            let mut bytes = good.clone();
            bytes[offset] = value;
            let err = Frame::read_from(&mut &bytes[..], MAX_PAYLOAD).unwrap_err();
            assert!(err.to_string().contains(needle), "{offset}: {err}");
        }
    }

    #[test]
    fn unknown_kind_and_trailing_garbage_rejected() {
        assert!(Frame::decode(99, &[]).is_err());
        let f = Frame::Error { id: None, kind: "service".into(), message: "x".into() };
        let mut bytes = f.encode();
        bytes.push(0xEE); // trailing byte beyond the declared fields
        let len = (bytes.len() - HEADER_LEN) as u32;
        bytes[8..12].copy_from_slice(&len.to_le_bytes());
        let err = Frame::read_from(&mut &bytes[..], MAX_PAYLOAD).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn wrong_matrix_length_is_a_content_error() {
        // declared n=3 but only 4 floats present: decode must fail inside
        // the delimited payload, not over/under-read the stream
        let f = Frame::Expm { id: 1, n: 2, power: 2, method: Method::Ours, matrix: vec![1.0; 4] };
        let mut bytes = f.encode();
        bytes[HEADER_LEN + 16..HEADER_LEN + 20].copy_from_slice(&3u32.to_le_bytes());
        let (kind, payload) = read_raw(&mut &bytes[..], MAX_PAYLOAD).unwrap();
        assert!(Frame::decode(kind, &payload).is_err());
        // but the id is still salvageable for the error reply
        assert_eq!(salvage_id(kind, &payload), Some(1));
        // and the unpatched encoding still decodes
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn expm_prefix_split_matches_full_decode() {
        let f = Frame::Expm {
            id: 11,
            n: 2,
            power: 9,
            method: Method::Ours,
            matrix: vec![1.0, 2.0, 3.0, 4.0],
        };
        let bytes = f.encode();
        let (kind, payload) = read_raw(&mut &bytes[..], MAX_PAYLOAD).unwrap();
        assert_eq!(kind, KIND_EXPM);
        let (h, raw) = decode_expm_prefix(&payload).unwrap();
        assert_eq!(h, ExpmHeader { id: 11, power: 9, n: 2, method: Method::Ours });
        assert_eq!(raw.len(), 4 * 4);
        let mut out = [0.0f32; 4];
        fill_f32s(raw, &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
        // the prefix splitter enforces exact payload length too
        assert!(decode_expm_prefix(&payload[..payload.len() - 1]).is_err());
    }

    #[test]
    fn salvage_id_recovers_prefixes_only() {
        let expm = Frame::Expm { id: 77, n: 1, power: 1, method: Method::Ours, matrix: vec![1.0] };
        let bytes = expm.encode();
        assert_eq!(salvage_id(KIND_EXPM, &bytes[HEADER_LEN..]), Some(77));
        assert_eq!(salvage_id(KIND_EXPM, &[1, 2]), None); // too short
        let err = Frame::Error { id: Some(5), kind: "k".into(), message: "m".into() }.encode();
        assert_eq!(salvage_id(KIND_ERROR, &err[HEADER_LEN..]), Some(5));
        let anon = Frame::Error { id: None, kind: "k".into(), message: "m".into() }.encode();
        assert_eq!(salvage_id(KIND_ERROR, &anon[HEADER_LEN..]), None);
        assert_eq!(salvage_id(99, &bytes[HEADER_LEN..]), None);
    }
}
