//! Multi-threaded CPU matmul (row-parallel over the in-tree fork-join
//! substrate, `util::threadpool::parallel_rows`).
//!
//! The paper's host was a 16-core Xeon yet its CPU baseline is
//! single-threaded; this variant is the "fair CPU" ablation quantifying
//! what those idle 15 cores were worth (EXPERIMENTS.md §Ablations).

use crate::linalg::matrix::Matrix;
use crate::util::threadpool::{default_threads, parallel_rows};

/// `c = a * b`, rows of `c` computed in parallel, i-k-j inside each row.
pub fn matmul_threaded(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_threaded_with(a, b, default_threads())
}

/// [`matmul_threaded`] with an explicit thread count (thread-scaling bench).
pub fn matmul_threaded_with(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    let mut c = Matrix::zeros(a.n());
    matmul_threaded_with_into(a, b, threads, &mut c);
    c
}

/// In-place form of [`matmul_threaded`]: zeroes then accumulates into `c`
/// (which must not alias `a` or `b`) without allocating.
pub fn matmul_threaded_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    matmul_threaded_with_into(a, b, default_threads(), c);
}

/// In-place form of [`matmul_threaded_with`].
pub fn matmul_threaded_with_into(a: &Matrix, b: &Matrix, threads: usize, c: &mut Matrix) {
    let n = a.n();
    assert_eq!(n, b.n(), "matmul_threaded: size mismatch");
    assert_eq!(n, c.n(), "matmul_threaded: output size mismatch");
    let out = c.data_mut();
    out.fill(0.0);
    parallel_rows(out, n, threads, |i, crow| {
        for k in 0..n {
            let aik = a.get(i, k);
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    #[test]
    fn threaded_matches_naive() {
        let a = Matrix::random(64, 12);
        let b = Matrix::random(64, 13);
        let want = matmul_naive(&a, &b);
        assert!(matmul_threaded(&a, &b).approx_eq(&want, 1e-4, 1e-5));
    }

    #[test]
    fn explicit_thread_counts_agree() {
        let a = Matrix::random(32, 20);
        let b = Matrix::random(32, 21);
        let want = matmul_naive(&a, &b);
        for threads in [1, 2, 3, 7, 64] {
            let got = matmul_threaded_with(&a, &b, threads);
            assert!(got.approx_eq(&want, 1e-4, 1e-5), "threads={threads}");
        }
    }

    #[test]
    fn tiny_matrices_work() {
        let a = Matrix::random(1, 14);
        let b = Matrix::random(1, 15);
        let got = matmul_threaded(&a, &b);
        assert!((got.get(0, 0) - a.get(0, 0) * b.get(0, 0)).abs() < 1e-6);
    }
}
