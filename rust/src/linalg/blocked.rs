//! Blocked (tiled) CPU matmul — the host-side mirror of the paper's §4.3.7
//! TILING. One tile of `a`, `b` and `c` is kept hot in L1/L2 cache, exactly
//! as the OpenCL kernel keeps tiles in the 16 KB local memory.

use crate::linalg::matrix::Matrix;

/// Default block edge: 64 f32 rows ≈ 16 KB per tile pair, the same
/// working-set the paper's local memory held.
pub const DEFAULT_BLOCK: usize = 64;

/// `c = a * b` with `block x block` tiles (i-k-j inside each tile).
pub fn matmul_blocked(a: &Matrix, b: &Matrix, block: usize) -> Matrix {
    let mut c = Matrix::zeros(a.n());
    matmul_blocked_into(a, b, block, &mut c);
    c
}

/// In-place form of [`matmul_blocked`]: zeroes then accumulates into `c`
/// (which must not alias `a` or `b`) without allocating.
pub fn matmul_blocked_into(a: &Matrix, b: &Matrix, block: usize, c: &mut Matrix) {
    let n = a.n();
    assert_eq!(n, b.n(), "matmul_blocked: size mismatch");
    assert_eq!(n, c.n(), "matmul_blocked: output size mismatch");
    assert!(block > 0, "block must be positive");
    c.data_mut().fill(0.0);
    let bs = block.min(n);
    for ii in (0..n).step_by(bs) {
        let i_end = (ii + bs).min(n);
        for kk in (0..n).step_by(bs) {
            let k_end = (kk + bs).min(n);
            for jj in (0..n).step_by(bs) {
                let j_end = (jj + bs).min(n);
                for i in ii..i_end {
                    for k in kk..k_end {
                        let aik = a.get(i, k);
                        let brow = b.row(k);
                        let crow = &mut c.data_mut()[i * n..(i + 1) * n];
                        for j in jj..j_end {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }
}

/// [`matmul_blocked`] with [`DEFAULT_BLOCK`] (fn-pointer friendly).
pub fn matmul_blocked_default(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_blocked(a, b, DEFAULT_BLOCK)
}

/// [`matmul_blocked_into`] with [`DEFAULT_BLOCK`] (fn-pointer friendly).
pub fn matmul_blocked_default_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    matmul_blocked_into(a, b, DEFAULT_BLOCK, c);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    #[test]
    fn blocked_matches_naive_various_blocks() {
        let a = Matrix::random(48, 8);
        let b = Matrix::random(48, 9);
        let want = matmul_naive(&a, &b);
        for block in [1, 3, 8, 16, 48, 64, 100] {
            let got = matmul_blocked(&a, &b, block);
            assert!(got.approx_eq(&want, 1e-4, 1e-5), "block={block}");
        }
    }

    #[test]
    fn non_dividing_block_still_correct() {
        let a = Matrix::random(50, 10);
        let b = Matrix::random(50, 11);
        let want = matmul_naive(&a, &b);
        assert!(matmul_blocked(&a, &b, 16).approx_eq(&want, 1e-4, 1e-5));
    }

    #[test]
    #[should_panic]
    fn zero_block_panics() {
        matmul_blocked(&Matrix::zeros(4), &Matrix::zeros(4), 0);
    }
}
