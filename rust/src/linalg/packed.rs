//! Packed cache-blocked matmul microkernels — the raw-speed CPU tier.
//!
//! The [`blocked`](crate::linalg::blocked) kernel tiles the iteration
//! space but still streams operands from their row-major homes, so every
//! register tile pays strided loads. This module does what optimized
//! BLAS implementations (and the paper's hand-tuned GPU kernels) do:
//! *pack* the operands once into the exact layout the innermost loop
//! consumes, then drive a fixed `MR`×`NR` register-tile microkernel over
//! contiguous panels.
//!
//! Pack layout (`MR` = 4, `NR` = 8):
//!
//! ```text
//!   A (n×n, row-major)          Apanel p: k-major, MR values per k
//!   ┌─────────────┐             [ a(p·MR+0, k) a(p·MR+1, k) … a(p·MR+3, k) ]  k = 0..n
//!   │ rows p·MR.. │  ── pack ─▶ contiguous, one cache line feeds 4 rows
//!   └─────────────┘
//!   B (n×n, row-major)          Bpanel q: k-major, NR values per k
//!   ┌─────────────┐             [ b(k, q·NR+0) … b(k, q·NR+7) ]              k = 0..n
//!   │ cols q·NR.. │  ── pack ─▶ the SIMD lane vector, loaded unstrided
//!   └─────────────┘
//! ```
//!
//! Edge panels (n not a multiple of `MR`/`NR`, odd n) are zero-padded in
//! the packs; the store-back clips to the real rows/columns, so every
//! size is handled by the same kernel with no scalar cleanup loops.
//!
//! Two public kernels share this driver: [`matmul_packed`] always runs
//! the portable scalar microkernel (fixed-size accumulator arrays the
//! compiler keeps in registers and auto-vectorizes), and [`matmul_simd`]
//! runs an explicit `std::arch` microkernel (x86-64 AVX2+FMA, AArch64
//! NEON) when the `simd` feature is compiled in **and** the CPU reports
//! the features at runtime — otherwise it falls back to the scalar
//! packed path, so the variant is always safe to select.

use std::cell::RefCell;

use crate::linalg::matrix::Matrix;

/// Microkernel register-tile height: rows of `A` per packed panel.
pub const MR: usize = 4;

/// Microkernel register-tile width: columns of `B` per packed panel (one
/// 8-lane f32 SIMD vector).
pub const NR: usize = 8;

thread_local! {
    /// Per-thread packing scratch (`A` panels, `B` panels): steady-state
    /// multiplies reuse the buffers and allocate nothing.
    static PACK_SCRATCH: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Pack `a` into `MR`-row panels, k-major: panel `p` holds rows
/// `p·MR..p·MR+MR` as `n` consecutive groups of `MR` values (rows past
/// the matrix edge are zero).
fn pack_a(a: &Matrix, ap: &mut Vec<f32>) {
    let n = a.n();
    let panels = n.div_ceil(MR);
    ap.clear();
    ap.resize(panels * n * MR, 0.0);
    let src = a.data();
    for p in 0..panels {
        let base = p * n * MR;
        for i in 0..MR {
            let row = p * MR + i;
            if row >= n {
                break;
            }
            let srow = &src[row * n..(row + 1) * n];
            for (k, &v) in srow.iter().enumerate() {
                ap[base + k * MR + i] = v;
            }
        }
    }
}

/// Pack `b` into `NR`-column panels, k-major: panel `q` holds columns
/// `q·NR..q·NR+NR` as `n` consecutive groups of `NR` values (columns past
/// the matrix edge are zero).
fn pack_b(b: &Matrix, bp: &mut Vec<f32>) {
    let n = b.n();
    let panels = n.div_ceil(NR);
    bp.clear();
    bp.resize(panels * n * NR, 0.0);
    let src = b.data();
    for q in 0..panels {
        let base = q * n * NR;
        let j0 = q * NR;
        let cols = NR.min(n - j0);
        for k in 0..n {
            let srow = &src[k * n + j0..k * n + j0 + cols];
            bp[base + k * NR..base + k * NR + cols].copy_from_slice(srow);
        }
    }
}

/// Portable scalar `MR`×`NR` microkernel: full register tile of one
/// `Apanel`×`Bpanel` product over `depth` k-steps, written to `acc`
/// row-major. Fixed-size local accumulators keep the tile in registers
/// and let the compiler vectorize the `NR` lane loop.
fn kernel_scalar(ap: &[f32], bp: &[f32], depth: usize, acc: &mut [f32; MR * NR]) {
    let mut local = [[0.0f32; NR]; MR];
    for k in 0..depth {
        let av = &ap[k * MR..k * MR + MR];
        let bv = &bp[k * NR..k * NR + NR];
        for i in 0..MR {
            let ai = av[i];
            for j in 0..NR {
                local[i][j] += ai * bv[j];
            }
        }
    }
    for i in 0..MR {
        acc[i * NR..(i + 1) * NR].copy_from_slice(&local[i]);
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd_x86 {
    //! AVX2+FMA 4×8 microkernel (8 f32 lanes per accumulator row).

    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// Whether the CPU reports AVX2 and FMA at runtime.
    pub fn available() -> bool {
        std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
    }

    /// Fused-multiply-add register tile over packed panels.
    ///
    /// # Safety
    /// The caller must have confirmed [`available`], and the panels must
    /// hold at least `depth·MR` / `depth·NR` elements.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn kernel(ap: &[f32], bp: &[f32], depth: usize, acc: &mut [f32; MR * NR]) {
        let mut c0 = _mm256_setzero_ps();
        let mut c1 = _mm256_setzero_ps();
        let mut c2 = _mm256_setzero_ps();
        let mut c3 = _mm256_setzero_ps();
        for k in 0..depth {
            let bv = _mm256_loadu_ps(bp.as_ptr().add(k * NR));
            let a = ap.as_ptr().add(k * MR);
            c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a), bv, c0);
            c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(1)), bv, c1);
            c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(2)), bv, c2);
            c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(3)), bv, c3);
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), c0);
        _mm256_storeu_ps(acc.as_mut_ptr().add(NR), c1);
        _mm256_storeu_ps(acc.as_mut_ptr().add(2 * NR), c2);
        _mm256_storeu_ps(acc.as_mut_ptr().add(3 * NR), c3);
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod simd_aarch64 {
    //! NEON 4×8 microkernel (two 4-lane f32 vectors per accumulator row).

    use super::{MR, NR};
    use std::arch::aarch64::*;

    /// Whether the CPU reports NEON at runtime (always true on AArch64,
    /// checked anyway for symmetry with the x86 path).
    pub fn available() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    /// Fused-multiply-add register tile over packed panels.
    ///
    /// # Safety
    /// The caller must have confirmed [`available`], and the panels must
    /// hold at least `depth·MR` / `depth·NR` elements.
    #[target_feature(enable = "neon")]
    pub unsafe fn kernel(ap: &[f32], bp: &[f32], depth: usize, acc: &mut [f32; MR * NR]) {
        let mut c: [[float32x4_t; 2]; MR] = [[vdupq_n_f32(0.0); 2]; MR];
        for k in 0..depth {
            let b0 = vld1q_f32(bp.as_ptr().add(k * NR));
            let b1 = vld1q_f32(bp.as_ptr().add(k * NR + 4));
            for (i, row) in c.iter_mut().enumerate() {
                let a = vdupq_n_f32(*ap.get_unchecked(k * MR + i));
                row[0] = vfmaq_f32(row[0], a, b0);
                row[1] = vfmaq_f32(row[1], a, b1);
            }
        }
        for (i, row) in c.iter().enumerate() {
            vst1q_f32(acc.as_mut_ptr().add(i * NR), row[0]);
            vst1q_f32(acc.as_mut_ptr().add(i * NR + 4), row[1]);
        }
    }
}

/// Whether [`matmul_simd`] will actually run the explicit-SIMD
/// microkernel on this build + CPU (false means it falls back to the
/// scalar packed kernel).
pub fn simd_active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    return simd_x86::available();
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    return simd_aarch64::available();
    #[allow(unreachable_code)]
    false
}

/// One register tile through the selected microkernel.
fn run_kernel(ap: &[f32], bp: &[f32], depth: usize, acc: &mut [f32; MR * NR], simd: bool) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd && simd_x86::available() {
        // SAFETY: availability checked; panels are depth·MR / depth·NR long
        unsafe { simd_x86::kernel(ap, bp, depth, acc) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if simd && simd_aarch64::available() {
        // SAFETY: availability checked; panels are depth·MR / depth·NR long
        unsafe { simd_aarch64::kernel(ap, bp, depth, acc) };
        return;
    }
    let _ = simd;
    kernel_scalar(ap, bp, depth, acc);
}

/// Shared pack + panel-sweep driver behind both packed variants.
fn matmul_packed_impl(a: &Matrix, b: &Matrix, c: &mut Matrix, simd: bool) {
    let n = a.n();
    assert_eq!(b.n(), n, "matmul size mismatch");
    assert_eq!(c.n(), n, "output size mismatch");
    if n == 0 {
        return;
    }
    PACK_SCRATCH.with(|scratch| {
        let mut scratch = scratch.borrow_mut();
        let (ap, bp) = &mut *scratch;
        pack_a(a, ap);
        pack_b(b, bp);
        let row_panels = n.div_ceil(MR);
        let col_panels = n.div_ceil(NR);
        let out = c.data_mut();
        let mut acc = [0.0f32; MR * NR];
        for p in 0..row_panels {
            let apanel = &ap[p * n * MR..(p + 1) * n * MR];
            let i0 = p * MR;
            let rows = MR.min(n - i0);
            for q in 0..col_panels {
                let bpanel = &bp[q * n * NR..(q + 1) * n * NR];
                let j0 = q * NR;
                let cols = NR.min(n - j0);
                run_kernel(apanel, bpanel, n, &mut acc, simd);
                for i in 0..rows {
                    let row = (i0 + i) * n;
                    out[row + j0..row + j0 + cols]
                        .copy_from_slice(&acc[i * NR..i * NR + cols]);
                }
            }
        }
    });
}

/// Packed scalar matmul: `a · b` with packed panels and the portable
/// register-tile microkernel.
pub fn matmul_packed(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.n());
    matmul_packed_into(a, b, &mut c);
    c
}

/// In-place form of [`matmul_packed`] (output fully overwritten).
pub fn matmul_packed_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    matmul_packed_impl(a, b, c, false);
}

/// Packed matmul through the explicit-SIMD microkernel when the `simd`
/// feature and the CPU allow it ([`simd_active`]); the scalar packed
/// kernel otherwise.
pub fn matmul_simd(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.n());
    matmul_simd_into(a, b, &mut c);
    c
}

/// In-place form of [`matmul_simd`] (output fully overwritten).
pub fn matmul_simd_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    matmul_packed_impl(a, b, c, true);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    #[test]
    fn identity_is_neutral() {
        let a = Matrix::random(16, 3);
        let e = Matrix::identity(16);
        assert_eq!(matmul_packed(&a, &e), a);
        assert_eq!(matmul_packed(&e, &a), a);
    }

    #[test]
    fn matches_naive_at_edge_sizes() {
        // non-multiples of MR and NR, odd sizes, and the degenerate 1×1
        for n in [1usize, 2, 3, 5, 7, 8, 9, 12, 17, 24, 31, 33] {
            let a = Matrix::random(n, 5);
            let b = Matrix::random(n, 6);
            let want = matmul_naive(&a, &b);
            let got = matmul_packed(&a, &b);
            assert!(
                got.approx_eq(&want, 1e-4, 1e-4),
                "n={n} diff {}",
                got.max_abs_diff(&want)
            );
            let simd = matmul_simd(&a, &b);
            assert!(
                simd.approx_eq(&want, 1e-4, 1e-4),
                "simd n={n} diff {}",
                simd.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn into_overwrites_stale_output() {
        let a = Matrix::random(13, 1);
        let b = Matrix::random(13, 2);
        let want = matmul_packed(&a, &b);
        let mut c = Matrix::random(13, 99); // stale contents must vanish
        matmul_packed_into(&a, &b, &mut c);
        assert_eq!(c, want);
        let mut c = Matrix::random(13, 98);
        matmul_simd_into(&a, &b, &mut c);
        assert!(c.approx_eq(&want, 1e-4, 1e-4));
    }

    #[test]
    fn pack_layouts_zero_pad_the_edges() {
        // n=5: A needs 2 MR-panels (rows 4..8 padded), B one NR-panel
        // (cols 5..8 padded)
        let a = Matrix::random(5, 7);
        let (mut ap, mut bp) = (Vec::new(), Vec::new());
        pack_a(&a, &mut ap);
        pack_b(&a, &mut bp);
        assert_eq!(ap.len(), 2 * 5 * MR);
        assert_eq!(bp.len(), 5 * NR);
        // panel 1, k=0 holds rows 4..8 of column 0: row 4 real, rest zero
        assert_eq!(ap[5 * MR], a.get(4, 0));
        assert_eq!(&ap[5 * MR + 1..5 * MR + 4], &[0.0, 0.0, 0.0]);
        // k=0 group of the B panel: row 0, cols 0..5 real then zeros
        assert_eq!(&bp[..5], &a.data()[..5]);
        assert_eq!(&bp[5..8], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn simd_flag_is_consistent_with_build() {
        // without the feature the explicit path must report inactive
        #[cfg(not(feature = "simd"))]
        assert!(!simd_active());
        // with it, active or not, matmul_simd already proved parity above
        let _ = simd_active();
    }
}
