//! Dense square row-major `f32` matrix — the data type of the whole system.
//!
//! Row-major is deliberate: it is the layout the paper's coalesced
//! reads/writes assume (§4.3.3) and the layout the AOT artifacts expect.

use crate::error::{MatexpError, Result};
use crate::linalg::rand::XorShift64;

/// Dense square `n x n` matrix of `f32`, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    n: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// From a row-major buffer; `data.len()` must be `n * n`.
    pub fn from_vec(n: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != n * n {
            return Err(MatexpError::Linalg(format!(
                "from_vec: expected {} elements for n={}, got {}",
                n * n,
                n,
                data.len()
            )));
        }
        Ok(Self { n, data })
    }

    /// Deterministic uniform `[-1, 1)` matrix.
    pub fn random(n: usize, seed: u64) -> Self {
        let mut rng = XorShift64::new(seed);
        let data = (0..n * n).map(|_| rng.next_signed_f32()).collect();
        Self { n, data }
    }

    /// Random matrix rescaled so its spectral radius is ~`target`.
    ///
    /// High powers of an unscaled random matrix overflow f32 almost
    /// immediately; every experiment workload goes through this (the paper
    /// is silent on how its inputs avoided overflow — DESIGN.md §8).
    pub fn random_spectral(n: usize, target: f32, seed: u64) -> Self {
        let m = Self::random(n, seed);
        let radius = m.spectral_radius_estimate(400, seed ^ 0xDEAD);
        if radius == 0.0 {
            return m;
        }
        m.scaled(target / radius)
    }

    /// Deterministic row-stochastic matrix (rows sum to 1): the
    /// Markov-chain workload; its powers stay bounded by construction.
    pub fn random_stochastic(n: usize, seed: u64) -> Self {
        let mut rng = XorShift64::new(seed);
        let mut data = vec![0.0f32; n * n];
        for i in 0..n {
            let row = &mut data[i * n..(i + 1) * n];
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = rng.next_f32() + 1e-3;
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        Self { n, data }
    }

    /// Side length (the matrix is `n × n`).
    pub fn n(&self) -> usize {
        self.n
    }

    /// The row-major element buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access to the row-major element buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the row-major element buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element at `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.n + j]
    }

    /// Set element `(i, j)` to `v`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.n + j] = v;
    }

    /// Row slice (row-major makes this free).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// The transposed matrix.
    pub fn transpose(&self) -> Matrix {
        let n = self.n;
        let mut out = Matrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out.data[j * n + i] = self.data[i * n + j];
            }
        }
        out
    }

    /// A copy with every element multiplied by `s`.
    pub fn scaled(&self, s: f32) -> Matrix {
        Matrix {
            n: self.n,
            data: self.data.iter().map(|v| v * s).collect(),
        }
    }

    /// Largest absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.n, other.n, "max_abs_diff: size mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Largest absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|v| v.abs()).fold(0.0, f32::max)
    }

    /// Approximate equality with mixed absolute/relative tolerance.
    pub fn approx_eq(&self, other: &Matrix, atol: f32, rtol: f32) -> bool {
        if self.n != other.n {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs().max(a.abs()))
    }

    /// Power-iteration estimate of the spectral radius (dominant |λ|).
    pub fn spectral_radius_estimate(&self, iters: usize, seed: u64) -> f32 {
        let n = self.n;
        let mut rng = XorShift64::new(seed);
        let mut v: Vec<f64> = (0..n).map(|_| rng.next_signed_f32() as f64).collect();
        let mut lambda = 0.0f64;
        for _ in 0..iters {
            let mut w = vec![0.0f64; n];
            for i in 0..n {
                let row = self.row(i);
                let mut acc = 0.0f64;
                for j in 0..n {
                    acc += row[j] as f64 * v[j];
                }
                w[i] = acc;
            }
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm == 0.0 {
                return 0.0;
            }
            lambda = norm;
            for x in w.iter_mut() {
                *x /= norm;
            }
            v = w;
        }
        lambda as f32
    }

    /// Is every element finite?
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

impl std::fmt::Display for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.n, self.n)?;
        let show = self.n.min(6);
        for i in 0..show {
            write!(f, "  ")?;
            for j in 0..show {
                write!(f, "{:10.4} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if self.n > show { "..." } else { "" })?;
        }
        if self.n > show {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_diagonal() {
        let m = Matrix::identity(4);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(m.get(i, j), if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn from_vec_rejects_bad_len() {
        assert!(Matrix::from_vec(3, vec![0.0; 8]).is_err());
        assert!(Matrix::from_vec(3, vec![0.0; 9]).is_ok());
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::random(8, 1);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn random_is_deterministic() {
        assert_eq!(Matrix::random(16, 9), Matrix::random(16, 9));
        assert_ne!(Matrix::random(16, 9), Matrix::random(16, 10));
    }

    #[test]
    fn stochastic_rows_sum_to_one() {
        let m = Matrix::random_stochastic(32, 5);
        for i in 0..32 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }

    #[test]
    fn spectral_radius_of_identity_is_one() {
        let e = Matrix::identity(16).spectral_radius_estimate(50, 3);
        assert!((e - 1.0).abs() < 1e-3, "{e}");
    }

    #[test]
    fn spectral_radius_of_diagonal() {
        let mut m = Matrix::zeros(4);
        for (i, v) in [0.5, -3.0, 2.0, 0.1].iter().enumerate() {
            m.set(i, i, *v);
        }
        let e = m.spectral_radius_estimate(200, 4);
        assert!((e - 3.0).abs() < 1e-2, "{e}");
    }

    #[test]
    fn random_spectral_hits_target() {
        // power iteration on a random matrix converges slowly when the top
        // eigenvalues are close or complex — 15% is all this guarantees,
        // and all the workload needs (no f32 overflow at high powers).
        let m = Matrix::random_spectral(32, 0.5, 11);
        let r = m.spectral_radius_estimate(1000, 99);
        assert!((r - 0.5).abs() < 0.075, "{r}");
    }

    #[test]
    fn approx_eq_tolerances() {
        let a = Matrix::from_vec(2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut b = a.clone();
        b.set(0, 0, 1.0 + 1e-6);
        assert!(a.approx_eq(&b, 1e-5, 0.0));
        b.set(0, 0, 1.1);
        assert!(!a.approx_eq(&b, 1e-5, 1e-5));
    }

    #[test]
    fn display_does_not_panic() {
        let _ = format!("{}", Matrix::random(10, 1));
        let _ = format!("{}", Matrix::random(3, 1));
    }
}
