//! Cache-friendly CPU matmul variants (ablation vs the naive baseline).
//!
//! The paper's GPU kernel wins partly because its memory accesses are
//! coalesced (§4.3.3). The CPU analogue of coalescing is stride-1 inner
//! loops; these variants quantify that effect on the host side.

use crate::linalg::matrix::Matrix;

/// `c = a * b` after transposing `b`, so the inner loop walks two
/// contiguous rows (stride-1 on both operands).
pub fn matmul_transposed(a: &Matrix, b: &Matrix) -> Matrix {
    let n = a.n();
    assert_eq!(n, b.n(), "matmul_transposed: size mismatch");
    let bt = b.transpose();
    let mut c = Matrix::zeros(n);
    for i in 0..n {
        let arow = a.row(i);
        for j in 0..n {
            let brow = bt.row(j);
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += arow[k] * brow[k];
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// `i-k-j` loop order: the inner loop streams a row of `b` and a row of
/// `c` with stride 1; no transpose needed.
pub fn matmul_ikj(a: &Matrix, b: &Matrix) -> Matrix {
    let n = a.n();
    assert_eq!(n, b.n(), "matmul_ikj: size mismatch");
    let mut c = Matrix::zeros(n);
    for i in 0..n {
        for k in 0..n {
            let aik = a.get(i, k);
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            let crow = &mut c.data_mut()[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    #[test]
    fn transposed_matches_naive() {
        let a = Matrix::random(32, 3);
        let b = Matrix::random(32, 4);
        let want = matmul_naive(&a, &b);
        assert!(matmul_transposed(&a, &b).approx_eq(&want, 1e-4, 1e-5));
    }

    #[test]
    fn ikj_matches_naive() {
        let a = Matrix::random(32, 5);
        let b = Matrix::random(32, 6);
        let want = matmul_naive(&a, &b);
        assert!(matmul_ikj(&a, &b).approx_eq(&want, 1e-4, 1e-5));
    }

    #[test]
    fn ikj_handles_sparse_rows() {
        let mut a = Matrix::zeros(8);
        a.set(0, 3, 2.0);
        let b = Matrix::random(8, 7);
        let want = matmul_naive(&a, &b);
        assert!(matmul_ikj(&a, &b).approx_eq(&want, 1e-5, 1e-6));
    }
}
