//! Cache-friendly CPU matmul variants (ablation vs the naive baseline).
//!
//! The paper's GPU kernel wins partly because its memory accesses are
//! coalesced (§4.3.3). The CPU analogue of coalescing is stride-1 inner
//! loops; these variants quantify that effect on the host side.

use crate::linalg::matrix::Matrix;

/// `c = a * b` after transposing `b`, so the inner loop walks two
/// contiguous rows (stride-1 on both operands).
pub fn matmul_transposed(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.n());
    matmul_transposed_into(a, b, &mut c);
    c
}

/// In-place form of [`matmul_transposed`]: fully overwrites `c` without
/// allocating the output (the transpose scratch of `b` still allocates).
pub fn matmul_transposed_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let n = a.n();
    assert_eq!(n, b.n(), "matmul_transposed: size mismatch");
    assert_eq!(n, c.n(), "matmul_transposed: output size mismatch");
    let bt = b.transpose();
    for i in 0..n {
        let arow = a.row(i);
        for j in 0..n {
            let brow = bt.row(j);
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += arow[k] * brow[k];
            }
            c.set(i, j, acc);
        }
    }
}

/// `i-k-j` loop order: the inner loop streams a row of `b` and a row of
/// `c` with stride 1; no transpose needed.
pub fn matmul_ikj(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.n());
    matmul_ikj_into(a, b, &mut c);
    c
}

/// In-place form of [`matmul_ikj`]: zeroes then accumulates into `c`
/// (which must not alias `a` or `b`) without allocating.
pub fn matmul_ikj_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let n = a.n();
    assert_eq!(n, b.n(), "matmul_ikj: size mismatch");
    assert_eq!(n, c.n(), "matmul_ikj: output size mismatch");
    c.data_mut().fill(0.0);
    for i in 0..n {
        for k in 0..n {
            let aik = a.get(i, k);
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            let crow = &mut c.data_mut()[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    #[test]
    fn transposed_matches_naive() {
        let a = Matrix::random(32, 3);
        let b = Matrix::random(32, 4);
        let want = matmul_naive(&a, &b);
        assert!(matmul_transposed(&a, &b).approx_eq(&want, 1e-4, 1e-5));
    }

    #[test]
    fn ikj_matches_naive() {
        let a = Matrix::random(32, 5);
        let b = Matrix::random(32, 6);
        let want = matmul_naive(&a, &b);
        assert!(matmul_ikj(&a, &b).approx_eq(&want, 1e-4, 1e-5));
    }

    #[test]
    fn into_forms_overwrite_stale_output() {
        let a = Matrix::random(16, 1);
        let b = Matrix::random(16, 2);
        let want = matmul_naive(&a, &b);
        let mut c = Matrix::random(16, 3);
        matmul_transposed_into(&a, &b, &mut c);
        assert!(c.approx_eq(&want, 1e-4, 1e-5));
        let mut c = Matrix::random(16, 4);
        matmul_ikj_into(&a, &b, &mut c);
        assert!(c.approx_eq(&want, 1e-4, 1e-5));
    }

    #[test]
    fn ikj_handles_sparse_rows() {
        let mut a = Matrix::zeros(8);
        a.set(0, 3, 2.0);
        let b = Matrix::random(8, 7);
        let want = matmul_naive(&a, &b);
        assert!(matmul_ikj(&a, &b).approx_eq(&want, 1e-5, 1e-6));
    }
}
