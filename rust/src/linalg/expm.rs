//! CPU matrix exponentiation: the baselines of §4.1 (naive chain) plus a
//! CPU execution of the binary plan — used both as an experiment arm and
//! as the oracle the PJRT engine results are checked against.

use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::linalg::{
    autotune, blocked, naive, packed, strassen, threaded, transposed, MatmulFn, MatmulIntoFn,
};
use crate::plan::Plan;

/// Which CPU matmul backs the exponentiation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuAlgo {
    /// Paper §4.1: sequential i-j-k (the official baseline).
    Naive,
    /// B-transposed dot-product form.
    Transposed,
    /// i-k-j streaming form.
    Ikj,
    /// Cache-blocked tiles.
    Blocked,
    /// Rayon row-parallel (the "fair CPU" ablation).
    Threaded,
    /// Packed-panel register-tile microkernel (portable scalar).
    Packed,
    /// Packed microkernel through explicit `std::arch` SIMD when the
    /// `simd` feature and CPU allow it; scalar-packed fallback otherwise.
    Simd,
    /// Strassen fast multiply above the tuned crossover (packed base
    /// case below it).
    Strassen,
    /// Autotuned dispatch: the per-size winner recorded by
    /// [`crate::linalg::autotune`] (Blocked until the tuner has run).
    Auto,
}

impl CpuAlgo {
    /// The allocating form of this variant's matmul kernel.
    pub fn matmul(self) -> MatmulFn {
        match self {
            CpuAlgo::Naive => naive::matmul_naive,
            CpuAlgo::Transposed => transposed::matmul_transposed,
            CpuAlgo::Ikj => transposed::matmul_ikj,
            CpuAlgo::Blocked => blocked::matmul_blocked_default,
            CpuAlgo::Threaded => threaded::matmul_threaded,
            CpuAlgo::Packed => packed::matmul_packed,
            CpuAlgo::Simd => packed::matmul_simd,
            CpuAlgo::Strassen => strassen::matmul_strassen,
            CpuAlgo::Auto => autotune::matmul_auto,
        }
    }

    /// The in-place (output-buffer) form of this variant — what the
    /// buffer-residency layer launches through.
    pub fn matmul_into(self) -> MatmulIntoFn {
        match self {
            CpuAlgo::Naive => naive::matmul_naive_into,
            CpuAlgo::Transposed => transposed::matmul_transposed_into,
            CpuAlgo::Ikj => transposed::matmul_ikj_into,
            CpuAlgo::Blocked => blocked::matmul_blocked_default_into,
            CpuAlgo::Threaded => threaded::matmul_threaded_into,
            CpuAlgo::Packed => packed::matmul_packed_into,
            CpuAlgo::Simd => packed::matmul_simd_into,
            CpuAlgo::Strassen => strassen::matmul_strassen_into,
            CpuAlgo::Auto => autotune::matmul_auto_into,
        }
    }

    /// Canonical lowercase name (CLI/config vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            CpuAlgo::Naive => "naive",
            CpuAlgo::Transposed => "transposed",
            CpuAlgo::Ikj => "ikj",
            CpuAlgo::Blocked => "blocked",
            CpuAlgo::Threaded => "threaded",
            CpuAlgo::Packed => "packed",
            CpuAlgo::Simd => "simd",
            CpuAlgo::Strassen => "strassen",
            CpuAlgo::Auto => "auto",
        }
    }

    /// Every variant, for exhaustive parsing/tests/ablations.
    pub fn all() -> [CpuAlgo; 9] {
        [
            CpuAlgo::Naive,
            CpuAlgo::Transposed,
            CpuAlgo::Ikj,
            CpuAlgo::Blocked,
            CpuAlgo::Threaded,
            CpuAlgo::Packed,
            CpuAlgo::Simd,
            CpuAlgo::Strassen,
            CpuAlgo::Auto,
        ]
    }
}

impl std::str::FromStr for CpuAlgo {
    type Err = MatexpError;

    fn from_str(s: &str) -> Result<Self> {
        CpuAlgo::all()
            .into_iter()
            .find(|a| a.name() == s.to_ascii_lowercase())
            .ok_or_else(|| {
                MatexpError::Config(format!(
                    "unknown cpu algo {s:?} \
                     (naive|transposed|ikj|blocked|threaded|packed|simd|strassen|auto)"
                ))
            })
    }
}

impl std::fmt::Display for CpuAlgo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// `a^power` by `power - 1` successive multiplies (the paper's CPU loop).
pub fn expm_naive(a: &Matrix, power: u64, algo: CpuAlgo) -> Result<Matrix> {
    if power == 0 {
        return Err(MatexpError::Plan("power must be >= 1".into()));
    }
    let mm = algo.matmul();
    let mut acc = a.clone();
    for _ in 1..power {
        acc = mm(&acc, a);
    }
    Ok(acc)
}

/// Execute an arbitrary [`Plan`] on the CPU. This is the reference
/// evaluator for every plan kind (proptests replay plans through here and
/// through modular-scalar arithmetic — see `plan::eval`).
pub fn expm_plan(a: &Matrix, plan: &Plan, algo: CpuAlgo) -> Result<Matrix> {
    let mm = algo.matmul();
    let out = plan.eval(a.clone(), |x, y| mm(x, y))?;
    Ok(out)
}

/// `a^power` via the binary square-and-multiply plan.
pub fn expm(a: &Matrix, power: u64, algo: CpuAlgo) -> Result<Matrix> {
    if power == 0 {
        return Err(MatexpError::Plan("power must be >= 1".into()));
    }
    expm_plan(a, &Plan::binary(power, false), algo)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Matrix {
        Matrix::random_spectral(12, 0.95, 77)
    }

    #[test]
    fn cpu_algo_string_roundtrip() {
        use std::str::FromStr;
        for a in CpuAlgo::all() {
            assert_eq!(CpuAlgo::from_str(a.name()).unwrap(), a);
        }
        assert!(CpuAlgo::from_str("gpu").is_err());
        assert_eq!(CpuAlgo::from_str("Blocked").unwrap(), CpuAlgo::Blocked);
    }

    #[test]
    fn power_one_is_identity_op() {
        let a = base();
        assert_eq!(expm_naive(&a, 1, CpuAlgo::Naive).unwrap(), a);
        assert_eq!(expm(&a, 1, CpuAlgo::Naive).unwrap(), a);
    }

    #[test]
    fn power_zero_rejected() {
        assert!(expm_naive(&base(), 0, CpuAlgo::Naive).is_err());
        assert!(expm(&base(), 0, CpuAlgo::Naive).is_err());
    }

    #[test]
    fn binary_matches_naive_small_powers() {
        let a = base();
        for p in [1u64, 2, 3, 4, 5, 7, 8, 13, 16, 31, 32, 33] {
            let want = expm_naive(&a, p, CpuAlgo::Naive).unwrap();
            let got = expm(&a, p, CpuAlgo::Naive).unwrap();
            assert!(got.approx_eq(&want, 1e-3, 1e-3), "p={p}");
        }
    }

    #[test]
    fn all_algos_agree() {
        let a = base();
        let want = expm(&a, 9, CpuAlgo::Naive).unwrap();
        for algo in [
            CpuAlgo::Transposed,
            CpuAlgo::Ikj,
            CpuAlgo::Blocked,
            CpuAlgo::Threaded,
            CpuAlgo::Packed,
            CpuAlgo::Simd,
            CpuAlgo::Strassen,
            CpuAlgo::Auto,
        ] {
            let got = expm(&a, 9, algo).unwrap();
            assert!(got.approx_eq(&want, 1e-3, 1e-3), "{}", algo.name());
        }
    }

    #[test]
    fn in_place_forms_match_allocating_forms() {
        let a = Matrix::random(24, 41);
        let b = Matrix::random(24, 42);
        for algo in CpuAlgo::all() {
            if algo == CpuAlgo::Auto {
                // Auto reads the global tuning table, which concurrent
                // tests may update between the two calls — covered by
                // the approx test in linalg::autotune instead
                continue;
            }
            let want = (algo.matmul())(&a, &b);
            let mut c = Matrix::random(24, 43); // stale contents must vanish
            (algo.matmul_into())(&a, &b, &mut c);
            assert_eq!(c, want, "{}", algo.name());
        }
    }

    #[test]
    fn identity_powers_stay_identity() {
        let e = Matrix::identity(8);
        let got = expm(&e, 1024, CpuAlgo::Blocked).unwrap();
        assert!(got.approx_eq(&e, 1e-6, 0.0));
    }

    #[test]
    fn stochastic_high_power_stays_finite() {
        let a = Matrix::random_stochastic(16, 3);
        let got = expm(&a, 1024, CpuAlgo::Ikj).unwrap();
        assert!(got.is_finite());
        // rows of a stochastic matrix power still sum to ~1
        for i in 0..16 {
            let s: f32 = got.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-3, "row {i}: {s}");
        }
    }
}
