//! The paper's §4.1 "Sequential CPU" baseline: the textbook `i-j-k`
//! triple loop, single-threaded, no blocking, no vectorization hints.
//!
//! This is intentionally *not* optimized — it is the yardstick every GPU
//! speedup in Tables 2–5 is measured against. Faster CPU variants live in
//! the sibling modules as ablations.

use crate::linalg::matrix::Matrix;

/// `c = a * b` via the classic i-j-k loop (paper §4.1, verbatim structure).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.n());
    matmul_naive_into(a, b, &mut c);
    c
}

/// In-place form of [`matmul_naive`]: fully overwrites `c` (which must be
/// `n×n` and must not alias `a` or `b`) without allocating.
pub fn matmul_naive_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let n = a.n();
    assert_eq!(n, b.n(), "matmul_naive: size mismatch");
    assert_eq!(n, c.n(), "matmul_naive: output size mismatch");
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += a.get(i, k) * b.get(k, j);
            }
            c.set(i, j, acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_neutral() {
        let a = Matrix::random(16, 1);
        let e = Matrix::identity(16);
        assert_eq!(matmul_naive(&a, &e), a);
        assert_eq!(matmul_naive(&e, &a), a);
    }

    #[test]
    fn known_2x2() {
        let a = Matrix::from_vec(2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(2, vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let c = matmul_naive(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn zero_annihilates() {
        let a = Matrix::random(8, 2);
        let z = Matrix::zeros(8);
        assert_eq!(matmul_naive(&a, &z), z);
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        matmul_naive(&Matrix::zeros(4), &Matrix::zeros(8));
    }

    #[test]
    fn into_overwrites_stale_output() {
        let a = Matrix::random(8, 3);
        let b = Matrix::random(8, 4);
        let mut c = Matrix::random(8, 5); // stale garbage must vanish
        matmul_naive_into(&a, &b, &mut c);
        assert_eq!(c, matmul_naive(&a, &b));
    }

    #[test]
    #[should_panic]
    fn into_rejects_bad_output_size() {
        let mut c = Matrix::zeros(5);
        matmul_naive_into(&Matrix::zeros(4), &Matrix::zeros(4), &mut c);
    }
}
