//! Strassen fast matrix multiply: 7 recursive multiplies instead of 8.
//!
//! Above a crossover size the O(n^2.807) multiply count beats the extra
//! O(n²) adds; below it the packed microkernel
//! ([`crate::linalg::packed`]) wins on constants, so recursion bottoms
//! out there. The crossover is a tunable: the runtime autotuner
//! ([`crate::linalg::autotune`]) measures where the trade flips on the
//! actual machine and overrides [`DEFAULT_CROSSOVER`].
//!
//! Odd sizes are handled by per-level zero padding: each half-block is
//! extracted at `m = ⌈n/2⌉` with the missing row/column zero-filled, and
//! the write-back clips to the real output — no power-of-two requirement
//! anywhere, which matters because exponentiation workloads arrive at
//! arbitrary n.

use crate::linalg::matrix::Matrix;
use crate::linalg::packed;

/// Recursion cutoff used until the autotuner measures a better one:
/// sub-multiplies at or below this size run the packed microkernel
/// directly.
pub const DEFAULT_CROSSOVER: usize = 128;

/// `a · b` via Strassen recursion with the autotuned crossover
/// ([`crate::linalg::autotune::strassen_crossover`]).
pub fn matmul_strassen(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_strassen_with(a, b, crate::linalg::autotune::strassen_crossover())
}

/// In-place form of [`matmul_strassen`] (output fully overwritten).
pub fn matmul_strassen_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(c.n(), a.n(), "output size mismatch");
    let out = matmul_strassen(a, b);
    c.data_mut().copy_from_slice(out.data());
}

/// `a · b` via Strassen recursion with an explicit crossover (tests and
/// the autotuner's crossover probe use this; everything else should go
/// through [`matmul_strassen`]).
pub fn matmul_strassen_with(a: &Matrix, b: &Matrix, crossover: usize) -> Matrix {
    assert_eq!(a.n(), b.n(), "matmul size mismatch");
    rec(a, b, crossover.max(2))
}

fn rec(a: &Matrix, b: &Matrix, crossover: usize) -> Matrix {
    let n = a.n();
    if n <= crossover {
        return packed::matmul_packed(a, b);
    }
    // ⌈n/2⌉ half-blocks, zero-padded on the odd edge
    let m = n.div_ceil(2);
    let a11 = block(a, 0, 0, m);
    let a12 = block(a, 0, m, m);
    let a21 = block(a, m, 0, m);
    let a22 = block(a, m, m, m);
    let b11 = block(b, 0, 0, m);
    let b12 = block(b, 0, m, m);
    let b21 = block(b, m, 0, m);
    let b22 = block(b, m, m, m);

    // Strassen's seven products
    let m1 = rec(&add(&a11, &a22), &add(&b11, &b22), crossover);
    let m2 = rec(&add(&a21, &a22), &b11, crossover);
    let m3 = rec(&a11, &sub(&b12, &b22), crossover);
    let m4 = rec(&a22, &sub(&b21, &b11), crossover);
    let m5 = rec(&add(&a11, &a12), &b22, crossover);
    let m6 = rec(&sub(&a21, &a11), &add(&b11, &b12), crossover);
    let m7 = rec(&sub(&a12, &a22), &add(&b21, &b22), crossover);

    let c11 = add(&sub(&add(&m1, &m4), &m5), &m7);
    let c12 = add(&m3, &m5);
    let c21 = add(&m2, &m4);
    let c22 = add(&sub(&add(&m1, &m3), &m2), &m6);

    let mut c = Matrix::zeros(n);
    write_block(&mut c, &c11, 0, 0);
    write_block(&mut c, &c12, 0, m);
    write_block(&mut c, &c21, m, 0);
    write_block(&mut c, &c22, m, m);
    c
}

/// Extract the `m×m` block at `(i0, j0)`, zero-padding past the edge.
fn block(src: &Matrix, i0: usize, j0: usize, m: usize) -> Matrix {
    let n = src.n();
    let mut out = Matrix::zeros(m);
    let rows = m.min(n.saturating_sub(i0));
    let cols = m.min(n.saturating_sub(j0));
    let s = src.data();
    let d = out.data_mut();
    for i in 0..rows {
        let row = (i0 + i) * n + j0;
        d[i * m..i * m + cols].copy_from_slice(&s[row..row + cols]);
    }
    out
}

/// Write `blk` into `dst` at `(i0, j0)`, clipping the padded edge.
fn write_block(dst: &mut Matrix, blk: &Matrix, i0: usize, j0: usize) {
    let n = dst.n();
    let m = blk.n();
    let rows = m.min(n.saturating_sub(i0));
    let cols = m.min(n.saturating_sub(j0));
    let s = blk.data();
    let d = dst.data_mut();
    for i in 0..rows {
        let row = (i0 + i) * n + j0;
        d[row..row + cols].copy_from_slice(&s[i * m..i * m + cols]);
    }
}

/// Elementwise `x + y`.
fn add(x: &Matrix, y: &Matrix) -> Matrix {
    let mut out = x.clone();
    for (d, s) in out.data_mut().iter_mut().zip(y.data()) {
        *d += *s;
    }
    out
}

/// Elementwise `x - y`.
fn sub(x: &Matrix, y: &Matrix) -> Matrix {
    let mut out = x.clone();
    for (d, s) in out.data_mut().iter_mut().zip(y.data()) {
        *d -= *s;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::naive::matmul_naive;

    #[test]
    fn matches_naive_with_deep_recursion() {
        // crossover 2 forces multiple recursion levels, including the
        // odd-size padding path (5, 7, 9, 13)
        for n in [1usize, 2, 3, 4, 5, 7, 8, 9, 13, 16, 24] {
            let a = Matrix::random(n, 21);
            let b = Matrix::random(n, 22);
            let want = matmul_naive(&a, &b);
            let got = matmul_strassen_with(&a, &b, 2);
            assert!(
                got.approx_eq(&want, 1e-3, 1e-3),
                "n={n} diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn default_crossover_path_matches_packed() {
        // below the crossover, strassen IS the packed kernel
        let a = Matrix::random(24, 31);
        let b = Matrix::random(24, 32);
        assert_eq!(
            matmul_strassen(&a, &b),
            packed::matmul_packed(&a, &b)
        );
    }

    #[test]
    fn into_overwrites_stale_output() {
        let a = Matrix::random(9, 41);
        let b = Matrix::random(9, 42);
        let want = matmul_strassen_with(&a, &b, 2);
        let mut c = Matrix::random(9, 99); // stale contents must vanish
        let out = matmul_strassen(&a, &b);
        c.data_mut().copy_from_slice(out.data());
        assert!(c.approx_eq(&want, 1e-4, 1e-4));
        let mut c2 = Matrix::random(9, 98);
        matmul_strassen_into(&a, &b, &mut c2);
        assert_eq!(c2, out);
    }

    #[test]
    fn block_extraction_pads_and_clips() {
        // n=3 → m=2: the (m, m) block holds only element (2, 2)
        let a = Matrix::from_vec(3, (0..9).map(|v| v as f32).collect()).unwrap();
        let b22 = block(&a, 2, 2, 2);
        assert_eq!(b22.data(), &[8.0, 0.0, 0.0, 0.0]);
        let mut back = Matrix::zeros(3);
        write_block(&mut back, &b22, 2, 2);
        assert_eq!(back.get(2, 2), 8.0);
        assert_eq!(back.get(0, 0), 0.0);
    }
}
