//! CPU linear-algebra substrate.
//!
//! The paper's "Sequential CPU" baseline (§4.1) is [`naive::matmul_naive`]
//! — the classic `i-j-k` triple loop, executed `N - 1` times for `A^N`.
//! The stronger CPU variants ([`transposed`], [`blocked`], [`threaded`])
//! exist as ablations: they quantify how much of the paper's reported GPU
//! speedup is really "GPU vs *unoptimized* CPU" (DESIGN.md §6).

pub mod blocked;
pub mod expm;
pub mod matrix;
pub mod naive;
pub mod rand;
pub mod threaded;
pub mod transposed;

pub use expm::{expm, CpuAlgo};
pub use matrix::Matrix;

/// A CPU matmul implementation: `c = a * b` for square matrices.
pub type MatmulFn = fn(&Matrix, &Matrix) -> Matrix;

/// An in-place CPU matmul: writes `a * b` into a caller-provided output
/// buffer (fully overwritten; must not alias the operands). This is the
/// zero-allocation form the buffer-residency layer launches through —
/// outputs come from a recycling [`crate::runtime::BufferArena`] instead
/// of a fresh `n×n` allocation per launch.
pub type MatmulIntoFn = fn(&Matrix, &Matrix, &mut Matrix);

/// All CPU matmul variants, for sweeps and dispatch by name.
pub fn matmul_variants() -> Vec<(&'static str, MatmulFn)> {
    vec![
        ("naive", naive::matmul_naive as MatmulFn),
        ("transposed", transposed::matmul_transposed as MatmulFn),
        ("ikj", transposed::matmul_ikj as MatmulFn),
        ("blocked", blocked::matmul_blocked_default as MatmulFn),
        ("threaded", threaded::matmul_threaded as MatmulFn),
    ]
}
