//! CPU linear-algebra substrate.
//!
//! The paper's "Sequential CPU" baseline (§4.1) is [`naive::matmul_naive`]
//! — the classic `i-j-k` triple loop, executed `N - 1` times for `A^N`.
//! The stronger CPU variants ([`transposed`], [`blocked`], [`threaded`])
//! exist as ablations: they quantify how much of the paper's reported GPU
//! speedup is really "GPU vs *unoptimized* CPU" (DESIGN.md §6).
//!
//! The raw-speed tier on top of those ([`packed`], [`strassen`],
//! [`autotune`]) is the CPU answer to the paper's hand-tuned GPU
//! kernels: packed register-tile microkernels (scalar and explicit
//! SIMD), a Strassen fast multiply above a tuned crossover, and a
//! runtime autotuner that races the variants per size and dispatches
//! through the winners (`CpuAlgo::Auto`).

pub mod autotune;
pub mod blocked;
pub mod expm;
pub mod matrix;
pub mod naive;
pub mod packed;
pub mod rand;
pub mod strassen;
pub mod threaded;
pub mod transposed;

pub use expm::{expm, CpuAlgo};
pub use matrix::Matrix;

/// A CPU matmul implementation: `c = a * b` for square matrices.
pub type MatmulFn = fn(&Matrix, &Matrix) -> Matrix;

/// An in-place CPU matmul: writes `a * b` into a caller-provided output
/// buffer (fully overwritten; must not alias the operands). This is the
/// zero-allocation form the buffer-residency layer launches through —
/// outputs come from a recycling [`crate::runtime::BufferArena`] instead
/// of a fresh `n×n` allocation per launch.
pub type MatmulIntoFn = fn(&Matrix, &Matrix, &mut Matrix);

/// All CPU matmul variants, for sweeps and dispatch by name. Derived
/// from [`CpuAlgo::all`] so the list can never drift from the enum.
pub fn matmul_variants() -> Vec<(&'static str, MatmulFn)> {
    CpuAlgo::all()
        .into_iter()
        .map(|a| (a.name(), a.matmul()))
        .collect()
}
