//! Runtime kernel autotuner: probe the CPU matmul variants per size,
//! record the winners, dispatch through them.
//!
//! The pool's micro-calibration (`pool/cost.rs`) times one multiply at
//! one fixed size and extrapolates as uniform `2n³` — good enough to
//! split tiles, wrong about *which kernel* to run, because the variants
//! cross over: the packed microkernel wins small-to-mid sizes, SIMD
//! stretches that lead, and Strassen's 7-multiply recursion overtakes
//! everything past a machine-dependent n. This module generalizes that
//! calibration into a keyed tuning table (the `PlanCache` discipline —
//! a process-global table keyed by probe size, populated once, consulted
//! on every dispatch):
//!
//! 1. [`run`] races the candidate variants at each configured size
//!    (best-of-k timed multiplies) and records a [`TuneRow`] per size.
//! 2. [`CpuAlgo::Auto`](crate::linalg::CpuAlgo) dispatches through
//!    [`best_for`] — the winner at the nearest probed size.
//! 3. The Strassen recursion cutoff and the scheduler's
//!    `PlanKind::Strassen` threshold come from the same table
//!    ([`strassen_crossover`], [`strassen_threshold`]).
//! 4. The pool cost model consumes [`cpu_curve`] so LPT assignment sees
//!    the real per-size throughput curve instead of one extrapolated
//!    point.
//!
//! Winner selection ([`select_winner`]) is a pure function of the
//! measurements, so identical probe data always produces an identical
//! table — the determinism contract the tests pin. Candidate order always
//! starts with `Blocked` (the pre-tier default): a recorded winner is the
//! measured minimum, so tuned dispatch can never pick a variant slower
//! than the default *at a probed size*.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::json_obj;
use crate::linalg::expm::CpuAlgo;
use crate::linalg::matrix::Matrix;
use crate::util::json::Json;

/// One row of the tuning table: the measured winner at one probed size.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneRow {
    /// Probed matrix side length.
    pub n: usize,
    /// Winning variant at this size.
    pub winner: CpuAlgo,
    /// Best-of-probes seconds for one winner multiply.
    pub secs: f64,
    /// Effective winner throughput, `2n³ / secs / 1e9`.
    pub gflops: f64,
}

struct TuneState {
    rows: BTreeMap<usize, TuneRow>,
    probes: u64,
}

fn state() -> &'static Mutex<TuneState> {
    static S: OnceLock<Mutex<TuneState>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(TuneState { rows: BTreeMap::new(), probes: 0 }))
}

fn lock() -> std::sync::MutexGuard<'static, TuneState> {
    state().lock().expect("autotune table poisoned")
}

/// Smallest probed size where Strassen won (0 = none yet).
static STRASSEN_AT: AtomicUsize = AtomicUsize::new(0);

/// Tuned Strassen recursion cutoff (0 = use the compiled default).
static CROSSOVER: AtomicUsize = AtomicUsize::new(0);

/// The variants raced at size `n`, in deterministic tie-break order.
/// `Blocked` (the pre-tier default) always leads so a winner can never be
/// slower than it at a probed size; `Naive`/`Transposed` are excluded
/// (dominated at every size worth a probe budget); Strassen only enters
/// once recursion has room to pay for its extra adds.
pub fn candidates(n: usize) -> Vec<CpuAlgo> {
    let mut c = vec![
        CpuAlgo::Blocked,
        CpuAlgo::Ikj,
        CpuAlgo::Threaded,
        CpuAlgo::Packed,
        CpuAlgo::Simd,
    ];
    if n >= 64 {
        c.push(CpuAlgo::Strassen);
    }
    c
}

/// Pick the winner from `(variant, seconds)` measurements: smallest
/// finite positive time, ties broken by earlier position. Pure — the same
/// measurements always select the same winner, which is what makes the
/// whole table deterministic for a given set of probe timings.
pub fn select_winner(measured: &[(CpuAlgo, f64)]) -> Option<(CpuAlgo, f64)> {
    let mut best: Option<(CpuAlgo, f64)> = None;
    for &(algo, secs) in measured {
        if !secs.is_finite() || secs <= 0.0 {
            continue;
        }
        if best.is_none_or(|(_, b)| secs < b) {
            best = Some((algo, secs));
        }
    }
    best
}

/// Record one size's measurements into the table and refresh the derived
/// Strassen thresholds. Returns the stored row (`None` when no
/// measurement was usable). This is also the test seam: synthetic
/// measurements drive exactly the code path the live probes do.
pub fn record(n: usize, measured: &[(CpuAlgo, f64)]) -> Option<TuneRow> {
    let (mut winner, secs) = select_winner(measured)?;
    if winner == CpuAlgo::Auto {
        winner = CpuAlgo::Blocked; // Auto can't win a race it dispatches
    }
    let row = TuneRow {
        n,
        winner,
        secs,
        gflops: 2.0 * (n as f64).powi(3) / secs / 1e9,
    };
    let mut s = lock();
    s.probes += measured.len() as u64;
    s.rows.insert(n, row.clone());
    // derived thresholds: first size Strassen wins, and the largest
    // probed size where something else still won (= recursion cutoff)
    let first_strassen = s
        .rows
        .values()
        .filter(|r| r.winner == CpuAlgo::Strassen)
        .map(|r| r.n)
        .min();
    STRASSEN_AT.store(first_strassen.unwrap_or(0), Ordering::Relaxed);
    if first_strassen.is_some() {
        let cutoff = s
            .rows
            .values()
            .filter(|r| r.winner != CpuAlgo::Strassen)
            .map(|r| r.n)
            .max()
            .unwrap_or(0);
        CROSSOVER.store(cutoff, Ordering::Relaxed);
    }
    Some(row)
}

/// Time one multiply through `algo`, best of `probes` runs.
fn probe_one(algo: CpuAlgo, a: &Matrix, b: &Matrix, c: &mut Matrix, probes: usize) -> f64 {
    let f = algo.matmul_into();
    let mut best = f64::INFINITY;
    for _ in 0..probes.max(1) {
        let t = Instant::now();
        f(a, b, c);
        best = best.min(t.elapsed().as_secs_f64());
    }
    best
}

/// Race the candidates at each size and record the winners. Returns the
/// recorded rows in probe order. Deterministic inputs (seeded operands),
/// measured timings — the *selection* from those timings is pure.
pub fn run(sizes: &[usize], probes: usize, seed: u64) -> Vec<TuneRow> {
    let mut out = Vec::new();
    for &n in sizes {
        if n == 0 {
            continue;
        }
        let a = Matrix::random_spectral(n, 0.9, seed);
        let b = Matrix::random_spectral(n, 0.9, seed ^ 0x9E37_79B9);
        let mut c = Matrix::zeros(n);
        let measured: Vec<(CpuAlgo, f64)> = candidates(n)
            .into_iter()
            .map(|algo| (algo, probe_one(algo, &a, &b, &mut c, probes)))
            .collect();
        if let Some(row) = record(n, &measured) {
            out.push(row);
        }
    }
    out
}

/// Run the autotuner once per process when the config enables it. Worker
/// engine construction calls this at startup; later calls (more workers,
/// tests) are no-ops.
pub fn ensure(cfg: &crate::config::AutotuneConfig, seed: u64) {
    static RAN: OnceLock<()> = OnceLock::new();
    if !cfg.enabled {
        return;
    }
    RAN.get_or_init(|| {
        run(&cfg.sizes, cfg.probes, seed);
    });
}

/// The tuned variant for size `n`: the recorded winner at the nearest
/// probed size (log-scale distance, so 96 maps to 128 rather than 64
/// being equidistant-by-subtraction). `Blocked` before any tuning.
pub fn best_for(n: usize) -> CpuAlgo {
    let s = lock();
    let target = (n.max(1) as f64).ln();
    let mut best: Option<(f64, CpuAlgo)> = None;
    for (&pn, row) in &s.rows {
        let d = ((pn.max(1) as f64).ln() - target).abs();
        if best.is_none_or(|(bd, _)| d < bd) {
            best = Some((d, row.winner));
        }
    }
    match best {
        Some((_, w)) if w != CpuAlgo::Auto => w,
        _ => CpuAlgo::Blocked,
    }
}

/// The `CpuAlgo::Auto` allocating kernel: dispatch through the table.
pub fn matmul_auto(a: &Matrix, b: &Matrix) -> Matrix {
    (best_for(a.n()).matmul())(a, b)
}

/// The `CpuAlgo::Auto` in-place kernel: dispatch through the table.
pub fn matmul_auto_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    (best_for(a.n()).matmul_into())(a, b, c)
}

/// Smallest probed size where Strassen won the race — the scheduler's
/// threshold for selecting `PlanKind::Strassen`. `None` until a probe
/// says so.
pub fn strassen_threshold() -> Option<usize> {
    match STRASSEN_AT.load(Ordering::Relaxed) {
        0 => None,
        n => Some(n),
    }
}

/// The Strassen recursion cutoff: the largest probed size where a
/// non-Strassen variant still won, or the compiled default before tuning.
pub fn strassen_crossover() -> usize {
    match CROSSOVER.load(Ordering::Relaxed) {
        0 => crate::linalg::strassen::DEFAULT_CROSSOVER,
        n => n,
    }
}

/// Winner seconds-per-multiply at every probed size, ascending — the
/// pool cost model's measured throughput curve.
pub fn cpu_curve() -> Vec<(usize, f64)> {
    lock().rows.values().map(|r| (r.n, r.secs)).collect()
}

/// Every recorded tuning row, probed sizes ascending.
pub fn snapshot() -> Vec<TuneRow> {
    lock().rows.values().cloned().collect()
}

/// Total variant probes recorded since process start.
pub fn probes_total() -> u64 {
    lock().probes
}

/// The tuning table as JSON (metrics endpoint, `expm --explain`).
pub fn to_json() -> Json {
    Json::Arr(
        snapshot()
            .iter()
            .map(|r| {
                json_obj![
                    ("n", r.n as f64),
                    ("winner", r.winner.name()),
                    ("secs", r.secs),
                    ("gflops", r.gflops),
                ]
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_winner_is_deterministic_and_order_tied() {
        let measured = vec![
            (CpuAlgo::Blocked, 2.0),
            (CpuAlgo::Packed, 1.0),
            (CpuAlgo::Simd, 1.0), // tie: earlier candidate wins
            (CpuAlgo::Strassen, f64::NAN),
        ];
        let a = select_winner(&measured);
        let b = select_winner(&measured);
        assert_eq!(a, b, "same probe data must select the same winner");
        assert_eq!(a, Some((CpuAlgo::Packed, 1.0)));
    }

    #[test]
    fn select_winner_skips_unusable_timings() {
        assert_eq!(select_winner(&[]), None);
        assert_eq!(
            select_winner(&[(CpuAlgo::Blocked, f64::INFINITY), (CpuAlgo::Ikj, -1.0)]),
            None
        );
    }

    #[test]
    fn record_builds_a_deterministic_table() {
        // distinct odd sizes so parallel tests can't collide on the key
        let measured = vec![(CpuAlgo::Blocked, 3.0e-3), (CpuAlgo::Packed, 1.0e-3)];
        let r1 = record(9941, &measured).unwrap();
        let r2 = record(9941, &measured).unwrap();
        assert_eq!(r1, r2, "same probe data must produce the same row");
        assert_eq!(r1.winner, CpuAlgo::Packed);
        assert_eq!(best_for(9941), CpuAlgo::Packed);
        assert!(r1.gflops > 0.0);
    }

    #[test]
    fn strassen_win_sets_threshold_and_crossover() {
        record(9973, &[(CpuAlgo::Blocked, 5.0), (CpuAlgo::Strassen, 1.0)]);
        record(9949, &[(CpuAlgo::Blocked, 1.0), (CpuAlgo::Strassen, 5.0)]);
        let t = strassen_threshold().expect("threshold set after a strassen win");
        assert!(t <= 9973);
        // the cutoff is a size where something else won, so recursion
        // always has a measured-profitable base case
        let c = strassen_crossover();
        assert!(c >= 9949 || c == crate::linalg::strassen::DEFAULT_CROSSOVER);
    }

    #[test]
    fn run_probes_record_real_winners() {
        let rows = run(&[12], 1, 7);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].n, 12);
        assert!(rows[0].secs.is_finite() && rows[0].secs > 0.0);
        assert!(probes_total() >= candidates(12).len() as u64);
        // whatever won, auto dispatch at that size must agree numerically
        let a = Matrix::random(12, 1);
        let b = Matrix::random(12, 2);
        let want = crate::linalg::naive::matmul_naive(&a, &b);
        assert!(matmul_auto(&a, &b).approx_eq(&want, 1e-4, 1e-4));
        let mut c = Matrix::random(12, 99);
        matmul_auto_into(&a, &b, &mut c);
        assert!(c.approx_eq(&want, 1e-4, 1e-4));
    }

    #[test]
    fn best_for_defaults_to_blocked_far_from_any_probe() {
        // before/without nearby rows the fallback is the pre-tier default;
        // with rows, it returns SOME recorded winner — never Auto
        let w = best_for(3);
        assert_ne!(w, CpuAlgo::Auto);
    }

    #[test]
    fn json_snapshot_has_one_entry_per_row() {
        record(9967, &[(CpuAlgo::Blocked, 2.0e-3)]);
        match to_json() {
            Json::Arr(rows) => assert_eq!(rows.len(), snapshot().len()),
            other => panic!("expected array, got {other:?}"),
        }
    }
}
