//! Tiny deterministic PRNG (xorshift64*), so workloads are reproducible
//! without pulling in the `rand` crate.

/// xorshift64* — fast, decent-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Seed must not be zero; zero is mapped to a fixed odd constant.
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        // take the top 24 bits for a clean mantissa
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in `[-1, 1)`.
    pub fn next_signed_f32(&mut self) -> f32 {
        self.next_f32() * 2.0 - 1.0
    }

    /// Uniform integer in `[0, bound)`.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        self.next_u64() % bound.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zero_seed_is_valid() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShift64::new(3);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn f32_mean_is_roughly_half() {
        let mut r = XorShift64::new(4);
        let mean: f32 = (0..100_000).map(|_| r.next_f32()).sum::<f32>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }
}
