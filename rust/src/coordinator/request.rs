//! Request/response types of the serving layer.

use std::time::Instant;

use crate::cache::CacheControl;
use crate::exec::Priority;
use crate::linalg::matrix::Matrix;
use crate::plan::{Plan, PlanKind};
use crate::trace::TraceId;

pub use crate::runtime::engine::ExecStats;

/// How the coordinator should compute `A^N`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Paper §4.3 with device-resident registers (binary plan).
    Ours,
    /// §4.3.8 limit: packed `[acc, base]` state, one launch per bit.
    OursPacked,
    /// Binary plan with `square2`/`square4` chain launches.
    OursChained,
    /// Extension: addition-chain plan.
    AdditionChain,
    /// Whole exponentiation in one launch (needs an `expm{N}` artifact).
    FusedArtifact,
    /// Paper §4.2 baseline: one launch per multiply, host round-trip each.
    NaiveGpu,
    /// Ablation A2's counterfactual: the same register plan as `Ours`,
    /// but with a full host round-trip per launch.
    PlanRoundtrip,
    /// Paper §4.1 baseline: sequential i-j-k on the CPU.
    CpuSeq,
}

impl Method {
    /// Canonical lowercase name (CLI/config/wire vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            Method::Ours => "ours",
            Method::OursPacked => "ours-packed",
            Method::OursChained => "ours-chained",
            Method::AdditionChain => "addition-chain",
            Method::FusedArtifact => "fused-artifact",
            Method::NaiveGpu => "naive-gpu",
            Method::PlanRoundtrip => "plan-roundtrip",
            Method::CpuSeq => "cpu-seq",
        }
    }

    /// Every method, for exhaustive parsing/tests.
    pub fn all() -> [Method; 8] {
        [
            Method::Ours,
            Method::OursPacked,
            Method::OursChained,
            Method::AdditionChain,
            Method::FusedArtifact,
            Method::NaiveGpu,
            Method::PlanRoundtrip,
            Method::CpuSeq,
        ]
    }
}

impl std::str::FromStr for Method {
    type Err = crate::error::MatexpError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Method::all()
            .into_iter()
            .find(|m| m.as_str() == s.to_ascii_lowercase())
            .ok_or_else(|| crate::error::MatexpError::Config(format!("unknown method {s:?}")))
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One exponentiation request — the scheduled form of a
/// [`crate::exec::Submission`] (build one with [`ExpmRequest::new`] or
/// lower a submission via the [`crate::exec::Executor`] surface).
#[derive(Clone, Debug)]
pub struct ExpmRequest {
    /// Request id (reply-routing key inside the coordinator).
    pub id: u64,
    /// The operand matrix.
    pub matrix: Matrix,
    /// The exponent `N` in `A^N`.
    pub power: u64,
    /// Which execution method to run.
    pub method: Method,
    /// Explicit launch-plan override (local submissions only; plans do
    /// not cross the wire).
    pub plan: Option<Plan>,
    /// Absolute completion deadline; expired requests fail with the
    /// typed [`crate::error::MatexpError::Deadline`].
    pub deadline: Option<Instant>,
    /// Scheduling priority (`High` skips batch coalescing).
    pub priority: Priority,
    /// Requested accuracy bound (tight bounds pin conservative plans; a
    /// non-finite result violates any tolerance).
    pub tolerance: Option<f32>,
    /// Cache directive for this request (see [`CacheControl`]).
    pub cache: CacheControl,
    /// Correlates every [`crate::trace::Span`] this request produces
    /// (carried from the submission, or minted by [`ExpmRequest::new`]).
    pub trace: TraceId,
    /// When the serving coordinator enqueued this request (stamped by the
    /// service; `None` on direct engine/pool execution). The worker turns
    /// it into the `queue_us` stage of [`ExecStats`].
    pub queued_at: Option<Instant>,
}

impl ExpmRequest {
    /// A plain request with default qualifiers (no deadline, normal
    /// priority, no plan override, no tolerance).
    pub fn new(id: u64, matrix: Matrix, power: u64, method: Method) -> ExpmRequest {
        ExpmRequest {
            id,
            matrix,
            power,
            method,
            plan: None,
            deadline: None,
            priority: Priority::default(),
            tolerance: None,
            cache: CacheControl::default(),
            trace: TraceId::mint(),
            queued_at: None,
        }
    }

    /// Matrix side length.
    pub fn n(&self) -> usize {
        self.matrix.n()
    }
}

/// The served answer.
#[derive(Clone, Debug)]
pub struct ExpmResponse {
    /// Echo of the request's id.
    pub id: u64,
    /// The computed `A^N` (or the cached copy of it).
    pub result: Matrix,
    /// What the execution cost (zeroed launches/transfers on cache hits).
    pub stats: ExecStats,
    /// Echo of the request's method.
    pub method: Method,
    /// Which planner ran (None for fused/packed/CPU paths).
    pub plan_kind: Option<PlanKind>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn method_string_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::from_str(m.as_str()).unwrap(), m);
        }
        assert!(Method::from_str("gpu-magic").is_err());
        assert_eq!(Method::from_str("plan-roundtrip").unwrap(), Method::PlanRoundtrip);
    }

    #[test]
    fn request_reports_size_and_defaults() {
        let r = ExpmRequest::new(1, Matrix::zeros(8), 4, Method::Ours);
        assert_eq!(r.n(), 8);
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.plan.is_none() && r.deadline.is_none() && r.tolerance.is_none());
        assert_ne!(r.trace, TraceId::NONE);
        assert!(r.queued_at.is_none());
    }
}
