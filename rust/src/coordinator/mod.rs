//! Serving coordinator — Layer 3 proper.
//!
//! The paper frames GPU matrix exponentiation as commodity supercomputing
//! ("the vision of super computer at every desk"); this module is the
//! deployment shape that vision implies: a multi-worker service that
//! admits `A^N` requests, groups them by matrix size in a dynamic batcher,
//! plans each one (binary / packed / fused / naive), and executes plans on
//! per-worker backend engines ([`crate::runtime::Backend`]) with
//! device-resident buffers.
//!
//! Data flow (submission is async — `submit_job` returns a
//! [`crate::exec::JobHandle`] immediately; nothing parks per request):
//!
//! ```text
//! submit_job() ──admission──▶ collector thread ──Batcher──▶ batch queue
//!      │ JobHandle                                           │ (mpsc)
//!      ▼ wait/try_result/cancel    worker 0..W (own Engine) ─┤
//!      reply registry (id → sender) ◀────────────────────────┘
//! ```

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod service;
pub mod worker;

pub use batcher::{Batch, Batcher};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{ExecStats, ExpmRequest, ExpmResponse, Method};
pub use service::{Service, ServiceHandle};
