//! Lock-free service metrics: counters + a fixed-bucket latency histogram,
//! plus batcher queue depth and (for the pool backend) per-device
//! utilization and steal counts.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::cache::CacheCounters;
use crate::json_obj;
use crate::pool::DeviceUtil;
use crate::util::json::Json;

/// Histogram bucket upper bounds, microseconds (log-spaced, last = +inf).
pub const LATENCY_BUCKETS_US: [u64; 12] = [
    100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 100_000, 500_000, 2_000_000, u64::MAX,
];

/// Shared, atomically-updated service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests submitted (accepted or not).
    pub requests_total: AtomicU64,
    /// Requests answered successfully.
    pub responses_total: AtomicU64,
    /// Requests rejected by admission control.
    pub rejected_total: AtomicU64,
    /// Requests that failed in execution (or lost their caller).
    pub errors_total: AtomicU64,
    /// Batches shipped to workers.
    pub batches_total: AtomicU64,
    /// Requests across all shipped batches.
    pub batched_requests_total: AtomicU64,
    /// Kernel launches across all served responses.
    pub launches_total: AtomicU64,
    /// Matrix multiplies across all served responses.
    pub multiplies_total: AtomicU64,
    /// Host-edge bytes copied across all served responses (the residency
    /// layer's live counterpart of `ExecStats.bytes_copied`).
    pub bytes_copied_total: AtomicU64,
    /// Launch outputs served from recycled arena buffers, all responses.
    pub buffers_recycled_total: AtomicU64,
    /// Gauge: requests admitted but not yet shipped to a worker.
    /// Incremented at submission, decremented when the collector ships
    /// the batch — so the gauge is live even while the collector idles
    /// (it used to be overwritten only once per collector-loop turn,
    /// which left it stale between batches).
    pub queue_depth: AtomicU64,
    /// Wire bytes read off client connections (JSON lines and binary
    /// frames both), maintained by the TCP front-end.
    pub wire_bytes_in_total: AtomicU64,
    /// Wire bytes written to client connections.
    pub wire_bytes_out_total: AtomicU64,
    /// Binary frames handled (read or written) by the TCP front-end —
    /// how much traffic has moved off the JSON line codec.
    pub frames_total: AtomicU64,
    /// Request payload bytes decoded straight into recycled wire-arena
    /// buffers (the zero-copy frame path's saving: each counted byte is
    /// one that skipped a fresh heap allocation at the wire edge).
    pub wire_bytes_recycled_total: AtomicU64,
    latency_buckets: [AtomicU64; 12],
    latency_sum_us: AtomicU64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests submitted (accepted or not).
    pub requests_total: u64,
    /// Requests answered successfully.
    pub responses_total: u64,
    /// Requests rejected by admission control.
    pub rejected_total: u64,
    /// Requests that failed in execution (or lost their caller).
    pub errors_total: u64,
    /// Batches shipped to workers.
    pub batches_total: u64,
    /// Requests across all shipped batches.
    pub batched_requests_total: u64,
    /// Kernel launches across all served responses.
    pub launches_total: u64,
    /// Matrix multiplies across all served responses.
    pub multiplies_total: u64,
    /// Host-edge bytes copied across all served responses.
    pub bytes_copied_total: u64,
    /// Recycled-buffer launch outputs across all served responses.
    pub buffers_recycled_total: u64,
    /// Requests admitted but not yet shipped to a worker at snapshot time.
    pub queue_depth: u64,
    /// Wire bytes read off client connections.
    pub wire_bytes_in_total: u64,
    /// Wire bytes written to client connections.
    pub wire_bytes_out_total: u64,
    /// Binary frames handled by the TCP front-end.
    pub frames_total: u64,
    /// Request payload bytes decoded into recycled wire-arena buffers.
    pub wire_bytes_recycled_total: u64,
    /// Total cross-queue steals in the device pool (0 off the pool backend).
    pub steals_total: u64,
    /// Per-device utilization (empty off the pool backend); filled by
    /// [`crate::coordinator::service::ServiceHandle::metrics`].
    pub devices: Vec<DeviceUtil>,
    /// Process-wide cache-tier counters (plan / prepared / result), from
    /// [`crate::cache::stats::snapshot`].
    pub cache: CacheCounters,
    /// CPU-kernel autotuner winner table (empty when autotuning is off),
    /// from [`crate::linalg::autotune::snapshot`].
    pub autotune: Vec<crate::linalg::autotune::TuneRow>,
    /// Persistence-tier counters (all zero when no `--store-dir` is
    /// configured), from [`crate::store::counters`].
    pub store: crate::store::StoreCounters,
    /// Latency histogram as `(bucket upper bound µs, count)` pairs.
    pub latency_buckets: Vec<(u64, u64)>,
    /// Mean served latency, microseconds.
    pub latency_mean_us: f64,
    /// Median served latency (bucket upper bound), microseconds.
    pub latency_p50_us: u64,
    /// 99th-percentile served latency (bucket upper bound), microseconds.
    pub latency_p99_us: u64,
}

impl Metrics {
    /// All-zero metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one served response's latency.
    pub fn observe_latency_us(&self, us: u64) {
        let idx = LATENCY_BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(11);
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
    }

    fn percentile(buckets: &[(u64, u64)], total: u64, q: f64) -> u64 {
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for &(bound, count) in buckets {
            seen += count;
            if seen >= target {
                return bound;
            }
        }
        u64::MAX
    }

    /// A point-in-time copy of every counter (plus the process-wide
    /// cache-tier counters).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let buckets: Vec<(u64, u64)> = LATENCY_BUCKETS_US
            .iter()
            .zip(&self.latency_buckets)
            .map(|(&b, c)| (b, c.load(Ordering::Relaxed)))
            .collect();
        let observed: u64 = buckets.iter().map(|&(_, c)| c).sum();
        let sum = self.latency_sum_us.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests_total: self.requests_total.load(Ordering::Relaxed),
            responses_total: self.responses_total.load(Ordering::Relaxed),
            rejected_total: self.rejected_total.load(Ordering::Relaxed),
            errors_total: self.errors_total.load(Ordering::Relaxed),
            batches_total: self.batches_total.load(Ordering::Relaxed),
            batched_requests_total: self.batched_requests_total.load(Ordering::Relaxed),
            launches_total: self.launches_total.load(Ordering::Relaxed),
            multiplies_total: self.multiplies_total.load(Ordering::Relaxed),
            bytes_copied_total: self.bytes_copied_total.load(Ordering::Relaxed),
            buffers_recycled_total: self.buffers_recycled_total.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            wire_bytes_in_total: self.wire_bytes_in_total.load(Ordering::Relaxed),
            wire_bytes_out_total: self.wire_bytes_out_total.load(Ordering::Relaxed),
            frames_total: self.frames_total.load(Ordering::Relaxed),
            wire_bytes_recycled_total: self.wire_bytes_recycled_total.load(Ordering::Relaxed),
            steals_total: 0,
            devices: Vec::new(),
            cache: crate::cache::stats::snapshot(),
            autotune: crate::linalg::autotune::snapshot(),
            store: crate::store::counters(),
            latency_mean_us: if observed == 0 { 0.0 } else { sum as f64 / observed as f64 },
            latency_p50_us: Self::percentile(&buckets, observed, 0.50),
            latency_p99_us: Self::percentile(&buckets, observed, 0.99),
            latency_buckets: buckets,
        }
    }
}

impl MetricsSnapshot {
    /// Serialize for the TCP `metrics` endpoint / `matexp serve` logs.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .latency_buckets
            .iter()
            .map(|&(bound, count)| {
                Json::Arr(vec![Json::Num(bound as f64), Json::Num(count as f64)])
            })
            .collect();
        let autotune: Vec<Json> = self
            .autotune
            .iter()
            .map(|r| {
                json_obj![
                    ("n", r.n as f64),
                    ("winner", r.winner.name()),
                    ("secs", r.secs),
                    ("gflops", r.gflops),
                ]
            })
            .collect();
        let devices: Vec<Json> = self
            .devices
            .iter()
            .map(|d| {
                json_obj![
                    ("name", d.name.as_str()),
                    ("kind", d.kind.as_str()),
                    ("jobs", d.jobs),
                    ("steals", d.steals),
                    ("launches", d.launches),
                    ("busy_s", d.busy_s),
                    ("bytes_copied", d.bytes_copied),
                    ("buffers_recycled", d.buffers_recycled),
                    ("queue_depth", d.queue_depth),
                ]
            })
            .collect();
        json_obj![
            ("requests_total", self.requests_total),
            ("responses_total", self.responses_total),
            ("rejected_total", self.rejected_total),
            ("errors_total", self.errors_total),
            ("batches_total", self.batches_total),
            ("batched_requests_total", self.batched_requests_total),
            ("launches_total", self.launches_total),
            ("multiplies_total", self.multiplies_total),
            ("bytes_copied_total", self.bytes_copied_total),
            ("buffers_recycled_total", self.buffers_recycled_total),
            ("queue_depth", self.queue_depth),
            ("wire_bytes_in_total", self.wire_bytes_in_total),
            ("wire_bytes_out_total", self.wire_bytes_out_total),
            ("frames_total", self.frames_total),
            ("wire_bytes_recycled_total", self.wire_bytes_recycled_total),
            ("steals_total", self.steals_total),
            ("cache", self.cache.to_json()),
            ("store", self.store.to_json()),
            ("autotune", Json::Arr(autotune)),
            ("devices", Json::Arr(devices)),
            ("latency_buckets", Json::Arr(buckets)),
            ("latency_mean_us", self.latency_mean_us),
            ("latency_p50_us", self.latency_p50_us),
            ("latency_p99_us", self.latency_p99_us),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests_total.fetch_add(3, Ordering::Relaxed);
        m.launches_total.fetch_add(10, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests_total, 3);
        assert_eq!(s.launches_total, 10);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.observe_latency_us(90); // bucket 100
        }
        m.observe_latency_us(1_500_000); // bucket 2_000_000
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, 100);
        assert_eq!(s.latency_p99_us, 100);
        assert!(s.latency_mean_us > 90.0);
        let total: u64 = s.latency_buckets.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency_p50_us, 0);
        assert_eq!(s.latency_mean_us, 0.0);
    }

    #[test]
    fn pool_fields_serialize() {
        let m = Metrics::new();
        m.queue_depth.store(3, Ordering::Relaxed);
        let mut s = m.snapshot();
        assert_eq!(s.queue_depth, 3);
        s.steals_total = 2;
        s.devices.push(DeviceUtil {
            name: "sim#0".into(),
            kind: crate::pool::PoolDeviceKind::Sim,
            jobs: 5,
            steals: 2,
            launches: 9,
            busy_s: 0.5,
            bytes_copied: 4096,
            buffers_recycled: 3,
            queue_depth: 1,
        });
        let j = s.to_json().to_string();
        assert!(j.contains("steals_total"), "{j}");
        assert!(j.contains("sim#0"), "{j}");
        assert!(j.contains("queue_depth"), "{j}");
        assert!(j.contains("buffers_recycled"), "{j}");
    }

    #[test]
    fn residency_totals_serialize() {
        let m = Metrics::new();
        m.bytes_copied_total.fetch_add(8192, Ordering::Relaxed);
        m.buffers_recycled_total.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.bytes_copied_total, 8192);
        assert_eq!(s.buffers_recycled_total, 5);
        let j = s.to_json().to_string();
        assert!(j.contains("\"bytes_copied_total\":8192"), "{j}");
        assert!(j.contains("\"buffers_recycled_total\":5"), "{j}");
    }

    #[test]
    fn cache_counters_ride_the_metrics_json() {
        let s = Metrics::new().snapshot();
        let j = s.to_json().to_string();
        assert!(j.contains("\"cache\""), "{j}");
        for field in ["plan_hits", "prepared_hits", "result_hits", "result_evictions"] {
            assert!(j.contains(field), "{field} missing from {j}");
        }
    }

    #[test]
    fn store_counters_ride_the_metrics_json() {
        // store counters are process-global (other tests may bump them),
        // so assert presence of every field rather than exact values
        let s = Metrics::new().snapshot();
        let j = s.to_json().to_string();
        assert!(j.contains("\"store\""), "{j}");
        for field in ["hits", "misses", "spills", "loads", "entries", "bytes"] {
            assert!(j.contains(field), "{field} missing from {j}");
        }
    }

    #[test]
    fn wire_totals_serialize() {
        let m = Metrics::new();
        m.wire_bytes_in_total.fetch_add(100, Ordering::Relaxed);
        m.wire_bytes_out_total.fetch_add(250, Ordering::Relaxed);
        m.frames_total.fetch_add(3, Ordering::Relaxed);
        m.wire_bytes_recycled_total.fetch_add(64, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.wire_bytes_in_total, s.wire_bytes_out_total, s.frames_total), (100, 250, 3));
        assert_eq!(s.wire_bytes_recycled_total, 64);
        let j = s.to_json().to_string();
        assert!(j.contains("\"wire_bytes_in_total\":100"), "{j}");
        assert!(j.contains("\"wire_bytes_out_total\":250"), "{j}");
        assert!(j.contains("\"frames_total\":3"), "{j}");
        assert!(j.contains("\"wire_bytes_recycled_total\":64"), "{j}");
    }

    #[test]
    fn autotune_table_rides_the_metrics_json() {
        // the table itself is process-global (other tests may have
        // populated it), so assert shape rather than contents
        let s = Metrics::new().snapshot();
        let j = s.to_json().to_string();
        assert!(j.contains("\"autotune\":["), "{j}");
        for row in &s.autotune {
            assert!(j.contains(row.winner.name()), "{j}");
        }
    }

    #[test]
    fn huge_latency_lands_in_last_bucket() {
        let m = Metrics::new();
        m.observe_latency_us(u64::MAX - 1);
        let s = m.snapshot();
        assert_eq!(s.latency_buckets.last().unwrap().1, 1);
    }
}
