//! The serving loop: submit → admission → collector/batcher → workers.
//!
//! Threads:
//! * N worker threads, each with its own backend engine (backends may be
//!   `!Send`), pulling batches from a shared queue;
//! * one collector thread running the [`Batcher`] (size-or-deadline);
//! * submission is **asynchronous** (the blocking `submit` shim was
//!   removed in 0.4.0): [`ServiceHandle::submit_job`]
//!   registers a reply slot and returns a [`JobHandle`] immediately —
//!   nobody parks a thread per in-flight request. `wait`/`try_result`/
//!   `cancel`/deadline expiry all operate on the handle; the TCP
//!   front-end multiplexes many in-flight jobs over one reply channel
//!   per connection ([`ServiceHandle::submit_with_id`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::MatexpConfig;
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ExpmRequest, ExpmResponse};
use crate::coordinator::{scheduler, worker};
use crate::error::{MatexpError, Result};
use crate::exec::{JobHandle, ReplyRegistry, ReplySender, Submission};
use crate::pool::DevicePool;
use crate::runtime::BackendKind;
use crate::json_obj;
use crate::trace;
use crate::util::json::Json;

/// Namespace for [`Service::start`].
pub struct Service;

/// Live handle to a running coordinator.
pub struct ServiceHandle {
    cfg: MatexpConfig,
    sizes: Vec<usize>,
    submit_tx: Option<SyncSender<ExpmRequest>>,
    replies: ReplyRegistry,
    metrics: Arc<Metrics>,
    /// The shared device pool when `cfg.backend` is `pool` (workers hold
    /// clones; kept here for observability and lifetime).
    pool: Option<Arc<DevicePool>>,
    next_id: AtomicU64,
    collector: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Spawn workers + collector on the configured backend, return the
    /// handle. An empty `sizes` inventory means size-unrestricted (the
    /// pure-Rust backends); the PJRT backend publishes its artifact sizes
    /// so admission can reject unservable requests up front.
    pub fn start(cfg: MatexpConfig) -> Result<ServiceHandle> {
        cfg.validate()?;
        trace::configure(&cfg.trace);
        let sizes = servable_sizes(&cfg)?;
        let metrics = Arc::new(Metrics::new());
        let replies: ReplyRegistry = Arc::new(Mutex::new(HashMap::new()));

        // one shared device pool for all workers (the pool serializes
        // per-device work on its own threads)
        let pool = if cfg.backend == BackendKind::Pool {
            Some(Arc::new(DevicePool::new(&cfg)?))
        } else {
            None
        };

        let (submit_tx, submit_rx) = sync_channel::<ExpmRequest>(cfg.batcher.max_queue);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // readiness barrier: workers signal once their engine is built
        // (and warmed per cfg.warmup_sizes), so `start` returning means
        // the first real request is served at steady-state latency.
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(), String>>(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for widx in 0..cfg.workers {
            let cfg_w = cfg.clone();
            let batch_rx = Arc::clone(&batch_rx);
            let replies = Arc::clone(&replies);
            let metrics = Arc::clone(&metrics);
            let ready_tx = ready_tx.clone();
            let pool_w = pool.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("matexp-worker-{widx}"))
                    .spawn(move || {
                        worker_loop(&cfg_w, pool_w, &batch_rx, &replies, &metrics, &ready_tx)
                    })
                    .map_err(MatexpError::Io)?,
            );
        }
        drop(ready_tx);
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    return Err(MatexpError::Service(format!("worker failed to start: {msg}")))
                }
                Err(_) => return Err(MatexpError::Service("worker died during startup".into())),
            }
        }

        let collector = {
            let batcher_cfg = cfg.batcher.clone();
            let metrics = Arc::clone(&metrics);
            let replies = Arc::clone(&replies);
            std::thread::Builder::new()
                .name("matexp-collector".into())
                .spawn(move || collector_loop(batcher_cfg, submit_rx, batch_tx, &replies, &metrics))
                .map_err(MatexpError::Io)?
        };

        Ok(ServiceHandle {
            cfg,
            sizes,
            submit_tx: Some(submit_tx),
            replies,
            metrics,
            pool,
            next_id: AtomicU64::new(1),
            collector: Some(collector),
            workers,
        })
    }
}

fn collector_loop(
    batcher_cfg: crate::config::BatcherConfig,
    submit_rx: Receiver<ExpmRequest>,
    batch_tx: SyncSender<Batch>,
    replies: &ReplyRegistry,
    metrics: &Metrics,
) {
    let mut batcher = Batcher::new(batcher_cfg);
    let ship = |batch: Batch, metrics: &Metrics| {
        metrics.batches_total.fetch_add(1, Ordering::Relaxed);
        metrics
            .batched_requests_total
            .fetch_add(batch.requests.len() as u64, Ordering::Relaxed);
        // shipped requests leave the queue: the gauge was incremented at
        // submission, so the enqueue/dequeue pair keeps it live even when
        // this loop idles (it used to be overwritten here each iteration,
        // which left it stale between batches)
        metrics.queue_depth.fetch_sub(batch.requests.len() as u64, Ordering::Relaxed);
        if let Err(send_err) = batch_tx.send(batch) {
            // workers are gone: fail every request in the dropped batch
            // through its reply slot — leaving the slots registered would
            // park their JobHandles forever (the registry itself keeps
            // each reply channel alive, so no disconnect ever fires)
            let dropped = send_err.0;
            for req in dropped.requests {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let slot = replies.lock().expect("reply map poisoned").remove(&req.id);
                if let Some(tx) = slot {
                    let _ = tx.send((
                        req.id,
                        Err(MatexpError::Service(
                            "workers shut down before executing the request".into(),
                        )),
                    ));
                }
            }
        }
    };
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match submit_rx.recv_timeout(timeout) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    ship(batch, metrics);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.flush_all() {
                    ship(batch, metrics);
                }
                return;
            }
        }
        for batch in batcher.flush_due(Instant::now()) {
            ship(batch, metrics);
        }
    }
}

/// Size inventory for admission control: PJRT is bounded by its compiled
/// artifacts; the pure-Rust backends serve any size (empty inventory).
fn servable_sizes(cfg: &MatexpConfig) -> Result<Vec<usize>> {
    match cfg.backend {
        // pool devices are cpu/sim, so the pool is size-unrestricted too
        BackendKind::Cpu | BackendKind::Sim | BackendKind::Pool => Ok(Vec::new()),
        BackendKind::Pjrt => pjrt_sizes(cfg),
    }
}

#[cfg(feature = "xla")]
fn pjrt_sizes(cfg: &MatexpConfig) -> Result<Vec<usize>> {
    let registry = crate::runtime::artifacts::ArtifactRegistry::discover(&cfg.artifacts_dir)?;
    let sizes = registry.sizes(cfg.variant);
    if sizes.is_empty() {
        return Err(MatexpError::Artifact(format!(
            "no {} artifacts found under {}",
            cfg.variant,
            cfg.artifacts_dir.display()
        )));
    }
    Ok(sizes)
}

#[cfg(not(feature = "xla"))]
fn pjrt_sizes(_cfg: &MatexpConfig) -> Result<Vec<usize>> {
    Err(MatexpError::Config(
        "backend \"pjrt\" needs this crate built with `--features xla`".into(),
    ))
}

fn worker_loop(
    cfg: &MatexpConfig,
    pool: Option<Arc<DevicePool>>,
    batch_rx: &Mutex<Receiver<Batch>>,
    replies: &ReplyRegistry,
    metrics: &Metrics,
    ready_tx: &SyncSender<std::result::Result<(), String>>,
) {
    let mut engine = match worker::build_worker_engine(cfg, pool) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e.to_string()));
            return;
        }
    };
    loop {
        let batch = {
            let guard = batch_rx.lock().expect("batch queue poisoned");
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // collector gone: shutdown
            }
        };
        let started = Instant::now();
        // close each request's queue stage: enqueue stamp → this dequeue
        // (the span is recorded here so cancelled requests still show
        // their queueing; `queue_us` rides the response stats)
        let dequeued_us = trace::now_us();
        let mut queue_info: HashMap<u64, (u64, u64, usize)> = HashMap::new();
        for req in &batch.requests {
            let q_us = req
                .queued_at
                .map_or(0, |q| started.saturating_duration_since(q).as_micros() as u64);
            if req.queued_at.is_some() {
                trace::record_span_at(
                    trace::SpanKind::Queue,
                    req.trace,
                    dequeued_us.saturating_sub(q_us),
                    dequeued_us,
                    req.n(),
                );
            }
            queue_info.insert(req.id, (req.trace.get(), q_us, req.n()));
        }
        // the pool dispatches whole batches request-parallel (per-device
        // queues + stealing); everything else executes serially here with
        // per-request latency (a parallel batch's requests all share the
        // batch wall — they really did complete together)
        let parallel = engine.pool_engine().is_some()
            && scheduler::pool_dispatch(batch.n, batch.requests.len(), cfg)
                == scheduler::PoolDispatch::RequestParallel;
        let outcomes: Vec<(u64, Result<ExpmResponse>, Option<Duration>)> = if parallel {
            let pe = engine.pool_engine().expect("checked above");
            pe.execute_batch(batch.requests)
                .into_iter()
                .map(|(id, outcome)| (id, outcome, None))
                .collect()
        } else {
            batch
                .requests
                .into_iter()
                .map(|req| {
                    let t0 = Instant::now();
                    let id = req.id;
                    let outcome = worker::execute(&mut engine, req);
                    (id, outcome, Some(t0.elapsed()))
                })
                .collect()
        };
        for (id, mut outcome, elapsed) in outcomes {
            let (trace_raw, q_us, n) = queue_info.get(&id).copied().unwrap_or((0, 0, 0));
            if let Ok(resp) = &mut outcome {
                resp.stats.queue_us = q_us;
            }
            let reply_tx = replies.lock().expect("reply map poisoned").remove(&id);
            match (&outcome, reply_tx) {
                (Ok(resp), Some(tx)) => {
                    metrics.responses_total.fetch_add(1, Ordering::Relaxed);
                    metrics.launches_total.fetch_add(resp.stats.launches as u64, Ordering::Relaxed);
                    metrics
                        .multiplies_total
                        .fetch_add(resp.stats.multiplies as u64, Ordering::Relaxed);
                    metrics
                        .bytes_copied_total
                        .fetch_add(resp.stats.bytes_copied, Ordering::Relaxed);
                    metrics
                        .buffers_recycled_total
                        .fetch_add(resp.stats.buffers_recycled, Ordering::Relaxed);
                    let latency = elapsed.unwrap_or_else(|| started.elapsed());
                    metrics.observe_latency_us(latency.as_micros() as u64);
                    slow_log(resp, trace_raw, n, latency);
                    let _ = tx.send((id, outcome));
                }
                (Err(_), Some(tx)) => {
                    metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send((id, outcome));
                }
                (_, None) => {
                    // caller gave up (cancelled / deadline expired / handle
                    // dropped); count the work anyway
                    metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Emit the slow-request record to stderr as single-line JSON when one
/// request's end-to-end service latency (dequeue → response, plus its
/// queue stage) crosses the configured threshold
/// ([`crate::config::TraceSettings::slow_ms`] / `--trace-slow-ms`;
/// 0 disables the log).
fn slow_log(resp: &ExpmResponse, trace_raw: u64, n: usize, latency: Duration) {
    let threshold = trace::slow_threshold_us();
    let latency_us = (latency.as_micros() as u64).saturating_add(resp.stats.queue_us);
    if threshold == 0 || latency_us < threshold {
        return;
    }
    let line: Json = json_obj![
        ("slow_request", json_obj![
            ("id", resp.id),
            ("trace", trace_raw),
            ("n", n),
            ("method", resp.method.as_str()),
            ("latency_us", latency_us),
            ("queue_us", resp.stats.queue_us),
            ("plan_us", resp.stats.plan_us),
            ("prepare_us", resp.stats.prepare_us),
            ("launch_us", resp.stats.launch_us),
            ("launches", resp.stats.launches),
        ]),
    ];
    eprintln!("{}", line.to_string());
}

/// Register the reply slot and hand the request to the collector — and,
/// critically, deregister the slot on EVERY error path: a slot whose
/// request never reached the queue would otherwise leak forever (no
/// worker will ever remove it).
fn enqueue(
    replies: &ReplyRegistry,
    submit_tx: &SyncSender<ExpmRequest>,
    req: ExpmRequest,
    reply_tx: ReplySender,
) -> Result<()> {
    let id = req.id;
    replies.lock().expect("reply map poisoned").insert(id, reply_tx);
    if submit_tx.send(req).is_err() {
        replies.lock().expect("reply map poisoned").remove(&id);
        return Err(MatexpError::Service("collector gone".into()));
    }
    Ok(())
}

impl ServiceHandle {
    /// Matrix sizes this service can serve on the device-path methods;
    /// empty means unrestricted (size-agnostic backend).
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Human-readable description of what this coordinator runs on.
    pub fn platform(&self) -> String {
        format!(
            "matexp coordinator ({} workers on backend {})",
            self.cfg.workers, self.cfg.backend
        )
    }

    /// The live metrics struct itself, for layers that update counters
    /// directly (the TCP front-end's wire-byte accounting).
    pub(crate) fn metrics_shared(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Metrics snapshot; on the pool backend it carries the live
    /// per-device utilization and steal counts too.
    pub fn metrics(&self) -> crate::coordinator::metrics::MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        if let Some(pool) = &self.pool {
            let pm = pool.metrics();
            snap.steals_total = pm.devices.iter().map(|d| d.steals).sum();
            snap.devices = pm.devices;
        }
        snap
    }

    /// Reserve a request id (the TCP front-end registers its connection
    /// bookkeeping under the id *before* submitting, so a fast worker
    /// reply can never race past it).
    pub fn reserve_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Asynchronous submission: admit, register the reply slot, enqueue,
    /// and return a [`JobHandle`] — the caller is NOT parked. Admission
    /// failures surface here (typed); execution outcomes arrive through
    /// the handle.
    pub fn submit_job(&self, submission: Submission) -> Result<JobHandle> {
        let id = self.reserve_id();
        let trace = submission.trace;
        let deadline = submission.deadline.map(|d| Instant::now() + d);
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit_request(submission.into_request_at(id, deadline), tx)?;
        Ok(JobHandle::pending(id, trace, deadline, rx, Arc::clone(&self.replies)))
    }

    /// Asynchronous submission with a caller-chosen reserved id
    /// ([`Self::reserve_id`]) and a caller-owned reply channel, so one
    /// channel can carry many in-flight jobs (the TCP front-end runs a
    /// whole pipelined connection over one).
    pub fn submit_with_id(
        &self,
        id: u64,
        submission: Submission,
        reply_tx: ReplySender,
    ) -> Result<()> {
        self.submit_request(submission.into_request(id), reply_tx)
    }

    fn submit_request(&self, mut req: ExpmRequest, reply_tx: ReplySender) -> Result<()> {
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = scheduler::admit(&req, &self.sizes, &self.cfg) {
            self.metrics.rejected_total.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let submit_tx = self
            .submit_tx
            .as_ref()
            .ok_or_else(|| MatexpError::Service("service shut down".into()))?;
        req.queued_at = Some(Instant::now());
        enqueue(&self.replies, submit_tx, req, reply_tx)?;
        // gauge up at enqueue, down when the collector ships the batch —
        // live regardless of whether the collector loop is spinning
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Graceful shutdown: drain the queue, join all threads.
    pub fn shutdown(mut self) {
        self.submit_tx.take(); // closes the collector's input
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        // collector drop closed batch_tx; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        self.submit_tx.take();
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Method;
    use crate::linalg::matrix::Matrix;
    use std::sync::mpsc::channel;

    /// A handle with a live intake queue but NO collector and NO workers:
    /// submissions park in `_intake`, so reply-slot lifecycle (cancel,
    /// deadline, drop) is observable deterministically.
    fn inert_handle() -> (ServiceHandle, Receiver<ExpmRequest>) {
        let (tx, rx) = sync_channel(64);
        let handle = ServiceHandle {
            cfg: MatexpConfig::default(),
            sizes: Vec::new(),
            submit_tx: Some(tx),
            replies: Arc::new(Mutex::new(HashMap::new())),
            metrics: Arc::new(Metrics::new()),
            pool: None,
            next_id: AtomicU64::new(1),
            collector: None,
            workers: Vec::new(),
        };
        (handle, rx)
    }

    fn slots(handle: &ServiceHandle) -> usize {
        handle.replies.lock().unwrap().len()
    }

    /// Regression: a failed hand-off to the collector used to leave the
    /// reply-map entry behind forever. Every error path must deregister.
    #[test]
    fn enqueue_deregisters_reply_slot_when_collector_is_gone() {
        let replies: ReplyRegistry = Arc::new(Mutex::new(HashMap::new()));
        let (submit_tx, submit_rx) = sync_channel::<ExpmRequest>(1);
        drop(submit_rx); // collector is gone
        let (reply_tx, _reply_rx) = channel();
        let req = ExpmRequest::new(7, Matrix::identity(4), 2, Method::Ours);
        let err = enqueue(&replies, &submit_tx, req, reply_tx).unwrap_err();
        assert!(matches!(err, MatexpError::Service(_)), "{err:?}");
        assert!(replies.lock().unwrap().is_empty(), "reply slot leaked");
    }

    #[test]
    fn cancel_deregisters_the_reply_slot() {
        let (handle, _intake) = inert_handle();
        let mut job = handle.submit_job(Submission::expm(Matrix::identity(8), 4)).unwrap();
        assert_eq!(slots(&handle), 1);
        assert!(job.cancel(), "job was still pending, so cancel wins");
        assert_eq!(slots(&handle), 0);
        assert!(matches!(job.wait(), Err(MatexpError::Service(_))));
    }

    #[test]
    fn deadline_expiry_deregisters_and_is_typed() {
        let (handle, _intake) = inert_handle();
        let mut job = handle
            .submit_job(
                Submission::expm(Matrix::identity(8), 4).deadline(Duration::from_millis(5)),
            )
            .unwrap();
        match job.wait() {
            Err(MatexpError::Deadline(_)) => {}
            other => panic!("want typed deadline error, got {other:?}"),
        }
        assert_eq!(slots(&handle), 0);
    }

    #[test]
    fn dropped_handle_deregisters() {
        let (handle, _intake) = inert_handle();
        let job = handle.submit_job(Submission::expm(Matrix::identity(8), 4)).unwrap();
        assert_eq!(slots(&handle), 1);
        drop(job);
        assert_eq!(slots(&handle), 0);
    }

    /// Satellite regression: the queue-depth gauge used to be written
    /// only inside the collector loop, so with an idle (or absent)
    /// collector it stayed stale. It now moves at enqueue time.
    #[test]
    fn queue_depth_moves_at_enqueue_without_a_collector() {
        let (handle, _intake) = inert_handle();
        assert_eq!(handle.metrics.snapshot().queue_depth, 0);
        let _j1 = handle.submit_job(Submission::expm(Matrix::identity(8), 4)).unwrap();
        let _j2 = handle.submit_job(Submission::expm(Matrix::identity(8), 4)).unwrap();
        assert_eq!(handle.metrics.snapshot().queue_depth, 2, "enqueue increments the gauge");
        // a rejected submission never enters the queue
        let _ = handle.submit_job(Submission::expm(Matrix::identity(8), 0));
        assert_eq!(handle.metrics.snapshot().queue_depth, 2);
    }

    /// End-to-end through a real service: the request's spans land in the
    /// flight recorder under the handle's trace id, the queue stage rides
    /// the response stats, and the queue-depth gauge drains back to zero.
    #[test]
    fn served_request_traces_and_drains_the_gauge() {
        // hold the recorder guard: a parallel test may disable recording
        let _guard = crate::trace::test_guard();
        let mut cfg = MatexpConfig::default();
        cfg.workers = 1;
        let handle = Service::start(cfg).unwrap();
        let mut job = handle
            .submit_job(Submission::expm(Matrix::random_spectral(8, 0.9, 3), 64))
            .unwrap();
        let trace_id = job.trace();
        assert_ne!(trace_id, crate::trace::TraceId::NONE);
        let resp = job.wait().unwrap();
        assert!(resp.result.is_finite());
        let spans: Vec<trace::Span> = trace::recent_spans()
            .into_iter()
            .filter(|s| s.trace_id == trace_id.get())
            .collect();
        assert!(spans.iter().any(|s| s.kind == trace::SpanKind::Queue), "{spans:?}");
        assert!(spans.iter().any(|s| s.kind == trace::SpanKind::Execute), "{spans:?}");
        assert!(spans.iter().any(|s| s.kind == trace::SpanKind::Launch), "{spans:?}");
        trace::validate_spans(&spans).unwrap();
        assert_eq!(handle.metrics().queue_depth, 0, "every request shipped");
        handle.shutdown();
    }

    #[test]
    fn admission_failure_registers_nothing() {
        let (handle, _intake) = inert_handle();
        let err = handle.submit_job(Submission::expm(Matrix::identity(8), 0)).unwrap_err();
        assert!(err.to_string().contains("power"), "{err}");
        assert_eq!(slots(&handle), 0);
        assert_eq!(handle.metrics.snapshot().rejected_total, 1);
    }
}
