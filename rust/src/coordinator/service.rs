//! The serving loop: submit → admission → collector/batcher → workers.
//!
//! Threads:
//! * N worker threads, each with its own backend engine (backends may be
//!   `!Send`), pulling batches from a shared queue;
//! * one collector thread running the [`Batcher`] (size-or-deadline);
//! * callers block on a per-request reply channel (the TCP front-end wraps
//!   `submit` in `spawn_blocking`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::MatexpConfig;
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ExpmRequest, ExpmResponse, Method};
use crate::coordinator::{scheduler, worker};
use crate::error::{MatexpError, Result};
use crate::linalg::matrix::Matrix;
use crate::pool::DevicePool;
use crate::runtime::BackendKind;

type Reply = std::result::Result<ExpmResponse, String>;
type ReplyMap = Arc<Mutex<HashMap<u64, SyncSender<Reply>>>>;

/// Namespace for [`Service::start`].
pub struct Service;

/// Live handle to a running coordinator.
pub struct ServiceHandle {
    cfg: MatexpConfig,
    sizes: Vec<usize>,
    submit_tx: Option<SyncSender<ExpmRequest>>,
    replies: ReplyMap,
    metrics: Arc<Metrics>,
    /// The shared device pool when `cfg.backend` is `pool` (workers hold
    /// clones; kept here for observability and lifetime).
    pool: Option<Arc<DevicePool>>,
    next_id: AtomicU64,
    collector: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Spawn workers + collector on the configured backend, return the
    /// handle. An empty `sizes` inventory means size-unrestricted (the
    /// pure-Rust backends); the PJRT backend publishes its artifact sizes
    /// so admission can reject unservable requests up front.
    pub fn start(cfg: MatexpConfig) -> Result<ServiceHandle> {
        cfg.validate()?;
        let sizes = servable_sizes(&cfg)?;
        let metrics = Arc::new(Metrics::new());
        let replies: ReplyMap = Arc::new(Mutex::new(HashMap::new()));

        // one shared device pool for all workers (the pool serializes
        // per-device work on its own threads)
        let pool = if cfg.backend == BackendKind::Pool {
            Some(Arc::new(DevicePool::new(&cfg)?))
        } else {
            None
        };

        let (submit_tx, submit_rx) = sync_channel::<ExpmRequest>(cfg.batcher.max_queue);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // readiness barrier: workers signal once their engine is built
        // (and warmed per cfg.warmup_sizes), so `start` returning means
        // the first real request is served at steady-state latency.
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(), String>>(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for widx in 0..cfg.workers {
            let cfg_w = cfg.clone();
            let batch_rx = Arc::clone(&batch_rx);
            let replies = Arc::clone(&replies);
            let metrics = Arc::clone(&metrics);
            let ready_tx = ready_tx.clone();
            let pool_w = pool.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("matexp-worker-{widx}"))
                    .spawn(move || {
                        worker_loop(&cfg_w, pool_w, &batch_rx, &replies, &metrics, &ready_tx)
                    })
                    .map_err(MatexpError::Io)?,
            );
        }
        drop(ready_tx);
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    return Err(MatexpError::Service(format!("worker failed to start: {msg}")))
                }
                Err(_) => return Err(MatexpError::Service("worker died during startup".into())),
            }
        }

        let collector = {
            let batcher_cfg = cfg.batcher.clone();
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("matexp-collector".into())
                .spawn(move || collector_loop(batcher_cfg, submit_rx, batch_tx, &metrics))
                .map_err(MatexpError::Io)?
        };

        Ok(ServiceHandle {
            cfg,
            sizes,
            submit_tx: Some(submit_tx),
            replies,
            metrics,
            pool,
            next_id: AtomicU64::new(1),
            collector: Some(collector),
            workers,
        })
    }
}

fn collector_loop(
    batcher_cfg: crate::config::BatcherConfig,
    submit_rx: Receiver<ExpmRequest>,
    batch_tx: SyncSender<Batch>,
    metrics: &Metrics,
) {
    let mut batcher = Batcher::new(batcher_cfg);
    let ship = |batch: Batch, metrics: &Metrics| {
        metrics.batches_total.fetch_add(1, Ordering::Relaxed);
        metrics
            .batched_requests_total
            .fetch_add(batch.requests.len() as u64, Ordering::Relaxed);
        // if workers are gone we silently drop; submit() callers observe a
        // closed reply channel
        let _ = batch_tx.send(batch);
    };
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match submit_rx.recv_timeout(timeout) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req, Instant::now()) {
                    ship(batch, metrics);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                for batch in batcher.flush_all() {
                    ship(batch, metrics);
                }
                return;
            }
        }
        for batch in batcher.flush_due(Instant::now()) {
            ship(batch, metrics);
        }
        metrics.queue_depth.store(batcher.len() as u64, Ordering::Relaxed);
    }
}

/// Size inventory for admission control: PJRT is bounded by its compiled
/// artifacts; the pure-Rust backends serve any size (empty inventory).
fn servable_sizes(cfg: &MatexpConfig) -> Result<Vec<usize>> {
    match cfg.backend {
        // pool devices are cpu/sim, so the pool is size-unrestricted too
        BackendKind::Cpu | BackendKind::Sim | BackendKind::Pool => Ok(Vec::new()),
        BackendKind::Pjrt => pjrt_sizes(cfg),
    }
}

#[cfg(feature = "xla")]
fn pjrt_sizes(cfg: &MatexpConfig) -> Result<Vec<usize>> {
    let registry = crate::runtime::artifacts::ArtifactRegistry::discover(&cfg.artifacts_dir)?;
    let sizes = registry.sizes(cfg.variant);
    if sizes.is_empty() {
        return Err(MatexpError::Artifact(format!(
            "no {} artifacts found under {}",
            cfg.variant,
            cfg.artifacts_dir.display()
        )));
    }
    Ok(sizes)
}

#[cfg(not(feature = "xla"))]
fn pjrt_sizes(_cfg: &MatexpConfig) -> Result<Vec<usize>> {
    Err(MatexpError::Config(
        "backend \"pjrt\" needs this crate built with `--features xla`".into(),
    ))
}

fn worker_loop(
    cfg: &MatexpConfig,
    pool: Option<Arc<DevicePool>>,
    batch_rx: &Mutex<Receiver<Batch>>,
    replies: &ReplyMap,
    metrics: &Metrics,
    ready_tx: &SyncSender<std::result::Result<(), String>>,
) {
    let mut engine = match worker::build_worker_engine(cfg, pool) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e.to_string()));
            return;
        }
    };
    loop {
        let batch = {
            let guard = batch_rx.lock().expect("batch queue poisoned");
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // collector gone: shutdown
            }
        };
        let started = Instant::now();
        // the pool dispatches whole batches request-parallel (per-device
        // queues + stealing); everything else executes serially here with
        // per-request latency (a parallel batch's requests all share the
        // batch wall — they really did complete together)
        let parallel = matches!(&engine, worker::WorkerEngine::Pool(_))
            && scheduler::pool_dispatch(batch.n, batch.requests.len(), cfg)
                == scheduler::PoolDispatch::RequestParallel;
        let outcomes: Vec<(u64, Result<ExpmResponse>, Option<Duration>)> = if parallel {
            let worker::WorkerEngine::Pool(pe) = &engine else { unreachable!() };
            pe.execute_batch(batch.requests)
                .into_iter()
                .map(|(id, outcome)| (id, outcome, None))
                .collect()
        } else {
            batch
                .requests
                .into_iter()
                .map(|req| {
                    let t0 = Instant::now();
                    let id = req.id;
                    let outcome = worker::execute(&mut engine, cfg, req);
                    (id, outcome, Some(t0.elapsed()))
                })
                .collect()
        };
        for (id, outcome, elapsed) in outcomes {
            let reply_tx = replies.lock().expect("reply map poisoned").remove(&id);
            match (&outcome, reply_tx) {
                (Ok(resp), Some(tx)) => {
                    metrics.responses_total.fetch_add(1, Ordering::Relaxed);
                    metrics.launches_total.fetch_add(resp.stats.launches as u64, Ordering::Relaxed);
                    metrics
                        .multiplies_total
                        .fetch_add(resp.stats.multiplies as u64, Ordering::Relaxed);
                    metrics
                        .bytes_copied_total
                        .fetch_add(resp.stats.bytes_copied, Ordering::Relaxed);
                    metrics
                        .buffers_recycled_total
                        .fetch_add(resp.stats.buffers_recycled, Ordering::Relaxed);
                    let latency = elapsed.unwrap_or_else(|| started.elapsed());
                    metrics.observe_latency_us(latency.as_micros() as u64);
                    let _ = tx.send(outcome.map_err(|e| e.to_string()));
                }
                (Err(_), Some(tx)) => {
                    metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(outcome.map_err(|e| e.to_string()));
                }
                (_, None) => {
                    // caller gave up (channel dropped); count the work anyway
                    metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

impl ServiceHandle {
    /// Matrix sizes this service can serve on the device-path methods;
    /// empty means unrestricted (size-agnostic backend).
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Metrics snapshot; on the pool backend it carries the live
    /// per-device utilization and steal counts too.
    pub fn metrics(&self) -> crate::coordinator::metrics::MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        if let Some(pool) = &self.pool {
            let pm = pool.metrics();
            snap.steals_total = pm.devices.iter().map(|d| d.steals).sum();
            snap.devices = pm.devices;
        }
        snap
    }

    /// Blocking request: admit, enqueue, wait for the worker's reply.
    pub fn submit(&self, matrix: Matrix, power: u64, method: Method) -> Result<ExpmResponse> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = ExpmRequest { id, matrix, power, method };
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = scheduler::admit(&req, &self.sizes, &self.cfg) {
            self.metrics.rejected_total.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let (tx, rx) = sync_channel::<Reply>(1);
        self.replies.lock().expect("reply map poisoned").insert(id, tx);
        let submit_tx = self
            .submit_tx
            .as_ref()
            .ok_or_else(|| MatexpError::Service("service shut down".into()))?;
        submit_tx
            .send(req)
            .map_err(|_| MatexpError::Service("collector gone".into()))?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(MatexpError::Service(msg)),
            Err(_) => Err(MatexpError::Service("worker dropped the request".into())),
        }
    }

    /// Graceful shutdown: drain the queue, join all threads.
    pub fn shutdown(mut self) {
        self.submit_tx.take(); // closes the collector's input
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        // collector drop closed batch_tx; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        self.submit_tx.take();
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
