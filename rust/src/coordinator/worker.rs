//! Worker: owns an [`Engine`] over the configured backend (backends may
//! be `!Send`, so each worker thread builds its own) — or, for the `pool`
//! backend, a [`PoolEngine`] handle onto the shared device pool — and
//! executes scheduled requests.

use std::sync::Arc;
use std::time::Instant;

use crate::cache::ResultCachePolicy;
use crate::config::MatexpConfig;
use crate::coordinator::request::{ExecStats, ExpmRequest, ExpmResponse};
use crate::coordinator::scheduler::{strategy_for, Strategy};
use crate::error::Result;
use crate::linalg::{self, CpuAlgo};
use crate::pool::{DevicePool, PoolEngine};
use crate::runtime::engine::AnyEngine;
use crate::runtime::{Backend, BackendKind, Engine};
use crate::trace;

/// Execute one request on this worker's engine: the strategy dispatch
/// behind every [`crate::exec::Executor`] — deadline preflight, the
/// result-cache consult (tier 3: a warm hit answers without touching the
/// backend), the method→discipline mapping, and the shared
/// post-execution contract checks (late completion, tolerance
/// violations).
pub fn execute_request<B: Backend>(
    engine: &mut Engine<B>,
    cfg: &MatexpConfig,
    req: &ExpmRequest,
) -> Result<ExpmResponse> {
    crate::exec::check_deadline(req.deadline)?;
    // everything below runs in the request's trace context: launch /
    // prepare spans recorded by the engine correlate to req.trace, and
    // the stage accumulators drain into the response's stats
    let _scope = trace::enter(req.trace);
    let exec_start = trace::now_us();
    let cache = ResultCachePolicy::for_request(cfg, req);
    if let Some(resp) = cache.lookup(req.id) {
        trace::record_span(trace::SpanKind::Execute, req.trace, exec_start, req.n());
        return crate::exec::enforce(req.deadline, req.tolerance, resp);
    }
    let plan_t0 = trace::now_us();
    let strategy = strategy_for(req, cfg);
    trace::add_stage(trace::Stage::Plan, trace::now_us().saturating_sub(plan_t0));
    let (result, stats, plan_kind) = match strategy {
        Strategy::DeviceResident(plan) => {
            let kind = plan.kind;
            let (m, s) = engine.run_plan(&req.matrix, &plan)?;
            (m, s, Some(kind))
        }
        Strategy::PlanRoundtrip(plan) => {
            let kind = plan.kind;
            let (m, s) = engine.run_plan_roundtrip(&req.matrix, &plan)?;
            (m, s, Some(kind))
        }
        Strategy::Packed => {
            let (m, s) = engine.run_packed(&req.matrix, req.power)?;
            (m, s, None)
        }
        Strategy::Fused => {
            let (m, s) = engine.run_fused(&req.matrix, req.power)?;
            (m, s, None)
        }
        Strategy::NaiveRoundtrip => {
            let (m, s) = engine.run_naive_roundtrip(&req.matrix, req.power)?;
            (m, s, None)
        }
        Strategy::CpuSequential => {
            let t0 = Instant::now();
            let m = linalg::expm::expm_naive(&req.matrix, req.power, CpuAlgo::Naive)?;
            let stats = ExecStats {
                multiplies: (req.power - 1) as usize,
                wall_s: t0.elapsed().as_secs_f64(),
                ..ExecStats::default()
            };
            (m, stats, None)
        }
    };
    let mut stats = stats;
    let [plan_us, prepare_us, launch_us] = trace::take_stages();
    stats.plan_us = plan_us;
    stats.prepare_us = prepare_us;
    stats.launch_us = launch_us;
    let resp = ExpmResponse { id: req.id, result, stats, method: req.method, plan_kind };
    // enforce BEFORE storing: a response that violates its contract
    // (late, or non-finite under a tolerance) must not occupy cache
    // budget with an answer that can never be served successfully
    let resp = crate::exec::enforce(req.deadline, req.tolerance, resp)?;
    cache.store(&resp);
    trace::record_span(trace::SpanKind::Execute, req.trace, exec_start, req.n());
    Ok(resp)
}

/// Build the engine a worker thread uses (one per thread; compiled/cached
/// state lives inside for the worker's lifetime). Sizes listed in
/// `cfg.warmup_sizes` are prepared AND executed once so the worker's
/// first real request is served at steady-state latency.
pub fn build_engine(cfg: &MatexpConfig) -> Result<AnyEngine> {
    // open the persistent store first (warm-loads a saved autotune table
    // and memoized plans, so a restart skips re-probing/re-planning)
    crate::store::configure(&cfg.store)?;
    // probe CPU kernel variants once per process (no-op unless enabled);
    // the winner table steers CpuAlgo::Auto and the Strassen threshold
    crate::linalg::autotune::ensure(&cfg.autotune, cfg.seed);
    crate::store::persist_autotune();
    let mut engine = Engine::from_config(cfg)?;
    for &n in &cfg.warmup_sizes {
        // a size the backend cannot serve is a config mistake worth surfacing
        engine.warmup_exec(n)?;
    }
    Ok(engine)
}

/// What a coordinator worker actually drives: its own single-backend
/// engine, or a handle onto the shared multi-device pool — bound to the
/// config it was built from, so strategy dispatch
/// (`use_square_chains`, admission limits, …) follows the caller's
/// configuration rather than crate defaults.
pub struct WorkerEngine {
    cfg: MatexpConfig,
    kind: WorkerKind,
}

/// The execution substrate behind a [`WorkerEngine`].
pub enum WorkerKind {
    /// The worker's own single-backend engine.
    Single(Box<AnyEngine>),
    /// A handle onto the shared multi-device pool.
    Pool(PoolEngine),
}

impl WorkerEngine {
    /// Human-readable description of the execution substrate.
    pub fn platform(&self) -> String {
        match &self.kind {
            WorkerKind::Single(e) => e.platform(),
            WorkerKind::Pool(pe) => pe.platform(),
        }
    }

    /// The configuration this worker dispatches with.
    pub fn config(&self) -> &MatexpConfig {
        &self.cfg
    }

    /// The pool engine, when this worker drives the shared device pool.
    pub fn pool_engine(&self) -> Option<&PoolEngine> {
        match &self.kind {
            WorkerKind::Pool(pe) => Some(pe),
            WorkerKind::Single(_) => None,
        }
    }
}

/// Build a worker's engine. For the `pool` backend, `shared_pool` (built
/// once by the service) is wrapped; without one, a fresh pool is spawned —
/// the CLI's single-shot path.
pub fn build_worker_engine(
    cfg: &MatexpConfig,
    shared_pool: Option<Arc<DevicePool>>,
) -> Result<WorkerEngine> {
    crate::store::configure(&cfg.store)?;
    // runs before DevicePool::new so pool calibration can consume the
    // autotuner's measured CPU curve (idempotent across workers)
    crate::linalg::autotune::ensure(&cfg.autotune, cfg.seed);
    crate::store::persist_autotune();
    let kind = if cfg.backend == BackendKind::Pool {
        let pool = match shared_pool {
            Some(p) => p,
            None => Arc::new(DevicePool::new(cfg)?),
        };
        WorkerKind::Pool(PoolEngine::with_pool(pool))
    } else {
        WorkerKind::Single(Box::new(build_engine(cfg)?))
    };
    Ok(WorkerEngine { cfg: cfg.clone(), kind })
}

/// Execute one request on whatever engine the worker holds, dispatching
/// with the config the worker was built from. By value: the pool path
/// ships the matrix to a device thread, so an owned request avoids a
/// deep copy there (the single-backend path just borrows it).
pub fn execute(engine: &mut WorkerEngine, req: ExpmRequest) -> Result<ExpmResponse> {
    match &mut engine.kind {
        WorkerKind::Single(e) => execute_request(e, &engine.cfg, &req),
        WorkerKind::Pool(pe) => pe.execute_request(req),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Method;
    use crate::linalg::matrix::Matrix;

    fn setup() -> (AnyEngine, MatexpConfig) {
        let mut cfg = MatexpConfig::default();
        cfg.warmup_sizes = vec![8];
        (build_engine(&cfg).unwrap(), cfg)
    }

    fn req(method: Method, power: u64) -> ExpmRequest {
        ExpmRequest::new(1, Matrix::random_spectral(8, 0.9, 5), power, method)
    }

    #[test]
    fn all_backend_methods_agree_with_cpu() {
        let (mut engine, cfg) = setup();
        let r_cpu = execute_request(&mut engine, &cfg, &req(Method::CpuSeq, 13)).unwrap();
        for method in [
            Method::Ours,
            Method::OursPacked,
            Method::OursChained,
            Method::AdditionChain,
            Method::NaiveGpu,
            Method::PlanRoundtrip,
        ] {
            let r = execute_request(&mut engine, &cfg, &req(method, 13)).unwrap();
            assert!(
                r.result.approx_eq(&r_cpu.result, 1e-3, 1e-3),
                "{method} diverges from CPU, max diff {}",
                r.result.max_abs_diff(&r_cpu.result)
            );
        }
    }

    #[test]
    fn stats_reflect_method_costs() {
        let (mut engine, cfg) = setup();
        let naive = execute_request(&mut engine, &cfg, &req(Method::NaiveGpu, 64)).unwrap();
        assert_eq!(naive.stats.launches, 63);
        assert_eq!(naive.stats.h2d_transfers, 2 * 63);
        let ours = execute_request(&mut engine, &cfg, &req(Method::OursPacked, 64)).unwrap();
        assert!(ours.stats.launches <= 9, "{:?}", ours.stats); // 6 squarings + pack + unpack
        assert_eq!(ours.stats.h2d_transfers, 1);
        assert_eq!(ours.stats.d2h_transfers, 1);
        assert_eq!(ours.stats.multiplies, 6);
    }

    #[test]
    fn fused_runs_for_shipped_powers() {
        let (mut engine, cfg) = setup();
        let m = Matrix::random_spectral(8, 0.9, 6);
        let r = ExpmRequest::new(2, m, 64, Method::FusedArtifact);
        let resp = execute_request(&mut engine, &cfg, &r).unwrap();
        assert_eq!(resp.stats.launches, 1);
        // and errors cleanly for an absent power
        let r = ExpmRequest::new(3, Matrix::identity(8), 65, Method::FusedArtifact);
        assert!(execute_request(&mut engine, &cfg, &r).is_err());
    }

    #[test]
    fn pool_worker_engine_serves_requests() {
        let mut cfg = MatexpConfig::default();
        cfg.backend = BackendKind::Pool;
        cfg.pool.devices =
            vec![crate::pool::PoolDeviceKind::Cpu, crate::pool::PoolDeviceKind::Cpu];
        let mut engine = build_worker_engine(&cfg, None).unwrap();
        assert!(engine.platform().contains("pool"), "{}", engine.platform());
        let r = execute(&mut engine, req(Method::Ours, 13)).unwrap();
        let want = execute(&mut engine, req(Method::CpuSeq, 13)).unwrap();
        assert!(r.result.approx_eq(&want.result, 1e-3, 1e-3));
        assert_eq!(r.stats.per_device.len(), 1, "{:?}", r.stats.per_device);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn unbuildable_backend_surfaces_from_build_engine() {
        // build_engine must propagate backend-construction failures, not
        // swallow them: pjrt without the xla feature is a clean error
        let mut cfg = MatexpConfig::default();
        cfg.backend = crate::runtime::BackendKind::Pjrt;
        let err = build_engine(&cfg).unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
    }
}
