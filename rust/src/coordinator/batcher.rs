//! Dynamic batcher: coalesce requests by matrix size.
//!
//! Requests for the same `n` share compiled executables and warm device
//! state, so dispatching them together to one worker amortizes dispatch
//! overhead and maximizes executable-cache hits. Classic
//! size-or-deadline policy (vLLM-router style): a batch ships when it
//! reaches `max_batch` or when its oldest request has waited `max_wait`.
//!
//! The batcher is pure (no threads, injected clock) so every policy edge
//! is unit-testable; the service wraps it in a collector thread.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::config::BatcherConfig;
use crate::coordinator::request::ExpmRequest;
use crate::exec::Priority;

/// A group of same-size requests dispatched to one worker.
#[derive(Debug)]
pub struct Batch {
    /// Matrix size shared by all requests in the batch.
    pub n: usize,
    /// The batched requests, in arrival order.
    pub requests: Vec<ExpmRequest>,
    /// When the oldest member was enqueued.
    pub opened_at: Instant,
}

/// How much longer an all-[`Priority::Low`] batch may wait for
/// batch-mates than the configured `max_wait` (latency-insensitive work
/// coalesces harder and yields the workers to fresher traffic).
const LOW_PRIORITY_WAIT_FACTOR: u32 = 4;

struct Pending {
    n: usize,
    requests: Vec<ExpmRequest>,
    opened_at: Instant,
    /// Every member is `Priority::Low` (a Normal/High arrival restores
    /// the regular deadline for the whole batch).
    all_low: bool,
}

/// Size-or-deadline dynamic batcher, one pending batch per matrix size.
pub struct Batcher {
    cfg: BatcherConfig,
    pending: Vec<Pending>,
    /// FIFO of sizes, so flushes preserve arrival order across sizes.
    order: VecDeque<usize>,
    queued: usize,
}

impl Batcher {
    /// An empty batcher with the given knobs.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher { cfg, pending: Vec::new(), order: VecDeque::new(), queued: 0 }
    }

    /// Total queued (not yet shipped) requests.
    pub fn len(&self) -> usize {
        self.queued
    }

    /// `true` when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Would one more request exceed the backpressure bound?
    pub fn is_full(&self) -> bool {
        self.queued >= self.cfg.max_queue
    }

    /// Enqueue a request; returns a batch if it just became full — or
    /// immediately for a [`Priority::High`] request, which must not wait
    /// for batch-mates (it ships with whatever same-size requests were
    /// already pending).
    pub fn push(&mut self, req: ExpmRequest, now: Instant) -> Option<Batch> {
        let n = req.n();
        let urgent = req.priority == Priority::High;
        let low = req.priority == Priority::Low;
        self.queued += 1;
        match self.pending.iter_mut().find(|p| p.n == n) {
            Some(p) => {
                p.all_low &= low;
                p.requests.push(req);
            }
            None => {
                self.pending.push(Pending {
                    n,
                    requests: vec![req],
                    opened_at: now,
                    all_low: low,
                });
                self.order.push_back(n);
            }
        }
        let p = self.pending.iter().find(|p| p.n == n).expect("just inserted");
        if urgent || p.requests.len() >= self.cfg.max_batch {
            return self.take(n);
        }
        None
    }

    /// The wait budget of one pending batch: `max_wait`, stretched by
    /// [`LOW_PRIORITY_WAIT_FACTOR`] when every member is `Priority::Low`.
    fn wait_budget(&self, p: &Pending) -> Duration {
        let base = Duration::from_millis(self.cfg.max_wait_ms);
        if p.all_low {
            base * LOW_PRIORITY_WAIT_FACTOR
        } else {
            base
        }
    }

    /// Ship every pending batch whose oldest request exceeded its wait
    /// budget.
    pub fn flush_due(&mut self, now: Instant) -> Vec<Batch> {
        let due: Vec<usize> = self
            .pending
            .iter()
            .filter(|p| now.duration_since(p.opened_at) >= self.wait_budget(p))
            .map(|p| p.n)
            .collect();
        due.into_iter().filter_map(|n| self.take(n)).collect()
    }

    /// Ship everything immediately (shutdown / test drain).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let sizes: Vec<usize> = self.order.iter().copied().collect();
        sizes.into_iter().filter_map(|n| self.take(n)).collect()
    }

    /// Earliest deadline among pending batches (collector sleep hint).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.iter().map(|p| p.opened_at + self.wait_budget(p)).min()
    }

    fn take(&mut self, n: usize) -> Option<Batch> {
        let idx = self.pending.iter().position(|p| p.n == n)?;
        let p = self.pending.remove(idx);
        self.order.retain(|&o| o != n);
        self.queued -= p.requests.len();
        Some(Batch { n: p.n, requests: p.requests, opened_at: p.opened_at })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Method;
    use crate::linalg::matrix::Matrix;

    fn req(id: u64, n: usize) -> ExpmRequest {
        ExpmRequest::new(id, Matrix::zeros(n), 8, Method::Ours)
    }

    fn cfg(max_batch: usize, max_wait_ms: u64, max_queue: usize) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait_ms, max_queue }
    }

    #[test]
    fn ships_when_full() {
        let mut b = Batcher::new(cfg(3, 1000, 100));
        let now = Instant::now();
        assert!(b.push(req(1, 8), now).is_none());
        assert!(b.push(req(2, 8), now).is_none());
        let batch = b.push(req(3, 8), now).expect("full batch ships");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.n, 8);
        assert!(b.is_empty());
    }

    #[test]
    fn sizes_do_not_mix() {
        let mut b = Batcher::new(cfg(2, 1000, 100));
        let now = Instant::now();
        assert!(b.push(req(1, 8), now).is_none());
        assert!(b.push(req(2, 16), now).is_none());
        // still no batch: each size has only one member
        assert_eq!(b.len(), 2);
        let batch = b.push(req(3, 8), now).unwrap();
        assert!(batch.requests.iter().all(|r| r.n() == 8));
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = Batcher::new(cfg(10, 5, 100));
        let t0 = Instant::now();
        b.push(req(1, 8), t0);
        b.push(req(2, 16), t0 + Duration::from_millis(3));
        // at t0+5ms only the size-8 batch is due
        let due = b.flush_due(t0 + Duration::from_millis(5));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].n, 8);
        // at t0+8ms the size-16 batch is due too
        let due = b.flush_due(t0 + Duration::from_millis(8));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].n, 16);
        assert!(b.is_empty());
    }

    #[test]
    fn next_deadline_is_earliest() {
        let mut b = Batcher::new(cfg(10, 5, 100));
        let t0 = Instant::now();
        b.push(req(1, 8), t0);
        b.push(req(2, 16), t0 + Duration::from_millis(2));
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(5));
    }

    #[test]
    fn flush_all_preserves_arrival_order() {
        let mut b = Batcher::new(cfg(10, 1000, 100));
        let now = Instant::now();
        b.push(req(1, 32), now);
        b.push(req(2, 8), now);
        b.push(req(3, 32), now);
        let all = b.flush_all();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].n, 32, "first-arrived size ships first");
        assert_eq!(all[1].n, 8);
        assert_eq!(all[0].requests.len(), 2);
    }

    #[test]
    fn backpressure_bound() {
        let mut b = Batcher::new(cfg(100, 1000, 2));
        let now = Instant::now();
        b.push(req(1, 8), now);
        assert!(!b.is_full());
        b.push(req(2, 8), now);
        assert!(b.is_full());
    }

    #[test]
    fn high_priority_ships_immediately_with_pending_batchmates() {
        let mut b = Batcher::new(cfg(16, 1000, 100));
        let now = Instant::now();
        assert!(b.push(req(1, 8), now).is_none(), "normal priority waits");
        let mut urgent = req(2, 8);
        urgent.priority = Priority::High;
        let batch = b.push(urgent, now).expect("high priority must not wait");
        assert_eq!(batch.requests.len(), 2, "ships with queued same-size mates");
        assert!(b.is_empty());
        // a lone high-priority request ships alone
        let mut solo = req(3, 16);
        solo.priority = Priority::High;
        let batch = b.push(solo, now).expect("ships alone");
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn low_priority_waits_longer_until_a_normal_joins() {
        let mut b = Batcher::new(cfg(16, 5, 100));
        let t0 = Instant::now();
        let mut lazy = req(1, 8);
        lazy.priority = Priority::Low;
        b.push(lazy, t0);
        // past the normal deadline: an all-low batch keeps waiting…
        assert!(b.flush_due(t0 + Duration::from_millis(5)).is_empty());
        assert_eq!(
            b.next_deadline().unwrap(),
            t0 + Duration::from_millis(5 * LOW_PRIORITY_WAIT_FACTOR as u64)
        );
        // …until its stretched budget expires
        let due = b.flush_due(t0 + Duration::from_millis(5 * LOW_PRIORITY_WAIT_FACTOR as u64));
        assert_eq!(due.len(), 1);

        // a Normal arrival restores the regular deadline for the batch
        let mut lazy = req(2, 8);
        lazy.priority = Priority::Low;
        b.push(lazy, t0);
        b.push(req(3, 8), t0);
        let due = b.flush_due(t0 + Duration::from_millis(5));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests.len(), 2);
    }

    #[test]
    fn ids_survive_batching() {
        let mut b = Batcher::new(cfg(2, 1000, 100));
        let now = Instant::now();
        b.push(req(7, 8), now);
        let batch = b.push(req(9, 8), now).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![7, 9]);
    }
}
