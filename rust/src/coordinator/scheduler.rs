//! Request scheduling: admission control + method → execution strategy.
//!
//! The scheduler is where the paper's algorithm choice becomes policy: it
//! turns a [`Method`] and power into the concrete thing a worker engine
//! runs (a register [`Plan`], the packed bit-loop, the fused artifact, a
//! naive round-trip loop, or the CPU baseline).

use crate::cache::{plan::plan_for, PlanKey};
use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmRequest, Method};
use crate::error::{MatexpError, Result};
use crate::plan::{Plan, PlanKind};

/// Largest exponent the service accepts. Plans stay tiny (O(log N)) but
/// f32 dynamic range makes larger powers numerically meaningless.
pub const MAX_POWER: u64 = 1 << 30;

/// What a worker should actually execute.
#[derive(Clone, Debug, PartialEq)]
pub enum Strategy {
    /// Replay a register plan with device-resident buffers.
    DeviceResident(Plan),
    /// Replay a register plan with a full host round-trip per launch
    /// (ablation A2's counterfactual).
    PlanRoundtrip(Plan),
    /// Packed-state bit loop (`pack2`/`step_*`/`unpack0`).
    Packed,
    /// Single-launch `expm{N}` artifact.
    Fused,
    /// Naive per-launch round-trip loop (§4.2).
    NaiveRoundtrip,
    /// Sequential CPU (§4.1).
    CpuSequential,
}

/// Validate a request against the config and the backend's servable
/// sizes. An empty `sizes` slice means the backend is size-unrestricted
/// (the pure-Rust backends); a non-empty slice is the artifact inventory
/// (PJRT). Every client-fixable rejection (bad power, non-finite input,
/// size limits, unmeetable tolerance, bad plan override) surfaces as the
/// typed [`MatexpError::Admission`] so clients — including remote ones,
/// via the wire's error `kind` — can tell "fix your request" apart from
/// service failures.
pub fn admit(req: &ExpmRequest, sizes: &[usize], cfg: &MatexpConfig) -> Result<()> {
    if req.power == 0 {
        return Err(MatexpError::Admission("power must be >= 1".into()));
    }
    if req.power > MAX_POWER {
        return Err(MatexpError::Admission(format!(
            "power {} exceeds MAX_POWER {MAX_POWER}",
            req.power
        )));
    }
    if req.n() == 0 {
        return Err(MatexpError::Admission("matrix is empty (n=0)".into()));
    }
    if req.n() > cfg.max_n {
        return Err(MatexpError::Admission(format!(
            "matrix size {} exceeds the configured max_n {}",
            req.n(),
            cfg.max_n
        )));
    }
    if !req.matrix.is_finite() {
        return Err(MatexpError::Admission("matrix contains non-finite values".into()));
    }
    if let Some(tol) = req.tolerance {
        // NaN is non-finite, so it is rejected here too
        if !tol.is_finite() || tol <= 0.0 {
            return Err(MatexpError::Admission(format!(
                "tolerance {tol} is not a positive finite bound"
            )));
        }
    }
    // an explicit plan override must compute the power the request names
    // (a mismatched plan would silently answer a different exponent, and
    // a huge plan.power would bypass the MAX_POWER guard checked above),
    // and only the plan-replaying disciplines accept one — on packed/
    // fused/naive/cpu methods an override would silently switch the
    // execution discipline while the response still reports the method
    if let Some(plan) = &req.plan {
        if plan.power != req.power {
            return Err(MatexpError::Admission(format!(
                "plan override computes power {} but the request asks for {}",
                plan.power, req.power
            )));
        }
        match req.method {
            Method::Ours | Method::OursChained | Method::AdditionChain
            | Method::PlanRoundtrip => {}
            other => {
                return Err(MatexpError::Admission(format!(
                    "method {other} does not replay an explicit plan override"
                )))
            }
        }
    }
    match req.method {
        Method::CpuSeq => Ok(()), // CPU path accepts any size
        _ if sizes.is_empty() || sizes.contains(&req.n()) => Ok(()),
        _ => Err(MatexpError::Service(format!(
            "no artifacts for n={} (have {:?}); method {} needs them",
            req.n(),
            sizes,
            req.method
        ))),
    }
    // FusedArtifact availability for a specific power is checked by the
    // worker (it has the backend); admission only validates what it can.
}

/// How a device pool should run a batch ([`crate::pool`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolDispatch {
    /// Shard every multiply across the devices (one large matrix: the
    /// per-multiply work is big enough to amortize the extra launches).
    TileShard,
    /// Run whole requests on per-device queues with work stealing
    /// (batches, or matrices too small to shard profitably).
    RequestParallel,
}

/// Pool dispatch policy: tile-shard a *single* large request; batches and
/// small matrices go request-parallel. A forced grid (`cfg.pool.grid`,
/// `--pool-grid`) pins single requests of ANY size to the sharded path so
/// ablations measure what they asked for.
pub fn pool_dispatch(n: usize, requests: usize, cfg: &MatexpConfig) -> PoolDispatch {
    if requests <= 1 && (n >= cfg.pool.shard_min_n || cfg.pool.grid.is_some()) {
        PoolDispatch::TileShard
    } else {
        PoolDispatch::RequestParallel
    }
}

/// Tolerances below this bound pin the conservative binary plan (chained
/// `square4` launches reassociate more aggressively).
pub(crate) const CONSERVATIVE_TOL: f32 = 1e-6;

/// The shared conservative-plan predicate. The result cache keys on this
/// too ([`crate::cache::ResultKey`]), so entries can never cross the
/// plan-selection boundary even within one tolerance decade.
pub(crate) fn is_conservative(tolerance: Option<f32>) -> bool {
    tolerance.is_some_and(|t| t < CONSERVATIVE_TOL)
}

/// Pick the execution strategy for an admitted request. An explicit
/// plan override ([`ExpmRequest::plan`], set by
/// [`crate::exec::Submission::plan`]) wins over the method→plan mapping;
/// a tight tolerance pins the conservative binary plan for `Ours`.
///
/// Plans built here go through the process-wide
/// [`crate::cache::PlanCache`] (tier 1, keyed by `(n, power, kind,
/// method)`), honoring `cfg.cache.plans` and the request's
/// [`crate::cache::CacheControl`] — the one construction site, so the
/// engine, pool and service all amortize planning identically. Explicit
/// overrides skip the cache: the caller already holds the plan.
pub fn strategy_for(req: &ExpmRequest, cfg: &MatexpConfig) -> Strategy {
    if let Some(plan) = &req.plan {
        return match req.method {
            Method::PlanRoundtrip => Strategy::PlanRoundtrip(plan.clone()),
            _ => Strategy::DeviceResident(plan.clone()),
        };
    }
    // fetch-or-build `kind` for this request through the plan cache
    let cached = |kind: PlanKind, build: &dyn Fn() -> Plan| {
        let key = PlanKey { n: req.n(), power: req.power, kind, method: req.method };
        plan_for(key, req.cache, cfg.cache.plans, build)
    };
    match req.method {
        Method::Ours => {
            let conservative = is_conservative(req.tolerance);
            // autotuned fast-multiply tier: once the tuner has measured
            // Strassen winning at some size, non-conservative requests at
            // or above it take the Strassen-kind plan (same squaring
            // schedule, fast-multiply dispatch intent)
            let strassen = cfg.autotune.enabled
                && !conservative
                && crate::linalg::autotune::strassen_threshold()
                    .is_some_and(|t| req.n() >= t);
            Strategy::DeviceResident(if strassen {
                cached(PlanKind::Strassen, &|| Plan::strassen(req.power))
            } else if cfg.use_square_chains && !conservative {
                cached(PlanKind::Chained, &|| Plan::chained(req.power, &[4, 2]))
            } else {
                cached(PlanKind::Binary, &|| Plan::binary(req.power, false))
            })
        }
        Method::OursChained => Strategy::DeviceResident(
            cached(PlanKind::Chained, &|| Plan::chained(req.power, &[4, 2])),
        ),
        Method::OursPacked => Strategy::Packed,
        Method::AdditionChain => Strategy::DeviceResident(
            cached(PlanKind::AdditionChain, &|| Plan::addition_chain(req.power)),
        ),
        Method::FusedArtifact => Strategy::Fused,
        Method::NaiveGpu => Strategy::NaiveRoundtrip,
        Method::PlanRoundtrip => Strategy::PlanRoundtrip(
            cached(PlanKind::Binary, &|| Plan::binary(req.power, false)),
        ),
        Method::CpuSeq => Strategy::CpuSequential,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    fn req(n: usize, power: u64, method: Method) -> ExpmRequest {
        ExpmRequest::new(0, Matrix::identity(n), power, method)
    }

    fn cfg() -> MatexpConfig {
        MatexpConfig::default()
    }

    #[test]
    fn admits_known_size() {
        admit(&req(64, 512, Method::Ours), &[8, 64, 128], &cfg()).unwrap();
    }

    #[test]
    fn rejects_unknown_size_for_gpu_methods() {
        assert!(admit(&req(100, 512, Method::Ours), &[8, 64], &cfg()).is_err());
        // but the CPU path takes anything
        admit(&req(100, 512, Method::CpuSeq), &[8, 64], &cfg()).unwrap();
    }

    #[test]
    fn empty_size_list_admits_any_size() {
        // size-unrestricted backends (cpu/sim) publish no size inventory
        admit(&req(100, 512, Method::Ours), &[], &cfg()).unwrap();
        admit(&req(7, 2, Method::OursPacked), &[], &cfg()).unwrap();
    }

    #[test]
    fn enforces_configured_max_n_with_typed_error() {
        let mut c = cfg();
        c.max_n = 64;
        admit(&req(64, 8, Method::Ours), &[], &c).unwrap();
        let err = admit(&req(65, 8, Method::Ours), &[], &c).unwrap_err();
        assert!(
            matches!(err, MatexpError::Admission(_)),
            "want typed admission error, got {err:?}"
        );
        assert!(err.to_string().contains("max_n"), "{err}");
        // the CPU path is not exempt from the size cap
        assert!(admit(&req(65, 8, Method::CpuSeq), &[], &c).is_err());
        // empty matrices are rejected, typed too
        let err = admit(&req(0, 8, Method::Ours), &[], &c).unwrap_err();
        assert!(matches!(err, MatexpError::Admission(_)), "{err:?}");
    }

    #[test]
    fn pool_dispatch_by_size_and_batch() {
        let mut c = cfg();
        c.pool.shard_min_n = 256;
        assert_eq!(pool_dispatch(512, 1, &c), PoolDispatch::TileShard);
        assert_eq!(pool_dispatch(255, 1, &c), PoolDispatch::RequestParallel);
        assert_eq!(pool_dispatch(512, 4, &c), PoolDispatch::RequestParallel);
        // a forced grid pins single requests of any size to the shard path
        c.pool.grid = Some(2);
        assert_eq!(pool_dispatch(16, 1, &c), PoolDispatch::TileShard);
        assert_eq!(pool_dispatch(16, 4, &c), PoolDispatch::RequestParallel);
    }

    #[test]
    fn rejects_power_zero_and_huge() {
        assert!(admit(&req(64, 0, Method::Ours), &[64], &cfg()).is_err());
        assert!(admit(&req(64, MAX_POWER + 1, Method::Ours), &[64], &cfg()).is_err());
    }

    #[test]
    fn rejects_non_finite_matrix() {
        let mut m = Matrix::identity(8);
        m.set(0, 0, f32::NAN);
        let r = ExpmRequest::new(0, m, 2, Method::Ours);
        assert!(admit(&r, &[8], &cfg()).is_err());
    }

    #[test]
    fn rejects_plan_override_power_mismatch() {
        let mut r = req(8, 512, Method::Ours);
        r.plan = Some(Plan::binary(512, false));
        admit(&r, &[], &cfg()).unwrap();
        // a plan computing a different exponent than the request names
        r.plan = Some(Plan::binary(256, false));
        let err = admit(&r, &[], &cfg()).unwrap_err();
        assert!(matches!(err, MatexpError::Admission(_)), "{err:?}");
        // …and a huge plan must not smuggle past the MAX_POWER guard
        let mut r = req(8, 2, Method::Ours);
        r.plan = Some(Plan::binary(1 << 29, false));
        assert!(admit(&r, &[], &cfg()).is_err());
    }

    #[test]
    fn rejects_plan_override_on_non_plan_disciplines() {
        // a plan override on packed/fused/naive/cpu methods would
        // silently switch the discipline behind the reported method
        for method in [
            Method::OursPacked,
            Method::FusedArtifact,
            Method::NaiveGpu,
            Method::CpuSeq,
        ] {
            let mut r = req(8, 64, method);
            r.plan = Some(Plan::binary(64, false));
            let err = admit(&r, &[], &cfg()).unwrap_err();
            assert!(matches!(err, MatexpError::Admission(_)), "{method}: {err:?}");
        }
        // the plan-replaying disciplines accept it
        for method in [Method::Ours, Method::OursChained, Method::AdditionChain, Method::PlanRoundtrip] {
            let mut r = req(8, 64, method);
            r.plan = Some(Plan::binary(64, false));
            admit(&r, &[], &cfg()).unwrap_or_else(|e| panic!("{method}: {e}"));
        }
    }

    #[test]
    fn rejects_unmeetable_tolerances_typed() {
        for bad in [0.0f32, -1.0, f32::NAN, f32::INFINITY] {
            let mut r = req(8, 4, Method::Ours);
            r.tolerance = Some(bad);
            let err = admit(&r, &[], &cfg()).unwrap_err();
            assert!(matches!(err, MatexpError::Admission(_)), "{bad}: {err:?}");
        }
        let mut r = req(8, 4, Method::Ours);
        r.tolerance = Some(1e-4);
        admit(&r, &[], &cfg()).unwrap();
    }

    #[test]
    fn strategy_respects_config_chains() {
        let mut c = cfg();
        c.use_square_chains = false;
        match strategy_for(&req(64, 512, Method::Ours), &c) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Binary),
            s => panic!("{s:?}"),
        }
        c.use_square_chains = true;
        match strategy_for(&req(64, 512, Method::Ours), &c) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Chained),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn autotuned_strassen_threshold_selects_the_strassen_kind() {
        // teach the tuner that Strassen wins at a test-unique size; the
        // threshold is the smallest strassen-winning size on record, so
        // it can only be ≤ this one
        crate::linalg::autotune::record(
            643,
            &[
                (crate::linalg::CpuAlgo::Blocked, 5.0),
                (crate::linalg::CpuAlgo::Strassen, 1.0),
            ],
        );
        let threshold = crate::linalg::autotune::strassen_threshold().unwrap();
        assert!(threshold <= 643);
        let mut c = cfg();
        c.autotune.enabled = true;
        match strategy_for(&req(threshold, 512, Method::Ours), &c) {
            Strategy::DeviceResident(p) => {
                assert_eq!(p.kind, crate::plan::PlanKind::Strassen);
                // same squaring schedule as the binary plan
                assert_eq!(p.multiplies(), Plan::binary(512, false).multiplies());
            }
            s => panic!("{s:?}"),
        }
        // a tight tolerance still pins the conservative binary plan
        let mut r = req(threshold, 512, Method::Ours);
        r.tolerance = Some(1e-7);
        match strategy_for(&r, &c) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Binary),
            s => panic!("{s:?}"),
        }
        // with autotune disabled (the default), nothing changes
        match strategy_for(&req(threshold, 512, Method::Ours), &cfg()) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Chained),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn strategy_covers_every_method() {
        for m in Method::all() {
            let _ = strategy_for(&req(64, 100, m), &cfg());
        }
        match strategy_for(&req(64, 100, Method::PlanRoundtrip), &cfg()) {
            Strategy::PlanRoundtrip(p) => assert_eq!(p.kind, crate::plan::PlanKind::Binary),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn explicit_plan_override_wins() {
        let mut r = req(64, 100, Method::Ours);
        r.plan = Some(Plan::addition_chain(100));
        match strategy_for(&r, &cfg()) {
            Strategy::DeviceResident(p) => {
                assert_eq!(p.kind, crate::plan::PlanKind::AdditionChain)
            }
            s => panic!("{s:?}"),
        }
        r.method = Method::PlanRoundtrip;
        assert!(matches!(strategy_for(&r, &cfg()), Strategy::PlanRoundtrip(_)));
    }

    #[test]
    fn tight_tolerance_pins_the_conservative_binary_plan() {
        let c = cfg(); // default config chains squarings
        assert!(c.use_square_chains);
        let mut r = req(64, 512, Method::Ours);
        r.tolerance = Some(1e-7);
        match strategy_for(&r, &c) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Binary),
            s => panic!("{s:?}"),
        }
        // a loose tolerance keeps the configured chained plan
        r.tolerance = Some(1e-3);
        match strategy_for(&r, &c) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Chained),
            s => panic!("{s:?}"),
        }
    }
}
