//! Request scheduling: admission control + method → execution strategy.
//!
//! The scheduler is where the paper's algorithm choice becomes policy: it
//! turns a [`Method`] and power into the concrete thing a worker engine
//! runs (a register [`Plan`], the packed bit-loop, the fused artifact, a
//! naive round-trip loop, or the CPU baseline).

use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmRequest, Method};
use crate::error::{MatexpError, Result};
use crate::plan::Plan;

/// Largest exponent the service accepts. Plans stay tiny (O(log N)) but
/// f32 dynamic range makes larger powers numerically meaningless.
pub const MAX_POWER: u64 = 1 << 30;

/// What a worker should actually execute.
#[derive(Clone, Debug, PartialEq)]
pub enum Strategy {
    /// Replay a register plan with device-resident buffers.
    DeviceResident(Plan),
    /// Packed-state bit loop (`pack2`/`step_*`/`unpack0`).
    Packed,
    /// Single-launch `expm{N}` artifact.
    Fused,
    /// Naive per-launch round-trip loop (§4.2).
    NaiveRoundtrip,
    /// Sequential CPU (§4.1).
    CpuSequential,
}

/// Validate a request against the config and the backend's servable
/// sizes. An empty `sizes` slice means the backend is size-unrestricted
/// (the pure-Rust backends); a non-empty slice is the artifact inventory
/// (PJRT). Size-limit violations surface as the typed
/// [`MatexpError::Admission`] so clients can tell "fix your request"
/// apart from service failures.
pub fn admit(req: &ExpmRequest, sizes: &[usize], cfg: &MatexpConfig) -> Result<()> {
    if req.power == 0 {
        return Err(MatexpError::Service("power must be >= 1".into()));
    }
    if req.power > MAX_POWER {
        return Err(MatexpError::Service(format!(
            "power {} exceeds MAX_POWER {MAX_POWER}",
            req.power
        )));
    }
    if req.n() == 0 {
        return Err(MatexpError::Admission("matrix is empty (n=0)".into()));
    }
    if req.n() > cfg.max_n {
        return Err(MatexpError::Admission(format!(
            "matrix size {} exceeds the configured max_n {}",
            req.n(),
            cfg.max_n
        )));
    }
    if !req.matrix.is_finite() {
        return Err(MatexpError::Service("matrix contains non-finite values".into()));
    }
    match req.method {
        Method::CpuSeq => Ok(()), // CPU path accepts any size
        _ if sizes.is_empty() || sizes.contains(&req.n()) => Ok(()),
        _ => Err(MatexpError::Service(format!(
            "no artifacts for n={} (have {:?}); method {} needs them",
            req.n(),
            sizes,
            req.method
        ))),
    }
    // FusedArtifact availability for a specific power is checked by the
    // worker (it has the backend); admission only validates what it can.
}

/// How a device pool should run a batch ([`crate::pool`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolDispatch {
    /// Shard every multiply across the devices (one large matrix: the
    /// per-multiply work is big enough to amortize the extra launches).
    TileShard,
    /// Run whole requests on per-device queues with work stealing
    /// (batches, or matrices too small to shard profitably).
    RequestParallel,
}

/// Pool dispatch policy: tile-shard a *single* large request; batches and
/// small matrices go request-parallel. A forced grid (`cfg.pool.grid`,
/// `--pool-grid`) pins single requests of ANY size to the sharded path so
/// ablations measure what they asked for.
pub fn pool_dispatch(n: usize, requests: usize, cfg: &MatexpConfig) -> PoolDispatch {
    if requests <= 1 && (n >= cfg.pool.shard_min_n || cfg.pool.grid.is_some()) {
        PoolDispatch::TileShard
    } else {
        PoolDispatch::RequestParallel
    }
}

/// Pick the execution strategy for an admitted request.
pub fn strategy_for(req: &ExpmRequest, cfg: &MatexpConfig) -> Strategy {
    match req.method {
        Method::Ours => Strategy::DeviceResident(if cfg.use_square_chains {
            Plan::chained(req.power, &[4, 2])
        } else {
            Plan::binary(req.power, false)
        }),
        Method::OursChained => Strategy::DeviceResident(Plan::chained(req.power, &[4, 2])),
        Method::OursPacked => Strategy::Packed,
        Method::AdditionChain => Strategy::DeviceResident(Plan::addition_chain(req.power)),
        Method::FusedArtifact => Strategy::Fused,
        Method::NaiveGpu => Strategy::NaiveRoundtrip,
        Method::CpuSeq => Strategy::CpuSequential,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    fn req(n: usize, power: u64, method: Method) -> ExpmRequest {
        ExpmRequest { id: 0, matrix: Matrix::identity(n), power, method }
    }

    fn cfg() -> MatexpConfig {
        MatexpConfig::default()
    }

    #[test]
    fn admits_known_size() {
        admit(&req(64, 512, Method::Ours), &[8, 64, 128], &cfg()).unwrap();
    }

    #[test]
    fn rejects_unknown_size_for_gpu_methods() {
        assert!(admit(&req(100, 512, Method::Ours), &[8, 64], &cfg()).is_err());
        // but the CPU path takes anything
        admit(&req(100, 512, Method::CpuSeq), &[8, 64], &cfg()).unwrap();
    }

    #[test]
    fn empty_size_list_admits_any_size() {
        // size-unrestricted backends (cpu/sim) publish no size inventory
        admit(&req(100, 512, Method::Ours), &[], &cfg()).unwrap();
        admit(&req(7, 2, Method::OursPacked), &[], &cfg()).unwrap();
    }

    #[test]
    fn enforces_configured_max_n_with_typed_error() {
        let mut c = cfg();
        c.max_n = 64;
        admit(&req(64, 8, Method::Ours), &[], &c).unwrap();
        let err = admit(&req(65, 8, Method::Ours), &[], &c).unwrap_err();
        assert!(
            matches!(err, MatexpError::Admission(_)),
            "want typed admission error, got {err:?}"
        );
        assert!(err.to_string().contains("max_n"), "{err}");
        // the CPU path is not exempt from the size cap
        assert!(admit(&req(65, 8, Method::CpuSeq), &[], &c).is_err());
        // empty matrices are rejected, typed too
        let err = admit(&req(0, 8, Method::Ours), &[], &c).unwrap_err();
        assert!(matches!(err, MatexpError::Admission(_)), "{err:?}");
    }

    #[test]
    fn pool_dispatch_by_size_and_batch() {
        let mut c = cfg();
        c.pool.shard_min_n = 256;
        assert_eq!(pool_dispatch(512, 1, &c), PoolDispatch::TileShard);
        assert_eq!(pool_dispatch(255, 1, &c), PoolDispatch::RequestParallel);
        assert_eq!(pool_dispatch(512, 4, &c), PoolDispatch::RequestParallel);
        // a forced grid pins single requests of any size to the shard path
        c.pool.grid = Some(2);
        assert_eq!(pool_dispatch(16, 1, &c), PoolDispatch::TileShard);
        assert_eq!(pool_dispatch(16, 4, &c), PoolDispatch::RequestParallel);
    }

    #[test]
    fn rejects_power_zero_and_huge() {
        assert!(admit(&req(64, 0, Method::Ours), &[64], &cfg()).is_err());
        assert!(admit(&req(64, MAX_POWER + 1, Method::Ours), &[64], &cfg()).is_err());
    }

    #[test]
    fn rejects_non_finite_matrix() {
        let mut m = Matrix::identity(8);
        m.set(0, 0, f32::NAN);
        let r = ExpmRequest { id: 0, matrix: m, power: 2, method: Method::Ours };
        assert!(admit(&r, &[8], &cfg()).is_err());
    }

    #[test]
    fn strategy_respects_config_chains() {
        let mut c = cfg();
        c.use_square_chains = false;
        match strategy_for(&req(64, 512, Method::Ours), &c) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Binary),
            s => panic!("{s:?}"),
        }
        c.use_square_chains = true;
        match strategy_for(&req(64, 512, Method::Ours), &c) {
            Strategy::DeviceResident(p) => assert_eq!(p.kind, crate::plan::PlanKind::Chained),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn strategy_covers_every_method() {
        for m in Method::all() {
            let _ = strategy_for(&req(64, 100, m), &cfg());
        }
    }
}
