//! Crate-wide error type (hand-rolled: the default build has zero
//! external dependencies).

/// Everything that can go wrong across the coordinator, runtime and
/// substrates. The `From` impls let `?` flow through all layers.
#[derive(Debug)]
pub enum MatexpError {
    /// Artifact directory / manifest problems (missing `make artifacts`?).
    Artifact(String),

    /// Execution-backend failures (degenerate op parameters, buffer
    /// mismatch, PJRT).
    Backend(String),

    /// The backend (or its artifact set) genuinely does not ship this op
    /// at this size — the one `prepare` failure warmup may skip for
    /// optional ops. Anything else propagates.
    UnsupportedOp(String),

    /// PJRT / XLA runtime failures.
    Xla(String),

    /// Invalid plan or plan/executable mismatch.
    Plan(String),

    /// Shape/dimension mismatches in the CPU substrate.
    Linalg(String),

    /// Bad configuration.
    Config(String),

    /// Serving-layer failures (queue closed, worker died, protocol).
    Service(String),

    /// The wire connection is dead (EOF mid-pipeline, a protocol
    /// violation, or a failed write) and has been poisoned: every
    /// outstanding ticket on it resolves to this instead of blocking
    /// forever on a socket that will never answer.
    Disconnected(String),

    /// Admission-control rejections: the request is well-formed but
    /// violates a configured limit (max matrix size, max power), so the
    /// caller can distinguish "fix your request" from "the service broke".
    Admission(String),

    /// The job's deadline expired — before execution, while waiting on a
    /// [`crate::exec::JobHandle`], or (for a result that arrived late)
    /// after. Typed so callers can retry with a looser deadline instead
    /// of treating it as a service failure.
    Deadline(String),

    /// Persistent-store failures: a torn or corrupt on-disk entry (bad
    /// magic, checksum mismatch, truncation), an unwritable store
    /// directory, or an undecodable artifact. Typed so the tiered cache
    /// can treat a damaged entry as a miss — never serve wrong bits —
    /// while the store keeps serving its healthy entries.
    Store(String),

    /// Underlying I/O failures (sockets, config files, artifacts).
    Io(std::io::Error),

    /// JSON parse/encode failures (config, wire protocol).
    Json(crate::util::json::JsonError),
}

impl std::fmt::Display for MatexpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MatexpError::Artifact(m) => write!(f, "artifact error: {m}"),
            MatexpError::Backend(m) => write!(f, "backend error: {m}"),
            MatexpError::UnsupportedOp(m) => write!(f, "unsupported op: {m}"),
            MatexpError::Xla(m) => write!(f, "xla runtime error: {m}"),
            MatexpError::Plan(m) => write!(f, "plan error: {m}"),
            MatexpError::Linalg(m) => write!(f, "linalg error: {m}"),
            MatexpError::Config(m) => write!(f, "config error: {m}"),
            MatexpError::Service(m) => write!(f, "service error: {m}"),
            MatexpError::Disconnected(m) => write!(f, "connection lost: {m}"),
            MatexpError::Admission(m) => write!(f, "admission rejected: {m}"),
            MatexpError::Deadline(m) => write!(f, "deadline exceeded: {m}"),
            MatexpError::Store(m) => write!(f, "store error: {m}"),
            MatexpError::Io(e) => write!(f, "io error: {e}"),
            MatexpError::Json(e) => write!(f, "json error: {e}"),
        }
    }
}

impl std::error::Error for MatexpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MatexpError::Io(e) => Some(e),
            MatexpError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MatexpError {
    fn from(e: std::io::Error) -> Self {
        MatexpError::Io(e)
    }
}

impl From<crate::util::json::JsonError> for MatexpError {
    fn from(e: crate::util::json::JsonError) -> Self {
        MatexpError::Json(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for MatexpError {
    fn from(e: xla::Error) -> Self {
        MatexpError::Xla(e.to_string())
    }
}

/// Crate-wide result alias over [`MatexpError`].
pub type Result<T> = std::result::Result<T, MatexpError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_layer() {
        assert!(MatexpError::Backend("x".into()).to_string().starts_with("backend error"));
        assert!(MatexpError::Config("x".into()).to_string().starts_with("config error"));
        assert!(MatexpError::UnsupportedOp("x".into()).to_string().starts_with("unsupported op"));
        assert!(MatexpError::Deadline("x".into()).to_string().starts_with("deadline exceeded"));
        assert!(MatexpError::Disconnected("x".into()).to_string().starts_with("connection lost"));
        assert!(MatexpError::Store("x".into()).to_string().starts_with("store error"));
        let io: MatexpError = std::io::Error::new(std::io::ErrorKind::Other, "gone").into();
        assert!(io.to_string().contains("gone"));
    }
}
