//! Crate-wide error type.

use thiserror::Error;

/// Everything that can go wrong across the coordinator, runtime and
/// substrates. The `From` impls let `?` flow through all layers.
#[derive(Error, Debug)]
pub enum MatexpError {
    /// Artifact directory / manifest problems (missing `make artifacts`?).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failures.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Invalid plan or plan/executable mismatch.
    #[error("plan error: {0}")]
    Plan(String),

    /// Shape/dimension mismatches in the CPU substrate.
    #[error("linalg error: {0}")]
    Linalg(String),

    /// Bad configuration.
    #[error("config error: {0}")]
    Config(String),

    /// Serving-layer failures (queue closed, worker died, protocol).
    #[error("service error: {0}")]
    Service(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),
}

impl From<xla::Error> for MatexpError {
    fn from(e: xla::Error) -> Self {
        MatexpError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, MatexpError>;
