//! Rendezvous (highest-random-weight) hashing over the result-cache
//! content digest — the placement function of the cluster tier.
//!
//! The router's whole reason to exist is cache affinity: the paper's
//! amortization argument (plan once, serve many) only compounds across
//! machines if every repetition of a hot matrix lands on the node whose
//! result cache already holds it. Rendezvous hashing gives exactly that
//! with no coordination state: every `(digest, member)` pair gets a
//! deterministic pseudo-random score, and a digest is **owned** by the
//! member with the highest score. Two properties make it the right
//! choice over a mod-N ring:
//!
//! - **Minimal disruption.** Removing a member only moves the digests it
//!   owned (their second-highest scorer takes over — every other
//!   digest's argmax is untouched). Adding a member steals an expected
//!   `1/(N+1)` of the keyspace, uniformly from everyone. A ring with
//!   naive `digest % N` placement reshuffles almost everything on any
//!   membership change, flushing every warm cache in the cluster.
//! - **Statelessness.** The owner is a pure function of the digest and
//!   the live member set, so the router never persists a placement table
//!   and two routers in front of the same members agree by construction.
//!
//! The digest is the same 128-bit dual-FNV content digest the result
//! cache keys on ([`crate::cache::ResultKey`]) — routing and caching
//! hash *the same bytes*, so "lands on the warm node" is exact, not
//! probabilistic. Scores mix the member name into the digest with an
//! FNV-1a pass and a splitmix64 finalizer; the finalizer's avalanche is
//! what makes per-member scores independent enough for the `1/N`
//! balance property (a bare FNV of `digest || name` correlates scores
//! across members that share a prefix).

/// FNV-1a offset basis (the same constant the result-cache digest uses).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Score one `(digest, member)` pair. Higher wins; the member with the
/// top score over the live set owns the digest.
///
/// Deterministic across processes and platforms (pure integer mixing,
/// no hasher randomization), so a router restart — or a second router —
/// reproduces the same placement for the same member set.
pub fn score(digest: (u64, u64), member: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for b in member.as_bytes() {
        h = (h ^ u64::from(*b)).wrapping_mul(FNV_PRIME);
    }
    // fold both digest lanes in at different rotations so the pair acts
    // as a full 128-bit key, then avalanche with splitmix64's finalizer
    let mut x = h ^ digest.0.rotate_left(17) ^ digest.1.rotate_left(43);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Index of the member that owns `digest` — the argmax of
/// [`score`] over `members`, ties broken by name so the choice is total.
/// `None` when `members` is empty.
pub fn owner(digest: (u64, u64), members: &[&str]) -> Option<usize> {
    let mut best: Option<(u64, &str, usize)> = None;
    for (i, m) in members.iter().enumerate() {
        let s = score(digest, m);
        let wins = match best {
            None => true,
            // ties (astronomically rare) break toward the smaller name so
            // the choice is a total order, not iteration-order luck
            Some((bs, bm, _)) => s > bs || (s == bs && *m < bm),
        };
        if wins {
            best = Some((s, m, i));
        }
    }
    best.map(|(_, _, i)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rand::XorShift64;
    use crate::util::prop::property;

    fn digests(count: usize, seed: u64) -> Vec<(u64, u64)> {
        let mut rng = XorShift64::new(seed);
        (0..count).map(|_| (rng.next_u64(), rng.next_u64())).collect()
    }

    #[test]
    fn owner_is_deterministic_and_total() {
        let members = ["a:1", "b:2", "c:3"];
        for d in digests(100, 7) {
            let first = owner(d, &members).unwrap();
            assert_eq!(owner(d, &members), Some(first));
            assert!(first < members.len());
        }
        assert_eq!(owner((1, 2), &[]), None);
    }

    #[test]
    fn removal_moves_only_the_removed_members_digests() {
        // the defining HRW property, checked exhaustively: dropping one
        // member never changes the owner of a digest it did not own
        let members = ["n0:1", "n1:1", "n2:1", "n3:1", "n4:1"];
        for d in digests(500, 11) {
            let before = owner(d, &members).unwrap();
            for gone in 0..members.len() {
                if gone == before {
                    continue;
                }
                let survivors: Vec<&str> =
                    members.iter().enumerate().filter(|(i, _)| *i != gone).map(|(_, m)| *m).collect();
                assert_eq!(survivors[owner(d, &survivors).unwrap()], members[before]);
            }
        }
    }

    #[test]
    fn join_moves_about_one_over_n() {
        // adding a 6th member to 5 should steal ~1/6 of the keyspace,
        // uniformly: measure over a big digest sample
        let five = ["n0:1", "n1:1", "n2:1", "n3:1", "n4:1"];
        let six = ["n0:1", "n1:1", "n2:1", "n3:1", "n4:1", "n5:1"];
        let sample = digests(4000, 23);
        let moved = sample
            .iter()
            .filter(|d| five[owner(**d, &five).unwrap()] != six[owner(**d, &six).unwrap()])
            .count();
        let frac = moved as f64 / sample.len() as f64;
        assert!((0.10..=0.25).contains(&frac), "moved fraction {frac} far from 1/6");
        // and every digest that moved, moved TO the new member
        for d in &sample {
            let b = five[owner(*d, &five).unwrap()];
            let a = six[owner(*d, &six).unwrap()];
            assert!(a == b || a == "n5:1", "{b} -> {a} is not a steal by the joiner");
        }
    }

    #[test]
    fn placement_is_balanced() {
        let members = ["n0:1", "n1:1", "n2:1", "n3:1"];
        let sample = digests(4000, 31);
        let mut counts = [0usize; 4];
        for d in &sample {
            counts[owner(*d, &members).unwrap()] += 1;
        }
        let fair = sample.len() / members.len();
        for (i, c) in counts.iter().enumerate() {
            assert!(
                (fair / 2..=fair * 2).contains(c),
                "member {i} owns {c} of {} (fair share {fair})",
                sample.len()
            );
        }
    }

    #[test]
    fn prop_rendezvous_stable_under_membership_changes() {
        property("hrw_removal_stability", 200, |g| {
            let n = g.usize(2, 8);
            let members: Vec<String> = (0..n).map(|i| format!("node{i}:70{i:02}")).collect();
            let refs: Vec<&str> = members.iter().map(String::as_str).collect();
            let d = (g.u64(0, u64::MAX - 1), g.u64(0, u64::MAX - 1));
            let before = owner(d, &refs).unwrap();
            // remove a random member that is NOT the owner: owner must hold
            let gone = g.usize(0, n - 1);
            if gone != before {
                let survivors: Vec<&str> =
                    refs.iter().enumerate().filter(|(i, _)| *i != gone).map(|(_, m)| *m).collect();
                assert_eq!(survivors[owner(d, &survivors).unwrap()], refs[before]);
            }
            // add a member: the owner either holds or the joiner steals
            let mut grown = refs.clone();
            grown.push("joiner:7999");
            let after = grown[owner(d, &grown).unwrap()];
            assert!(after == refs[before] || after == "joiner:7999");
        });
    }
}
