//! Cluster membership: the router's live view of its member servers.
//!
//! A [`Member`] is one backend `matexp serve` process, tracked entirely
//! with atomics so the routing hot path (score, pick, count) never takes
//! a lock — the [`Membership`] `RwLock` guards only the *set* (join,
//! leave, snapshot), which changes rarely. Each member carries:
//!
//! - `up` — flipped by the health-check thread and by egress failures;
//!   a down member is excluded from routing until a probe succeeds.
//! - `draining` — set by the `cluster drain` op; a draining member
//!   finishes its in-flight work but receives nothing new.
//! - `outstanding` — router-side in-flight count, the load signal for
//!   least-load routing and the shed-at admission gate.
//! - `routed_affinity` / `routed_least_load` — per-policy totals behind
//!   the `matexp_cluster_requests_routed_total` Prometheus series.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One member server, as the router sees it. Shared via `Arc` between
/// the routing path, the health checker, and the status/metrics
/// renderers; all fields are atomics, so readers never block routing.
#[derive(Debug)]
pub struct Member {
    name: String,
    up: AtomicBool,
    draining: AtomicBool,
    outstanding: AtomicU64,
    routed_affinity: AtomicU64,
    routed_least_load: AtomicU64,
}

impl Member {
    /// A fresh member at `addr` (`host:port`), initially up and not
    /// draining — the health checker will demote it if the first probe
    /// fails.
    pub fn new(addr: impl Into<String>) -> Arc<Member> {
        Arc::new(Member {
            name: addr.into(),
            up: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            outstanding: AtomicU64::new(0),
            routed_affinity: AtomicU64::new(0),
            routed_least_load: AtomicU64::new(0),
        })
    }

    /// The member's address, which doubles as its identity: the
    /// rendezvous hash key, the `member` label on Prometheus series, and
    /// the handle `cluster drain`/`leave` ops refer to.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Whether the last health probe (or egress attempt) succeeded.
    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::Relaxed)
    }

    /// Mark the member up or down (health checker and egress failures).
    pub fn set_up(&self, up: bool) {
        self.up.store(up, Ordering::Relaxed);
    }

    /// Whether the member is draining (finishing in-flight work only).
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Enter or leave the draining state.
    pub fn set_draining(&self, draining: bool) {
        self.draining.store(draining, Ordering::Relaxed);
    }

    /// Router-side in-flight requests against this member right now.
    pub fn outstanding(&self) -> u64 {
        self.outstanding.load(Ordering::Relaxed)
    }

    /// Eligible to receive new work: up and not draining.
    pub fn eligible(&self) -> bool {
        self.is_up() && !self.is_draining()
    }

    /// Per-policy routed totals: `(affinity, least_load)`.
    pub fn routed(&self) -> (u64, u64) {
        (self.routed_affinity.load(Ordering::Relaxed), self.routed_least_load.load(Ordering::Relaxed))
    }

    pub(crate) fn begin_request(&self) {
        self.outstanding.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn end_request(&self) {
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
    }

    pub(crate) fn note_affinity(&self) {
        self.routed_affinity.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_least_load(&self) {
        self.routed_least_load.fetch_add(1, Ordering::Relaxed);
    }
}

/// The mutable member set. Lock scope is set changes only — routing
/// takes a [`Membership::snapshot`] (a clone of the `Arc` list) and
/// works lock-free from there.
#[derive(Debug, Default)]
pub struct Membership {
    members: RwLock<Vec<Arc<Member>>>,
}

impl Membership {
    /// Build the initial set from configured addresses (duplicates are
    /// collapsed; order is preserved for stable status output).
    pub fn new(addrs: &[String]) -> Membership {
        let m = Membership::default();
        for a in addrs {
            m.join(a);
        }
        m
    }

    /// Current members, cheap to clone and safe to iterate without
    /// holding the set lock.
    pub fn snapshot(&self) -> Vec<Arc<Member>> {
        self.members.read().expect("membership lock poisoned").clone()
    }

    /// Add a member at `addr`. Returns `false` (and changes nothing) if
    /// it is already present.
    pub fn join(&self, addr: &str) -> bool {
        let mut set = self.members.write().expect("membership lock poisoned");
        if set.iter().any(|m| m.name() == addr) {
            return false;
        }
        set.push(Member::new(addr));
        true
    }

    /// Remove the member at `addr`. Returns `false` if it was not
    /// present. In-flight requests against it finish on the snapshot
    /// their connection already holds.
    pub fn leave(&self, addr: &str) -> bool {
        let mut set = self.members.write().expect("membership lock poisoned");
        let before = set.len();
        set.retain(|m| m.name() != addr);
        set.len() != before
    }

    /// Look up a member by address.
    pub fn get(&self, addr: &str) -> Option<Arc<Member>> {
        self.members.read().expect("membership lock poisoned").iter().find(|m| m.name() == addr).cloned()
    }

    /// Number of members (up or not).
    pub fn len(&self) -> usize {
        self.members.read().expect("membership lock poisoned").len()
    }

    /// Whether the set is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_leave_and_lookup() {
        let m = Membership::new(&["a:1".into(), "b:2".into(), "a:1".into()]);
        assert_eq!(m.len(), 2, "duplicate join collapses");
        assert!(!m.join("b:2"));
        assert!(m.join("c:3"));
        assert!(m.leave("a:1"));
        assert!(!m.leave("a:1"));
        assert!(m.get("c:3").is_some());
        assert!(m.get("a:1").is_none());
        let names: Vec<String> = m.snapshot().iter().map(|x| x.name().to_string()).collect();
        assert_eq!(names, vec!["b:2".to_string(), "c:3".to_string()]);
    }

    #[test]
    fn member_state_flips_and_counts() {
        let m = Member::new("a:1");
        assert!(m.eligible());
        m.set_draining(true);
        assert!(!m.eligible());
        m.set_draining(false);
        m.set_up(false);
        assert!(!m.eligible());
        m.begin_request();
        m.begin_request();
        assert_eq!(m.outstanding(), 2);
        m.end_request();
        assert_eq!(m.outstanding(), 1);
        m.note_affinity();
        m.note_affinity();
        m.note_least_load();
        assert_eq!(m.routed(), (2, 1));
    }
}
