//! In-process cluster simulation: N real servers on loopback ports plus
//! a router in front, owned by one handle — the cluster equivalent of
//! [`crate::server::serve_background`], and what the integration tests
//! and CI smoke drive.
//!
//! Nothing here is mocked: each member is a full
//! [`crate::coordinator::Service`] behind a real TCP
//! [`crate::server::Server`], and the router egresses over real
//! [`crate::server::MatexpClient`] connections. "Kill a member" closes
//! its listener and connections exactly like a crashed process would, so
//! failover tests exercise the same code paths a production deployment
//! hits — just without containers.

use std::sync::Arc;

use super::router::Router;
use crate::config::{ClusterSettings, MatexpConfig};
use crate::coordinator::service::{Service, ServiceHandle};
use crate::error::Result;
use crate::server::server::{serve_background, Server};

/// One spawned member: its service handle plus the TCP front-end.
struct SimMember {
    addr: String,
    server: Option<Server>,
    service: Option<Arc<ServiceHandle>>,
}

/// A local cluster: N member servers plus the router, shut down as one.
pub struct Cluster {
    router: Option<Router>,
    members: Vec<SimMember>,
}

impl Cluster {
    /// Spawn `n` members (each a full service on an ephemeral loopback
    /// port, result cache enabled — affinity is pointless without it)
    /// and a router over them, with default [`ClusterSettings`].
    pub fn spawn_local(n: usize) -> Result<Cluster> {
        Cluster::spawn_local_with(n, ClusterSettings::default())
    }

    /// [`Cluster::spawn_local`] with explicit settings (`members` is
    /// filled in from the spawned servers; anything preconfigured there
    /// is kept, letting tests mix in unreachable members).
    pub fn spawn_local_with(n: usize, mut settings: ClusterSettings) -> Result<Cluster> {
        let mut members = Vec::with_capacity(n);
        for _ in 0..n {
            let mut cfg = MatexpConfig::default();
            cfg.workers = 2;
            cfg.batcher.max_wait_ms = 1;
            cfg.cache.results = true;
            let service = Arc::new(Service::start(cfg)?);
            let server = serve_background(Arc::clone(&service), "127.0.0.1:0", 8)?;
            let addr = server.local_addr().to_string();
            settings.members.push(addr.clone());
            members.push(SimMember { addr, server: Some(server), service: Some(service) });
        }
        let router = Router::start("127.0.0.1:0", &settings, 8)?;
        Ok(Cluster { router: Some(router), members })
    }

    /// The router's listening address — point clients (or the loadtest)
    /// here exactly as they would at a single server.
    pub fn router_addr(&self) -> String {
        self.router.as_ref().expect("router running").local_addr().to_string()
    }

    /// Member `i`'s direct listening address.
    pub fn member_addr(&self, i: usize) -> &str {
        &self.members[i].addr
    }

    /// Number of members spawned (killed ones included).
    pub fn members(&self) -> usize {
        self.members.len()
    }

    /// Kill member `i` the way a crash would look from outside: close
    /// its listener and every open connection. Idempotent. The router
    /// notices via egress failure or the next health probe.
    pub fn kill_member(&mut self, i: usize) {
        if let Some(server) = self.members[i].server.take() {
            server.shutdown();
        }
        if let Some(service) = self.members[i].service.take() {
            if let Ok(service) = Arc::try_unwrap(service) {
                service.shutdown();
            }
        }
    }

    /// Shut the whole cluster down: router first (so nothing routes into
    /// closing members), then every member.
    pub fn shutdown(mut self) {
        if let Some(router) = self.router.take() {
            router.shutdown();
        }
        for i in 0..self.members.len() {
            self.kill_member(i);
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        if let Some(router) = self.router.take() {
            router.shutdown();
        }
        for i in 0..self.members.len() {
            self.kill_member(i);
        }
    }
}
