//! The router front-end: one listening socket speaking the full wire
//! protocol (JSON lines *and* binary frames), fanning `expm` work out to
//! member servers over [`MatexpClient`] egress connections.
//!
//! ## Data path
//!
//! ```text
//! client ──lines/frames──▶ router conn handler
//!                             │  digest = digest_f32(matrix)      (Route span)
//!                             │  pick: HRW owner, else least-load, else shed
//!                             ▼
//!                     MatexpClient egress ──frames──▶ member serve  (MemberSend span)
//!                             │
//!                             ◀── result/typed error, relayed in the
//!                                 client's own codec and id
//! ```
//!
//! Each accepted connection is handled **sequentially** — one request in
//! flight per client connection (pipelined ids are still echoed
//! faithfully; concurrency comes from many connections, exactly like the
//! loadtest drives it). Every handler keeps its own lazily-opened egress
//! client per member, so member TCP connections are pooled per client
//! connection and reconnect (with backoff) independently.
//!
//! ## Routing policy
//!
//! Cache-eligible requests ([`CacheControl::Use`]/`Refresh`) go to the
//! rendezvous owner of the matrix digest ([`super::hash`]) — the member
//! whose result cache is warm for that exact content. If the owner is
//! saturated (`outstanding ≥ shed_at`), the request **spills** to the
//! least-loaded unsaturated member; when every live member is saturated
//! the router sheds with the typed [`MatexpError::Admission`] the
//! single-server admission gate already uses, so clients cannot tell a
//! router apart from an overloaded server. `CacheControl::Bypass`
//! requests skip the affinity step entirely — there is no warm state to
//! aim at — and always go least-load.
//!
//! ## Failure and drain semantics
//!
//! A member that fails a health probe or an egress attempt is marked
//! down and excluded from routing until a probe succeeds; its share of
//! the digest space falls to the per-digest runners-up (an HRW property
//! — nobody else's placement moves). An egress failure *before* anything
//! was sent reroutes transparently; a failure *mid-request* surfaces as
//! the typed `Disconnected` error (the work may have executed — an
//! idempotent retry is the client's call, not the router's). Draining a
//! member stops new routing immediately, waits (bounded) for its
//! router-side in-flight count to reach zero, tells the member itself to
//! stop accepting direct work, and detaches it from the set.

use std::collections::{HashMap, HashSet};
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::hash;
use super::membership::{Member, Membership};
use crate::cache::result::digest_f32;
use crate::config::ClusterSettings;
use crate::coordinator::request::Method;
use crate::error::{MatexpError, Result};
use crate::exec::CacheControl;
use crate::json_obj;
use crate::linalg::matrix::Matrix;
use crate::server::client::{MatexpClient, ReconnectPolicy};
use crate::server::frame::{self, Frame};
use crate::server::proto::{ClusterAction, MetricsFormat, WireRequest, WireResponse};
use crate::trace::prometheus::PREFIX;
use crate::trace::{self, SpanKind, TraceId};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

/// Egress reconnect backoff ceiling, milliseconds.
const RECONNECT_MAX_MS: u64 = 2_000;
/// Health probe connect/read timeout, milliseconds.
const PROBE_TIMEOUT_MS: u64 = 250;
/// Upper bound on how long a drain waits for in-flight work.
const DRAIN_WAIT_MS: u64 = 5_000;

/// Which routing policy placed a request — the `policy` label on
/// `matexp_cluster_requests_routed_total`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rendezvous owner of the matrix digest (warm result cache).
    Affinity,
    /// Lowest outstanding count (cache-bypass traffic or spill from a
    /// saturated affinity owner).
    LeastLoad,
}

impl RoutePolicy {
    /// Canonical label value (`affinity` / `least_load`).
    pub fn as_str(self) -> &'static str {
        match self {
            RoutePolicy::Affinity => "affinity",
            RoutePolicy::LeastLoad => "least_load",
        }
    }
}

/// State shared by every connection handler, the health checker, and the
/// status/metrics renderers.
pub(crate) struct RouterShared {
    pub(crate) membership: Membership,
    pub(crate) shed_at: u64,
    pub(crate) shed_total: AtomicU64,
    pub(crate) reconnect: ReconnectPolicy,
    pub(crate) health_ms: u64,
}

/// The running router: accept loop + health checker + open-connection
/// registry, shut down as one unit (mirrors [`crate::server::Server`]).
pub struct Router {
    local_addr: SocketAddr,
    accept_thread: Option<thread::JoinHandle<()>>,
    health_thread: Option<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    shared: Arc<RouterShared>,
}

impl Router {
    /// Bind `addr` (use port 0 for an ephemeral port) and start routing
    /// to `settings.members`. `conn_threads` bounds concurrent client
    /// connections. Errors if the member list is empty.
    pub fn start(addr: &str, settings: &ClusterSettings, conn_threads: usize) -> Result<Router> {
        if settings.members.is_empty() {
            return Err(MatexpError::Config(
                "cluster has no members (set --members or cluster.members)".into(),
            ));
        }
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(RouterShared {
            membership: Membership::new(&settings.members),
            shed_at: settings.shed_at as u64,
            shed_total: AtomicU64::new(0),
            reconnect: ReconnectPolicy {
                max_attempts: settings.reconnect_attempts,
                base_ms: settings.reconnect_base_ms,
                max_ms: RECONNECT_MAX_MS,
            },
            health_ms: settings.health_ms,
        });
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));

        let health_thread = thread::Builder::new().name("matexp-health".into()).spawn({
            let stop = Arc::clone(&stop);
            let shared = Arc::clone(&shared);
            move || health_loop(&stop, &shared)
        })?;

        let accept_thread = thread::Builder::new().name("matexp-route-accept".into()).spawn({
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let shared = Arc::clone(&shared);
            move || {
                let pool = ThreadPool::new(conn_threads, "matexp-route-conn");
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    if let Ok(clone) = stream.try_clone() {
                        let mut held = conns.lock().expect("router conn registry poisoned");
                        held.retain(|s| s.peer_addr().is_ok());
                        held.push(clone);
                    }
                    let shared = Arc::clone(&shared);
                    pool.execute(move || {
                        let _ = route_connection(&shared, stream);
                    });
                }
            }
        })?;

        Ok(Router {
            local_addr,
            accept_thread: Some(accept_thread),
            health_thread: Some(health_thread),
            stop,
            conns,
            shared,
        })
    }

    /// The bound listening address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The router's status document — the same JSON the `metrics` and
    /// `cluster status` wire ops answer with.
    pub fn status(&self) -> Json {
        status_json(&self.shared)
    }

    /// Block until the router is shut down from another thread (the
    /// foreground `matexp route` path).
    pub fn join(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.health_thread.take() {
            let _ = t.join();
        }
    }

    /// Stop accepting, close every client connection, and join the
    /// accept and health threads.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        for s in self.conns.lock().expect("router conn registry poisoned").drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        // unblock the accept loop so it observes the stop flag
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.health_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Per-connection egress: one lazily-opened client per member address,
/// with the router's reconnect policy attached.
struct Egress {
    clients: HashMap<String, MatexpClient>,
    reconnect: ReconnectPolicy,
}

impl Egress {
    fn client_for(&mut self, addr: &str) -> Result<&mut MatexpClient> {
        if !self.clients.contains_key(addr) {
            let mut c = MatexpClient::connect(addr)?.with_reconnect(self.reconnect);
            // members of this build ack frames; a JSON-only member just
            // stays on lines, which is slower but equally correct
            c.negotiate_binary()?;
            self.clients.insert(addr.to_string(), c);
        }
        Ok(self.clients.get_mut(addr).expect("just inserted"))
    }

    fn drop_client(&mut self, addr: &str) {
        self.clients.remove(addr);
    }
}

/// How an egress attempt failed — the distinction that decides between
/// transparent reroute and a typed error to the client.
enum EgressFailure {
    /// Nothing reached the member (connect/negotiate failed): safe to
    /// reroute the request elsewhere.
    Connect(MatexpError),
    /// The connection died with the request possibly in flight: the
    /// member may have executed it, so this request fails typed.
    InFlight(MatexpError),
    /// The member answered with a typed error: pass it through verbatim.
    Typed(MatexpError),
}

fn send_to_member(
    egress: &mut Egress,
    member: &Member,
    matrix: &Matrix,
    power: u64,
    method: Method,
    cache: CacheControl,
    trace_id: TraceId,
) -> std::result::Result<(Matrix, crate::server::proto::WireStats), EgressFailure> {
    let client = match egress.client_for(member.name()) {
        Ok(c) => c,
        Err(e) => return Err(EgressFailure::Connect(e)),
    };
    let t0 = trace::now_us();
    match client.expm_cached(matrix, power, method, cache) {
        Ok(ok) => {
            trace::record_span_at(SpanKind::MemberSend, trace_id, t0, trace::now_us(), matrix.n());
            Ok(ok)
        }
        Err(e @ MatexpError::Disconnected(_)) => Err(EgressFailure::InFlight(e)),
        Err(e) => Err(EgressFailure::Typed(e)),
    }
}

/// The routing decision: HRW owner for cache-eligible traffic, least
/// load otherwise, typed `Admission` when every live member is at the
/// shed threshold. Pure over the snapshot so it unit-tests directly.
pub(crate) fn pick_member(
    members: &[Arc<Member>],
    digest: (u64, u64),
    cache: CacheControl,
    shed_at: u64,
    excluded: &HashSet<String>,
) -> Result<(Arc<Member>, RoutePolicy)> {
    let eligible: Vec<&Arc<Member>> =
        members.iter().filter(|m| m.eligible() && !excluded.contains(m.name())).collect();
    if eligible.is_empty() {
        return Err(MatexpError::Service("no live cluster members".into()));
    }
    if cache != CacheControl::Bypass {
        let names: Vec<&str> = eligible.iter().map(|m| m.name()).collect();
        let i = hash::owner(digest, &names).expect("eligible set is non-empty");
        if eligible[i].outstanding() < shed_at {
            return Ok((Arc::clone(eligible[i]), RoutePolicy::Affinity));
        }
    }
    // the owner is saturated (or the request bypasses the cache): spill
    // to the least-loaded unsaturated member, ties broken by name
    let mut best: Option<&Arc<Member>> = None;
    for m in &eligible {
        if m.outstanding() >= shed_at {
            continue;
        }
        let wins = match best {
            None => true,
            Some(b) => {
                let (mo, bo) = (m.outstanding(), b.outstanding());
                mo < bo || (mo == bo && m.name() < b.name())
            }
        };
        if wins {
            best = Some(m);
        }
    }
    match best {
        Some(m) => Ok((Arc::clone(m), RoutePolicy::LeastLoad)),
        None => Err(MatexpError::Admission(format!(
            "cluster saturated: all {} live members at shed-at={shed_at} outstanding",
            eligible.len()
        ))),
    }
}

fn route_expm(
    shared: &RouterShared,
    egress: &mut Egress,
    matrix: &Matrix,
    power: u64,
    method: Method,
    cache: CacheControl,
) -> Result<(Matrix, crate::server::proto::WireStats)> {
    let trace_id = TraceId::mint();
    let digest = digest_f32(matrix.data());
    let mut excluded: HashSet<String> = HashSet::new();
    loop {
        let t0 = trace::now_us();
        let members = shared.membership.snapshot();
        let (member, policy) = match pick_member(&members, digest, cache, shared.shed_at, &excluded)
        {
            Ok(pick) => pick,
            Err(e) => {
                if matches!(e, MatexpError::Admission(_)) {
                    shared.shed_total.fetch_add(1, Ordering::Relaxed);
                }
                return Err(e);
            }
        };
        trace::record_span_at(SpanKind::Route, trace_id, t0, trace::now_us(), matrix.n());
        match policy {
            RoutePolicy::Affinity => member.note_affinity(),
            RoutePolicy::LeastLoad => member.note_least_load(),
        }
        member.begin_request();
        let outcome = send_to_member(egress, &member, matrix, power, method, cache, trace_id);
        member.end_request();
        match outcome {
            Ok(ok) => return Ok(ok),
            Err(EgressFailure::Connect(_)) => {
                // never reached the member: mark it down and reroute
                member.set_up(false);
                egress.drop_client(member.name());
                excluded.insert(member.name().to_string());
            }
            Err(EgressFailure::InFlight(e)) => {
                // possibly executed: this request fails typed; the member
                // is marked down so the NEXT request reroutes cleanly
                member.set_up(false);
                egress.drop_client(member.name());
                return Err(e);
            }
            Err(EgressFailure::Typed(e)) => return Err(e),
        }
    }
}

fn ok_doc(doc: Json) -> WireResponse {
    WireResponse::Ok {
        result: None,
        stats: None,
        metrics: Some(doc),
        payload: crate::server::proto::Payload::Json,
        id: None,
        frame: None,
    }
}

/// The router's status document: role, shed state, and one entry per
/// member with liveness and per-policy routed counts. This is what the
/// `metrics` (JSON) and `cluster status` ops answer, and what the
/// loadtest reads its per-member spread from.
pub(crate) fn status_json(shared: &RouterShared) -> Json {
    let members: Vec<Json> = shared
        .membership
        .snapshot()
        .iter()
        .map(|m| {
            let (aff, ll) = m.routed();
            json_obj![
                ("member", m.name()),
                ("up", m.is_up()),
                ("draining", m.is_draining()),
                ("outstanding", m.outstanding()),
                ("routed_affinity", aff),
                ("routed_least_load", ll),
                ("routed", aff + ll),
            ]
        })
        .collect();
    json_obj![
        ("role", "router"),
        ("members", Json::Arr(members)),
        ("shed_at", shared.shed_at),
        ("shed_total", shared.shed_total.load(Ordering::Relaxed)),
    ]
}

/// Render the cluster's Prometheus series (`matexp_cluster_member_up`,
/// `matexp_cluster_requests_routed_total{member,policy}`,
/// `matexp_cluster_shed_total`) — the router's answer to
/// `metrics --format prometheus`, lint-clean under
/// [`crate::trace::prometheus::lint`].
pub fn render_prometheus(members: &[Arc<Member>], shed_total: u64) -> String {
    let mut out = String::with_capacity(1024);
    let _ = writeln!(
        out,
        "# HELP {PREFIX}cluster_member_up Member liveness as seen by the router (1 = routable)."
    );
    let _ = writeln!(out, "# TYPE {PREFIX}cluster_member_up gauge");
    for m in members {
        let _ =
            writeln!(out, "{PREFIX}cluster_member_up{{member=\"{}\"}} {}", m.name(), u64::from(m.is_up()));
    }
    let _ = writeln!(
        out,
        "# HELP {PREFIX}cluster_requests_routed_total Requests routed, per member and policy."
    );
    let _ = writeln!(out, "# TYPE {PREFIX}cluster_requests_routed_total counter");
    for m in members {
        let (aff, ll) = m.routed();
        let _ = writeln!(
            out,
            "{PREFIX}cluster_requests_routed_total{{member=\"{}\",policy=\"affinity\"}} {aff}",
            m.name()
        );
        let _ = writeln!(
            out,
            "{PREFIX}cluster_requests_routed_total{{member=\"{}\",policy=\"least_load\"}} {ll}",
            m.name()
        );
    }
    let _ = writeln!(
        out,
        "# HELP {PREFIX}cluster_shed_total Requests shed because every live member was saturated."
    );
    let _ = writeln!(out, "# TYPE {PREFIX}cluster_shed_total counter");
    let _ = writeln!(out, "{PREFIX}cluster_shed_total {shed_total}");
    out
}

fn metrics_reply(shared: &RouterShared, format: MetricsFormat) -> WireResponse {
    match format {
        MetricsFormat::Json => ok_doc(status_json(shared)),
        MetricsFormat::Prometheus => ok_doc(Json::from(render_prometheus(
            &shared.membership.snapshot(),
            shared.shed_total.load(Ordering::Relaxed),
        ))),
    }
}

fn handle_cluster(
    shared: &RouterShared,
    action: ClusterAction,
    addr: Option<String>,
) -> WireResponse {
    match action {
        ClusterAction::Status => ok_doc(status_json(shared)),
        ClusterAction::Join => match addr {
            Some(a) if a.contains(':') => {
                shared.membership.join(&a);
                ok_doc(status_json(shared))
            }
            Some(a) => WireResponse::from_error(&MatexpError::Config(format!(
                "member address {a:?} is not host:port"
            ))),
            None => WireResponse::from_error(&MatexpError::Config(
                "cluster join needs an \"addr\" (the member to add)".into(),
            )),
        },
        ClusterAction::Leave => match addr {
            Some(a) => {
                if shared.membership.leave(&a) {
                    ok_doc(status_json(shared))
                } else {
                    WireResponse::from_error(&MatexpError::Config(format!("unknown member {a:?}")))
                }
            }
            None => WireResponse::from_error(&MatexpError::Config(
                "cluster leave needs an \"addr\" (the member to remove)".into(),
            )),
        },
        ClusterAction::Drain => match addr {
            Some(a) => drain_member(shared, &a),
            None => WireResponse::from_error(&MatexpError::Config(
                "cluster drain needs an \"addr\" (the member to drain)".into(),
            )),
        },
        ClusterAction::Pull => match addr {
            // one member's export — the owner a joining peer warms from
            Some(a) => match shared.membership.get(&a) {
                Some(member) => ok_doc(json_obj![
                    ("role", "router"),
                    ("member", member.name()),
                    ("artifacts", Json::Arr(member_export(member.name()))),
                ]),
                None => WireResponse::from_error(&MatexpError::Config(format!(
                    "unknown member {a:?}"
                ))),
            },
            // no addr: aggregate every live member's hottest artifacts
            None => {
                let mut all = Vec::new();
                for member in shared.membership.snapshot() {
                    if member.is_up() {
                        all.extend(member_export(member.name()));
                    }
                }
                ok_doc(json_obj![("role", "router"), ("artifacts", Json::Arr(all))])
            }
        },
    }
}

/// Fetch one member's hot-artifact export, best effort: a member that
/// cannot be reached or answers without an `artifacts` array contributes
/// nothing rather than failing the pull.
fn member_export(addr: &str) -> Vec<Json> {
    let Ok(mut c) = MatexpClient::connect(addr) else { return Vec::new() };
    let Ok(doc) = c.cluster(ClusterAction::Pull, None) else { return Vec::new() };
    match doc.get("artifacts").and_then(|a| a.as_arr()) {
        Some(items) => items.to_vec(),
        None => Vec::new(),
    }
}

fn drain_member(shared: &RouterShared, addr: &str) -> WireResponse {
    let Some(member) = shared.membership.get(addr) else {
        return WireResponse::from_error(&MatexpError::Config(format!("unknown member {addr:?}")));
    };
    // stop routing new work immediately, then wait (bounded) for the
    // router-side in-flight count to reach zero
    member.set_draining(true);
    let deadline = Instant::now() + Duration::from_millis(DRAIN_WAIT_MS);
    while member.outstanding() > 0 && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(2));
    }
    let drained = member.outstanding() == 0;
    // tell the member itself to refuse direct work too (best effort —
    // a member that is already gone has nothing left to refuse)
    if let Ok(mut c) = MatexpClient::connect(addr) {
        let _ = c.cluster(ClusterAction::Drain, None);
    }
    if drained {
        shared.membership.leave(addr);
    }
    let mut doc = status_json(shared);
    if let Json::Obj(fields) = &mut doc {
        fields.insert("drained".into(), Json::from(drained));
        fields.insert("detached".into(), Json::from(drained));
    }
    ok_doc(doc)
}

/// Recover the client-chosen id from a line that failed to decode, so
/// the error reply still routes to the right pipelined ticket.
fn salvage_id(line: &str) -> Option<u64> {
    Json::parse(line).ok()?.get("id")?.as_u64()
}

fn route_connection(shared: &Arc<RouterShared>, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut egress = Egress { clients: HashMap::new(), reconnect: shared.reconnect };
    loop {
        // one-byte peek dispatches the codec, mirroring the server
        let first = match reader.fill_buf() {
            Ok([]) => return Ok(()),
            Ok(buf) => buf[0],
            Err(_) => return Ok(()),
        };
        if first == frame::MAGIC[0] {
            let (f, _) = Frame::read_from(&mut reader, frame::MAX_PAYLOAD)?;
            let Frame::Expm { id, n, power, method, matrix } = f else {
                // a reply frame as a request: the stream is broken
                return Ok(());
            };
            let reply = match Matrix::from_vec(n, matrix) {
                // frames carry no cache directive: always cache-eligible
                Ok(m) => match route_expm(shared, &mut egress, &m, power, method, CacheControl::Use)
                {
                    Ok((result, stats)) => {
                        Frame::ExpmOk { id, n, stats, result: result.into_vec() }
                    }
                    Err(e) => Frame::from_error(&e, Some(id)),
                },
                Err(e) => Frame::from_error(&e, Some(id)),
            };
            if writer.write_all(&reply.encode()).is_err() {
                return Ok(());
            }
        } else {
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) => return Ok(()),
                Ok(_) => {}
                Err(_) => return Ok(()),
            }
            let text = line.trim_end();
            if text.is_empty() {
                continue;
            }
            let reply = match WireRequest::decode(text) {
                Err(e) => WireResponse::from_error(&e).with_id(salvage_id(text)),
                Ok(WireRequest::Ping) => WireResponse::pong(),
                Ok(WireRequest::Hello { frame_version }) => {
                    WireResponse::hello_ack(frame_version.min(u32::from(frame::VERSION)))
                }
                Ok(WireRequest::Metrics { format }) => metrics_reply(shared, format),
                Ok(WireRequest::Trace) => {
                    ok_doc(trace::chrome::export(&trace::recent_spans()))
                }
                Ok(WireRequest::Cluster { action, addr }) => handle_cluster(shared, action, addr),
                Ok(WireRequest::Expm { n, power, method, matrix, payload, id, cache }) => {
                    match Matrix::from_vec(n, matrix) {
                        Ok(m) => match route_expm(shared, &mut egress, &m, power, method, cache) {
                            Ok((result, stats)) => WireResponse::Ok {
                                result: Some(result.into_vec()),
                                stats: Some(stats),
                                metrics: None,
                                payload,
                                id,
                                frame: None,
                            },
                            Err(e) => WireResponse::from_error(&e).with_id(id),
                        },
                        Err(e) => WireResponse::from_error(&e).with_id(id),
                    }
                }
            };
            let encoded = match reply.encode() {
                Ok(s) => s,
                // a non-finite result can't ride a JSON array: report the
                // typed error instead of emitting a corrupt payload
                Err(e) => WireResponse::from_error(&e)
                    .encode()
                    .expect("error lines always encode"),
            };
            if writer.write_all(encoded.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
                return Ok(());
            }
        }
    }
}

/// One ping probe against a member, with connect and read timeouts —
/// raw sockets, not [`MatexpClient`], so a hung member cannot wedge the
/// health thread.
fn probe(addr: &str, timeout: Duration) -> bool {
    let Ok(mut addrs) = addr.to_socket_addrs() else { return false };
    let Some(sock) = addrs.next() else { return false };
    let Ok(mut stream) = TcpStream::connect_timeout(&sock, timeout) else { return false };
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_nodelay(true);
    if stream.write_all(b"{\"op\":\"ping\"}\n").is_err() {
        return false;
    }
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(k) if k > 0 => {
            matches!(WireResponse::decode(line.trim_end()), Ok(WireResponse::Ok { .. }))
        }
        _ => false,
    }
}

fn health_loop(stop: &AtomicBool, shared: &RouterShared) {
    while !stop.load(Ordering::SeqCst) {
        for m in shared.membership.snapshot() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            m.set_up(probe(m.name(), Duration::from_millis(PROBE_TIMEOUT_MS)));
        }
        // sleep in small slices so shutdown stays prompt
        let mut slept = 0;
        while slept < shared.health_ms && !stop.load(Ordering::SeqCst) {
            let step = (shared.health_ms - slept).min(25);
            thread::sleep(Duration::from_millis(step));
            slept += step;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three() -> Vec<Arc<Member>> {
        vec![Member::new("a:1"), Member::new("b:2"), Member::new("c:3")]
    }

    fn shared_with(shed_at: u64) -> RouterShared {
        RouterShared {
            membership: Membership::new(&["a:1".into(), "b:2".into(), "c:3".into()]),
            shed_at,
            shed_total: AtomicU64::new(0),
            reconnect: ReconnectPolicy::default(),
            health_ms: 500,
        }
    }

    #[test]
    fn affinity_is_stable_and_respects_liveness() {
        let members = three();
        let none = HashSet::new();
        let d = (42, 77);
        let (first, policy) = pick_member(&members, d, CacheControl::Use, 64, &none).unwrap();
        assert_eq!(policy, RoutePolicy::Affinity);
        for _ in 0..10 {
            let (m, _) = pick_member(&members, d, CacheControl::Use, 64, &none).unwrap();
            assert_eq!(m.name(), first.name(), "same digest, same owner");
        }
        // owner down -> a different member takes over, deterministically
        first.set_up(false);
        let (fallback, _) = pick_member(&members, d, CacheControl::Use, 64, &none).unwrap();
        assert_ne!(fallback.name(), first.name());
        // owner back up -> placement returns (no lasting reshuffle)
        first.set_up(true);
        let (back, _) = pick_member(&members, d, CacheControl::Use, 64, &none).unwrap();
        assert_eq!(back.name(), first.name());
    }

    #[test]
    fn bypass_and_saturation_go_least_load() {
        let members = three();
        let none = HashSet::new();
        members[0].begin_request();
        members[0].begin_request();
        members[1].begin_request();
        // bypass traffic ignores the digest: least-loaded member wins
        let (m, policy) = pick_member(&members, (1, 1), CacheControl::Bypass, 64, &none).unwrap();
        assert_eq!(policy, RoutePolicy::LeastLoad);
        assert_eq!(m.name(), "c:3");
        // a saturated affinity owner spills to least-load
        let d = (42, 77);
        let (owner, _) = pick_member(&members, d, CacheControl::Use, 64, &none).unwrap();
        while owner.outstanding() < 4 {
            owner.begin_request();
        }
        let (spill, policy) = pick_member(&members, d, CacheControl::Use, 4, &none).unwrap();
        assert_eq!(policy, RoutePolicy::LeastLoad);
        assert_ne!(spill.name(), owner.name());
    }

    #[test]
    fn full_cluster_sheds_with_admission_and_empty_cluster_is_service() {
        let members = three();
        let none = HashSet::new();
        for m in &members {
            m.begin_request();
        }
        let e = pick_member(&members, (9, 9), CacheControl::Use, 1, &none).unwrap_err();
        assert!(matches!(e, MatexpError::Admission(_)), "{e:?}");
        // draining members are not admission candidates either
        for m in &members {
            m.end_request();
            m.set_draining(true);
        }
        let e = pick_member(&members, (9, 9), CacheControl::Use, 1, &none).unwrap_err();
        assert!(matches!(e, MatexpError::Service(_)), "{e:?}");
        let e = pick_member(&[], (9, 9), CacheControl::Use, 1, &none).unwrap_err();
        assert!(matches!(e, MatexpError::Service(_)), "{e:?}");
    }

    #[test]
    fn excluded_members_are_skipped() {
        let members = three();
        let d = (42, 77);
        let none = HashSet::new();
        let (owner, _) = pick_member(&members, d, CacheControl::Use, 64, &none).unwrap();
        let mut excluded = HashSet::new();
        excluded.insert(owner.name().to_string());
        let (next, _) = pick_member(&members, d, CacheControl::Use, 64, &excluded).unwrap();
        assert_ne!(next.name(), owner.name());
    }

    #[test]
    fn prometheus_exposition_is_lint_clean_and_labeled() {
        let members = three();
        members[0].note_affinity();
        members[0].note_affinity();
        members[1].note_least_load();
        members[2].set_up(false);
        let text = render_prometheus(&members, 3);
        crate::trace::prometheus::lint(&text).unwrap();
        assert!(text.contains("matexp_cluster_member_up{member=\"a:1\"} 1"), "{text}");
        assert!(text.contains("matexp_cluster_member_up{member=\"c:3\"} 0"), "{text}");
        assert!(
            text.contains(
                "matexp_cluster_requests_routed_total{member=\"a:1\",policy=\"affinity\"} 2"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "matexp_cluster_requests_routed_total{member=\"b:2\",policy=\"least_load\"} 1"
            ),
            "{text}"
        );
        assert!(text.contains("matexp_cluster_shed_total 3"), "{text}");
    }

    #[test]
    fn status_document_reports_members_and_shed_state() {
        let shared = shared_with(8);
        shared.shed_total.fetch_add(2, Ordering::Relaxed);
        let members = shared.membership.snapshot();
        members[1].note_affinity();
        let doc = status_json(&shared);
        assert_eq!(doc.get("role").and_then(Json::as_str), Some("router"));
        assert_eq!(doc.get("shed_at").and_then(Json::as_u64), Some(8));
        assert_eq!(doc.get("shed_total").and_then(Json::as_u64), Some(2));
        let rows = doc.get("members").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1].get("routed").and_then(Json::as_u64), Some(1));
        assert_eq!(rows[0].get("up").and_then(Json::as_bool), Some(true));
    }
}
