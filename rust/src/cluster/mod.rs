//! # The distributed serving tier
//!
//! One process with a device pool serves one machine's worth of the
//! paper's workload; the ROADMAP's north star ("matrix exponentiation
//! for millions of users") needs many. This module turns N independent
//! `matexp serve` processes into one service behind a **content-affinity
//! router** — the cluster-scale version of the paper's economics: cheap
//! commodity nodes, coordinated so the expensive work (planning,
//! compiling, executing a hot matrix) is paid once *per cluster*, not
//! once per node.
//!
//! ## Why content affinity
//!
//! The result cache ([`crate::cache`]) is content-addressed: a repeated
//! hot matrix is a cache hit *only on the node that computed it first*.
//! A load balancer that sprays requests round-robin turns an N-node
//! cluster into N cold caches. The router instead hashes the same
//! 128-bit content digest the cache keys on, and rendezvous hashing
//! ([`hash`]) maps each digest to one owner — so every repetition of a
//! hot matrix lands where its result already lives, and membership
//! changes move only the minimal `1/N` slice of the digest space.
//!
//! ## Pieces
//!
//! | piece | role |
//! |---|---|
//! | [`hash`] | rendezvous (HRW) placement over the result-cache digest |
//! | [`Membership`] / [`Member`] | lock-free member registry: liveness, drain state, load counters |
//! | [`Router`] | the front-end: both wire codecs in, [`crate::server::MatexpClient`] frames out |
//! | [`Cluster`] | in-process cluster-sim: N real servers + router, one handle |
//!
//! The router owns the cluster's operational surface: periodic health
//! probes (a down member's digest range falls to per-digest runners-up),
//! runtime membership via the `cluster` wire op (join/leave/drain/
//! status), backpressure shedding with the same typed
//! [`crate::error::MatexpError::Admission`] a single server uses, and
//! graceful drain. Observability rides the existing rails: `route` and
//! `member_send` spans in the trace ring ([`crate::trace`]) and
//! `matexp_cluster_*` series in the Prometheus exposition
//! ([`router::render_prometheus`]).
//!
//! ## Quick start
//!
//! ```no_run
//! use matexp::cluster::Cluster;
//! use matexp::prelude::*;
//! use matexp::server::MatexpClient;
//!
//! // three real servers on loopback + a router, one handle
//! let cluster = Cluster::spawn_local(3)?;
//! let mut client = MatexpClient::connect(&cluster.router_addr())?;
//! let a = Matrix::identity(32);
//! let (result, stats) = client.expm(&a, 1024, Method::Ours)?;
//! assert_eq!(result.n(), 32);
//! # let _ = stats;
//! cluster.shutdown();
//! # Ok::<(), matexp::error::MatexpError>(())
//! ```
//!
//! (`no_run` to keep doctests socket-free; the integration suite runs
//! the same flow for real, including failover and drain.)

pub mod hash;
pub mod membership;
pub mod router;
pub mod sim;

pub use membership::{Member, Membership};
pub use router::{render_prometheus, RoutePolicy, Router};
pub use sim::Cluster;
