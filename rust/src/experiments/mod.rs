//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§5) plus the design-choice ablations DESIGN.md calls out.
//!
//! | id        | paper artifact              | entrypoint                      |
//! |-----------|-----------------------------|---------------------------------|
//! | T1        | Table 1 (C2050 spec)        | `DeviceSpec::tesla_c2050()`     |
//! | T2/F5/F6  | Table 2, Figs 5–6 (n=64)    | [`tables::run_table`] (id 2)    |
//! | T3/F7/F8  | Table 3, Figs 7–8 (n=128)   | id 3                            |
//! | T4/F9/F10 | Table 4, Figs 9–10 (n=256)  | id 4                            |
//! | T5/F11/F12| Table 5, Figs 11–12 (n=512) | id 5                            |
//! | A1        | §4.3.7 TILE sweep           | [`ablations::tile_sweep`]       |
//! | A2        | §4.3.8 transfer discipline  | [`ablations::transfer_ablation`]|
//! | A3        | launch fusion               | [`ablations::fusion_ablation`]  |
//! | A4        | CPU-baseline fairness       | [`ablations::cpu_variants`]     |
//! | A5        | buffer residency            | [`ablations::residency_data_path`] |
//! | A6        | cache tiers (plan/prepared/result) | [`ablations::cache_setup_arms`] |
//! | S1        | pool scaling (extension)    | [`scaling::run_pool_scaling`]   |

pub mod ablations;
pub mod paper;
pub mod report;
pub mod scaling;
pub mod tables;

pub use ablations::{ArmResult, ResidencyArm};
pub use paper::{paper_cell, paper_table, paper_tables, PaperCell, PaperTable};
pub use report::{render_ablation, render_figures, render_table};
pub use scaling::{render_scaling, run_pool_scaling, ScalingArm, ScalingTable};
pub use tables::{run_table, run_table_sim, CellResult, MethodTimes, TableResult};
