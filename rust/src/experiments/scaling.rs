//! Pool scaling experiment: the Table-4-style workload (one request per
//! paper power) run on device pools of growing size, against a single
//! calibrated SimBackend.
//!
//! Two numbers per pool arm:
//!
//! * **workload** — the four requests dispatched request-parallel across
//!   the pool (per-device queues + stealing); wall is the busiest
//!   device's share, exactly what the pool's critical path is.
//! * **shard** — the largest power as ONE tile-sharded request (the
//!   latency story); `None` when the cost-model splitter refuses to shard
//!   at this size because the split would lose to its fastest member.
//!
//! Predicted columns come from the same cost models the splitter runs on
//! (analytic C2050 model; measured CPU probe), so prediction vs measured
//! is itself a check of the splitter's inputs.

use std::fmt::Write as _;

use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmRequest, Method};
use crate::error::{MatexpError, Result};
use crate::exec::Executor;
use crate::linalg::matrix::Matrix;
use crate::plan::{Plan, Step};
use crate::pool::cost::DeviceCost;
use crate::pool::{PoolDeviceKind, PoolEngine, ShardDecision};
use crate::runtime::engine::AnyEngine;
use crate::runtime::BackendKind;
use crate::simulator::timing::GpuTimingModel;

/// The paper's Table-4 power column (N = 64..512).
pub const TABLE4_POWERS: [u64; 4] = [64, 128, 256, 512];

/// One pool configuration's outcome.
#[derive(Clone, Debug)]
pub struct ScalingArm {
    /// Arm label ("2xsim", "cpu+4sim", …).
    pub name: String,
    /// Pool membership of this arm.
    pub devices: Vec<PoolDeviceKind>,
    /// Predicted workload wall (request-parallel makespan), seconds.
    pub predicted_s: f64,
    /// Measured workload wall (busiest device's share), seconds.
    pub measured_s: Option<f64>,
    /// Predicted wall for the largest power tile-sharded, if the splitter
    /// shards at this size.
    pub shard_predicted_s: Option<f64>,
    /// Measured wall for that sharded request.
    pub shard_measured_s: Option<f64>,
    /// Cross-queue steals observed during the measured run.
    pub steals: u64,
    /// Host-edge bytes the measured workload copied (summed across the
    /// pool's devices) — the residency layer's live counter, so the
    /// clone-vs-resident ablation is visible from the scaling run too.
    pub bytes_copied: Option<u64>,
    /// Recycled-buffer launch outputs during the measured workload.
    pub buffers_recycled: Option<u64>,
}

/// The whole experiment: baseline + arms.
#[derive(Clone, Debug)]
pub struct ScalingTable {
    /// Matrix side length of the workload.
    pub n: usize,
    /// The workload's power column (Table 4's N values).
    pub powers: Vec<u64>,
    /// Single calibrated SimBackend running the workload serially.
    pub baseline_predicted_s: f64,
    /// Measured single-device workload wall, when measured.
    pub baseline_measured_s: Option<f64>,
    /// Single-device wall for the largest power (the shard comparator).
    pub baseline_shard_predicted_s: f64,
    /// Measured single-device wall for that request, when measured.
    pub baseline_shard_measured_s: Option<f64>,
    /// One row per pool configuration.
    pub arms: Vec<ScalingArm>,
}

impl ScalingTable {
    /// Predicted workload speedup of arm `i` over the single sim device.
    pub fn speedup_pred(&self, i: usize) -> f64 {
        self.baseline_predicted_s / self.arms[i].predicted_s.max(1e-12)
    }

    /// Measured workload speedup of arm `i`, if measured.
    pub fn speedup_meas(&self, i: usize) -> Option<f64> {
        match (self.baseline_measured_s, self.arms[i].measured_s) {
            (Some(base), Some(arm)) => Some(base / arm.max(1e-12)),
            _ => None,
        }
    }
}

/// The ISSUE's arm ladder: 1/2/4/8 simulated C2050s, plus CPU+4×sim.
pub fn default_scaling_arms() -> Vec<Vec<PoolDeviceKind>> {
    let mut arms: Vec<Vec<PoolDeviceKind>> =
        [1usize, 2, 4, 8].iter().map(|&k| vec![PoolDeviceKind::Sim; k]).collect();
    let mut hetero = vec![PoolDeviceKind::Cpu];
    hetero.extend(std::iter::repeat(PoolDeviceKind::Sim).take(4));
    arms.push(hetero);
    arms
}

fn arm_name(devices: &[PoolDeviceKind]) -> String {
    let cpus = devices.iter().filter(|d| **d == PoolDeviceKind::Cpu).count();
    let sims = devices.len() - cpus;
    match (cpus, sims) {
        (0, s) => format!("pool {s}x sim"),
        (c, 0) => format!("pool {c}x cpu"),
        (c, s) => format!("pool {c}x cpu + {s}x sim"),
    }
}

/// Predicted wall for one device-resident plan replay on the sim model:
/// per-launch overhead + roofline kernel time per step + the two host
/// crossings (and the pair-split round-trips of fused SqMul steps).
pub fn predict_plan_resident(model: &GpuTimingModel, n: usize, plan: &Plan) -> f64 {
    let mut s = model.transfer_time(n, 2);
    for step in &plan.steps {
        let mult = step.multiplies();
        if mult == 0 {
            continue;
        }
        s += model.eff_launch_overhead(n) + model.kernel_time(n, mult);
        if matches!(step, Step::SqMul { .. }) {
            s += model.transfer_time(n, 4);
        }
    }
    s
}

/// Predicted wall for one whole request on one device.
fn predict_request(cost: &DeviceCost, n: usize, plan: &Plan) -> f64 {
    match cost {
        DeviceCost::Model(m) => predict_plan_resident(m, n, plan),
        DeviceCost::Measured { fixed_s, per_flop_s } => {
            plan.multiplies() as f64 * (fixed_s + 2.0 * (n as f64).powi(3) * per_flop_s)
        }
    }
}

/// LPT makespan of the request set across the given device cost models
/// (same scheduling discipline as the pool, via
/// [`crate::pool::cost::lpt_assign`], just with full-plan request costs).
pub fn predict_workload(costs: &[DeviceCost], n: usize, plans: &[Plan]) -> f64 {
    crate::pool::cost::lpt_assign(costs.len(), plans.len(), |d, j| {
        predict_request(&costs[d], n, &plans[j])
    })
    .1
}

/// The workload's plans, exactly as the service plans `Method::Ours`.
fn workload_plans(cfg: &MatexpConfig, powers: &[u64]) -> Vec<Plan> {
    powers.iter().map(|&p| super::tables::ours_plan(cfg, p)).collect()
}

/// Run the scaling experiment at matrix size `n`. `measure` executes
/// every arm on live pools (real sim clocks / CPU time); prediction-only
/// is instant and what the tests assert on.
pub fn run_pool_scaling(
    base_cfg: &MatexpConfig,
    n: usize,
    arm_devices: &[Vec<PoolDeviceKind>],
    measure: bool,
) -> Result<ScalingTable> {
    let powers: Vec<u64> = TABLE4_POWERS.to_vec();
    let plans = workload_plans(base_cfg, &powers);
    let (model, _) = super::tables::calibrated_models();
    let sim_cost = DeviceCost::Model(model.clone());

    let baseline_predicted_s: f64 =
        plans.iter().map(|p| predict_plan_resident(&model, n, p)).sum();
    let largest = *powers.last().expect("non-empty workload");
    let largest_plan = plans.last().expect("non-empty workload").clone();
    let baseline_shard_predicted_s = predict_plan_resident(&model, n, &largest_plan);

    let (baseline_measured_s, baseline_shard_measured_s) = if measure {
        let mut cfg = base_cfg.clone();
        cfg.backend = BackendKind::Sim;
        let mut engine = AnyEngine::from_config(&cfg)?;
        let a = Matrix::random_spectral(n, 0.999, cfg.seed);
        let mut total = 0.0;
        let mut shard_base = 0.0;
        for (plan, &power) in plans.iter().zip(&powers) {
            let stats = engine
                .run(crate::exec::Submission::expm(a.clone(), power).plan(plan.clone()))?
                .stats;
            total += stats.wall_s;
            if power == largest {
                shard_base = stats.wall_s;
            }
        }
        (Some(total), Some(shard_base))
    } else {
        (None, None)
    };

    let mut arms = Vec::with_capacity(arm_devices.len());
    for devices in arm_devices {
        if devices.is_empty() {
            return Err(MatexpError::Config("scaling arm with no devices".into()));
        }
        let mut cfg = base_cfg.clone();
        cfg.backend = BackendKind::Pool;
        cfg.pool.devices = devices.clone();

        // predicted columns need the same cost models the pool will build;
        // CPU probes require a live device, so predict those only when
        // measuring (sim-only arms predict without any pool)
        let needs_pool = measure || devices.contains(&PoolDeviceKind::Cpu);
        let engine = if needs_pool { Some(PoolEngine::from_config(&cfg)?) } else { None };
        let costs: Vec<DeviceCost> = match &engine {
            Some(e) => e.pool().costs().to_vec(),
            None => devices.iter().map(|_| sim_cost.clone()).collect(),
        };

        let predicted_s = predict_workload(&costs, n, &plans);
        let shard_plan = match crate::pool::cost::plan_shard(
            &costs,
            n,
            cfg.pool.max_grid,
            cfg.pool.grid,
        ) {
            ShardDecision::Shard(sp) => Some(sp),
            ShardDecision::Single { .. } => None,
        };
        let shard_predicted_s = shard_plan
            .as_ref()
            .map(|sp| sp.predicted_step_s * largest_plan.multiplies() as f64);

        let (measured_s, shard_measured_s, steals, bytes_copied, buffers_recycled) =
            match (&engine, measure) {
                (Some(e), true) => {
                    let reqs: Vec<ExpmRequest> = powers
                        .iter()
                        .enumerate()
                        .map(|(i, &power)| {
                            ExpmRequest::new(
                                i as u64 + 1,
                                Matrix::random_spectral(n, 0.999, cfg.seed + i as u64),
                                power,
                                Method::Ours,
                            )
                        })
                        .collect();
                    let replies = e.execute_batch(reqs);
                    let mut per_device: std::collections::BTreeMap<String, f64> =
                        std::collections::BTreeMap::new();
                    let mut bytes = 0u64;
                    let mut recycled = 0u64;
                    for (_, outcome) in replies {
                        let resp = outcome?;
                        bytes += resp.stats.bytes_copied;
                        recycled += resp.stats.buffers_recycled;
                        for d in &resp.stats.per_device {
                            *per_device.entry(d.device.clone()).or_insert(0.0) += d.wall_s;
                        }
                    }
                    let busiest = per_device.values().cloned().fold(0.0, f64::max);
                    let shard_measured = match &shard_plan {
                        Some(sp) => {
                            let a = Matrix::random_spectral(n, 0.999, cfg.seed);
                            let (_, stats) = e.expm_sharded(&a, &largest_plan, sp)?;
                            Some(stats.wall_s)
                        }
                        None => None,
                    };
                    let steals: u64 =
                        e.pool().metrics().devices.iter().map(|d| d.steals).sum();
                    (Some(busiest), shard_measured, steals, Some(bytes), Some(recycled))
                }
                _ => (None, None, 0, None, None),
            };

        arms.push(ScalingArm {
            name: arm_name(devices),
            devices: devices.clone(),
            predicted_s,
            measured_s,
            shard_predicted_s,
            shard_measured_s,
            steals,
            bytes_copied,
            buffers_recycled,
        });
    }

    Ok(ScalingTable {
        n,
        powers,
        baseline_predicted_s,
        baseline_measured_s,
        baseline_shard_predicted_s,
        baseline_shard_measured_s,
        arms,
    })
}

/// Render the scaling table (the `experiment --pool-scaling` output).
pub fn render_scaling(t: &ScalingTable) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "== Pool scaling — Table-4 workload (N in {:?}) at n={} ==",
        t.powers, t.n
    );
    let fmt_opt = |v: Option<f64>| match v {
        Some(v) => crate::bench::format_secs(v),
        None => "-".into(),
    };
    let fmt_bytes = |v: Option<u64>| match v {
        Some(b) if b >= 1 << 20 => format!("{:.1}MiB", b as f64 / (1 << 20) as f64),
        Some(b) => format!("{b}B"),
        None => "-".into(),
    };
    let _ = writeln!(
        s,
        "{:<22} {:>12} {:>9} {:>12} {:>9} {:>12} {:>12} {:>7} {:>10} {:>9}",
        "arm",
        "pred wall",
        "pred x",
        "meas wall",
        "meas x",
        "shard pred",
        "shard meas",
        "steals",
        "copied",
        "recycled"
    );
    let _ = writeln!(
        s,
        "{:<22} {:>12} {:>9} {:>12} {:>9} {:>12} {:>12} {:>7} {:>10} {:>9}",
        "single sim (baseline)",
        crate::bench::format_secs(t.baseline_predicted_s),
        "1.00",
        fmt_opt(t.baseline_measured_s),
        if t.baseline_measured_s.is_some() { "1.00" } else { "-" },
        crate::bench::format_secs(t.baseline_shard_predicted_s),
        fmt_opt(t.baseline_shard_measured_s),
        "-",
        "-",
        "-"
    );
    for (i, arm) in t.arms.iter().enumerate() {
        let meas_x = match t.speedup_meas(i) {
            Some(x) => format!("{x:.2}"),
            None => "-".into(),
        };
        let _ = writeln!(
            s,
            "{:<22} {:>12} {:>9} {:>12} {:>9} {:>12} {:>12} {:>7} {:>10} {:>9}",
            arm.name,
            crate::bench::format_secs(arm.predicted_s),
            format!("{:.2}", t.speedup_pred(i)),
            fmt_opt(arm.measured_s),
            meas_x,
            fmt_opt(arm.shard_predicted_s),
            fmt_opt(arm.shard_measured_s),
            arm.steals,
            fmt_bytes(arm.bytes_copied),
            match arm.buffers_recycled {
                Some(r) => r.to_string(),
                None => "-".into(),
            }
        );
    }
    let _ = writeln!(
        s,
        "(workload = request-parallel makespan; shard = largest power tile-sharded, \
         \"-\" = splitter falls back to its fastest member; copied/recycled = the \
         residency layer's host-edge bytes and arena hits over the measured workload)"
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MatexpConfig {
        MatexpConfig::default()
    }

    #[test]
    fn four_sim_pool_hits_the_issue_speedup_on_table4_at_1024() {
        // Acceptance: >= 1.7x for a 4-sim-device pool over a single
        // SimBackend on the 1024x1024 Table-4 workload.
        let arms = vec![vec![PoolDeviceKind::Sim; 4]];
        let t = run_pool_scaling(&cfg(), 1024, &arms, false).unwrap();
        let speedup = t.speedup_pred(0);
        assert!(speedup >= 1.7, "4x sim pool only {speedup:.2}x");
        // and the tile-sharded single request helps too at this size
        let shard = t.arms[0].shard_predicted_s.expect("shards at n=1024");
        assert!(
            shard < t.baseline_shard_predicted_s,
            "shard {shard} vs single {}",
            t.baseline_shard_predicted_s
        );
    }

    #[test]
    fn scaling_is_monotone_in_device_count() {
        let arms: Vec<Vec<PoolDeviceKind>> =
            [1usize, 2, 4, 8].iter().map(|&k| vec![PoolDeviceKind::Sim; k]).collect();
        let t = run_pool_scaling(&cfg(), 1024, &arms, false).unwrap();
        let mut last = 0.0;
        for i in 0..t.arms.len() {
            let x = t.speedup_pred(i);
            assert!(x >= last * 0.999, "arm {i}: {x} < {last}");
            last = x;
        }
        // 1-device pool is the baseline itself (same device-resident path)
        assert!((t.speedup_pred(0) - 1.0).abs() < 0.05, "{}", t.speedup_pred(0));
    }

    #[test]
    fn measured_small_run_matches_predictions_and_criteria() {
        // measured at n=128 so debug-mode numerics stay cheap; the
        // request-parallel speedup is size-independent enough to assert
        // the >= 1.7x criterion on the measured column too
        let arms = vec![vec![PoolDeviceKind::Sim; 4]];
        let t = run_pool_scaling(&cfg(), 128, &arms, true).unwrap();
        let meas = t.speedup_meas(0).expect("measured");
        assert!(meas >= 1.7, "measured 4x sim pool only {meas:.2}x");
        // prediction and sim-clock measurement run on the same model:
        // they must agree tightly for sim-only pools
        let pred = t.arms[0].predicted_s;
        let got = t.arms[0].measured_s.unwrap();
        let ratio = (pred / got).max(got / pred);
        assert!(ratio < 1.2, "pred {pred} vs meas {got}");
        // the measured run surfaces the residency counters: each of the 4
        // device-resident requests copies exactly its two host edges
        let bytes = t.arms[0].bytes_copied.expect("measured run counts bytes");
        assert_eq!(bytes, 4 * 2 * 128 * 128 * 4);
        assert!(t.arms[0].buffers_recycled.expect("measured") > 0);
    }

    #[test]
    fn heterogeneous_split_never_hurts_the_faster_member() {
        // cpu + sim at n=128: the cost model must sideline whichever
        // member loses, so the pool wall stays within 10% of the faster
        // member alone
        let arms = vec![vec![PoolDeviceKind::Cpu, PoolDeviceKind::Sim]];
        let t = run_pool_scaling(&cfg(), 128, &arms, true).unwrap();
        let pool_wall = t.arms[0].measured_s.unwrap();
        let sim_alone = t.baseline_measured_s.unwrap();
        // the faster member is whichever of {sim alone, cpu alone} wins;
        // sim alone is an upper bound for it, so this is the strict check
        assert!(
            pool_wall <= sim_alone * 1.10,
            "hetero pool {pool_wall} vs sim alone {sim_alone}"
        );
    }
}
