//! Regeneration of the paper's Tables 2–5 (and Figures 5–12, which are
//! the same numbers re-plotted).
//!
//! Every cell is produced three ways:
//! * **paper** — the published number ([`super::paper`]);
//! * **simulated** — the calibrated Tesla C2050 analytic model
//!   ([`crate::simulator`]) predicting the cell;
//! * **measured** — this testbed: an [`Engine`] over any backend for both
//!   GPU-discipline arms and the naive i-j-k loop for the CPU arm (capped
//!   + extrapolated, see [`crate::config::MatexpConfig::cpu_measure_cap`]).

use std::time::Instant;

use crate::config::MatexpConfig;
use crate::coordinator::request::Method;
use crate::error::Result;
use crate::exec::{Executor, Submission};
use crate::experiments::paper::{self, PaperCell};
use crate::linalg::{self, matrix::Matrix};
use crate::plan::Plan;
use crate::runtime::{Backend, CpuBackend, Engine};
use crate::simulator::calibrate;
use crate::simulator::device::DeviceSpec;
use crate::simulator::timing::GpuTimingModel;

/// The three methods of every paper table.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MethodTimes {
    /// Naive-GPU seconds (§4.2 discipline).
    pub naive_gpu_s: f64,
    /// Sequential-CPU seconds (§4.1 baseline).
    pub seq_cpu_s: f64,
    /// "Our Approach" seconds (§4.3 device-resident).
    pub ours_s: f64,
}

impl MethodTimes {
    /// "Naïve Speed UP" row: sequential CPU / naive GPU.
    pub fn naive_speedup(&self) -> f64 {
        self.seq_cpu_s / self.naive_gpu_s
    }
    /// "Our Approach vs Naïve GPU" row.
    pub fn ours_vs_naive(&self) -> f64 {
        self.naive_gpu_s / self.ours_s
    }
    /// Our approach vs sequential CPU (the figures' tall bars).
    pub fn ours_speedup(&self) -> f64 {
        self.seq_cpu_s / self.ours_s
    }
}

/// One regenerated cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Matrix side length.
    pub n: usize,
    /// The exponent `N` of this column.
    pub power: u64,
    /// The paper's published numbers for this cell, when it has them.
    pub paper: Option<PaperCell>,
    /// The calibrated model's prediction for this cell.
    pub simulated: MethodTimes,
    /// Present when run with a live engine (`measure = true`).
    pub measured: Option<MethodTimes>,
    /// Launch counts (naive, ours) — the mechanism behind the ratios.
    pub launches: (usize, usize),
}

/// One regenerated table.
#[derive(Clone, Debug)]
pub struct TableResult {
    /// Our table id (2..=5, in n-order).
    pub id: u8,
    /// Matrix side length of the whole table.
    pub n: usize,
    /// One regenerated cell per power column.
    pub cells: Vec<CellResult>,
}

/// Calibrated (GPU model, CPU effective flops) from the paper's own
/// published columns — the simulator's anchor.
pub fn calibrated_models() -> (GpuTimingModel, f64) {
    // spec-sheet analytic components (transfer, roofline kernel) +
    // per-size calibrated launch costs + fitted session overhead —
    // see simulator::calibrate for why not a single 3-parameter fit.
    let mut gpu = GpuTimingModel::from_spec(DeviceSpec::tesla_c2050());
    gpu.per_size_launch_s = calibrate::fit_per_size(&paper::naive_gpu_observations());
    gpu.session_overhead_s =
        calibrate::fit_session_overhead(&paper::ours_observations(), &gpu);
    let cpu_flops = calibrate::fit_cpu_flops(&paper::seq_cpu_observations());
    (gpu, cpu_flops)
}

/// Plan "ours" the way the config says the service plans it.
pub fn ours_plan(cfg: &MatexpConfig, power: u64) -> Plan {
    if cfg.use_square_chains {
        Plan::chained(power, &[4, 2])
    } else {
        Plan::binary(power, cfg.fused_sqmul)
    }
}

/// Simulate one cell on the calibrated models.
///
/// The simulated "ours" column always uses the plain binary plan — that is
/// the algorithm the paper ran on the C2050; our fused/chained variants
/// are extensions and would make the simulated column incomparable to the
/// published one. (The *measured* column uses [`ours_plan`], i.e. whatever
/// the config says the service really does.)
pub fn simulate_cell(
    gpu: &GpuTimingModel,
    cpu_flops: f64,
    _cfg: &MatexpConfig,
    n: usize,
    power: u64,
) -> MethodTimes {
    let naive = gpu.simulate_roundtrip(&Plan::naive(power), n);
    let ours = gpu.simulate_device_resident(&Plan::binary(power, false), n);
    let cpu_s = 2.0 * (n as f64).powi(3) * (power - 1) as f64 / cpu_flops;
    MethodTimes { naive_gpu_s: naive.total_s, seq_cpu_s: cpu_s, ours_s: ours.total_s }
}

/// Measure the sequential-CPU arm: run `min(cap, power-1)` multiplies of
/// the naive i-j-k loop and extrapolate linearly (per-multiply cost does
/// not depend on the exponent).
pub fn measure_cpu_extrapolated(a: &Matrix, power: u64, cap: usize) -> f64 {
    let multiplies = (power - 1) as usize;
    if multiplies == 0 {
        return 0.0;
    }
    let sample = multiplies.min(cap.max(1));
    let t0 = Instant::now();
    let mut acc = a.clone();
    for _ in 0..sample {
        acc = linalg::naive::matmul_naive(&acc, a);
    }
    let measured = t0.elapsed().as_secs_f64();
    std::hint::black_box(&acc);
    measured * multiplies as f64 / sample as f64
}

/// Measure one cell end-to-end on a live engine (any backend).
///
/// Call [`Engine::warmup_exec`] once beforehand for steady-state numbers
/// ([`run_table`] does). On a time-modeling backend ([`Backend::models_time`],
/// the simulator) the GPU arms report *modeled* seconds, so the
/// sequential-CPU arm is modeled from the same calibration rather than
/// measured on this host — otherwise the column would divide real 2020s
/// host seconds by simulated 2012 device seconds.
pub fn measure_cell<B: Backend>(
    engine: &mut Engine<B>,
    cfg: &MatexpConfig,
    a: &Matrix,
    power: u64,
) -> Result<MethodTimes> {
    let naive_stats = engine
        .run(Submission::expm(a.clone(), power).method(Method::NaiveGpu))?
        .stats;
    let ours_stats = engine
        .run(Submission::expm(a.clone(), power).plan(ours_plan(cfg, power)))?
        .stats;
    let cpu_s = if engine.backend().models_time() {
        let (_, cpu_flops) = calibrated_models();
        2.0 * (a.n() as f64).powi(3) * (power - 1) as f64 / cpu_flops
    } else {
        measure_cpu_extrapolated(a, power, cfg.cpu_measure_cap)
    };
    Ok(MethodTimes {
        naive_gpu_s: naive_stats.wall_s,
        seq_cpu_s: cpu_s,
        ours_s: ours_stats.wall_s,
    })
}

/// Regenerate one paper table (2..=5). Pass a live engine to produce the
/// measured column (simulation always is produced); see [`run_table_sim`]
/// for the engine-less form.
pub fn run_table<B: Backend>(
    id: u8,
    cfg: &MatexpConfig,
    mut engine: Option<&mut Engine<B>>,
) -> Result<TableResult> {
    let spec = paper::paper_table(id).ok_or_else(|| {
        crate::error::MatexpError::Config(format!("no paper table {id} (have 2..=5)"))
    })?;
    let (gpu, cpu_flops) = calibrated_models();
    let a = Matrix::random_spectral(spec.n, 0.999, cfg.seed);
    if let Some(e) = engine.as_mut() {
        e.warmup_exec(spec.n)?; // once per table: steady-state, not first-touch
    }
    let mut cells = Vec::new();
    for cell in spec.cells {
        let power = cell.power;
        let simulated = simulate_cell(&gpu, cpu_flops, cfg, spec.n, power);
        let measured = match engine.as_mut() {
            Some(e) => Some(measure_cell(&mut **e, cfg, &a, power)?),
            None => None,
        };
        cells.push(CellResult {
            n: spec.n,
            power,
            paper: Some(*cell),
            simulated,
            measured,
            launches: (
                Plan::naive(power).launches(),
                ours_plan(cfg, power).launches(),
            ),
        });
    }
    Ok(TableResult { id, n: spec.n, cells })
}

/// [`run_table`] without a measured column: paper + simulated only.
pub fn run_table_sim(id: u8, cfg: &MatexpConfig) -> Result<TableResult> {
    run_table::<CpuBackend>(id, cfg, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MatexpConfig {
        MatexpConfig::default()
    }

    #[test]
    fn calibration_reproduces_paper_naive_column() {
        // A 3-parameter per-launch model fitting 16 published cells.
        // The paper's own n=64 column is NOT linear in N−1 (its per-launch
        // cost grows 3.3x from N=64 to N=1024), so no constant-per-launch
        // model can match every cell tightly; we require every cell within
        // 2.2x and a geometric-mean error under 35% (EXPERIMENTS.md §T2).
        let (gpu, _) = calibrated_models();
        let mut log_sum = 0.0;
        let mut count = 0;
        for t in paper::paper_tables() {
            for c in t.cells {
                let sim = gpu.simulate_roundtrip(&Plan::naive(c.power), t.n).total_s;
                let ratio = (sim / c.naive_gpu_s).max(c.naive_gpu_s / sim);
                assert!(
                    ratio < 2.2,
                    "n={} N={}: sim {sim:.3} vs paper {} ({ratio:.2}x)",
                    t.n,
                    c.power,
                    c.naive_gpu_s
                );
                log_sum += ratio.ln();
                count += 1;
            }
        }
        let geomean = (log_sum / count as f64).exp();
        assert!(geomean < 1.35, "geomean misfit {geomean:.3}x");
    }

    #[test]
    fn simulated_tables_preserve_the_paper_claims() {
        let cfg = cfg();
        let (gpu, cpu_flops) = calibrated_models();
        for t in paper::paper_tables() {
            for c in t.cells {
                let sim = simulate_cell(&gpu, cpu_flops, &cfg, t.n, c.power);
                // who wins
                assert!(sim.ours_s < sim.naive_gpu_s, "ours wins (n={} N={})", t.n, c.power);
                assert!(sim.naive_gpu_s < sim.seq_cpu_s, "naive GPU beats CPU (n={} N={})", t.n, c.power);
                // by roughly what factor: within 4x of the published ratio.
                // (3x holds everywhere except n=512, where the paper's own
                // data is internally inconsistent: its "ours" spends 20 ms
                // per multiply while its naive loop spends 4 ms per launch
                // on identical kernels — see EXPERIMENTS.md §T5.)
                let ratio = sim.ours_vs_naive() / c.ours_vs_naive();
                assert!(
                    (0.25..4.0).contains(&ratio),
                    "n={} N={}: sim ours-vs-naive {:.1} vs paper {:.1}",
                    t.n,
                    c.power,
                    sim.ours_vs_naive(),
                    c.ours_vs_naive()
                );
            }
        }
    }

    #[test]
    fn speedup_grows_with_power_as_in_figures() {
        // Figures 6/8/10/12: ours-vs-naive grows with the exponent
        let cfg = cfg();
        let (gpu, cpu_flops) = calibrated_models();
        for n in [64usize, 128, 256, 512] {
            let mut last = 0.0;
            for power in [64u64, 128, 256, 512] {
                let sim = simulate_cell(&gpu, cpu_flops, &cfg, n, power);
                assert!(sim.ours_vs_naive() > last, "n={n} N={power}");
                last = sim.ours_vs_naive();
            }
        }
    }

    #[test]
    fn cpu_extrapolation_is_linear() {
        let a = Matrix::random_spectral(24, 0.9, 3);
        let full = measure_cpu_extrapolated(&a, 17, usize::MAX);
        let capped = measure_cpu_extrapolated(&a, 17, 4);
        // both estimate the same quantity; they must agree within noise
        let rel = (full - capped).abs() / full.max(1e-12);
        assert!(rel < 0.9, "full {full} vs capped {capped}");
        assert_eq!(measure_cpu_extrapolated(&a, 1, 4), 0.0);
    }

    #[test]
    fn unknown_table_id_rejected() {
        assert!(run_table_sim(7, &cfg()).is_err());
    }

    #[test]
    fn measured_column_produced_with_live_engine() {
        let mut cfg = cfg();
        cfg.cpu_measure_cap = 1;
        let mut engine = Engine::cpu(crate::linalg::CpuAlgo::Blocked);
        let t = run_table(2, &cfg, Some(&mut engine)).unwrap();
        assert!(t.cells.iter().all(|c| c.measured.is_some()));
        let m = t.cells[0].measured.unwrap();
        assert!(m.naive_gpu_s > 0.0 && m.ours_s > 0.0 && m.seq_cpu_s > 0.0);
    }

    #[test]
    fn simulation_only_table_runs_fast() {
        let t = run_table_sim(2, &cfg()).unwrap();
        assert_eq!(t.n, 64);
        assert_eq!(t.cells.len(), 5);
        assert!(t.cells.iter().all(|c| c.measured.is_none()));
        assert!(t.cells.iter().all(|c| c.paper.is_some()));
        // launch counts: naive N-1 vs ours ~log
        let last = t.cells.last().unwrap();
        assert_eq!(last.launches.0, 1023);
        assert!(last.launches.1 <= 10);
    }
}
