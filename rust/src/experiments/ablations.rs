//! Ablations quantifying the individual design choices the paper lists in
//! §4.3 (and the fairness questions the paper leaves open).
//!
//! * **A1 tiles** — §4.3.7: the tiled Pallas kernel across block sizes.
//! * **A2 transfers** — §4.3.8: the same binary plan, device-resident vs
//!   per-launch host round-trips.
//! * **A3 fusion** — §4.3.5/our extension: plain binary vs fused `sqmul`
//!   vs `square2`/`square4` chains vs the packed single-buffer loop.
//! * **A4 cpu** — the "fair CPU" question: naive vs cache-aware vs
//!   multi-threaded CPU baselines.
//! * **A5 residency** — the buffer-residency ablation behind
//!   `--ablate-residency`: clone-per-launch vs pooled resident execution,
//!   both as a pure data-path replay (the multiply elided, so the gap is
//!   exactly the memory traffic) and as full engine runs whose
//!   `ExecStats.bytes_copied` quantify each discipline's host traffic.

use std::rc::Rc;
use std::time::Instant;

use crate::coordinator::request::Method;
use crate::error::Result;
use crate::exec::{Executor, Submission};
use crate::linalg::{self, matrix::Matrix};
use crate::plan::Plan;
use crate::runtime::{Backend, BufferArena, Engine, ExecStats};

#[cfg(feature = "xla")]
use crate::runtime::{artifacts::ArtifactRegistry, PjrtBackend};

/// One ablation arm's outcome.
#[derive(Clone, Debug)]
pub struct ArmResult {
    pub name: String,
    pub wall_s: f64,
    pub launches: usize,
    pub multiplies: usize,
    pub transfers: usize,
    /// Structural metadata (tile shape, vmem estimate) where applicable.
    pub detail: String,
}

impl ArmResult {
    fn from_stats(name: impl Into<String>, stats: &ExecStats, detail: impl Into<String>) -> Self {
        ArmResult {
            name: name.into(),
            wall_s: stats.wall_s,
            launches: stats.launches,
            multiplies: stats.multiplies,
            transfers: stats.h2d_transfers + stats.d2h_transfers,
            detail: detail.into(),
        }
    }
}

/// A1 — §4.3.7 TILE sweep: run every tiled matmul artifact at size `n`,
/// reporting wall time + the manifest's VMEM/MXU estimates. Tiled
/// artifacts only exist on the PJRT backend, so this ablation needs the
/// `xla` feature.
#[cfg(feature = "xla")]
pub fn tile_sweep(
    engine: &mut Engine<PjrtBackend>,
    registry: &ArtifactRegistry,
    n: usize,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.99, seed);
    let b = Matrix::random_spectral(n, 0.99, seed ^ 1);
    let mut out = Vec::new();
    let mut tiles = registry.tiles("matmul", n);
    tiles.sort_by_key(|e| e.blocks.clone());
    for entry in tiles {
        // warm: compile outside the timed region
        engine.run_matmul_entry(registry, &entry.name, &a, &b)?;
        let t0 = Instant::now();
        let (_, stats) = engine.run_matmul_entry(registry, &entry.name, &a, &b)?;
        let wall = t0.elapsed().as_secs_f64().min(stats.wall_s.max(f64::MIN_POSITIVE));
        let detail = format!(
            "blocks={:?} vmem={} mxu={:.2}",
            entry.blocks.clone().unwrap_or_default(),
            entry.vmem_bytes.map(|b| format!("{}KiB", b / 1024)).unwrap_or_else(|| "?".into()),
            entry.mxu_utilization.unwrap_or(0.0),
        );
        out.push(ArmResult {
            name: entry.name.clone(),
            wall_s: wall,
            launches: stats.launches,
            multiplies: stats.multiplies,
            transfers: stats.h2d_transfers + stats.d2h_transfers,
            detail,
        });
    }
    Ok(out)
}

/// A2 — §4.3.8 transfer ablation: identical binary plan, two residency
/// disciplines. The gap is purely host↔device traffic + launch path.
pub fn transfer_ablation<B: Backend>(
    engine: &mut Engine<B>,
    n: usize,
    power: u64,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.999, seed);
    let plan = Plan::binary(power, false);
    engine.warmup_exec(n)?; // steady-state: XLA first-execution init is ~4 ms/op
    let resident = engine.run(Submission::expm(a.clone(), power).plan(plan.clone()))?.stats;
    let roundtrip = engine
        .run(Submission::expm(a, power).method(Method::PlanRoundtrip).plan(plan))?
        .stats;
    Ok(vec![
        ArmResult::from_stats("device-resident", &resident, format!("plan=binary N={power}")),
        ArmResult::from_stats("per-launch-roundtrip", &roundtrip, format!("plan=binary N={power}")),
    ])
}

/// A3 — launch-fusion ablation: every "ours" execution discipline at the
/// same (n, power).
pub fn fusion_ablation<B: Backend>(
    engine: &mut Engine<B>,
    n: usize,
    power: u64,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.999, seed);
    engine.warmup_exec(n)?; // steady-state: XLA first-execution init is ~4 ms/op
    let mut out = Vec::new();
    for (name, plan) in [
        ("binary", Plan::binary(power, false)),
        ("binary-fused-sqmul", Plan::binary(power, true)),
        ("chained-square4", Plan::chained(power, &[4, 2])),
        ("addition-chain", Plan::addition_chain(power)),
    ] {
        let kind = plan.kind;
        let stats = engine.run(Submission::expm(a.clone(), power).plan(plan))?.stats;
        out.push(ArmResult::from_stats(name, &stats, format!("kind={kind}")));
    }
    let packed = engine
        .run(Submission::expm(a.clone(), power).method(Method::OursPacked))?
        .stats;
    out.push(ArmResult::from_stats("packed-state", &packed, "pack2/step_mul/step_sq"));
    if engine_supports_fused(engine, &a, power) {
        let fused = engine
            .run(Submission::expm(a.clone(), power).method(Method::FusedArtifact))?
            .stats;
        out.push(ArmResult::from_stats("fused-artifact", &fused, format!("expm{power} single launch")));
    }
    Ok(out)
}

fn engine_supports_fused<B: Backend>(engine: &mut Engine<B>, a: &Matrix, power: u64) -> bool {
    engine.run(Submission::expm(a.clone(), power).method(Method::FusedArtifact)).is_ok()
}

/// One arm of the residency data-path ablation.
#[derive(Clone, Debug)]
pub struct ResidencyArm {
    pub name: &'static str,
    /// Seconds spent purely on the data path (uploads, output
    /// allocation, downloads) for the whole chain.
    pub data_path_s: f64,
    /// Host-edge bytes this discipline copied.
    pub bytes_copied: u64,
    /// Outputs served from recycled arena buffers (0 for the cloning arm).
    pub buffers_recycled: u64,
}

/// A5 (data path) — replay the *buffer traffic* of a `steps`-step
/// squaring chain under both disciplines, with the multiply itself
/// elided (it is identical in both arms and would drown the signal in
/// O(n³) compute): the measured gap is exactly the O(k·n²) clone traffic
/// the paper's §4.3.8 residency discipline eliminates.
///
/// * **clone-per-launch** — the seed data path: every launch re-uploads
///   its operand (deep clone), allocates a fresh `n×n` output, and
///   downloads the result (deep clone).
/// * **resident** — the arena data path: the input is adopted once, each
///   launch writes into a recycled buffer, and only the final result
///   crosses back to the host.
///
/// Returns `[clone_per_launch, resident]`.
pub fn residency_data_path(n: usize, steps: usize, seed: u64) -> [ResidencyArm; 2] {
    let host = Matrix::random(n, seed);
    let sz = (n * n * std::mem::size_of::<f32>()) as u64;

    // -- clone-per-launch (the pre-residency data path) --
    let t0 = Instant::now();
    let mut bytes = 0u64;
    let mut host_reg = host.clone();
    for _ in 0..steps {
        let operand = host_reg.clone(); // H2D: upload deep-cloned
        bytes += sz;
        let mut dev_out = Matrix::zeros(n); // fresh n×n output per launch
        std::hint::black_box((&operand, &mut dev_out)); // kernel elided
        host_reg = dev_out.clone(); // D2H: result deep-cloned back
        bytes += sz;
    }
    std::hint::black_box(&host_reg);
    let clone_arm = ResidencyArm {
        name: "clone-per-launch",
        data_path_s: t0.elapsed().as_secs_f64(),
        bytes_copied: bytes,
        buffers_recycled: 0,
    };

    // -- resident (the arena data path) --
    let arena = BufferArena::new();
    let t0 = Instant::now();
    arena.count_copied(sz); // the ONE host→device edge
    let mut dev = Rc::new(arena.adopt(host.clone()));
    for _ in 0..steps {
        let mut out = arena.alloc(n); // recycled from the second step on
        std::hint::black_box((&dev, out.matrix_mut())); // kernel elided
        dev = Rc::new(out); // previous buffer returns to the arena
    }
    arena.count_copied(sz); // the ONE device→host edge
    let result = dev.matrix().clone();
    std::hint::black_box(&result);
    let stats = arena.take();
    let resident_arm = ResidencyArm {
        name: "resident",
        data_path_s: t0.elapsed().as_secs_f64(),
        bytes_copied: stats.bytes_copied,
        buffers_recycled: stats.buffers_recycled,
    };

    [clone_arm, resident_arm]
}

/// [`residency_data_path`] rendered as ablation arms (`transfers` column
/// counts host-edge copies).
pub fn residency_data_path_arms(n: usize, steps: usize, seed: u64) -> Vec<ArmResult> {
    residency_data_path(n, steps, seed)
        .into_iter()
        .map(|arm| ArmResult {
            name: arm.name.to_string(),
            wall_s: arm.data_path_s,
            launches: steps,
            multiplies: 0,
            transfers: (arm.bytes_copied / (n * n * 4).max(1) as u64) as usize,
            detail: format!(
                "bytes_copied={} recycled={} (kernel elided: data path only)",
                arm.bytes_copied, arm.buffers_recycled
            ),
        })
        .collect()
}

/// A5 (full engine) — the same comparison as real executions: the
/// resident device plan vs the clone-per-launch counterfactual
/// (`Method::PlanRoundtrip`), with each arm's `bytes_copied` /
/// `buffers_recycled` / `peak_resident_bytes` in the detail column.
pub fn residency_engine_arms<B: Backend>(
    engine: &mut Engine<B>,
    n: usize,
    power: u64,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.999, seed);
    let plan = Plan::binary(power, false);
    engine.warmup_exec(n)?;
    let resident = engine.run(Submission::expm(a.clone(), power).plan(plan.clone()))?.stats;
    let roundtrip = engine
        .run(Submission::expm(a, power).method(Method::PlanRoundtrip).plan(plan))?
        .stats;
    let describe = |s: &ExecStats| {
        format!(
            "bytes_copied={} recycled={} peak_resident={}B",
            s.bytes_copied, s.buffers_recycled, s.peak_resident_bytes
        )
    };
    Ok(vec![
        ArmResult::from_stats("resident", &resident, describe(&resident)),
        ArmResult::from_stats("clone-per-launch", &roundtrip, describe(&roundtrip)),
    ])
}

/// A4 — CPU-baseline fairness sweep: one multiply per variant at size `n`.
pub fn cpu_variants(n: usize, seed: u64) -> Vec<ArmResult> {
    let a = Matrix::random_spectral(n, 0.99, seed);
    let b = Matrix::random_spectral(n, 0.99, seed ^ 7);
    linalg::matmul_variants()
        .into_iter()
        .map(|(name, mm)| {
            let t0 = Instant::now();
            let c = mm(&a, &b);
            let wall = t0.elapsed().as_secs_f64();
            std::hint::black_box(&c);
            ArmResult {
                name: name.to_string(),
                wall_s: wall,
                launches: 0,
                multiplies: 1,
                transfers: 0,
                detail: format!("{:.2} GFLOP/s", 2.0 * (n as f64).powi(3) / wall / 1e9),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CpuAlgo;
    use crate::runtime::CpuEngine;

    fn engine() -> CpuEngine {
        Engine::cpu(CpuAlgo::Blocked)
    }

    #[test]
    fn cpu_variants_all_report() {
        let arms = cpu_variants(48, 1);
        assert_eq!(arms.len(), 5);
        assert!(arms.iter().all(|a| a.wall_s > 0.0));
    }

    #[test]
    fn transfer_ablation_shows_transfer_gap() {
        let mut e = engine();
        let arms = transfer_ablation(&mut e, 32, 256, 9).unwrap();
        assert_eq!(arms.len(), 2);
        let resident = &arms[0];
        let roundtrip = &arms[1];
        // identical work…
        assert_eq!(resident.multiplies, roundtrip.multiplies);
        // …but O(1) vs O(launches) transfers
        assert_eq!(resident.transfers, 2);
        assert!(roundtrip.transfers >= 2 * roundtrip.launches);
    }

    #[test]
    fn fusion_ablation_orders_launch_counts() {
        let mut e = engine();
        let arms = fusion_ablation(&mut e, 32, 256, 9).unwrap();
        let get = |name: &str| {
            arms.iter().find(|a| a.name == name).unwrap_or_else(|| panic!("{name} missing"))
        };
        // 256 = 2^8: binary 8 launches, chained 2 (square4×2), packed 8+pack+unpack
        assert_eq!(get("binary").launches, 8);
        assert!(get("chained-square4").launches < get("binary").launches);
        let fused = arms.iter().find(|a| a.name == "fused-artifact");
        assert_eq!(fused.expect("256 is a shipped fused power").launches, 1);
    }

    #[test]
    fn fusion_ablation_skips_fused_for_unshipped_power() {
        let mut e = engine();
        let arms = fusion_ablation(&mut e, 16, 100, 3).unwrap();
        assert!(arms.iter().all(|a| a.name != "fused-artifact"));
        assert!(arms.len() >= 5);
    }

    #[test]
    fn residency_data_path_copies_two_edges_vs_two_per_step() {
        let [clone_arm, resident] = residency_data_path(64, 10, 7);
        assert_eq!(clone_arm.bytes_copied, 2 * 10 * 64 * 64 * 4);
        assert_eq!(resident.bytes_copied, 2 * 64 * 64 * 4);
        assert_eq!(resident.buffers_recycled, 9, "ping-pong recycles all but the warm-up allocs");
        assert_eq!(clone_arm.buffers_recycled, 0);
    }

    #[test]
    fn residency_engine_arms_report_the_copy_gap() {
        let mut e = engine();
        let arms = residency_engine_arms(&mut e, 32, 256, 5).unwrap();
        let resident = &arms[0];
        let roundtrip = &arms[1];
        assert_eq!(resident.multiplies, roundtrip.multiplies, "identical logical work");
        assert!(resident.detail.contains("bytes_copied=8192"), "{}", resident.detail);
        assert!(roundtrip.transfers > resident.transfers);
    }
}
