//! Ablations quantifying the individual design choices the paper lists in
//! §4.3 (and the fairness questions the paper leaves open).
//!
//! * **A1 tiles** — §4.3.7: the tiled Pallas kernel across block sizes.
//! * **A2 transfers** — §4.3.8: the same binary plan, device-resident vs
//!   per-launch host round-trips.
//! * **A3 fusion** — §4.3.5/our extension: plain binary vs fused `sqmul`
//!   vs `square2`/`square4` chains vs the packed single-buffer loop.
//! * **A4 cpu** — the "fair CPU" question: naive vs cache-aware vs
//!   multi-threaded CPU baselines.
//! * **A5 residency** — the buffer-residency ablation behind
//!   `--ablate-residency`: clone-per-launch vs pooled resident execution,
//!   both as a pure data-path replay (the multiply elided, so the gap is
//!   exactly the memory traffic) and as full engine runs whose
//!   `ExecStats.bytes_copied` quantify each discipline's host traffic.
//! * **A6 cache** — the cache-tier ablation behind `--ablate-cache`:
//!   cold vs plan-warm vs result-warm serving, as (1) a setup-path
//!   measurement with the execution elided ([`cache_setup_arms`]: the
//!   per-request planner + prepare work tiers 1–2 eliminate), (2) a
//!   result-tier comparison ([`cache_result_arms`]: the calibrated-C2050
//!   *modeled* cold execution — the repro's standard yardstick for 2012
//!   device time — against the *measured* warm serve), and (3, with
//!   `--measure`) full engine runs per tier ([`cache_engine_arms`]).

use std::rc::Rc;
use std::time::Instant;

use crate::cache::{CacheControl, PreparedSet, ResultCache, ResultKey};
use crate::config::MatexpConfig;
use crate::coordinator::request::{ExpmRequest, Method};
use crate::coordinator::scheduler::{self, Strategy};
use crate::coordinator::worker;
use crate::error::{MatexpError, Result};
use crate::exec::{Executor, Submission};
use crate::linalg::{self, matrix::Matrix};
use crate::plan::Plan;
use crate::runtime::{Backend, BufferArena, CpuBackend, Engine, ExecStats};

#[cfg(feature = "xla")]
use crate::runtime::{artifacts::ArtifactRegistry, PjrtBackend};

/// One ablation arm's outcome.
#[derive(Clone, Debug)]
pub struct ArmResult {
    /// Arm label ("device-resident", "plan-warm", …).
    pub name: String,
    /// Wall-clock seconds (the arm's detail says measured vs modeled).
    pub wall_s: f64,
    /// Kernel launches the arm performed (or would perform).
    pub launches: usize,
    /// Matrix multiplies across those launches.
    pub multiplies: usize,
    /// Host↔device transfers.
    pub transfers: usize,
    /// Structural metadata (tile shape, vmem estimate) where applicable.
    pub detail: String,
}

impl ArmResult {
    fn from_stats(name: impl Into<String>, stats: &ExecStats, detail: impl Into<String>) -> Self {
        ArmResult {
            name: name.into(),
            wall_s: stats.wall_s,
            launches: stats.launches,
            multiplies: stats.multiplies,
            transfers: stats.h2d_transfers + stats.d2h_transfers,
            detail: detail.into(),
        }
    }
}

/// A1 — §4.3.7 TILE sweep: run every tiled matmul artifact at size `n`,
/// reporting wall time + the manifest's VMEM/MXU estimates. Tiled
/// artifacts only exist on the PJRT backend, so this ablation needs the
/// `xla` feature.
#[cfg(feature = "xla")]
pub fn tile_sweep(
    engine: &mut Engine<PjrtBackend>,
    registry: &ArtifactRegistry,
    n: usize,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.99, seed);
    let b = Matrix::random_spectral(n, 0.99, seed ^ 1);
    let mut out = Vec::new();
    let mut tiles = registry.tiles("matmul", n);
    tiles.sort_by_key(|e| e.blocks.clone());
    for entry in tiles {
        // warm: compile outside the timed region
        engine.run_matmul_entry(registry, &entry.name, &a, &b)?;
        let t0 = Instant::now();
        let (_, stats) = engine.run_matmul_entry(registry, &entry.name, &a, &b)?;
        let wall = t0.elapsed().as_secs_f64().min(stats.wall_s.max(f64::MIN_POSITIVE));
        let detail = format!(
            "blocks={:?} vmem={} mxu={:.2}",
            entry.blocks.clone().unwrap_or_default(),
            entry.vmem_bytes.map(|b| format!("{}KiB", b / 1024)).unwrap_or_else(|| "?".into()),
            entry.mxu_utilization.unwrap_or(0.0),
        );
        out.push(ArmResult {
            name: entry.name.clone(),
            wall_s: wall,
            launches: stats.launches,
            multiplies: stats.multiplies,
            transfers: stats.h2d_transfers + stats.d2h_transfers,
            detail,
        });
    }
    Ok(out)
}

/// A2 — §4.3.8 transfer ablation: identical binary plan, two residency
/// disciplines. The gap is purely host↔device traffic + launch path.
pub fn transfer_ablation<B: Backend>(
    engine: &mut Engine<B>,
    n: usize,
    power: u64,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.999, seed);
    let plan = Plan::binary(power, false);
    engine.warmup_exec(n)?; // steady-state: XLA first-execution init is ~4 ms/op
    let resident = engine.run(Submission::expm(a.clone(), power).plan(plan.clone()))?.stats;
    let roundtrip = engine
        .run(Submission::expm(a, power).method(Method::PlanRoundtrip).plan(plan))?
        .stats;
    Ok(vec![
        ArmResult::from_stats("device-resident", &resident, format!("plan=binary N={power}")),
        ArmResult::from_stats("per-launch-roundtrip", &roundtrip, format!("plan=binary N={power}")),
    ])
}

/// A3 — launch-fusion ablation: every "ours" execution discipline at the
/// same (n, power).
pub fn fusion_ablation<B: Backend>(
    engine: &mut Engine<B>,
    n: usize,
    power: u64,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.999, seed);
    engine.warmup_exec(n)?; // steady-state: XLA first-execution init is ~4 ms/op
    let mut out = Vec::new();
    for (name, plan) in [
        ("binary", Plan::binary(power, false)),
        ("binary-fused-sqmul", Plan::binary(power, true)),
        ("chained-square4", Plan::chained(power, &[4, 2])),
        ("addition-chain", Plan::addition_chain(power)),
    ] {
        let kind = plan.kind;
        let stats = engine.run(Submission::expm(a.clone(), power).plan(plan))?.stats;
        out.push(ArmResult::from_stats(name, &stats, format!("kind={kind}")));
    }
    let packed = engine
        .run(Submission::expm(a.clone(), power).method(Method::OursPacked))?
        .stats;
    out.push(ArmResult::from_stats("packed-state", &packed, "pack2/step_mul/step_sq"));
    if engine_supports_fused(engine, &a, power) {
        let fused = engine
            .run(Submission::expm(a.clone(), power).method(Method::FusedArtifact))?
            .stats;
        out.push(ArmResult::from_stats("fused-artifact", &fused, format!("expm{power} single launch")));
    }
    Ok(out)
}

fn engine_supports_fused<B: Backend>(engine: &mut Engine<B>, a: &Matrix, power: u64) -> bool {
    engine.run(Submission::expm(a.clone(), power).method(Method::FusedArtifact)).is_ok()
}

/// One arm of the residency data-path ablation.
#[derive(Clone, Debug)]
pub struct ResidencyArm {
    /// Arm label ("clone-per-launch" / "resident").
    pub name: &'static str,
    /// Seconds spent purely on the data path (uploads, output
    /// allocation, downloads) for the whole chain.
    pub data_path_s: f64,
    /// Host-edge bytes this discipline copied.
    pub bytes_copied: u64,
    /// Outputs served from recycled arena buffers (0 for the cloning arm).
    pub buffers_recycled: u64,
}

/// A5 (data path) — replay the *buffer traffic* of a `steps`-step
/// squaring chain under both disciplines, with the multiply itself
/// elided (it is identical in both arms and would drown the signal in
/// O(n³) compute): the measured gap is exactly the O(k·n²) clone traffic
/// the paper's §4.3.8 residency discipline eliminates.
///
/// * **clone-per-launch** — the seed data path: every launch re-uploads
///   its operand (deep clone), allocates a fresh `n×n` output, and
///   downloads the result (deep clone).
/// * **resident** — the arena data path: the input is adopted once, each
///   launch writes into a recycled buffer, and only the final result
///   crosses back to the host.
///
/// Returns `[clone_per_launch, resident]`.
pub fn residency_data_path(n: usize, steps: usize, seed: u64) -> [ResidencyArm; 2] {
    let host = Matrix::random(n, seed);
    let sz = (n * n * std::mem::size_of::<f32>()) as u64;

    // -- clone-per-launch (the pre-residency data path) --
    let t0 = Instant::now();
    let mut bytes = 0u64;
    let mut host_reg = host.clone();
    for _ in 0..steps {
        let operand = host_reg.clone(); // H2D: upload deep-cloned
        bytes += sz;
        let mut dev_out = Matrix::zeros(n); // fresh n×n output per launch
        std::hint::black_box((&operand, &mut dev_out)); // kernel elided
        host_reg = dev_out.clone(); // D2H: result deep-cloned back
        bytes += sz;
    }
    std::hint::black_box(&host_reg);
    let clone_arm = ResidencyArm {
        name: "clone-per-launch",
        data_path_s: t0.elapsed().as_secs_f64(),
        bytes_copied: bytes,
        buffers_recycled: 0,
    };

    // -- resident (the arena data path) --
    let arena = BufferArena::new();
    let t0 = Instant::now();
    arena.count_copied(sz); // the ONE host→device edge
    let mut dev = Rc::new(arena.adopt(host.clone()));
    for _ in 0..steps {
        let mut out = arena.alloc(n); // recycled from the second step on
        std::hint::black_box((&dev, out.matrix_mut())); // kernel elided
        dev = Rc::new(out); // previous buffer returns to the arena
    }
    arena.count_copied(sz); // the ONE device→host edge
    let result = dev.matrix().clone();
    std::hint::black_box(&result);
    let stats = arena.take();
    let resident_arm = ResidencyArm {
        name: "resident",
        data_path_s: t0.elapsed().as_secs_f64(),
        bytes_copied: stats.bytes_copied,
        buffers_recycled: stats.buffers_recycled,
    };

    [clone_arm, resident_arm]
}

/// [`residency_data_path`] rendered as ablation arms (`transfers` column
/// counts host-edge copies).
pub fn residency_data_path_arms(n: usize, steps: usize, seed: u64) -> Vec<ArmResult> {
    residency_data_path(n, steps, seed)
        .into_iter()
        .map(|arm| ArmResult {
            name: arm.name.to_string(),
            wall_s: arm.data_path_s,
            launches: steps,
            multiplies: 0,
            transfers: (arm.bytes_copied / (n * n * 4).max(1) as u64) as usize,
            detail: format!(
                "bytes_copied={} recycled={} (kernel elided: data path only)",
                arm.bytes_copied, arm.buffers_recycled
            ),
        })
        .collect()
}

/// A5 (full engine) — the same comparison as real executions: the
/// resident device plan vs the clone-per-launch counterfactual
/// (`Method::PlanRoundtrip`), with each arm's `bytes_copied` /
/// `buffers_recycled` / `peak_resident_bytes` in the detail column.
pub fn residency_engine_arms<B: Backend>(
    engine: &mut Engine<B>,
    n: usize,
    power: u64,
    seed: u64,
) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.999, seed);
    let plan = Plan::binary(power, false);
    engine.warmup_exec(n)?;
    let resident = engine.run(Submission::expm(a.clone(), power).plan(plan.clone()))?.stats;
    let roundtrip = engine
        .run(Submission::expm(a, power).method(Method::PlanRoundtrip).plan(plan))?
        .stats;
    let describe = |s: &ExecStats| {
        format!(
            "bytes_copied={} recycled={} peak_resident={}B",
            s.bytes_copied, s.buffers_recycled, s.peak_resident_bytes
        )
    };
    Ok(vec![
        ArmResult::from_stats("resident", &resident, describe(&resident)),
        ArmResult::from_stats("clone-per-launch", &roundtrip, describe(&roundtrip)),
    ])
}

/// A6 (setup path) — the per-request serving overhead cache tiers 1–2
/// eliminate, with the execution itself elided (it is identical in both
/// arms and would drown the µs-scale setup signal in O(n³) compute —
/// the same trick as A5's data-path arms):
///
/// * **cold-setup** — every request runs the real scheduler with
///   [`CacheControl::Bypass`] (the planner builds the full launch plan)
///   and prepares every plan op against a fresh per-request
///   [`PreparedSet`] — what a server with no caching pays per request.
/// * **plan-warm** — the same scheduler calls with
///   [`CacheControl::Use`]: tier 1 serves the plan from the process-wide
///   cache and tier 2's warm prepared set skips every `prepare`.
///
/// Measured over `iters` requests; returns `[cold_setup, plan_warm]`.
pub fn cache_setup_arms(n: usize, power: u64, iters: usize) -> Vec<ArmResult> {
    let iters = iters.max(1);
    let cfg = MatexpConfig::default(); // plan cache on, chained plans
    let mk_req = |ctl: CacheControl| {
        let mut r = ExpmRequest::new(0, Matrix::zeros(n), power, Method::Ours);
        r.cache = ctl;
        r
    };
    let plan_of = |req: &ExpmRequest| match scheduler::strategy_for(req, &cfg) {
        Strategy::DeviceResident(plan) => plan,
        other => unreachable!("Method::Ours is a plan-replaying method: {other:?}"),
    };
    let mut backend = CpuBackend::new(linalg::CpuAlgo::Blocked);

    // -- cold-setup: planner + per-request fresh prepared set --
    let cold_req = mk_req(CacheControl::Bypass);
    let mut launches = 0;
    let t0 = Instant::now();
    for _ in 0..iters {
        let plan = plan_of(&cold_req);
        let mut prepared = PreparedSet::new();
        for op in plan.steps.iter().filter_map(|s| s.op()) {
            if !prepared.check(op, n) {
                backend.prepare(op, n).expect("cpu prepare is infallible for plan ops");
                prepared.record(op, n);
            }
        }
        launches = plan.launches();
        std::hint::black_box(&plan);
    }
    let cold_s = t0.elapsed().as_secs_f64();

    // -- plan-warm: tier 1 + tier 2 warm --
    let warm_req = mk_req(CacheControl::Use);
    let mut prepared = PreparedSet::new();
    let seed_plan = plan_of(&warm_req); // populates the global plan cache
    for op in seed_plan.steps.iter().filter_map(|s| s.op()) {
        if !prepared.check(op, n) {
            backend.prepare(op, n).expect("cpu prepare is infallible for plan ops");
            prepared.record(op, n);
        }
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        let plan = plan_of(&warm_req);
        for op in plan.steps.iter().filter_map(|s| s.op()) {
            if !prepared.check(op, n) {
                backend.prepare(op, n).expect("cpu prepare is infallible for plan ops");
                prepared.record(op, n);
            }
        }
        std::hint::black_box(&plan);
    }
    let warm_s = t0.elapsed().as_secs_f64();

    let per_req = |total: f64| format!("{:.2} µs/request", total / iters as f64 * 1e6);
    vec![
        ArmResult {
            name: "cold-setup".into(),
            wall_s: cold_s,
            launches,
            multiplies: 0,
            transfers: 0,
            detail: format!(
                "{} — planner run + per-op prepare, execution elided",
                per_req(cold_s)
            ),
        },
        ArmResult {
            name: "plan-warm".into(),
            wall_s: warm_s,
            launches,
            multiplies: 0,
            transfers: 0,
            detail: format!(
                "{} — plan-cache hit + warm prepared set, execution elided",
                per_req(warm_s)
            ),
        },
    ]
}

/// A6 (result tier) — what tier 3 buys on a hot request at `(n, power)`:
///
/// * **cold** — the *modeled* device-resident execution on the
///   calibrated Tesla C2050 (the same yardstick Tables 2–5 use for 2012
///   device time), because a real cold run at n=1024 is exactly the cost
///   the cache exists to avoid paying per measurement.
/// * **result-warm** — the *measured* warm serve: re-derive the content
///   digest of the operand, hit the LRU cache, copy the result out. No
///   device, no launches.
///
/// The arms mix modeled and measured seconds **on purpose** and say so
/// in their detail columns; `--measure` adds real engine runs
/// ([`cache_engine_arms`]) where both sides are measured.
pub fn cache_result_arms(n: usize, power: u64, seed: u64) -> Vec<ArmResult> {
    let (model, _) = crate::experiments::tables::calibrated_models();
    let plan = Plan::chained(power, &[4, 2]);
    let modeled = model.simulate_device_resident(&plan, n);

    let a = Matrix::random(n, seed);
    let bytes = (n * n * std::mem::size_of::<f32>()) as u64;
    let cache = ResultCache::new(bytes.max(1) * 4);
    cache.insert(
        ResultKey::for_parts(&a, power, Method::Ours, None),
        &a, // stand-in result payload of the right size
        Method::Ours,
        Some(plan.kind),
    );
    let reps = 8;
    let t0 = Instant::now();
    for _ in 0..reps {
        // the full warm serve: content digest + LRU lookup + result copy
        let key = ResultKey::for_parts(&a, power, Method::Ours, None);
        std::hint::black_box(cache.get(&key));
    }
    let warm_s = t0.elapsed().as_secs_f64() / reps as f64;

    vec![
        ArmResult {
            name: "cold".into(),
            wall_s: modeled.total_s,
            launches: plan.launches(),
            multiplies: plan.multiplies(),
            transfers: 2,
            detail: "MODELED: calibrated-C2050 device-resident execution".into(),
        },
        ArmResult {
            name: "result-warm".into(),
            wall_s: warm_s,
            launches: 0,
            multiplies: 0,
            transfers: 0,
            detail: format!("MEASURED: content digest + LRU hit + {bytes}-byte result copy"),
        },
    ]
}

/// A6 (full engine, `--measure`) — real serve times per tier through the
/// one execution surface:
///
/// * **cold** — a fresh config-built engine, [`CacheControl::Bypass`].
/// * **plan-warm** — the same engine again (plan + prepared tiers warm,
///   result tier disabled): device time is unchanged by tiers 1–2, which
///   this row demonstrates.
/// * **result-warm** — result caching enabled; the measured second serve
///   of an identical request (bit-identical answer, zero launches).
///
/// Wall columns are end-to-end serve times measured around the
/// `Executor::run` call (the engine's own `stats.wall_s` excludes the
/// setup work the caches remove).
pub fn cache_engine_arms(cfg: &MatexpConfig, n: usize, power: u64) -> Result<Vec<ArmResult>> {
    let a = Matrix::random_spectral(n, 0.999, cfg.seed ^ 0xA6);
    let timed = |engine: &mut worker::WorkerEngine, sub: Submission| -> Result<(f64, ExecStats)> {
        let t0 = Instant::now();
        let resp = engine.run(sub)?;
        Ok((t0.elapsed().as_secs_f64(), resp.stats))
    };

    let mut nores = cfg.clone();
    nores.cache.results = false;
    let mut engine = worker::build_worker_engine(&nores, None)?;
    let (cold_s, cold_stats) =
        timed(&mut engine, Submission::expm(a.clone(), power).cache(CacheControl::Bypass))?;
    let (plan_warm_s, plan_warm_stats) = timed(&mut engine, Submission::expm(a.clone(), power))?;

    let mut res = cfg.clone();
    res.cache.results = true;
    let mut warm_engine = worker::build_worker_engine(&res, None)?;
    let (_, _) = timed(&mut warm_engine, Submission::expm(a.clone(), power))?; // populate
    let (warm_s, warm_stats) = timed(&mut warm_engine, Submission::expm(a, power))?;
    if warm_stats.launches != 0 {
        return Err(MatexpError::Service(
            "result-warm arm was not served from the cache".into(),
        ));
    }

    let arm = |name: &str, wall: f64, stats: &ExecStats, detail: String| ArmResult {
        name: name.into(),
        wall_s: wall,
        launches: stats.launches,
        multiplies: stats.multiplies,
        transfers: stats.h2d_transfers + stats.d2h_transfers,
        detail,
    };
    Ok(vec![
        arm("cold", cold_s, &cold_stats, "fresh engine, CacheControl::Bypass".into()),
        arm(
            "plan-warm",
            plan_warm_s,
            &plan_warm_stats,
            "plan + prepared tiers warm (device time unchanged by design)".into(),
        ),
        arm(
            "result-warm",
            warm_s,
            &warm_stats,
            "second identical request, served from cache".into(),
        ),
    ])
}

/// A7 — kernel-tier ablation behind `--ablate-kernels`: every
/// [`crate::linalg::CpuAlgo`] variant multiplies once at size `n` (best
/// of two runs, so a cold first touch doesn't charge a kernel for page
/// faults), with GFLOP/s and the speedup over the `blocked` baseline —
/// the pre-tier default dispatch — in the detail column. The `simd` row
/// notes when it is actually the scalar-packed fallback (feature off, or
/// the ISA probe failed at runtime).
pub fn kernel_tier(n: usize, seed: u64) -> Vec<ArmResult> {
    let a = Matrix::random_spectral(n, 0.99, seed);
    let b = Matrix::random_spectral(n, 0.99, seed ^ 7);
    let timed: Vec<(&'static str, f64)> = linalg::matmul_variants()
        .into_iter()
        .map(|(name, mm)| {
            let mut best = f64::INFINITY;
            for _ in 0..2 {
                let t0 = Instant::now();
                let c = mm(&a, &b);
                best = best.min(t0.elapsed().as_secs_f64());
                std::hint::black_box(&c);
            }
            (name, best.max(f64::MIN_POSITIVE))
        })
        .collect();
    let blocked = timed
        .iter()
        .find(|&&(nm, _)| nm == "blocked")
        .map(|&(_, s)| s)
        .expect("blocked is always a registered variant");
    timed
        .into_iter()
        .map(|(name, wall)| ArmResult {
            name: name.to_string(),
            wall_s: wall,
            launches: 0,
            multiplies: 1,
            transfers: 0,
            detail: format!(
                "{:.2} GFLOP/s, {:.2}x vs blocked{}",
                2.0 * (n as f64).powi(3) / wall / 1e9,
                blocked / wall,
                if name == "simd" && !crate::linalg::packed::simd_active() {
                    " (scalar fallback: simd feature off or ISA unavailable)"
                } else {
                    ""
                },
            ),
        })
        .collect()
}

/// A4 — CPU-baseline fairness sweep: one multiply per variant at size `n`.
pub fn cpu_variants(n: usize, seed: u64) -> Vec<ArmResult> {
    let a = Matrix::random_spectral(n, 0.99, seed);
    let b = Matrix::random_spectral(n, 0.99, seed ^ 7);
    linalg::matmul_variants()
        .into_iter()
        .map(|(name, mm)| {
            let t0 = Instant::now();
            let c = mm(&a, &b);
            let wall = t0.elapsed().as_secs_f64();
            std::hint::black_box(&c);
            ArmResult {
                name: name.to_string(),
                wall_s: wall,
                launches: 0,
                multiplies: 1,
                transfers: 0,
                detail: format!("{:.2} GFLOP/s", 2.0 * (n as f64).powi(3) / wall / 1e9),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CpuAlgo;
    use crate::runtime::CpuEngine;

    fn engine() -> CpuEngine {
        Engine::cpu(CpuAlgo::Blocked)
    }

    #[test]
    fn cpu_variants_all_report() {
        let arms = cpu_variants(48, 1);
        assert_eq!(arms.len(), CpuAlgo::all().len());
        assert!(arms.iter().all(|a| a.wall_s > 0.0));
    }

    #[test]
    fn kernel_tier_reports_every_algo_with_speedups() {
        let arms = kernel_tier(48, 1);
        assert_eq!(arms.len(), CpuAlgo::all().len());
        assert!(arms.iter().all(|a| a.wall_s > 0.0));
        assert!(arms.iter().all(|a| a.detail.contains("GFLOP/s")), "{arms:?}");
        assert!(arms.iter().all(|a| a.detail.contains("x vs blocked")), "{arms:?}");
        let blocked = arms.iter().find(|a| a.name == "blocked").unwrap();
        assert!(blocked.detail.contains("1.00x vs blocked"), "{}", blocked.detail);
    }

    #[test]
    fn transfer_ablation_shows_transfer_gap() {
        let mut e = engine();
        let arms = transfer_ablation(&mut e, 32, 256, 9).unwrap();
        assert_eq!(arms.len(), 2);
        let resident = &arms[0];
        let roundtrip = &arms[1];
        // identical work…
        assert_eq!(resident.multiplies, roundtrip.multiplies);
        // …but O(1) vs O(launches) transfers
        assert_eq!(resident.transfers, 2);
        assert!(roundtrip.transfers >= 2 * roundtrip.launches);
    }

    #[test]
    fn fusion_ablation_orders_launch_counts() {
        let mut e = engine();
        let arms = fusion_ablation(&mut e, 32, 256, 9).unwrap();
        let get = |name: &str| {
            arms.iter().find(|a| a.name == name).unwrap_or_else(|| panic!("{name} missing"))
        };
        // 256 = 2^8: binary 8 launches, chained 2 (square4×2), packed 8+pack+unpack
        assert_eq!(get("binary").launches, 8);
        assert!(get("chained-square4").launches < get("binary").launches);
        let fused = arms.iter().find(|a| a.name == "fused-artifact");
        assert_eq!(fused.expect("256 is a shipped fused power").launches, 1);
    }

    #[test]
    fn fusion_ablation_skips_fused_for_unshipped_power() {
        let mut e = engine();
        let arms = fusion_ablation(&mut e, 16, 100, 3).unwrap();
        assert!(arms.iter().all(|a| a.name != "fused-artifact"));
        assert!(arms.len() >= 5);
    }

    #[test]
    fn residency_data_path_copies_two_edges_vs_two_per_step() {
        let [clone_arm, resident] = residency_data_path(64, 10, 7);
        assert_eq!(clone_arm.bytes_copied, 2 * 10 * 64 * 64 * 4);
        assert_eq!(resident.bytes_copied, 2 * 64 * 64 * 4);
        assert_eq!(resident.buffers_recycled, 9, "ping-pong recycles all but the warm-up allocs");
        assert_eq!(clone_arm.buffers_recycled, 0);
    }

    #[test]
    fn cache_setup_arms_show_the_warm_path_winning() {
        let arms = cache_setup_arms(64, 1024, 400);
        assert_eq!(arms.len(), 2);
        let (cold, warm) = (&arms[0], &arms[1]);
        assert_eq!(cold.name, "cold-setup");
        assert!(cold.wall_s > 0.0 && warm.wall_s > 0.0);
        assert!(
            warm.wall_s < cold.wall_s,
            "warm setup {} must beat cold {}",
            warm.wall_s,
            cold.wall_s
        );
        assert!(cold.launches > 0, "the elided plan still reports its launch count");
    }

    #[test]
    fn cache_result_arms_label_modeled_vs_measured() {
        let arms = cache_result_arms(128, 1024, 5);
        assert_eq!(arms.len(), 2);
        assert!(arms[0].detail.contains("MODELED"), "{}", arms[0].detail);
        assert!(arms[1].detail.contains("MEASURED"), "{}", arms[1].detail);
        assert_eq!(arms[1].launches, 0, "a warm serve launches nothing");
        assert!(arms[0].wall_s > arms[1].wall_s, "{arms:?}");
    }

    #[test]
    fn cache_engine_arms_serve_warm_from_cache() {
        let cfg = MatexpConfig::default();
        let arms = cache_engine_arms(&cfg, 24, 256).unwrap();
        assert_eq!(arms.len(), 3);
        let get = |name: &str| arms.iter().find(|a| a.name == name).unwrap();
        assert!(get("cold").launches > 0);
        assert_eq!(get("cold").launches, get("plan-warm").launches);
        assert_eq!(get("result-warm").launches, 0);
        assert!(get("result-warm").wall_s < get("cold").wall_s);
    }

    #[test]
    fn residency_engine_arms_report_the_copy_gap() {
        let mut e = engine();
        let arms = residency_engine_arms(&mut e, 32, 256, 5).unwrap();
        let resident = &arms[0];
        let roundtrip = &arms[1];
        assert_eq!(resident.multiplies, roundtrip.multiplies, "identical logical work");
        assert!(resident.detail.contains("bytes_copied=8192"), "{}", resident.detail);
        assert!(roundtrip.transfers > resident.transfers);
    }
}
