//! Rendering: paper-style tables, figure series (ASCII chart + CSV), and
//! ablation tables. The same renderer backs `matexp experiment`, the
//! criterion-style bench targets, and EXPERIMENTS.md regeneration.

use std::fmt::Write as _;

use crate::experiments::ablations::ArmResult;
use crate::experiments::tables::{CellResult, TableResult};

fn fmt_s(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v < 0.01 {
        format!("{v:.4}")
    } else if v < 10.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.2}")
    }
}

fn fmt_x(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

/// Render one regenerated table in the paper's row layout, one block per
/// source (paper / simulated / measured).
pub fn render_table(t: &TableResult) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "== Table {} — exponentiation of a {}x{} matrix ==",
        t.id, t.n, t.n
    );
    let powers: Vec<String> = t.cells.iter().map(|c| c.power.to_string()).collect();
    let _ = writeln!(s, "{:<34} {}", "power N", cols(&powers));

    let block = |s: &mut String, label: &str, pick: &dyn Fn(&CellResult) -> Option<[f64; 5]>| {
        let mut rows: Vec<Vec<String>> = vec![Vec::new(); 5];
        for c in &t.cells {
            match pick(c) {
                Some(vals) => {
                    rows[0].push(fmt_s(vals[0]));
                    rows[1].push(fmt_s(vals[1]));
                    rows[2].push(fmt_x(vals[2]));
                    rows[3].push(fmt_s(vals[3]));
                    rows[4].push(fmt_x(vals[4]));
                }
                None => {
                    for r in rows.iter_mut() {
                        r.push("-".into());
                    }
                }
            }
        }
        let names = [
            "Naive GPU (s)",
            "Sequential CPU (s)",
            "Naive Speed UP",
            "Our Approach (s)",
            "Ours vs Naive GPU",
        ];
        let _ = writeln!(s, "-- {label} --");
        for (name, row) in names.iter().zip(rows) {
            let _ = writeln!(s, "{name:<34} {}", cols(&row));
        }
    };

    block(&mut s, "paper (Tesla C2050, 2012)", &|c| {
        c.paper.map(|p| {
            [p.naive_gpu_s, p.seq_cpu_s, p.naive_speedup(), p.ours_s, p.ours_vs_naive()]
        })
    });
    block(&mut s, "simulated (calibrated C2050 model)", &|c| {
        let m = c.simulated;
        Some([m.naive_gpu_s, m.seq_cpu_s, m.naive_speedup(), m.ours_s, m.ours_vs_naive()])
    });
    block(&mut s, "measured (this testbed, CPU PJRT)", &|c| {
        c.measured.map(|m| {
            [m.naive_gpu_s, m.seq_cpu_s, m.naive_speedup(), m.ours_s, m.ours_vs_naive()]
        })
    });

    let launch_ratio: Vec<String> = t
        .cells
        .iter()
        .map(|c| format!("{}/{}", c.launches.0, c.launches.1))
        .collect();
    let _ = writeln!(s, "{:<34} {}", "launches naive/ours", cols(&launch_ratio));
    s
}

fn cols(cells: &[String]) -> String {
    cells.iter().map(|c| format!("{c:>10}")).collect::<Vec<_>>().join(" ")
}

/// The figure ids belonging to a table (times figure, speedup figure).
pub fn figure_ids(table_id: u8) -> (u8, u8) {
    // Table 2→Figs 5/6, 3→7/8, 4→9/10, 5→11/12
    let base = 5 + (table_id - 2) * 2;
    (base, base + 1)
}

/// Render the two figures of a table: the times chart (Fig 5/7/9/11) and
/// the speedup bars (Fig 6/8/10/12), as ASCII + CSV series.
pub fn render_figures(t: &TableResult) -> String {
    let (fig_t, fig_s) = figure_ids(t.id);
    let mut s = String::new();

    let _ = writeln!(s, "== Figure {fig_t} — times vs power (n={}) ==", t.n);
    let _ = writeln!(s, "csv: power,source,naive_gpu_s,seq_cpu_s,ours_s");
    for c in &t.cells {
        if let Some(p) = c.paper {
            let _ = writeln!(
                s,
                "csv: {},paper,{},{},{}",
                c.power,
                fmt_s(p.naive_gpu_s),
                fmt_s(p.seq_cpu_s),
                fmt_s(p.ours_s)
            );
        }
        let m = c.simulated;
        let _ = writeln!(
            s,
            "csv: {},simulated,{},{},{}",
            c.power,
            fmt_s(m.naive_gpu_s),
            fmt_s(m.seq_cpu_s),
            fmt_s(m.ours_s)
        );
        if let Some(m) = c.measured {
            let _ = writeln!(
                s,
                "csv: {},measured,{},{},{}",
                c.power,
                fmt_s(m.naive_gpu_s),
                fmt_s(m.seq_cpu_s),
                fmt_s(m.ours_s)
            );
        }
    }
    // ASCII log-scale chart of the simulated series (the paper's figure)
    let _ = writeln!(s, "{}", ascii_chart(t));

    let _ = writeln!(s, "== Figure {fig_s} — speedup vs sequential CPU (n={}) ==", t.n);
    let _ = writeln!(s, "csv: power,source,naive_speedup,ours_speedup");
    for c in &t.cells {
        if let Some(p) = c.paper {
            let _ = writeln!(
                s,
                "csv: {},paper,{},{}",
                c.power,
                fmt_x(p.naive_speedup()),
                fmt_x(p.ours_speedup())
            );
        }
        let _ = writeln!(
            s,
            "csv: {},simulated,{},{}",
            c.power,
            fmt_x(c.simulated.naive_speedup()),
            fmt_x(c.simulated.ours_speedup())
        );
        if let Some(m) = c.measured {
            let _ = writeln!(
                s,
                "csv: {},measured,{},{}",
                c.power,
                fmt_x(m.naive_speedup()),
                fmt_x(m.ours_speedup())
            );
        }
    }
    for c in &t.cells {
        let naive = c.simulated.naive_speedup();
        let ours = c.simulated.ours_speedup();
        let _ = writeln!(s, "N={:<5} naive |{}", c.power, bar(naive, ours));
        let _ = writeln!(s, "        ours |{}", bar(ours, ours.max(naive)));
    }
    s
}

/// Log-scale ASCII chart of the three simulated time series.
fn ascii_chart(t: &TableResult) -> String {
    let mut s = String::new();
    let series: [(&str, Box<dyn Fn(&CellResult) -> f64>); 3] = [
        ("seq-cpu  ", Box::new(|c: &CellResult| c.simulated.seq_cpu_s)),
        ("naive-gpu", Box::new(|c: &CellResult| c.simulated.naive_gpu_s)),
        ("ours     ", Box::new(|c: &CellResult| c.simulated.ours_s)),
    ];
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, f)| t.cells.iter().map(f))
        .filter(|v| *v > 0.0)
        .collect();
    let lo = all.iter().cloned().fold(f64::INFINITY, f64::min).ln();
    let hi = all.iter().cloned().fold(0.0f64, f64::max).ln();
    let span = (hi - lo).max(1e-9);
    for (name, f) in &series {
        let _ = write!(s, "{name} ");
        for c in &t.cells {
            let v = f(c);
            let w = (((v.ln() - lo) / span) * 40.0).round() as usize;
            let _ = write!(s, "{:<6}", format!("N={}", c.power));
            let _ = writeln!(s, "{}* {}", " ".repeat(w), fmt_s(v));
            let _ = write!(s, "{:width$} ", "", width = name.len() - 1);
        }
        s.truncate(s.trim_end_matches(' ').len());
    }
    s
}

fn bar(v: f64, max: f64) -> String {
    let width = ((v / max.max(1e-9)) * 50.0).round() as usize;
    format!("{} {:.1}x", "#".repeat(width.max(1)), v)
}

/// Render an ablation arm table.
pub fn render_ablation(title: &str, arms: &[ArmResult]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "== Ablation: {title} ==");
    let _ = writeln!(
        s,
        "{:<28} {:>10} {:>9} {:>10} {:>10}  {}",
        "arm", "wall", "launches", "multiplies", "transfers", "detail"
    );
    for a in arms {
        let _ = writeln!(
            s,
            "{:<28} {:>10} {:>9} {:>10} {:>10}  {}",
            a.name,
            crate::bench::format_secs(a.wall_s),
            a.launches,
            a.multiplies,
            a.transfers,
            a.detail
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MatexpConfig;
    use crate::experiments::tables::run_table_sim;

    #[test]
    fn figure_id_mapping_matches_paper() {
        assert_eq!(figure_ids(2), (5, 6));
        assert_eq!(figure_ids(3), (7, 8));
        assert_eq!(figure_ids(4), (9, 10));
        assert_eq!(figure_ids(5), (11, 12));
    }

    #[test]
    fn table_render_contains_all_blocks() {
        let t = run_table_sim(2, &MatexpConfig::default()).unwrap();
        let s = render_table(&t);
        for needle in ["Table 2", "paper", "simulated", "measured", "Naive Speed UP", "launches naive/ours"] {
            assert!(s.contains(needle), "missing {needle:?}:\n{s}");
        }
    }

    #[test]
    fn figures_render_csv_series() {
        let t = run_table_sim(5, &MatexpConfig::default()).unwrap();
        let s = render_figures(&t);
        assert!(s.contains("Figure 11"), "{s}");
        assert!(s.contains("Figure 12"), "{s}");
        assert!(s.lines().filter(|l| l.starts_with("csv:")).count() > 10);
    }

    #[test]
    fn ablation_render() {
        let arms = vec![ArmResult {
            name: "x".into(),
            wall_s: 0.5,
            launches: 3,
            multiplies: 4,
            transfers: 2,
            detail: "d".into(),
        }];
        let s = render_ablation("demo", &arms);
        assert!(s.contains("demo") && s.contains("x"), "{s}");
    }
}
